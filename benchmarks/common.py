"""Shared benchmark utilities: timing, CSV emit, calibrated paper waveform."""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Optional

import numpy as np

import repro.core as core

_ART_ROOT = os.path.join(os.path.dirname(__file__), "..", "artifacts")
# prefer the optimized sweep when present (EXPERIMENTS.md §Perf)
ART_DIR = (os.path.join(_ART_ROOT, "dryrun_v2")
           if os.path.isdir(os.path.join(_ART_ROOT, "dryrun_v2"))
           else os.path.join(_ART_ROOT, "dryrun"))


def us_per_call(fn: Callable, *args, n: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        fn(*args)
    return (time.perf_counter() - t0) / n * 1e6


def emit(name: str, us: float, derived: Dict) -> None:
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.1f},{kv}")


def paper_waveform(steps: int = 40, dt: float = 0.001,
                   n_chips: int = 512, seed: int = 0):
    """The Fig.-1 calibrated waveform: ~2 s iterations, ~19% comm valleys,
    per-chip square wave between near-TDP and comm power with EDP spikes
    and light jitter — the reference input for Figs. 5/6/7 reproductions."""
    tl = core.synthetic_timeline(period_s=2.0, comm_frac=0.19)
    cfg = core.WaveformConfig(dt=dt, steps=steps, jitter_s=0.002)
    chip = core.chip_waveform(tl, cfg)
    dc = core.aggregate(chip, n_chips, cfg, seed=seed)
    return chip, dc, cfg


def load_cells(mesh: str = "single") -> Dict[str, Dict]:
    import glob
    import json
    out = {}
    for p in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}.json"))):
        with open(p) as f:
            d = json.load(f)
        if "error" not in d:
            out[f"{d['arch']}__{d['shape']}"] = d
    return out

"""Grid-interactive control loop — BENCH_control.json.

Replays the canonical escalating trace (9 Hz bin amplitude ramping
through the spec threshold) through the closed control loop and
measures what the acceptance criteria care about:

  detection      lead time between the controller's first escalation
                 and the counterfactual (uncontrolled) breach — the
                 slope early-warning margin.
  dispatch       wall-clock intervention build+dispatch latency, cold
                 (first run compiles the design path) and warm
                 percentiles over repeated runs.
  recession      time from the first dispatch until the worst
                 grid-critical bin amplitude sits below the
                 release-hysteresis level.
  online monitor per-tick detector step cost, and bit-parity of the
                 online carry path against the offline oracle.

  PYTHONPATH=src python -m benchmarks.control_bench [--smoke]

Hard invariants (asserted, also under ``--smoke``): at least one
intervention fires; the post-intervention grid-critical amplitude drops
below the trigger threshold (recession below the release level);
detection happens before the counterfactual breach; warm dispatch
latency p50 < 1 s; online == offline monitor bitwise.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict

import numpy as np

from repro import control
from repro.core.spec import example_specs
from repro.kernels.goertzel.ops import (sliding_bin_power,
                                        sliding_carry_init, trace_mean)
from benchmarks.common import emit

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_control.json")
DT = 0.002
N_CHIPS = 512
FREQS = (0.5, 1.0, 2.0, 9.0)


def _pctl(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q))


def bench_loop(smoke: bool) -> Dict:
    spec = example_specs(job_mw=500.0)["moderate"]
    w = control.synthesize_ramp(dt=DT)
    repeats = 3 if smoke else 8

    t0 = time.perf_counter()
    cold_log = control.watch_trace(w, DT, spec=spec, n_chips=N_CHIPS)
    cold_wall = time.perf_counter() - t0
    cold = cold_log.summary()

    warm_lats, warm_summary = [], None
    warm_wall = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        log = control.watch_trace(w, DT, spec=spec, n_chips=N_CHIPS)
        warm_wall.append(time.perf_counter() - t0)
        warm_lats += log.dispatch_latencies()
        warm_summary = log.summary()

    # -- hard invariants ----------------------------------------------------
    assert cold["n_dispatches"] >= 1, "no intervention fired"
    assert cold["recession_t_s"] is not None, \
        "post-intervention amplitude never receded below release"
    assert cold["detection_lead_s"] is not None \
        and cold["detection_lead_s"] > 0, "detection after breach"
    assert warm_lats and _pctl(warm_lats, 50) < 1.0, \
        f"warm dispatch p50 {_pctl(warm_lats, 50):.3f}s >= 1s"

    trace_s = len(w) * DT
    emit("control.loop.cold", cold_wall * 1e6,
         {"trace_s": trace_s, "dispatches": cold["n_dispatches"]})
    emit("control.loop.warm", _pctl(warm_wall, 50) * 1e6,
         {"realtime_x": round(trace_s / _pctl(warm_wall, 50), 1)})
    emit("control.dispatch.warm_p50", _pctl(warm_lats, 50) * 1e6,
         {"p90_us": round(_pctl(warm_lats, 90) * 1e6, 1)})
    return {
        "trace": {"duration_s": trace_s, "dt": DT, "f_hz": 9.0,
                  "n_chips": N_CHIPS, "spec": "moderate"},
        "detection": {
            "first_escalate_t_s": cold["first_escalate_t_s"],
            "counterfactual_breach_t_s": cold["counterfactual_breach_t_s"],
            "detection_lead_s": cold["detection_lead_s"],
        },
        "dispatch_latency_s": {
            "cold_first": (cold_log.dispatch_latencies() or [None])[0],
            "warm_p50": _pctl(warm_lats, 50),
            "warm_p90": _pctl(warm_lats, 90),
            "warm_max": float(max(warm_lats)),
            "n_samples": len(warm_lats),
        },
        "loop_wall_s": {"cold": cold_wall, "warm_p50": _pctl(warm_wall, 50),
                        "realtime_x": trace_s / _pctl(warm_wall, 50)},
        "closed_loop": {
            "n_dispatches": cold["n_dispatches"],
            "recession_t_s": cold["recession_t_s"],
            "recession_after_dispatch_s": (
                cold["recession_t_s"] - cold["first_dispatch_t_s"]
                if cold["first_dispatch_t_s"] is not None else None),
            "final_level": warm_summary["final_level"],
            "interventions": [r["action"] for r in cold["interventions"]],
        },
    }


def bench_detector(smoke: bool) -> Dict:
    """Online monitor: per-tick step cost + offline bit-parity."""
    n = 30000 if smoke else 120000
    rng = np.random.default_rng(0)
    t = np.arange(n) * DT
    x = (5e8 + 4e7 * np.sin(2 * np.pi * 9.0 * t)
         + 1e5 * rng.normal(size=n)).astype(np.float32)
    win = 2000
    tick = 250                                     # 0.5 s control tick

    off = np.asarray(sliding_bin_power(x, DT, FREQS, win=win,
                                       interpret=True))
    carry = sliding_carry_init(DT, FREQS, win=win, mean=float(trace_mean(x)))
    outs, steps = [], []
    for pos in range(0, n, tick):
        t0 = time.perf_counter()
        amps, carry = sliding_bin_power(x[pos:pos + tick], DT, FREQS,
                                        win=win, carry=carry)
        steps.append(time.perf_counter() - t0)
        outs.append(amps)
    on = np.concatenate(outs, axis=0)
    assert (on == off).all(), "online carry path drifted from offline oracle"

    emit("control.detector.step", _pctl(steps[2:], 50) * 1e6,
         {"tick_s": tick * DT, "bins": len(FREQS)})
    return {
        "samples": n, "win": win, "tick_samples": tick,
        "bit_identical_to_offline": True,
        "step_us": {"p50": _pctl(steps[2:], 50) * 1e6,
                    "p90": _pctl(steps[2:], 90) * 1e6},
        "realtime_x": (tick * DT) / _pctl(steps[2:], 50),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace, fewer repeats (CI tier-1)")
    args = ap.parse_args()

    results = {"smoke": bool(args.smoke),
               "loop": bench_loop(args.smoke),
               "detector": bench_detector(args.smoke)}
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1)
    print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    main()

"""Grid vs gradient vs hybrid mitigation design — BENCH_design.json.

The operator question: given a workload, fleet size, and utility spec,
find the minimal-energy-overhead (MPF, battery-capacity) configuration
that passes the spec.  Three solvers over the same hard-validated search
space (``engine.design``):

  coarse grid   ``design_grid`` on the 5x6 ``design_mitigation`` default —
                fast, but only as good as its resolution;
  fine grid     the brute-force route to *gradient-grade* resolution:
                an NxN grid whose spacing matches what the gradient
                refiner resolves.  Cost grows with the square of the
                resolution — this is the path that "scales exponentially
                with parameters";
  gradient      ``design_gradient`` — jitted Adam through the smooth-
                relaxed (``smooth_tau``) pipeline + spec hinge loss,
                vmapped multi-start, hard re-validation of the finals;
  hybrid        coarse grid, then gradient refinement seeded from its
                top-k feasible configs (never worse than the coarse grid).

  PYTHONPATH=src python -m benchmarks.design_bench [--smoke]

Reported: wall-clock per designed config (cold = incl. compile, warm =
steady state) and the energy overhead of each solver's answer.  The
hard invariants (asserted, also under ``--smoke``): every solver's answer
passes the spec; gradient overhead <= best coarse-grid overhead; gradient
warm wall-clock < fine-grid wall-clock at matched resolution.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import repro.core as core
from repro.core import engine
from benchmarks.common import emit

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_design.json")
N_CHIPS = 512


def design_problem(smoke: bool = False):
    """The paper's square-wave workload aggregated to datacenter scale,
    judged against the 'tight' spec (10% dynamic range — the case GPU
    smoothing alone cannot meet)."""
    tl = core.synthetic_timeline(period_s=2.0, comm_frac=0.25)
    cfg = core.WaveformConfig(dt=0.005, steps=6 if smoke else 12,
                              jitter_s=0.005)
    w = core.aggregate(core.chip_waveform(tl, cfg), N_CHIPS, cfg)
    spec = core.example_specs(job_mw=w.mean() / 1e6)["tight"]
    return w, cfg, spec


def fine_grids(w: np.ndarray, n: int):
    """An n x n (MPF, capacity) lattice at gradient-grade resolution."""
    swing = float(w.max() - w.min())
    mpf_grid = [0.0] + list(np.linspace(0.3, 0.9, n - 1))
    cap_grid = [0.0] + list(np.linspace(0.05, 2.0, n - 1) * swing * 2.0)
    return mpf_grid, cap_grid


def timed(fn, n: int = 1):
    """(result, best-of-n wall-clock seconds)."""
    out, best = None, float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small problem, invariants only, no JSON artifact")
    ap.add_argument("--fine-n", type=int, default=48,
                    help="fine-grid resolution per axis")
    ap.add_argument("--steps", type=int, default=60,
                    help="gradient descent steps")
    args = ap.parse_args()
    fine_n = 32 if args.smoke else args.fine_n
    steps = 25 if args.smoke else args.steps

    w, cfg, spec = design_problem(args.smoke)
    dt = cfg.dt
    print(f"# design problem: {len(w)} samples, {N_CHIPS} chips, "
          f"spec={spec.name}")

    run_coarse = lambda: engine.design(spec, w, dt, N_CHIPS, method="grid",
                                       top_k=16)
    mpf_f, cap_f = fine_grids(w, fine_n)
    run_fine = lambda: engine.design_grid(
        spec, w, dt, N_CHIPS, mpf_f, cap_f,
        swing=float(w.max() - w.min()), top_k=16)
    run_grad = lambda: engine.design(spec, w, dt, N_CHIPS,
                                     method="gradient", steps=steps)
    run_hybrid = lambda: engine.design(spec, w, dt, N_CHIPS,
                                       method="hybrid", steps=steps)

    sols, cold, warm = {}, {}, {}
    for name, fn in (("coarse_grid", run_coarse), ("fine_grid", run_fine),
                     ("gradient", run_grad), ("hybrid", run_hybrid)):
        sols[name], cold[name] = timed(fn)
        _, warm[name] = timed(fn, n=1 if args.smoke else 2)
        assert sols[name] is not None and sols[name]["report"].ok, \
            f"{name} produced no passing design"
        emit(f"design/{name}", warm[name] * 1e6, {
            "cold_s": round(cold[name], 2),
            "mpf": round(sols[name]["mpf_frac"], 3),
            "cap_mj": round(sols[name]["battery_capacity_j"] / 1e6, 4),
            "overhead": round(sols[name]["energy_overhead"], 5)})

    best_coarse = min(a["energy_overhead"]
                      for a in sols["coarse_grid"]["alternatives"])
    # hard invariants: quality and wall-clock
    assert sols["gradient"]["energy_overhead"] <= best_coarse + 1e-6, \
        "gradient design worse than the best coarse-grid config"
    assert sols["hybrid"]["energy_overhead"] <= \
        sols["coarse_grid"]["energy_overhead"] + 1e-6, \
        "hybrid design worse than the coarse grid it refines"
    assert warm["gradient"] < warm["fine_grid"], (
        f"gradient ({warm['gradient']:.2f}s) not faster than the "
        f"equivalent-resolution {fine_n}x{fine_n} grid "
        f"({warm['fine_grid']:.2f}s)")

    if args.smoke:
        print(f"smoke OK: all four solvers pass {spec.name}; gradient "
              f"overhead {sols['gradient']['energy_overhead']:.4f} <= "
              f"best coarse {best_coarse:.4f}; gradient warm "
              f"{warm['gradient']:.2f}s < fine grid "
              f"{warm['fine_grid']:.2f}s")
        return

    result = {
        "n_samples": int(len(w)),
        "n_chips": N_CHIPS,
        "spec": spec.name,
        "fine_grid_resolution": f"{fine_n}x{fine_n}",
        "gradient_steps": steps,
        "solvers": {
            name: {
                "cold_s": round(cold[name], 3),
                "warm_s": round(warm[name], 3),
                "mpf_frac": round(sols[name]["mpf_frac"], 4),
                "battery_capacity_mj":
                    round(sols[name]["battery_capacity_j"] / 1e6, 5),
                "energy_overhead":
                    round(sols[name]["energy_overhead"], 6),
            } for name in sols},
        "gradient_vs_fine_grid_warm":
            round(warm["fine_grid"] / warm["gradient"], 2),
        "gradient_vs_best_coarse_overhead":
            round(sols["gradient"]["energy_overhead"] - best_coarse, 6),
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    print("wrote", os.path.abspath(OUT_PATH))


if __name__ == "__main__":
    main()

"""Fig. 1 — power waveform of an at-scale training job.

Synthesizes the utility-point waveform for every assigned arch's train_4k
cell from its dry-run artifact (exact FLOPs/bytes/collectives -> phase
timeline -> watts), plus the calibrated reference waveform used by the
Fig. 5/6/7 reproductions. Derived: swing amplitude, swing fraction, period.
"""
from __future__ import annotations

import numpy as np

import repro.core as core
from benchmarks.common import emit, load_cells, paper_waveform, us_per_call


def main() -> None:
    chip, dc, cfg = paper_waveform()
    us = us_per_call(lambda: paper_waveform()[1], n=3)
    s = core.swing_stats(dc)
    emit("fig1/calibrated_waveform", us, {
        "mean_mw": round(s["mean_w"] / 1e6, 3),
        "swing_mw": round(s["swing_w"] / 1e6, 3),
        "swing_frac": round(s["swing_frac"], 3),
        "chips": 512})

    cells = load_cells("single")
    for key, cell in sorted(cells.items()):
        if cell["shape"] != "train_4k":
            continue
        res = core.simulate_cell(cell, steps=12, dt=0.002)
        tl = core.from_dryrun_cell(cell)
        emit(f"fig1/{cell['arch']}", 0.0, {
            "period_s": round(tl.period_s, 3),
            "mean_mw": round(res.swing["mean_w"] / 1e6, 4),
            "swing_mw": round(res.swing["swing_w"] / 1e6, 4),
            "swing_frac": round(res.swing["swing_frac"], 3)})


if __name__ == "__main__":
    main()

"""Fig. 2 — server power breakdown (accelerators >50% of server power)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.hardware import DEFAULT_HW


def main() -> None:
    hw = DEFAULT_HW
    chip = hw.chip.tdp_w
    host = hw.server.overhead_per_chip_w()
    total = chip + host
    emit("fig2/server_breakdown", 0.0, {
        "chip_w": chip,
        "host_overhead_per_chip_w": round(host, 1),
        "chip_share": round(hw.chip_share(), 3),
        "claim_gt_50pct": hw.chip_share() > 0.5})
    # dynamic vs static split: only the chip share swings with the job
    swing_visible = (chip - hw.chip.comm_w) / total
    emit("fig2/swing_share_of_server", 0.0, {
        "swing_fraction_of_provisioned": round(swing_visible, 3)})


if __name__ == "__main__":
    main()

"""Fig. 3 — frequency components of the Fig.-1 waveform.

Claim reproduced: FFT energy concentrated in 0.2-3 Hz for second-scale
iterations, overlapping the paper's critical bands (<1 Hz inter-area,
1-2.5 Hz plant coupling, 7-100 Hz torsional).
"""
from __future__ import annotations

import numpy as np

import repro.core as core
from benchmarks.common import emit, load_cells, paper_waveform, us_per_call


def main() -> None:
    chip, dc, cfg = paper_waveform(steps=60)
    us = us_per_call(lambda: core.critical_band_report(dc, cfg.dt), n=3)
    rep = core.critical_band_report(dc, cfg.dt)
    emit("fig3/calibrated", us, {k: round(v, 4) for k, v in rep.items()})
    assert rep["paper_band_0p2_3hz"] > 0.5, "claim: energy concentrated 0.2-3Hz"

    for key, cell in sorted(load_cells("single").items()):
        if cell["shape"] != "train_4k":
            continue
        res = core.simulate_cell(cell, steps=24, dt=0.002)
        emit(f"fig3/{cell['arch']}", 0.0,
             {k: round(v, 4) for k, v in res.bands.items()})


if __name__ == "__main__":
    main()

"""Fig. 5 — GB200 power smoothing on a square-wave microbenchmark.

Reproduces the phase structure: ramp-up at the programmed rate, steady
phase, stop-delay hold at MPF after activity ends, then programmed
ramp-down. MPF = 65% TDP as in the paper's figure.
"""
from __future__ import annotations

import numpy as np

import repro.core as core
from benchmarks.common import emit, us_per_call
from repro.core.hardware import DEFAULT_HW


def main() -> None:
    hw = DEFAULT_HW
    dt = 0.001
    n = int(20 / dt)
    t = np.arange(n) * dt
    w = np.where((t > 2) & (t < 12), hw.chip.tdp_w, hw.chip.idle_w)

    gf = core.GpuPowerSmoothing(mpf_frac=0.65, ramp_up_w_per_s=300,
                                ramp_down_w_per_s=150, stop_delay_s=3.0,
                                activity_threshold_frac=0.5)
    us = us_per_call(lambda: gf.apply(w, dt), n=3)
    out, aux = gf.apply(w, dt)

    # phase extraction
    ramp_up_t = float(np.argmax(out >= 0.99 * hw.chip.tdp_w) * dt - 2.0)
    # stop delay: time output holds >= MPF after workload ends at t=12
    idx_end = int(12 / dt)
    hold = out[idx_end + 50:]
    hold_t = float(np.argmax(hold < 0.65 * hw.chip.tdp_w - 1) * dt)
    below = np.where(out[idx_end:] <= hw.chip.idle_w + 1)[0]
    rampdown_done = float(below[0] * dt) if len(below) else -1.0
    emit("fig5/squarewave_smoothing", us, {
        "mpf_w": aux["floor_w"],
        "ramp_up_s": round(ramp_up_t, 2),
        "stop_delay_hold_s": round(hold_t, 2),
        "ramp_down_done_after_s": round(rampdown_done, 2),
        "energy_overhead": round(aux["energy_overhead"], 4)})
    assert 2.5 < hold_t < 3.6, "stop delay should hold ~3 s at MPF"


if __name__ == "__main__":
    main()

"""Fig. 6 — power smoothing to the MPF on the production waveform.

Paper claim: MPF = 90% of TDP on the Fig.-1 waveform costs ~10.5% extra
energy. Reproduced on the calibrated waveform; the MPF sweep runs as ONE
vmapped ``engine.apply_batch`` call (the batched scenario engine), and the
per-arch numbers (from real dry-run timelines) show how the overhead
scales with the floor and with each workload's comm fraction.
"""
from __future__ import annotations

import numpy as np

import repro.core as core
from benchmarks.common import emit, load_cells, paper_waveform, us_per_call

PAPER_CLAIM = 0.105
MPF_GRID = (0.5, 0.65, 0.8, 0.9)


def main() -> None:
    chip, _, cfg = paper_waveform(steps=40)
    gfs = [core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=2000,
                                  ramp_down_w_per_s=2000, stop_delay_s=1.0)
           for mpf in MPF_GRID]
    us = us_per_call(lambda: core.apply_batch(gfs, chip, cfg.dt), n=3)
    outs, aux = core.apply_batch(gfs, chip, cfg.dt)
    for i, mpf in enumerate(MPF_GRID):
        overhead = float(aux["energy_overhead"][i])
        swing_after = float(outs[i].max() - outs[i].min())
        emit(f"fig6/mpf_{int(mpf*100)}", us / len(MPF_GRID), {
            "energy_overhead": round(overhead, 4),
            "chip_swing_after_w": round(swing_after, 1)})
        if mpf == 0.9:
            err = abs(overhead - PAPER_CLAIM)
            emit("fig6/paper_claim_check", 0.0, {
                "claimed": PAPER_CLAIM,
                "measured": round(overhead, 4),
                "abs_err": round(err, 4),
                "within_2pts": err < 0.02})

    # per-arch: the same MPF=90% applied to each arch's real timeline
    for key, cell in sorted(load_cells("single").items()):
        if cell["shape"] != "train_4k":
            continue
        tl = core.from_dryrun_cell(cell)
        wcfg = core.WaveformConfig(dt=0.002, steps=12)
        w = core.chip_waveform(tl, wcfg)
        gf = core.GpuPowerSmoothing(mpf_frac=0.9, ramp_up_w_per_s=2000,
                                    ramp_down_w_per_s=2000, stop_delay_s=1.0)
        _, aux = gf.apply(w, wcfg.dt)
        comm_frac = tl.phases[-1].duration_s / tl.period_s
        emit(f"fig6/arch_{cell['arch']}", 0.0, {
            "comm_frac": round(comm_frac, 3),
            "energy_overhead_mpf90": round(aux["energy_overhead"], 4)})


if __name__ == "__main__":
    main()

"""Fig. 7 — rack-level energy-storage solution on the Fig.-1 waveform.

Shows battery charge tracking the comm valleys / compute peaks, the
smoothed grid waveform, ~zero wasted energy, the capacity sweep (run as
one vmapped ``engine.apply_batch`` call), and the placement-level sweep
(server/rack/row/DC) that motivates the paper's rack-level choice.
"""
from __future__ import annotations

import numpy as np

import repro.core as core
from benchmarks.common import emit, paper_waveform, us_per_call
from repro.core.hardware import DEFAULT_HW

CAP_FACTORS = (0.25, 0.5, 1.0, 2.0, 4.0)


def main() -> None:
    _, dc, cfg = paper_waveform(steps=40)
    swing = float(dc.max() - dc.min())
    bat = core.RackBattery(capacity_j=2.0 * swing, max_discharge_w=swing,
                           max_charge_w=swing, efficiency=0.95,
                           target_tau_s=10.0)
    us = us_per_call(lambda: bat.apply(dc, cfg.dt), n=3)
    out, aux = bat.apply(dc, cfg.dt)
    emit("fig7/rack_battery", us, {
        "swing_before_mw": round(swing / 1e6, 3),
        "swing_after_mw": round(float(out.max() - out.min()) / 1e6, 3),
        "energy_overhead": round(aux["energy_overhead"], 5),
        "soc_min": round(aux["soc_min_frac"], 3),
        "soc_max": round(aux["soc_max_frac"], 3),
        "peak_reduction_mw": round(aux["peak_reduction_w"] / 1e6, 3)})
    assert abs(aux["energy_overhead"]) < 0.02, "storage must not waste energy"

    # capacity sweep: undersized batteries leave swing on the grid — the
    # whole grid evaluates in one vmapped call (batched scenario engine)
    bats = [core.RackBattery(capacity_j=f * swing, max_discharge_w=swing,
                             max_charge_w=swing, efficiency=0.95,
                             target_tau_s=10.0) for f in CAP_FACTORS]
    outs, aux_b = core.apply_batch(bats, dc, cfg.dt)
    for i, f in enumerate(CAP_FACTORS):
        emit(f"fig7/capacity_{f}x_swing", 0.0, {
            "swing_after_mw": round(float(outs[i].max() - outs[i].min()) / 1e6, 3),
            "energy_overhead": round(float(aux_b["energy_overhead"][i]), 5),
            "soc_min": round(float(aux_b["soc_min_frac"][i]), 3)})

    # placement sweep: same total capacity, different failure-domain size.
    # Rack level wins: below it (server) adds cost/space per node; above it
    # (row/DC) exposes PDUs/UPSes to the swing and enlarges failure domains.
    hw = DEFAULT_HW
    n_chips = 512
    for level, units in (("server", n_chips // hw.server.chips_per_host),
                         ("rack", n_chips // hw.topo.chips_per_rack),
                         ("row", 4), ("dc", 1)):
        per_unit = 2.0 * swing / units
        emit(f"fig7/placement_{level}", 0.0, {
            "units": units,
            "capacity_per_unit_kj": round(per_unit / 1e3, 1),
            "failure_domain_chips": n_chips // units,
            "converters_exposed": level in ("row", "dc")})


if __name__ == "__main__":
    main()

"""Kernel micro-benchmarks (Sec. IV-A ballast / IV-E backstop hot paths).

CPU wall times are for harness completeness only — TPU throughput is
derived from the FLOP/byte model printed alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, us_per_call
from repro.kernels.ballast.ops import ballast_burn, ballast_flops
from repro.kernels.ballast.ref import ballast_ref
from repro.kernels.goertzel.ref import goertzel_ref


def main() -> None:
    key = jax.random.PRNGKey(0)

    # ballast: arithmetic intensity at m=1024,k=n=256, 64 iters
    m, k, n, it = 1024, 256, 256, 64
    fl = ballast_flops(m, k, n, it)
    hbm_bytes = (m * k + k * n + m * n) * 4  # one round-trip of the tiles
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = (jnp.eye(k) * 0.999).astype(jnp.float32)
    f = jax.jit(lambda a, b: ballast_ref(a, b, it))
    f(a, b).block_until_ready()
    us = us_per_call(lambda: f(a, b).block_until_ready(), n=5)
    emit("kernels/ballast_ref", us, {
        "gflops_per_call": round(fl / 1e9, 2),
        "arith_intensity_flops_per_byte": round(fl / hbm_bytes, 1),
        "tpu_mxu_bound_us": round(fl / 197e12 * 1e6, 2)})

    # goertzel: 8 windows x 1024 samples x 4 bins
    wnd = jax.random.normal(key, (8, 1024))
    coef = 2.0 * jnp.cos(2 * jnp.pi * jnp.array([0.5, 1.0, 2.0, 9.0]) * 0.001)
    g = jax.jit(goertzel_ref)
    g(wnd, coef).block_until_ready()
    us = us_per_call(lambda: g(wnd, coef).block_until_ready(), n=5)
    ops = 8 * 1024 * 4 * 4  # 4 madds per sample per bin
    emit("kernels/goertzel_ref", us, {
        "ops_per_call": ops,
        "bins": 4, "window": 1024,
        "vs_full_fft_ops_ratio": round(ops / (8 * 1024 * np.log2(1024) * 5), 3)})


if __name__ == "__main__":
    main()

"""Kernel micro-benchmarks (Sec. IV-A ballast / IV-E backstop hot paths).

The headline measurement is the telemetry backstop's sliding monitor on
a 1e6-sample MW-scale trace, as two A/Bs:

- **layout A/B** — the v1 (bin-minor ``[win, K]``) vs v2 (lane-major
  ``[K, win]``) Pallas kernels, amplitudes materialized in both, vs the
  complex-cumsum jnp oracle.
- **fusion A/B** — the fused v2 monitor (worst bin + escalation class
  reduced in VMEM, blocked escalation scan) vs the two-pass baseline it
  replaced (materialize every ``[n, K]`` amplitude, then fold the
  per-sample escalation machine in a trace-length ``lax.scan``).

A third section times the online serve-path step: the fused detector
per 500-sample tick vs the bare amps-materializing path and vs the
like-for-like two-pass serve path (amps + the consumer-side
amps -> escalation fold the backstop ran before fusion).

All timings are device-synchronized (``block_until_ready`` inside the
timed closure), best-of-5 after a warm-up call.  The kernels run in
interpret mode on CPU — the same configuration the product path uses
off-TPU.  Writes BENCH_kernels.json; ``--smoke`` runs a small trace,
checks ref-vs-Pallas parity and skips the artifact (the CI mode).

CPU wall times for the ballast/goertzel sections are for harness
completeness only — TPU throughput is derived from the FLOP/byte model
printed alongside.

  PYTHONPATH=src python -m benchmarks.kernels_bench [--smoke]
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, us_per_call
from repro.core.telemetry import (escalation_classify, escalation_init,
                                  escalation_scan, escalation_step)
from repro.core.telemetry import warmup_scale
from repro.kernels.ballast.ops import ballast_burn, ballast_flops
from repro.kernels.ballast.ref import ballast_ref
from repro.kernels.goertzel.goertzel import sliding_goertzel_pallas
from repro.kernels.goertzel.ops import (_phase_tables, sliding_bin_power,
                                        sliding_monitor_fused)
from repro.kernels.goertzel.ref import (goertzel_ref, sliding_bin_power_jnp,
                                        sliding_bin_power_ref)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")

SLIDING_FREQS = (0.5, 1.0, 2.0, 9.0)   # the backstop's default critical bins


def _best_of(fn, n=5):
    fn()                                # warm (compile)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


@functools.partial(jax.jit, static_argnames=("dt", "freqs", "win",
                                             "interpret"))
def _sliding_v1(x, *, dt, freqs, win, interpret):
    """The v1 bin-minor layout at the same call convention as the v2
    product path (mean removal, zero-pad, caller-applied warm-up)."""
    n = x.shape[0]
    xc = x - jnp.mean(x)
    S = -(-n // win)
    pad = S * win - n
    if pad:
        xc = jnp.concatenate([xc, jnp.zeros((pad,), jnp.float32)])
    cosp, sinp, rot = (jnp.asarray(t) for t in _phase_tables(freqs, dt, win))
    raw = sliding_goertzel_pallas(xc.reshape(S, win), cosp, sinp, rot,
                                  block_s=1, interpret=interpret)
    scale = warmup_scale(jnp.arange(n, dtype=jnp.float32), win)
    return raw.reshape(S * win, -1)[:n] * scale[:, None]


@functools.partial(jax.jit, static_argnames=("dt", "freqs", "win",
                                             "sustain_n", "cool_n",
                                             "interpret", "use_jnp_amps"))
def _monitor_two_pass(x, *, dt, freqs, win, threshold, release,
                      sustain_n, cool_n, interpret, use_jnp_amps=False):
    """The pre-fusion monitor: materialize every [n, K] amplitude, reduce
    to the worst bin, then fold the per-sample escalation machine in a
    trace-length ``lax.scan``.  ``use_jnp_amps=True`` sources amplitudes
    from the jnp cumsum oracle — the PR-5 "jnp path" the headline
    speedup is measured against; ``False`` uses the v2 Pallas kernel, so
    the fused path's win over it is attributable to fusion alone (and
    worst/levels/detect are bitwise comparable)."""
    if use_jnp_amps:
        amps = sliding_bin_power_jnp(x, dt, freqs, win)
    else:
        amps = sliding_bin_power(x, dt, freqs, win=win, interpret=interpret)
    worst = amps.max(axis=1)
    n = x.shape[0]

    def body(carry, inp):
        amp, idx = inp
        return escalation_step(carry, amp, idx, threshold=threshold,
                               release=release, win=win, n=n,
                               sustain_n=sustain_n, cool_n=cool_n)

    (_, _, _, detect), levels = jax.lax.scan(
        body, escalation_init(), (worst, jnp.arange(n, dtype=jnp.int32)))
    return worst, levels, detect


def sliding_monitor_bench(n: int, dt: float, win: int, smoke: bool) -> dict:
    """Sliding-monitor throughput on an MW-scale trace (1e5 W line on a
    5e8 W DC offset — the acceptance scenario): layout A/B (v1 vs v2
    amps kernels vs the cumsum oracles) and fusion A/B (fused monitor vs
    the amps-materializing two-pass monitor)."""
    t = np.arange(n) * dt
    xnp = 5e8 + 1e5 * np.sin(2 * np.pi * 2.0 * t)
    x = jnp.asarray(xnp, jnp.float32)
    interpret = jax.default_backend() != "tpu"
    thr, rel = 2e5, 1.5e5          # above the 1e5 W line: machine armed,
    sustain_n = max(win // 40, 1)  # classify path fully exercised
    cool_n = max(win // 25, 1)

    # --- layout A/B: amplitudes materialized --------------------------------
    pallas = lambda: sliding_bin_power(
        x, dt, SLIDING_FREQS, win=win, interpret=interpret).block_until_ready()
    v1 = lambda: _sliding_v1(x, dt=dt, freqs=SLIDING_FREQS, win=win,
                             interpret=interpret).block_until_ready()
    jnp_oracle = jax.jit(
        lambda x: sliding_bin_power_jnp(x, dt, SLIDING_FREQS, win))
    t_pallas = _best_of(pallas)
    t_v1 = _best_of(v1)
    t_jnp = _best_of(lambda: jnp_oracle(x).block_until_ready())
    # the float64 cumsum oracle: one pass is enough (it is the slow one)
    t0 = time.perf_counter()
    ref = sliding_bin_power_ref(xnp, dt, np.asarray(SLIDING_FREQS), win)
    t_ref = time.perf_counter() - t0

    # parity while we are here: the bench never reports a wrong kernel
    out = np.asarray(sliding_bin_power(x, dt, SLIDING_FREQS, win=win,
                                       interpret=interpret))
    err = np.abs(out - ref).max() / 1e5
    assert err < 5e-3, f"sliding kernel diverged from f64 oracle: {err}"
    err_v1 = np.abs(np.asarray(_sliding_v1(
        x, dt=dt, freqs=SLIDING_FREQS, win=win,
        interpret=interpret)) - ref).max() / 1e5
    assert err_v1 < 5e-3, f"v1 kernel diverged from f64 oracle: {err_v1}"

    # --- fusion A/B: fused monitor vs two-pass ------------------------------
    fused = lambda use_pallas: sliding_monitor_fused(
        x, dt, SLIDING_FREQS, win=win, threshold=thr, release=rel,
        sustain_n=sustain_n, cool_n=cool_n, interpret=interpret,
        use_pallas=use_pallas)
    t_fused = _best_of(lambda: fused(True)[0].block_until_ready())
    t_fused_jnp = _best_of(lambda: fused(False)[0].block_until_ready())
    two_pass = lambda use_jnp_amps: _monitor_two_pass(
        x, dt=dt, freqs=SLIDING_FREQS, win=win, threshold=thr, release=rel,
        sustain_n=sustain_n, cool_n=cool_n, interpret=interpret,
        use_jnp_amps=use_jnp_amps)
    t_two_pass = _best_of(lambda: two_pass(False)[0].block_until_ready(), n=3)
    t_jnp_path = _best_of(lambda: two_pass(True)[0].block_until_ready(), n=3)

    # fusion parity: fused == two-pass on worst/levels/detect, bitwise
    # (same v2 amps source, so any difference is the fusion itself)
    wf, lf, df, _ = fused(True)
    wt, lt, dtect = two_pass(False)
    assert np.array_equal(np.asarray(wf), np.asarray(wt)), "worst diverged"
    assert np.array_equal(np.asarray(lf), np.asarray(lt)), "levels diverged"
    assert int(df) == int(dtect), "detect index diverged"

    res = {
        "n_samples": n,
        "win": win,
        "bins": len(SLIDING_FREQS),
        "pallas_ms": round(t_pallas * 1e3, 2),
        "pallas_v1_ms": round(t_v1 * 1e3, 2),
        "ref_cumsum_f64_ms": round(t_ref * 1e3, 2),
        "jnp_cumsum_ms": round(t_jnp * 1e3, 2),
        "samples_per_s_pallas": round(n / t_pallas),
        "samples_per_s_ref_cumsum": round(n / t_ref),
        "speedup_vs_ref_cumsum": round(t_ref / t_pallas, 1),
        "speedup_vs_jnp_cumsum": round(t_jnp / t_pallas, 1),
        "speedup_v2_vs_v1": round(t_v1 / t_pallas, 2),
        "max_err_vs_f64_frac_of_amp": float(f"{err:.2e}"),
        "fused_monitor": {
            "pallas_ms": round(t_fused * 1e3, 2),
            "jnp_scan_mirror_ms": round(t_fused_jnp * 1e3, 2),
            "two_pass_pallas_ms": round(t_two_pass * 1e3, 2),
            "jnp_path_ms": round(t_jnp_path * 1e3, 2),
            "samples_per_s_fused": round(n / t_fused),
            "speedup_fused_vs_two_pass": round(t_two_pass / t_fused, 1),
            "speedup_fused_vs_jnp_path": round(t_jnp_path / t_fused, 1),
        },
    }
    emit("kernels/sliding_pallas", t_pallas * 1e6, {
        "msamples_per_s": round(n / t_pallas / 1e6, 1),
        "speedup_vs_ref_cumsum": res["speedup_vs_ref_cumsum"],
        "speedup_vs_jnp_cumsum": res["speedup_vs_jnp_cumsum"],
        "speedup_v2_vs_v1": res["speedup_v2_vs_v1"]})
    emit("kernels/monitor_fused", t_fused * 1e6, {
        "msamples_per_s": round(n / t_fused / 1e6, 1),
        "speedup_vs_two_pass":
            res["fused_monitor"]["speedup_fused_vs_two_pass"],
        "speedup_vs_jnp_path":
            res["fused_monitor"]["speedup_fused_vs_jnp_path"]})
    if not smoke and res["speedup_vs_ref_cumsum"] < 5.0:
        print(f"# WARNING: sliding Pallas only "
              f"{res['speedup_vs_ref_cumsum']}x the cumsum oracle on this "
              "machine (target >=5x)")
    if not smoke and res["fused_monitor"]["speedup_fused_vs_jnp_path"] < 3.0:
        print(f"# WARNING: fused monitor only "
              f"{res['fused_monitor']['speedup_fused_vs_jnp_path']}x the "
              "jnp path on this machine (target >=3x)")
    return res


@functools.partial(jax.jit, static_argnames=("win", "sustain_n", "cool_n",
                                             "max_level"))
def _consumer_escalation(amps, idx0, esc, threshold, release, *, win,
                         sustain_n, cool_n, max_level):
    """The consumer-side amps -> escalation fold the serve path ran
    before in-kernel fusion: reduce the tick's [m, K] amplitude block to
    the worst bin, classify, and advance the shared machine.  Timed as
    the two-pass arm of the detector A/B."""
    worst = amps.max(axis=1)
    m = worst.shape[0]
    idx = idx0 + jnp.arange(m, dtype=jnp.int32)
    cls = escalation_classify(worst, idx, threshold=threshold, win=win,
                              n=jnp.float32(jnp.inf), release=release)
    esc2, levels = escalation_scan(cls, idx0, esc, sustain_n=sustain_n,
                                   cool_n=cool_n, max_level=max_level)
    return esc2, levels


def detector_tick_bench(smoke: bool) -> dict:
    """Per-tick cost of the online detector (the serve-path step),
    500-sample ticks: the fused v2 kernel path vs (a) the bare
    amps-materializing path (amplitudes only — no worst stream, no
    escalation) and (b) the like-for-like two-pass serve path (amps path
    + the consumer-side amps -> escalation fold the backstop ran before
    fusion)."""
    from repro.control.detector import OnlineGoertzelDetector
    dt, tick = 0.001, 500
    n_ticks = 8 if smoke else 40
    t = np.arange((n_ticks + 2) * tick) * dt
    x = (5e8 + 1e5 * np.sin(2 * np.pi * 2.0 * t)).astype(np.float32)
    chunks = [x[i * tick:(i + 1) * tick] for i in range(n_ticks + 2)]

    def per_tick(fused, escalate=False):
        det = OnlineGoertzelDetector(dt, SLIDING_FREQS, window_s=2.0,
                                     mean=float(x.mean()), fused=fused,
                                     threshold_w=2e5, release_w=1.5e5)
        win = det.win

        def one(c):
            frame = det.step(c)
            if escalate:                  # two-pass: fold amps into levels
                esc2, levels = _consumer_escalation(
                    frame.tick_amps, np.int32(frame.sample_idx + 1 - tick),
                    one.esc, np.float32(2e5), np.float32(1.5e5), win=win,
                    sustain_n=det.sustain_n, cool_n=det.cool_n,
                    max_level=det.max_level)
                one.esc = esc2
                np.asarray(levels)
        one.esc = escalation_init()
        one(chunks[0]), one(chunks[1])                    # warm the jits
        t0 = time.perf_counter()
        for c in chunks[2:]:
            one(c)
        return (time.perf_counter() - t0) / n_ticks
    t_fused = per_tick(True)
    t_amps = per_tick(False)
    t_two_pass = per_tick(False, escalate=True)
    res = {
        "tick_samples": tick,
        "fused_us_per_tick": round(t_fused * 1e6, 1),
        "amps_us_per_tick": round(t_amps * 1e6, 1),
        "two_pass_us_per_tick": round(t_two_pass * 1e6, 1),
        "speedup_fused_vs_two_pass": round(t_two_pass / t_fused, 2),
    }
    emit("kernels/detector_tick", t_fused * 1e6, {
        "amps_us_per_tick": res["amps_us_per_tick"],
        "two_pass_us_per_tick": res["two_pass_us_per_tick"],
        "speedup_fused_vs_two_pass": res["speedup_fused_vs_two_pass"]})
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace, parity checks only, no JSON artifact")
    args = ap.parse_args()
    key = jax.random.PRNGKey(0)

    # ballast: arithmetic intensity at m=1024,k=n=256, 64 iters
    m, k, n, it = 1024, 256, 256, 64
    fl = ballast_flops(m, k, n, it)
    hbm_bytes = (m * k + k * n + m * n) * 4  # one round-trip of the tiles
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = (jnp.eye(k) * 0.999).astype(jnp.float32)
    f = jax.jit(lambda a, b: ballast_ref(a, b, it))
    f(a, b).block_until_ready()
    us = us_per_call(lambda: f(a, b).block_until_ready(), n=5)
    emit("kernels/ballast_ref", us, {
        "gflops_per_call": round(fl / 1e9, 2),
        "arith_intensity_flops_per_byte": round(fl / hbm_bytes, 1),
        "tpu_mxu_bound_us": round(fl / 197e12 * 1e6, 2)})

    # goertzel: 8 windows x 1024 samples x 4 bins
    wnd = jax.random.normal(key, (8, 1024))
    coef = 2.0 * jnp.cos(2 * jnp.pi * jnp.array([0.5, 1.0, 2.0, 9.0]) * 0.001)
    g = jax.jit(goertzel_ref)
    g(wnd, coef).block_until_ready()
    us = us_per_call(lambda: g(wnd, coef).block_until_ready(), n=5)
    ops = 8 * 1024 * 4 * 4  # 4 madds per sample per bin
    emit("kernels/goertzel_ref", us, {
        "ops_per_call": ops,
        "bins": 4, "window": 1024,
        "vs_full_fft_ops_ratio": round(ops / (8 * 1024 * np.log2(1024) * 5), 3)})

    # sliding monitor: the backstop's product hot path
    if args.smoke:
        sliding_monitor_bench(n=100_000, dt=0.001, win=2000, smoke=True)
        detector_tick_bench(smoke=True)
        print("smoke OK: sliding v1/v2/fused kernels match the f64 cumsum "
              "oracle and the two-pass monitor")
        return
    res = sliding_monitor_bench(n=1_000_000, dt=0.001, win=8000, smoke=False)
    res["detector"] = detector_tick_bench(smoke=False)
    with open(OUT_PATH, "w") as fh:
        json.dump(res, fh, indent=2)
        fh.write("\n")
    print("wrote", os.path.abspath(OUT_PATH))


if __name__ == "__main__":
    main()

"""Kernel micro-benchmarks (Sec. IV-A ballast / IV-E backstop hot paths).

The headline measurement is the telemetry backstop's sliding monitor:
the streaming Pallas sliding-Goertzel kernel vs the complex-cumsum
oracles on a 1e6-sample MW-scale trace (throughput in samples/s).  The
kernel runs in interpret mode on CPU — the same configuration the
product path uses off-TPU — and still wins because it replaces the
oracles' per-sample phase generation (n*K complex exponentials) with
small host-precomputed [win, K] tables and segment-local prefix sums.
Writes BENCH_kernels.json; ``--smoke`` runs a small trace, checks
ref-vs-Pallas parity and skips the artifact (the CI mode).

CPU wall times for the ballast/goertzel sections are for harness
completeness only — TPU throughput is derived from the FLOP/byte model
printed alongside.

  PYTHONPATH=src python -m benchmarks.kernels_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, us_per_call
from repro.kernels.ballast.ops import ballast_burn, ballast_flops
from repro.kernels.ballast.ref import ballast_ref
from repro.kernels.goertzel.ops import sliding_bin_power
from repro.kernels.goertzel.ref import (goertzel_ref, sliding_bin_power_jnp,
                                        sliding_bin_power_ref)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")

SLIDING_FREQS = (0.5, 1.0, 2.0, 9.0)   # the backstop's default critical bins


def _best_of(fn, n=5):
    fn()                                # warm (compile)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def sliding_monitor_bench(n: int, dt: float, win: int, smoke: bool) -> dict:
    """Sliding-monitor throughput, ref vs Pallas, on an MW-scale trace
    (1e5 W line on a 5e8 W DC offset — the acceptance scenario)."""
    t = np.arange(n) * dt
    xnp = 5e8 + 1e5 * np.sin(2 * np.pi * 2.0 * t)
    x = jnp.asarray(xnp, jnp.float32)
    interpret = jax.default_backend() != "tpu"

    pallas = lambda: sliding_bin_power(
        x, dt, SLIDING_FREQS, win=win, interpret=interpret).block_until_ready()
    jnp_oracle = jax.jit(
        lambda x: sliding_bin_power_jnp(x, dt, SLIDING_FREQS, win))
    t_pallas = _best_of(pallas)
    t_jnp = _best_of(lambda: jnp_oracle(x).block_until_ready())
    # the float64 cumsum oracle: one pass is enough (it is the slow one)
    t0 = time.perf_counter()
    ref = sliding_bin_power_ref(xnp, dt, np.asarray(SLIDING_FREQS), win)
    t_ref = time.perf_counter() - t0

    # parity while we are here: the bench never reports a wrong kernel
    out = np.asarray(sliding_bin_power(x, dt, SLIDING_FREQS, win=win,
                                       interpret=interpret))
    err = np.abs(out - ref).max() / 1e5
    assert err < 5e-3, f"sliding kernel diverged from f64 oracle: {err}"

    res = {
        "n_samples": n,
        "win": win,
        "bins": len(SLIDING_FREQS),
        "pallas_ms": round(t_pallas * 1e3, 2),
        "ref_cumsum_f64_ms": round(t_ref * 1e3, 2),
        "jnp_cumsum_ms": round(t_jnp * 1e3, 2),
        "samples_per_s_pallas": round(n / t_pallas),
        "samples_per_s_ref_cumsum": round(n / t_ref),
        "speedup_vs_ref_cumsum": round(t_ref / t_pallas, 1),
        "speedup_vs_jnp_cumsum": round(t_jnp / t_pallas, 1),
        "max_err_vs_f64_frac_of_amp": float(f"{err:.2e}"),
    }
    emit("kernels/sliding_pallas", t_pallas * 1e6, {
        "msamples_per_s": round(n / t_pallas / 1e6, 1),
        "speedup_vs_ref_cumsum": res["speedup_vs_ref_cumsum"],
        "speedup_vs_jnp_cumsum": res["speedup_vs_jnp_cumsum"]})
    if not smoke and res["speedup_vs_ref_cumsum"] < 5.0:
        print(f"# WARNING: sliding Pallas only "
              f"{res['speedup_vs_ref_cumsum']}x the cumsum oracle on this "
              "machine (target >=5x)")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace, parity checks only, no JSON artifact")
    args = ap.parse_args()
    key = jax.random.PRNGKey(0)

    # ballast: arithmetic intensity at m=1024,k=n=256, 64 iters
    m, k, n, it = 1024, 256, 256, 64
    fl = ballast_flops(m, k, n, it)
    hbm_bytes = (m * k + k * n + m * n) * 4  # one round-trip of the tiles
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = (jnp.eye(k) * 0.999).astype(jnp.float32)
    f = jax.jit(lambda a, b: ballast_ref(a, b, it))
    f(a, b).block_until_ready()
    us = us_per_call(lambda: f(a, b).block_until_ready(), n=5)
    emit("kernels/ballast_ref", us, {
        "gflops_per_call": round(fl / 1e9, 2),
        "arith_intensity_flops_per_byte": round(fl / hbm_bytes, 1),
        "tpu_mxu_bound_us": round(fl / 197e12 * 1e6, 2)})

    # goertzel: 8 windows x 1024 samples x 4 bins
    wnd = jax.random.normal(key, (8, 1024))
    coef = 2.0 * jnp.cos(2 * jnp.pi * jnp.array([0.5, 1.0, 2.0, 9.0]) * 0.001)
    g = jax.jit(goertzel_ref)
    g(wnd, coef).block_until_ready()
    us = us_per_call(lambda: g(wnd, coef).block_until_ready(), n=5)
    ops = 8 * 1024 * 4 * 4  # 4 madds per sample per bin
    emit("kernels/goertzel_ref", us, {
        "ops_per_call": ops,
        "bins": 4, "window": 1024,
        "vs_full_fft_ops_ratio": round(ops / (8 * 1024 * np.log2(1024) * 5), 3)})

    # sliding monitor: the backstop's product hot path
    if args.smoke:
        sliding_monitor_bench(n=100_000, dt=0.001, win=2000, smoke=True)
        print("smoke OK: sliding Pallas kernel matches the f64 cumsum oracle")
        return
    res = sliding_monitor_bench(n=1_000_000, dt=0.001, win=8000, smoke=False)
    with open(OUT_PATH, "w") as fh:
        json.dump(res, fh, indent=2)
        fh.write("\n")
    print("wrote", os.path.abspath(OUT_PATH))


if __name__ == "__main__":
    main()

"""Generate EXPERIMENTS.md from dry-run artifacts + benchmark outputs.

  PYTHONPATH=src python -m benchmarks.make_experiments

Reads:  artifacts/dryrun   (paper-faithful BASELINE, frozen)
        artifacts/dryrun_v2 (optimized: flash-attn prefill costing, kv-pin,
                             free MoE activation placement)
Writes: EXPERIMENTS.md
"""
from __future__ import annotations

import glob
import json
import os

import repro.core as core
from benchmarks.roofline import analyze
from repro.configs import ARCH_IDS, get_config, shapes_for

ROOT = os.path.join(os.path.dirname(__file__), "..")
BASE = os.path.join(ROOT, "artifacts", "dryrun")
OPT = os.path.join(ROOT, "artifacts", "dryrun_v2")


def load(d, mesh=None):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            c = json.load(f)
        if "error" in c:
            continue
        if mesh and c.get("mesh") != mesh:
            continue
        out[f"{c['arch']}__{c['shape']}__{c['mesh']}"] = c
    return out


def f(x, nd=3):
    return f"{x:.{nd}f}"


def dryrun_table(cells):
    rows = ["| arch | shape | mesh | compile s | GFLOP/chip | GB/chip | coll GB/chip | state GB/chip |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(cells):
        c = cells[key]
        chips = c["n_chips"]
        rows.append("| {} | {} | {} | {} | {} | {} | {} | {} |".format(
            c["arch"], c["shape"], c["mesh"], c.get("compile_s", "-"),
            f(c["exact"]["flops"] / chips / 1e9, 0),
            f(c["exact"]["bytes"] / chips / 1e9, 1),
            f(sum(c["collectives"].values()) / 1e9, 1),
            f(c["memory"]["state_bytes_per_device"] / 1e9, 2)))
    return "\n".join(rows)


def roofline_table(cells):
    rows = ["| arch | shape | comp s | mem s | coll s | dominant | useful | roofline frac | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    agg = []
    for key in sorted(cells):
        c = cells[key]
        r = analyze(c)
        agg.append(r)
        rows.append("| {} | {} | {} | {} | {} | {} | {} | **{}** | {} |".format(
            r["arch"], r["shape"], f(r["t_compute_s"], 4), f(r["t_memory_s"], 4),
            f(r["t_collective_s"], 4), r["dominant"],
            f(min(r["useful_ratio"], 1.0), 3), f(r["roofline_fraction"], 3),
            r["suggestion"].split(":")[0]))
    return "\n".join(rows), agg


def perf_rows(cell_names):
    rows = ["| cell | variant | comp s | mem s | coll s | dominant | roofline frac |",
            "|---|---|---|---|---|---|---|"]
    for name in cell_names:
        for label, d in (("baseline", BASE), ("optimized", OPT)):
            p = os.path.join(d, name + ".json")
            if not os.path.exists(p):
                continue
            with open(p) as fh:
                c = json.load(fh)
            if "error" in c:
                continue
            r = analyze(c)
            rows.append("| {} | {} | {} | {} | {} | {} | **{}** |".format(
                name.replace("__single", ""), label,
                f(r["t_compute_s"], 3), f(r["t_memory_s"], 3),
                f(r["t_collective_s"], 3), r["dominant"],
                f(r["roofline_fraction"], 3)))
    return "\n".join(rows)


def power_sweep_section():
    """§Power — one declarative Study over (workload x config) under the
    'moderate' spec; dry-run timelines when artifacts exist, the calibrated
    synthetic workloads otherwise.  The unmitigated baseline batches with
    the mitigated configs (mixed None rows mask through the engine), and
    mixed-length workloads fuse into one padded pipeline call
    (core/study.py)."""
    workloads = {}
    for key, cell in sorted(_load_cells_safe().items()):
        if cell.get("shape") == "train_4k":
            workloads[cell["arch"]] = core.from_dryrun_cell(cell)
    source = "dry-run timelines (train_4k)"
    if not workloads:
        source = "calibrated synthetic timelines (no dry-run artifacts)"
        workloads = {
            "dense_2s": core.synthetic_timeline(period_s=2.0, comm_frac=0.19),
            "dense_1s": core.synthetic_timeline(period_s=1.0, comm_frac=0.30),
            "moe_3s": core.synthetic_timeline(period_s=3.0, comm_frac=0.25,
                                              moe_notch=True),
        }
    cfg = core.WaveformConfig(dt=0.002, steps=10, jitter_s=0.002)
    n_chips = 512
    ref = core.aggregate(
        core.chip_waveform(next(iter(workloads.values())), cfg), n_chips, cfg)
    swing = float(ref.max() - ref.min())
    spec = core.example_specs(job_mw=ref.mean() / 1e6)["moderate"]
    configs = {"none": None}
    for mpf in (0.65, 0.9):
        for cap_f in (0.5, 2.0):
            gpu = core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=2000,
                                         ramp_down_w_per_s=2000,
                                         stop_delay_s=1.0)
            bat = core.RackBattery(capacity_j=cap_f * swing,
                                   max_discharge_w=swing, max_charge_w=swing,
                                   target_tau_s=10.0)
            configs[f"mpf{int(mpf*100)}+bat{cap_f}x"] = (gpu, bat)
    study = core.Study(workloads, fleets=[n_chips], configs=configs,
                       specs=spec, wave_cfg=cfg, key=0)
    result = study.run()
    rows = ["| workload | config | swing MW | mitigated MW | overhead | spec |",
            "|---|---|---|---|---|---|"]
    for r in sorted(result, key=lambda r: (r["workload"], r["config"])):
        rows.append("| {} | {} | {} | {} | {} | {} |".format(
            r["workload"], r["config"], f(r["swing_mw"]),
            f(r["swing_mitigated_mw"]), f(r["energy_overhead"], 4),
            "PASS" if r["spec_ok"] else ",".join(r["violations"])))
    lines = [f"\n## §Power sweep — one Study over {source}\n",
             f"{len(result)} scenarios ({len(workloads)} workloads x "
             f"{len(configs)} mitigation configs x {n_chips} chips, "
             "baseline batched with mitigated rows), 'moderate' utility "
             "spec, one padded pipeline call.\n", "\n".join(rows)]
    bench = os.path.join(ROOT, "BENCH_sweep.json")
    if os.path.exists(bench):
        with open(bench) as fh:
            b = json.load(fh)
        lines.append(
            f"\nSweep wall-clock (benchmarks/sweep_bench.py, "
            f"{b['n_scenarios']} scenarios): serial {b['serial_s']}s -> "
            f"bucketed {b['bucketed_warm_s']}s / padded single-bucket "
            f"{b['padded_warm_s']}s warm "
            f"(**{b['speedup_warm_padded']}x**; cold incl. compile: "
            f"bucketed {b['bucketed_cold_s']}s vs padded "
            f"{b['padded_cold_s']}s, {b['padded_vs_bucketed_cold']}x less).")
    return "\n".join(lines)


def streaming_section():
    """§Streaming — the chunked fixed-memory executor, rendered from the
    ``scale`` section ``sweep_bench --scale`` wrote (10^4 scenarios,
    streaming vs materializing, subprocess-isolated wall + peak RSS)."""
    lines = ["\n## §Streaming — 10^4-scenario grids in fixed memory\n"]
    lines.append(
        "The materializing executor holds every scenario's waveforms at "
        "once (device arrays on CPU backends = host RSS), which caps "
        "grids at ~10^3 scenarios.  `Study.run(stream=chunk)` / "
        "`engine.stream_batches` iterate the scenario axis in fixed-size "
        "chunks: per chunk, the compiled pipeline synthesizes + mitigates "
        "on device with the stacked input buffer donated to XLA, vmapped "
        "per-(length, spec) analysis reduces to metrics *inside jit* "
        "(analysis batches pow2-padded so compiles stay O(log chunk)), "
        "and only O(chunk) metric arrays transfer to host — chunk k+1 is "
        "dispatched before chunk k's transfer, overlapping I/O with "
        "compute.  Results append per chunk into the columnar "
        "`StudyResult` (dict of numpy columns; ~0.5 KB/record host cost, "
        "lazy per-row dict views, query API unchanged and "
        "bit-compatible).  Chunked == one-shot bit-identically "
        "(chunk/tail/shard/analysis padding only ever adds rows that are "
        "sliced away; asserted in CI via `sweep_bench --smoke` and "
        "`tests/test_streaming.py`, including chunk boundaries that "
        "split a dedup prefix group).  Scenario-axis sharding composes: "
        "`ScenarioShardPlan` (Mesh/NamedSharding over a 1-D "
        '`("scenario",)` axis, process-local row slicing for multi-host) '
        "pads each chunk to a shard multiple before the compiled call.\n")
    bench = os.path.join(ROOT, "BENCH_sweep.json")
    b = {}
    if os.path.exists(bench):
        with open(bench) as fh:
            b = json.load(fh)
    s = b.get("scale")
    if s is None:
        lines.append("(run `python -m benchmarks.sweep_bench --scale` for "
                     "the measured section)")
        return "\n".join(lines)
    lines.append(
        f"Measured (`python -m benchmarks.sweep_bench --scale`, "
        f"{s['n_scenarios']} scenarios = 4 workloads x 25 configs x "
        f"{s['n_scenarios'] // 100} seeds, dt=4 ms / 6 iterations, each "
        "mode in its own subprocess so peak RSS is attributable):\n")
    lines.append("| mode | wall s | peak RSS MB | verdicts |")
    lines.append("|---|---|---|---|")
    lines.append(f"| materializing (`run()`) | {s['materializing_wall_s']} "
                 f"| {s['materializing_peak_rss_mb']} | "
                 f"{s['n_pass']}/{s['n_scenarios']} pass |")
    lines.append(f"| streaming (`run(stream={s['chunk']})`) | "
                 f"{s['streaming_wall_s']} | {s['streaming_peak_rss_mb']} | "
                 f"{s['n_pass']}/{s['n_scenarios']} pass |")
    lines.append(
        f"\n**{s['rss_ratio']}x less peak memory at wall-clock parity "
        f"({s['wall_ratio']}x)** — the streaming path's RSS is dominated "
        "by the fixed runtime + compiled programs, so the grid can grow "
        "another order of magnitude before memory moves "
        "(`BENCH_sweep.json`, `scale` section).  The serve path "
        "(`PowerComplianceService`) runs on the same executor with "
        "`stream_chunk=256` and retains metrics only.")
    d = b.get("distributed")
    if d is not None:
        r = d["resume"]
        lines.append(
            f"\nDistributed (same grid, 2-process `jax.distributed` "
            f"scenario mesh, CPU + gloo, {d['host_cpu_count']}-core host): "
            f"wall {d['wall_s']}s vs single-process "
            f"{d['single_process_wall_s']}s — scaling efficiency "
            f"{d['scaling_efficiency']} (bounded by physical cores; on a "
            f"1-core host two processes time-share and ~0.5 is the "
            f"ceiling), per-process peak RSS "
            f"{d['per_process_rss_mb']} MB, merged verdicts "
            f"{d['verdict_agreement']} vs single-process — bit-identical "
            "by test (`tests/test_distributed.py`).\n")
        lines.append(
            f"Resume (`run(stream={d['chunk']}, resume=dir)`): "
            f"checkpointing every chunk costs "
            f"{r['checkpoint_overhead_per_chunk_s']}s per "
            f"{r['chunk_wall_s']}s chunk "
            f"(**{r['overhead_ratio'] * 100:.1f}% overhead**, target "
            f"<10%), and restoring a finished chunk from disk takes "
            f"{r['restore_per_chunk_s']}s "
            f"({r['restore_ratio'] * 100:.1f}% of recomputing it) — a "
            "killed sweep resumes at a chunk boundary bit-identically "
            "(`sweep_bench --resume-smoke` SIGKILLs a run mid-stream in "
            "CI and asserts record parity).")
    m = s.get("million")
    if m is not None:
        lines.append(
            f"\n10^6-scenario acceptance run (single host, "
            f"`run(stream={m['chunk']}, resume=dir)`, {m['n_chunks']} "
            f"chunks): completed in {m['wall_s']}s "
            f"({m['scenarios_per_s']} scenarios/s) at "
            f"**{m['peak_rss_mb']} MB peak RSS** — within the "
            f"{m['rss_budget_mb']} MB budget (1.5x the 10^4 streaming "
            f"figure), {m['n_pass']}/{m['n_scenarios']} passing "
            "(`BENCH_sweep.json`, `scale.million`).")
    return "\n".join(lines)


def design_section():
    """§Design — grid vs gradient co-optimization of (MPF, battery
    capacity), numbers from BENCH_design.json
    (benchmarks/design_bench.py)."""
    lines = ["\n## §Design — gradient co-optimization of (MPF, battery)\n",
             "`design_mitigation` answers the operator question spec -> "
             "configuration.  The grid solver evaluates a coarse "
             "(MPF x capacity) lattice in one vmapped call; the *gradient* "
             "solver (`engine.design_gradient`) descends on the compliance "
             "frontier directly: every mitigation carries a structure-"
             "static `smooth_tau` relaxation (sigmoid gates / tanh mode "
             "switches / straight-through quantizers at temperature tau; "
             "tau=0 is the exact hard path the forward engine always "
             "runs), `UtilitySpec.loss_jax` turns the spec's thresholds "
             "into margin-shrunk quadratic hinges, and a jitted Adam loop "
             "(shared `core/optim.py`) with box projection and vmapped "
             "multi-start minimizes hinge loss + energy-overhead + an L1 "
             "sizing term.  Finals are re-validated under the hard tau=0 "
             "semantics (with a capacity ladder and the seeds), so the "
             "answer is always an exact-semantics, spec-passing config — "
             "`method=\"hybrid\"` seeds from the coarse grid's top-k and "
             "is never worse than it.\n",
             "Trade-off: the grid is unbeatable warm at coarse resolution "
             "(one compile, fully batched) but its cost grows with the "
             "product of the axis resolutions and its answer is quantized "
             "to the lattice; the gradient's cost is ~constant in "
             "resolution (steps x multi-starts), so it wins wall-clock "
             "whenever lattice-grade capacity sizing isn't enough — and "
             "it finds the frontier *between* grid points (smaller "
             "batteries at equal overhead).\n"]
    bench = os.path.join(ROOT, "BENCH_design.json")
    if os.path.exists(bench):
        with open(bench) as fh:
            b = json.load(fh)
        rows = ["| solver | warm s | cold s | MPF | capacity MJ | "
                "energy overhead |", "|---|---|---|---|---|---|"]
        for name, s in b["solvers"].items():
            rows.append("| {} | {} | {} | {} | {} | {} |".format(
                name, s["warm_s"], s["cold_s"], s["mpf_frac"],
                s["battery_capacity_mj"], s["energy_overhead"]))
        lines.append(
            f"Measured (benchmarks/design_bench.py, {b['n_samples']} "
            f"samples, {b['n_chips']} chips, '{b['spec']}' spec; fine "
            f"grid {b['fine_grid_resolution']}, gradient "
            f"{b['gradient_steps']} steps):\n\n" + "\n".join(rows) +
            f"\n\nGradient = **{b['gradient_vs_fine_grid_warm']}x** less "
            "warm wall-clock than the equivalent-resolution grid at "
            "comparable capacity, and never worse on overhead than "
            "the best coarse-grid config "
            f"(delta {b['gradient_vs_best_coarse_overhead']}).")
    return "\n".join(lines)


def serve_section():
    """§Serve — the amortized compliance-query path (learned warm-start
    design, coalesced batching, answer cache), numbers from
    BENCH_serve.json (benchmarks/serve_bench.py)."""
    lines = ["\n## §Serve — amortized compliance queries (warm-start, "
             "coalescing, answer cache)\n",
             "The serve path (`PowerComplianceService`) turns the heavy "
             "machinery above into a query service, and amortizes it at "
             "three levels — measured by `python -m benchmarks.serve_bench` "
             "into `BENCH_serve.json` (`--smoke` is the CI mode; the full "
             "run trains the predictor on a 72-cell Study sweep and writes "
             "the artifact).\n",
             "**Learned warm-start design** (`serve/warmstart.py`). "
             "`design()` cold is solver-minutes; most production queries "
             "are near previously-solved workloads. A small MLP maps a "
             "17-dim spectral fingerprint (Goertzel amplitudes at the "
             "grid-critical bins, swing, mean, fleet size, spec limits — "
             "`extract_features`) to design seeds (MPF, battery capacity, "
             "target tau). `engine.design(method=\"warmstart\")` expands "
             "the seed through a capacity ladder, re-validates every rung "
             "under the **hard tau=0 semantics**, and returns the cheapest "
             "passing rung (`aux[\"warmstart_path\"]=\"fast\"`); if no "
             "rung passes it escalates to gradient polish from the seed, "
             "then to full `method=\"hybrid\"` — so the verdict "
             "(feasible/infeasible) is always identical to the solver it "
             "amortizes, the prediction only moves wall-clock. Training "
             "data comes from one Study-driven sweep "
             "(`benchmarks/warmstart_data.py`: scenarios x catalog x tau "
             "ladder, labels = cheapest passing config per cell), "
             "checkpoints via `ckpt/checkpoint.py` "
             "(`WarmStartPredictor.save/load`, bit-exact round-trip).\n",
             "**Cross-query compiled reuse.** Executables are keyed by "
             "(trace length, spec *family*, mitigation structure) only: "
             "`UtilitySpec.family()` erases thresholds to a canonical "
             "static form and `UtilitySpec.limits()` re-injects them as "
             "traced scalars, so querying new fleets, new thresholds, or "
             "new workload mixes reuses the same compiled pipeline "
             "(`test_no_retrace_*` pins `_cache_size()` constant).\n",
             "**Concurrency-safe batched service.** The service front-ends "
             "the Study executor with a lock-protected true-LRU answer "
             "cache (eviction + recency tested), single-flight dedup (N "
             "identical concurrent queries elect one leader; followers "
             "wait on an `Event` and inherit a retry if the leader fails), "
             "memoized per-workload synthesis/features, and "
             "`query_many`/`handle_many` which coalesce N distinct queries "
             "into ONE Study execution (per-query PRNG keys are folded "
             "from *local* row indices and multi-query runs use per-length "
             "bucket padding, so coalesced answers are bit-identical to "
             "serial — pinned by `json.dumps` equality in "
             "`test_serve_service.py`).\n"]
    bench = os.path.join(ROOT, "BENCH_serve.json")
    if os.path.exists(bench):
        with open(bench) as fh:
            b = json.load(fh)
        d, s = b["design"], b["service"]
        lines.append(
            f"Measured (benchmarks/serve_bench.py, {b['n_chips']} chips, "
            "tight spec, full run):\n\n"
            "| path | cold s | warm s | vs cold hybrid |\n"
            "|---|---|---|---|\n"
            f"| design hybrid | {d['hybrid']['cold_s']} | "
            f"{d['hybrid']['warm_s']} | — |\n"
            f"| design warm-start | {d['warmstart']['cold_s']} | "
            f"**{d['warmstart']['warm_s']}** | "
            f"**{d['speedup_warm_vs_cold_hybrid']}x** |\n\n"
            f"Same energy overhead ({d['hybrid']['energy_overhead']}) on "
            f"both paths. Service: cache-hit p50 "
            f"**{s['cache_hit_p50_us']} µs** / p99 "
            f"{s['cache_hit_p99_us']} µs over 300 reps; "
            f"{s['singleflight']['threads']} concurrent identical queries "
            f"-> {s['singleflight']['study_runs']} study run "
            f"({s['singleflight']['waits']} single-flight waits); "
            f"{s['coalesce']['queries']} distinct queries coalesced -> "
            f"{s['coalesce']['study_runs']} study run, compiled-executable "
            f"count {s['compiled_executables']['before']} -> "
            f"{s['compiled_executables']['after']} (no retrace). Hot-path "
            "cost gates: `python -m benchmarks.roofline --kernels` asserts "
            "jaxpr-exact FLOPs/bytes of the sliding-Goertzel monitor, the "
            "fingerprint extractor, the warm-start MLP, and the ballast "
            "tile against recorded budgets (deterministic counts; a "
            "breach fails CI), pins each path's exact jaxpr primitive "
            "histogram (a fusion regression fails with a named "
            "per-primitive diff), and merges both into "
            "`BENCH_kernels.json` (`per_kernel`, "
            "`per_kernel_primitives`). The `repro-lint` recompile gate "
            "(`--tiers recompile`) re-runs the monitor and the batched "
            "engine in the same shape bucket and fails CI if any "
            "tracked jit cache grows — the serve path's compiled-reuse "
            "guarantee, enforced fleet-wide rather than per-test.")
    return "\n".join(lines)


def control_section():
    """§Control — the grid-interactive control plane's closed-loop run,
    numbers from BENCH_control.json (benchmarks/control_bench.py)."""
    lines = ["\n## §Control — grid-interactive closed loop "
             "(online detection -> intervention dispatch)\n",
             "`repro/control/` closes the loop on the serve path: a "
             "`ControlLoop` replays telemetry tick by tick "
             "(`ReplaySource`), runs the sliding-Goertzel monitor "
             "*incrementally* (`sliding_bin_power(..., carry=)` — the "
             "online chunked path is bit-identical to one offline call on "
             "the concatenated trace, asserted below), feeds "
             "slope-projected per-bin amplitudes into the shared "
             "threshold/hysteresis escalation machine "
             "(`core/telemetry.escalation_step`, also the backstop's), "
             "and escalates through an intervention ladder — warm-started "
             "`design()` -> power cap + ballast floor -> fleet phase "
             "stagger — applying each to the stream's own future, so the "
             "loop observably changes what it subsequently measures.  "
             "Every decision lands in a `ControlLog`; because the "
             "controller *prevents* the breach, detection lead is "
             "measured against the counterfactual breach of the raw, "
             "uncontrolled trace.\n"]
    bench = os.path.join(ROOT, "BENCH_control.json")
    if os.path.exists(bench):
        with open(bench) as fh:
            b = json.load(fh)
        lo, de = b["loop"], b["detector"]
        det, lat, cl = (lo["detection"], lo["dispatch_latency_s"],
                        lo["closed_loop"])
        lines.append(
            f"Measured (benchmarks/control_bench.py, "
            f"{lo['trace']['duration_s']:.0f} s replay, 9 Hz amplitude "
            f"ramp, {lo['trace']['n_chips']} chips, "
            f"'{lo['trace']['spec']}' spec"
            f"{', smoke' if b.get('smoke') else ''}):\n\n"
            "| metric | value |\n|---|---|\n"
            f"| first escalation | t={det['first_escalate_t_s']} s |\n"
            f"| counterfactual (uncontrolled) breach | "
            f"t={det['counterfactual_breach_t_s']} s |\n"
            f"| **detection lead** | **{det['detection_lead_s']:.1f} s "
            "before breach** |\n"
            f"| dispatch latency cold (first compile) | "
            f"{lat['cold_first']:.2f} s |\n"
            f"| dispatch latency warm p50 / p90 | "
            f"**{lat['warm_p50']*1e3:.0f} ms** / "
            f"{lat['warm_p90']*1e3:.0f} ms "
            f"(max {lat['warm_max']*1e3:.0f} ms, "
            f"n={lat['n_samples']}) |\n"
            f"| amplitude recession below release | "
            f"t={cl['recession_t_s']} s "
            f"({cl['recession_after_dispatch_s']:.1f} s after dispatch) |\n"
            f"| interventions dispatched | {cl['n_dispatches']} "
            f"({', '.join(sorted({a.split(':', 1)[1] for a in cl['interventions'] if a.startswith('dispatch:')}))}) |\n"
            f"| closed loop wall-clock | "
            f"{lo['loop_wall_s']['realtime_x']:.0f}x realtime |\n"
            f"| online detector step (win={de['win']}, "
            f"{len(FREQS_NOTE)} bins) | "
            f"{de['step_us']['p50']:.0f} µs per "
            f"{de['tick_samples'] * lo['trace']['dt']:.1f} s tick "
            f"({de['realtime_x']:.0f}x realtime) |\n"
            f"| online == offline monitor | "
            f"{'bitwise identical' if de['bit_identical_to_offline'] else 'DRIFTED'} "
            f"over {de['samples']} samples |\n\n"
            "Run it yourself: `python examples/control_loop_demo.py` "
            "prints the decision timeline; `repro-serve watch --replay "
            "ramp --timeline` is the CLI form.")
    else:
        lines.append("(run `python -m benchmarks.control_bench` for the "
                     "measured section)")
    return "\n".join(lines)


FREQS_NOTE = (0.5, 1.0, 2.0, 9.0)   # grid-critical bins the bench watches


def kernels_section():
    """§Kernels — the telemetry backstop's sliding-Goertzel monitor on the
    lane-major v2 Pallas kernels, numbers from BENCH_kernels.json
    (benchmarks/kernels_bench.py + roofline --kernels)."""
    lines = ["\n## §Kernels — sliding-Goertzel backstop monitor "
             "(lane-major v2 Pallas hot path)\n",
             "The backstop (Sec. IV-E) watches grid-critical bins with an "
             "every-sample sliding Goertzel monitor. The product path is "
             "the lane-major v2 kernel family (`kernels/goertzel`): phase "
             "tables and resonator state live in a `[K, win]` layout (the "
             "long window axis on TPU lanes, the handful of bins "
             "sublane-padded), the trace streams through VMEM in "
             "window-sized segments, per-bin prefix state restarts at every "
             "segment (hop-and-overlap) and carries across grid cells, and "
             "each window amplitude assembles from the current segment's "
             "head plus the previous segment's suffix rotated by a "
             "host-precomputed phase factor. The fused monitor variant "
             "(`sliding_monitor_fused`) also reduces per-bin amplitudes to "
             "the worst bin and its escalation class *inside the kernel* — "
             "the `[n, K]` amplitude matrix never leaves VMEM — and the "
             "blocked `core.telemetry.escalation_scan` turns classes into "
             "levels. Mean removal before accumulation keeps every partial "
             "sum at oscillation scale — the f32-cumsum estimator it "
             "replaced saturated warm-up windows at ~2x the DC offset and "
             "left a ~1e4 W rounding floor on the 9 Hz bin, burying the "
             "~1e5 W oscillations the monitor exists to catch. Kernels "
             "compile on TPU, interpret mode elsewhere; the structurally "
             "identical jitted jnp mirrors are bitwise equal to the "
             "interpret-mode kernels (the differentiable path), and the "
             "online `carry=` API is bit-identical to one offline call. "
             "Gold oracle: float64 `sliding_bin_power_ref`.\n"]
    bench = os.path.join(ROOT, "BENCH_kernels.json")
    if os.path.exists(bench):
        with open(bench) as fh:
            b = json.load(fh)
        lines.append(
            f"Measured (benchmarks/kernels_bench.py, CPU interpret mode, "
            f"{b['n_samples']:.0e}-sample MW-scale trace, win={b['win']}, "
            f"{b['bins']} bins): v2 Pallas {b['pallas_ms']} ms "
            f"({b['samples_per_s_pallas'] / 1e6:.0f} Msamples/s) vs f64 "
            f"cumsum oracle {b['ref_cumsum_f64_ms']} ms "
            f"(**{b['speedup_vs_ref_cumsum']}x**), jitted jnp cumsum "
            f"mirror {b['jnp_cumsum_ms']} ms "
            f"({b['speedup_vs_jnp_cumsum']}x), and the bin-minor v1 layout "
            f"{b['pallas_v1_ms']} ms ({b['speedup_v2_vs_v1']}x); max "
            f"deviation from the f64 oracle "
            f"{b['max_err_vs_f64_frac_of_amp']:.0e} of the oscillation "
            f"amplitude.")
        fm = b.get("fused_monitor")
        if fm:
            lines.append(
                f"\nFused monitor (same trace): {fm['pallas_ms']} ms "
                f"(**{fm['speedup_fused_vs_jnp_path']}x** the jnp "
                f"fused-scan path at {fm['jnp_path_ms']} ms, "
                f"{fm['speedup_fused_vs_two_pass']}x the two-pass "
                f"kernel+scan path at {fm['two_pass_pallas_ms']} ms), "
                f"bitwise equal to the two-pass escalation on "
                f"worst/levels/detect.")
        det = b.get("detector")
        if det:
            lines.append(
                f"\nOnline detector (serve path, "
                f"{det['tick_samples']}-sample ticks): fused "
                f"{det['fused_us_per_tick']} µs/tick vs the prior "
                f"amps+consumer-scan serve path at "
                f"{det['two_pass_us_per_tick']} µs/tick (bare "
                f"amps-materializing path {det['amps_us_per_tick']} "
                f"µs/tick, without worst/levels).")
        mb = b.get("measured_bandwidth")
        if mb and "fused_achieved_gb_per_s" in mb:
            lines.append(
                f"\nAttribution (roofline --kernels, jaxpr-exact bytes at "
                f"the bench shape): the fused path moves "
                f"{mb['fused_bytes'] / 1e6:.0f} MB vs "
                f"{mb['two_pass_jnp_bytes'] / 1e6:.0f} MB on the jnp path "
                f"(**{mb['bytes_ratio_two_pass_over_fused']}x fewer "
                f"bytes**) at {mb['fused_achieved_gb_per_s']} vs "
                f"{mb['two_pass_jnp_achieved_gb_per_s']} GB/s achieved — "
                f"the speedup is moved-bytes, not a faster pipe.")
    return "\n".join(lines)


def _load_cells_safe():
    try:
        from benchmarks.common import load_cells
        return load_cells("single")
    except Exception as e:  # corrupt artifact != absent artifact: say so
        print(f"# WARNING: dry-run artifacts unreadable ({e!r}); "
              "falling back to synthetic timelines")
        return {}


HEADER = """# EXPERIMENTS

All numbers are machine-generated from committed artifacts:
`artifacts/dryrun/*` (baseline sweep), `artifacts/dryrun_v2/*` (optimized
sweep), regenerate with `PYTHONPATH=src python -m benchmarks.make_experiments`.
The power-matrix sections run through the declarative Study API
(`repro.api`: declare -> run -> query; see README for the engine-call
migration table); raw engine functions remain the compile target.
Hardware target: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI
(assignment constants). This container is CPU-only: every cell is
lower+compile (XLA SPMD, 512 host devices), never executed at scale.

## Methodology notes (§Dry-run)

* **Compile proof.** Every (arch x shape x mesh) cell lowers AND compiles
  via `jax.jit(...).lower().compile()` on the 16x16 (single-pod, 256 chips)
  and 2x16x16 (multi-pod, 512 chips) meshes. 64/64 cells pass in both
  sweeps (8 archs x 3 shapes + rwkv6/jamba x 4 shapes, x 2 meshes);
  long_500k is skipped for the 8 pure full-attention archs per the
  assignment (DESIGN.md §Shape-coverage).
* **FLOPs/bytes.** `compiled.cost_analysis()` counts XLA while-loop bodies
  ONCE (verified: a scan of 8 matmuls reports the FLOPs of 1), silently
  dropping the x n_layers factor. We therefore compute exact global FLOPs
  by walking the step jaxpr and multiplying every scan body by its trip
  count (validated within 4% of a fully-unrolled compile of
  granite/train_4k: 2.93e14 vs 3.05e14 FLOPs/chip). Bytes use the same
  walk with a fusion-aware model (layout ops free, elementwise one write,
  VMEM-resident scan carries refunded, Pallas kernel internals free).
* **Collective bytes.** Parsed from the *partitioned* HLO with while-loop
  trip multipliers recovered from `known_trip_count` backend configs;
  ring-algorithm wire factors (all-reduce 2x). Validated within 3% of the
  unrolled compile.
* **Memory.** `memory_analysis()` argument/temp bytes + an exact
  sharding-derived state-bytes-per-device (the two agree bit-exactly on
  alias size). Train cells use grad accumulation (8 microbatches) and full
  remat so residuals fit v5e HBM; >=100B archs use bf16 params+moments
  (MOMENT_DTYPE table in launch/dryrun.py).
* **train_step** is lowered for train shapes; **serve_step** (single token
  against a seq_len KV cache) for decode shapes; **prefill_step** for
  prefill shapes — per the assignment.

"""

PERF_LOG = """
## §Perf — hypothesis -> change -> measure log

Three hillclimbed cells (selection per assignment): **dbrx-132b/train_4k**
(most collective-bound: 1020 s/step of wire time at baseline),
**granite-3-8b/train_4k** (most representative of the paper's workload —
dense bulk-synchronous LLM training, the Fig. 1 job), and
**qwen1.5-110b/prefill_32k** (worst roofline fraction among large dense
cells; memory-dominant).

### Iteration 1 — q-block the online-softmax attention (REFUTED, then root-caused)
* **Hypothesis.** The memory term of qwen/prefill_32k (29.1 s) is dominated
  by the flash-scan f32 accumulators ([B,H,S,D] = 268 MB/chip, rewritten to
  HBM on each of 32 KV chunks). Blocking q to 2048 keeps them VMEM-sized;
  expected memory term ~-70%.
* **Change.** `_q_chunked_sdpa` (outer q-block scan).
* **Measured.** memory 29.06 s -> 29.26 s: *no change*. Refuted.
* **Lesson.** The byte model charged accumulator traffic per (q,kv) block —
  total unchanged under blocking. Instrumentation refined: scan carries that
  fit VMEM are refunded (hlo_analysis.py). Re-measured: 29.06 -> 28.43 s —
  still flat, which localized the real cost: 94% of bytes were the
  **score-chain intermediates** (dot -> sub/exp/select -> dot), which pure-XLA
  TPU *does* materialize between kernels. The fix needs a fused kernel, not
  blocking.
* **Kept:** q-blocking (it is the grid structure the kernel needs).

### Iteration 2 — Pallas flash-attention kernel (CONFIRMED, 82x)
* **Hypothesis.** Fusing QK^T -> online-softmax -> PV into one Pallas kernel
  keeps scores in VMEM; HBM traffic drops to the q/k/v/out block streams:
  qwen prefill attention bytes ~5.9e15 -> ~7e13 (napkin: 80 layers x
  (q+k+v+out) streams).
* **Change.** `kernels/flash/` (pl.pallas_call, grid=(B*KV, S/2048), online
  softmax fori over 1024-wide KV chunks in VMEM; interpret-mode validated
  vs the dense oracle, err < 5e-7). Cost model walks kernel-body dots x grid.
* **Measured.** qwen/prefill bytes 5.96e15 -> 7.39e13 (**-98.8%**); memory
  term 29.06 s -> 0.35 s; granite/prefill 7.24 s -> 0.06 s. Dominant term
  flips memory -> collective. Confirmed.

### Iteration 3 — pin pre-duplication K/V sharding (CONFIRMED, -16..38% collectives)
* **Hypothesis.** The SPMD partitioner warned "involuntary full
  rematerialization" on K/V: the decode-cache's sequence sharding
  back-propagates into k before kv-head duplication, forcing a full
  all-gather of K/V per layer. Pinning pre-dup K/V to batch-only sharding
  makes the duplication a local slice. Expected: remove ~T x KV x D x
  layers gather bytes (qwen prefill: ~0.4e12 of 1.1e12 B).
* **Change.** `constrain(k, "kv_pre")` before `jnp.repeat` (attention.py).
* **Measured.** collective B/chip: qwen prefill 1.11e12 -> 7.24e11 (-35%),
  granite train 7.90e11 -> 6.60e11 (-16%), qwen train 3.25e12 -> 2.69e12
  (-17%), granite prefill 2.84e11 -> 1.76e11 (-38%). Warning gone. Confirmed.

### Iteration 4 — free the MoE activation placement (CONFIRMED, 5.1x)
* **Hypothesis.** dbrx train's 5.1e13 B/chip collectives are GSPMD
  reshards: forcing the [E,C,d] dispatch buffers onto the EP axis makes the
  token scatter/gather lower as full-buffer all-reduces. Removing the
  activation constraints (weights stay EP-sharded) lets the partitioner
  route via collective-permute.
* **Change.** `expert_buf`/`expert_hidden` roles -> unconstrained
  (parallel/sharding.py).
* **Measured.** dbrx/train collectives 5.10e13 -> 9.99e12 B/chip
  (**-80%**, all-reduce 5.02e13 -> 9.15e12); collective term 1020 s ->
  200 s; roofline fraction 0.004 -> ~0.02. Confirmed.

### Iteration 5 — microbatch/remat sweep on granite train (REFUTED, bounded the problem)
* **Hypothesis.** The residual granite collective term (13.2 s) is TP
  activation all-reduces; fewer microbatches (8 -> 2) should cut it ~4x
  (fewer accumulation passes).
* **Measured.** (mb, remat) sweep: (8,full) 13.2 s / 11.5 GB temp; (2,full)
  11.7 s / 42 GB; (8,dots) 11.5 s / 32 GB; (2,dots) 10.0 s / 126 GB.
  Refuted: AR wire bytes are proportional to *tokens*, invariant to
  microbatching (fewer-but-4x-larger payloads). Only the remat *replay* of
  forward ARs (-15%) and FSDP gathers (-50%) moved.
* **Lesson.** The TP-AR floor (~4.6e11 B/chip) is structural to Megatron
  TP at this batch; attacking it requires a different plan, not tuning.

### Iteration 6 — pure-FSDP plan for <=20B dense archs (REFUTED by GSPMD)
* **Hypothesis.** For granite (8B), drop TP entirely on train: batch 256
  over all 256 chips, weights FSDP over both axes. Napkin: weight gathers
  ~1.3e11 B/chip/step vs the 4.6e11 TP-AR -> collective term 13.2 -> ~4 s.
* **Change.** `make_plan(..., pure_fsdp=True)`: dp=(data,model),
  fsdp=(data,model), tp=None; microbatches forced to 1 (one seq/chip).
* **Measured.** collectives EXPLODED to 2.74e13 B (552 s), temp 2.3 TB:
  GSPMD lowers the batch-and-weights-on-the-same-axes pattern through
  "involuntary full rematerialization" (XLA b/433785288) — several ops
  replicate fully before resharding. Refuted *for this partitioner*; the
  plan is kept opt-in to re-test under Shardy. Debugging forward per the
  methodology: the first remat warning fires on a [32,4096,16] loss-chunk
  tensor, i.e. the CE scan's seq slicing conflicts with d_model sharded
  over the same axes.

### Iteration 7 — shard_map expert parallelism (CONFIRMED, 7.2x on top of #4)
* **Hypothesis.** After iteration 4, dbrx train still moved 1.0e13 B/chip:
  GSPMD cannot see that activations are already replicated over "model", so
  its token dispatch re-shuffles full buffers. A shard_map MoE exploiting
  that replication — each expert shard locally selects/computes its tokens,
  one psum of [tokens, d] combines — should cost exactly one dense-TP
  all-reduce per layer: napkin ~2.5e10 B/layer-pass -> ~1.4e12 B/step.
* **Change.** `moe_forward_shardmap` (models/moe.py): local sort-based
  capacity dispatch per expert shard, FSDP all_gather of local expert
  weights, psum combine over "model". Validated vs the dense oracle on a
  2x4 simulated mesh (err < 1e-6) incl. gradients (tests/test_moe_shardmap).
* **Measured.** dbrx/train collectives 1.00e13 -> 1.397e12 B/chip (term
  200 s -> 27.9 s; **36x from the 1020 s baseline**; roofline fraction
  0.004 -> 0.16). deepseek/train 2.2e12 -> 3.5e11 (48x vs its baseline);
  jamba/train 8.4e11. Decode cells measured 2.4x WORSE under shard_map
  (tiny token counts don't amortize the full-layer psum) — decode keeps
  the GSPMD path; recorded in serve/engine.py.

### Iterations attempted but not landed (napkin-math, next levers)
* **Megatron-style sequence parallelism** for the dense train cells: the
  residual all-reduce is TP activation-grad traffic (~5.8e11 B/chip on
  granite); SP converts each all-reduce into RS+AG over S, ~TP/2 x less
  per-chip wire -> predicted collective term 13.2 s -> ~2 s, fraction
  0.08 -> ~0.4. Invasive (norms over sharded S); next on the list.
* **shard_map all-to-all MoE dispatch**: explicit a2a would cut dbrx's
  remaining 9.9e12 B to ~2 orders less (tokens x d x k/E per hop); the
  GSPMD-free-placement result above is the low-risk half of that win.
* **int8 error-feedback gradient compression** is integrated as a
  first-class DP trainer variant (`make_dp_compressed_train_step`,
  validated on an 8-way simulated mesh: converges within 0.01 of exact at
  3.9x less gradient wire — tests/test_moe_shardmap.py). For the
  FSDP+TP cells its benefit is limited to the pod-axis gradient reduce.
* **Multi-link ICI accounting**: the roofline charges 1 of 4 ICI links
  (assignment formula). Real v5e rings stripe over 4 links; wall-clock
  collective terms are ~4x lower than tabled. Reported conservatively.

### Stopping rule
Hillclimbing stopped on the assignment's three-cell budget; the last two
iterations moved the dominant term 35-80% each, still >5% — further
iterations (SP, a2a MoE) are enumerated above with predicted wins.
"""


def main():
    base_single = load(BASE, "single")
    opt_single = load(OPT, "single")
    opt_all = load(OPT)
    base_all = load(BASE)

    lines = [HEADER]
    lines.append("## §Dry-run — optimized sweep (single + multi pod)\n")
    lines.append(f"Cells compiled OK: baseline {len(base_all)}/64, "
                 f"optimized {len(opt_all)}/64.\n")
    lines.append(dryrun_table(opt_all))

    lines.append("\n\n## §Roofline — per (arch x shape), single-pod 256 chips"
                 " (optimized system)\n")
    lines.append("Terms in seconds/step; roofline fraction = useful-compute "
                 "time (MODEL_FLOPS = 6·N_active·D train / 2·N_active·tokens "
                 "inference) over the dominant term. 'useful' = MODEL_FLOPS/"
                 "HLO_FLOPS (remat + causal-chunk waste shows here).\n")
    t, agg = roofline_table(opt_single)
    lines.append(t)

    doms = {}
    for r in agg:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    lines.append(f"\nDominant-term census: {doms}. The fleet-level picture "
                 "matches the paper's premise: bulk-synchronous training is "
                 "communication-phase-bound, which is exactly what creates "
                 "the power troughs the paper mitigates; `core/phases.py` "
                 "consumes these same numbers to synthesize each arch's "
                 "waveform.\n")

    lines.append("\n## §Perf — baseline vs optimized (hillclimbed cells)\n")
    lines.append(perf_rows([
        "dbrx-132b__train_4k__single",
        "granite-3-8b__train_4k__single",
        "qwen1.5-110b__prefill_32k__single",
    ]))
    lines.append(PERF_LOG)
    lines.append(power_sweep_section())
    lines.append(streaming_section())
    lines.append(design_section())
    lines.append(serve_section())
    lines.append(control_section())
    lines.append(kernels_section())

    lines.append("""
## Paper-claims validation (benchmarks, `python -m benchmarks.run`)

| claim (paper) | reproduced | where |
|---|---|---|
| power swings between near-TDP compute and near-idle comm phases (Fig 1) | swing fraction 0.5-0.7 of peak across archs, phase timelines derived per arch from compiled cells | fig1 |
| accelerators >50% of server power (Fig 2) | chip share 71.5% | fig2 |
| FFT energy concentrated 0.2-3 Hz (Fig 3) | calibrated waveform: >50% in band; per-arch reports | fig3 |
| GB200 smoothing phases: ramp-up / steady / stop-delay / ramp-down (Fig 5) | stop-delay hold measured 3.0 s at MPF=65% | fig5 |
| MPF=90% on the production waveform costs ~10.5% energy (Fig 6) | measured 10.6% on the calibrated waveform (within 0.2 pt) | fig6 |
| storage smooths without wasting energy (Fig 7) | overhead 0.3%, swing -85%, SoC within bounds | fig7 |
| Firefly <5% perf overhead, reaches 100% TDP | perf 0-4%, reaches TDP | table1/firefly |
| tightest specs unreachable by GPU smoothing alone (10% dyn range at MPF<=90%) | gpu_smoothing fails tight spec; combined passes | table1 |
| solution-comparison orderings (Table I) | all asserted quantitatively | table1 |
""")
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as fh:
        fh.write("\n".join(lines))
    print("wrote", out)


if __name__ == "__main__":
    main()

"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs / (chips * 197e12)            [s/step]
  memory term     = HLO_bytes / (chips * 819e9)             [s/step]
  collective term = per-chip collective bytes / 50e9        [s/step]
(FLOPs/bytes are the jaxpr-exact global counts — launch/hlo_analysis.py —
divided per chip; collective bytes come from the partitioned HLO with
while-loop trip multipliers, already per chip.)

Also: MODEL_FLOPS (6*N*D train / 2*N_active*tokens inference), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPS, the dominant term, a roofline
fraction (useful compute time / dominant term = the score), and a
suggestion for the dominant bottleneck. Emits CSV + artifacts/roofline.json.
"""
from __future__ import annotations

import json
import os
from typing import Dict

from benchmarks.common import emit, load_cells
from repro.configs import get_config

PEAK = 197e12
HBM = 819e9
LINK = 50e9

SUGGEST = {
    "compute": ("cut non-useful FLOPs: triangular-chunk attention schedule, "
                "remat policy 'dots' instead of 'full'"),
    "memory": ("raise arithmetic intensity: larger microbatch per pass, fuse "
               "loss chunks, widen attention KV chunks"),
    "collective": ("reshard: sequence-parallel activations to turn TP "
                   "all-reduces into reduce-scatter+all-gather; overlap "
                   "grad reduce with ballast/compute; int8 grad compression"),
}


def model_flops(cell: Dict) -> float:
    cfg = get_config(cell["arch"])
    n_act = cell["active_params"]
    if cell["kind"] == "train":
        tokens = 4096 * 256
        return 6.0 * n_act * tokens
    if cell["kind"] == "prefill":
        tokens = 32768 * 32
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    bsz = {"decode_32k": 128, "long_500k": 1}[cell["shape"]]
    return 2.0 * n_act * bsz


def analyze(cell: Dict) -> Dict:
    chips = cell["n_chips"]
    t_comp = cell["exact"]["flops"] / chips / PEAK
    t_mem = cell["exact"]["bytes"] / chips / HBM
    coll = sum(cell.get("collectives", {}).values())
    t_coll = coll / LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cell)
    useful_t = mf / chips / PEAK
    frac = useful_t / max(terms[dom], 1e-30)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": cell["kind"], "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / cell["exact"]["flops"],
        "roofline_fraction": frac,
        "hbm_state_gb": cell["memory"]["state_bytes_per_device"] / 1e9,
        "suggestion": SUGGEST[dom],
    }


def main() -> None:
    rows = []
    for mesh in ("single", "multi"):
        for key, cell in sorted(load_cells(mesh).items()):
            r = analyze(cell)
            rows.append(r)
            if mesh == "single":  # the roofline table is single-pod only
                emit(f"roofline/{key}", 0.0, {
                    "comp_s": f"{r['t_compute_s']:.4f}",
                    "mem_s": f"{r['t_memory_s']:.4f}",
                    "coll_s": f"{r['t_collective_s']:.4f}",
                    "dom": r["dominant"],
                    "useful": f"{r['useful_ratio']:.3f}",
                    "roofline_frac": f"{r['roofline_fraction']:.3f}"})
    out = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    emit("roofline/written", 0.0, {"cells": len(rows), "path": out})


if __name__ == "__main__":
    main()

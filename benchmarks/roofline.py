"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs / (chips * 197e12)            [s/step]
  memory term     = HLO_bytes / (chips * 819e9)             [s/step]
  collective term = per-chip collective bytes / 50e9        [s/step]
(FLOPs/bytes are the jaxpr-exact global counts — launch/hlo_analysis.py —
divided per chip; collective bytes come from the partitioned HLO with
while-loop trip multipliers, already per chip.)

Also: MODEL_FLOPS (6*N*D train / 2*N_active*tokens inference), the
useful-compute ratio MODEL_FLOPS/HLO_FLOPS, the dominant term, a roofline
fraction (useful compute time / dominant term = the score), and a
suggestion for the dominant bottleneck. Emits CSV + artifacts/roofline.json.

``--kernels`` is the hot-path cost regression gate (the CI mode): it
re-derives the jaxpr-exact FLOPs/bytes of the serve and backstop hot
kernels at fixed reference shapes, asserts each stays inside its
recorded budget (these counts are deterministic, so a budget breach
means someone made the kernel do more work), and merges the counts into
``BENCH_kernels.json`` under ``"per_kernel"``.  It also derives the
measured-bandwidth section (``"measured_bandwidth"``): jaxpr-exact bytes
moved by the fused v2 monitor vs the two-pass jnp path at the 1e6-sample
benchmark shape, divided by the wall times ``kernels_bench`` recorded —
so the before/after roofline shows the fused speedup is bytes-moved,
not just wall-clock.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

from benchmarks.common import emit, load_cells
from repro.configs import get_config

PEAK = 197e12
HBM = 819e9
LINK = 50e9

KERNELS_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_kernels.json")

# jaxpr-exact costs at the reference shapes below, with ~20% headroom;
# deterministic, so a breach = the hot path genuinely got heavier.
# sliding_goertzel moved to the lane-major v2 Pallas kernel: per-cell
# body FLOPs are counted once per grid step (hence the higher FLOPs
# budget vs the old jnp-cumsum path), but HBM traffic collapsed from
# 32.1e6 to 3.6e6 bytes — the kernel streams operand blocks and keeps
# the [S, win, K] intermediates in VMEM.  monitor_fused adds the
# in-kernel worst-bin/classify reduction + blocked escalation scan on
# top and still never materializes per-sample amplitudes.
KERNEL_BUDGETS = {
    "sliding_goertzel": {"max_flops": 13.0e6, "max_bytes": 4.3e6},
    "monitor_fused": {"max_flops": 24.0e6, "max_bytes": 21.9e6},
    "goertzel_fingerprint": {"max_flops": 0.73e6, "max_bytes": 1.8e6},
    "warmstart_mlp": {"max_flops": 0.78e6, "max_bytes": 0.28e6},
    "ballast": {"max_flops": 10.4e9, "max_bytes": 103.2e6},
}

# pinned jaxpr primitive histograms (repro.analysis Tier-2 registry,
# inner scan/cond/pallas bodies included).  FLOPs/bytes budgets have
# headroom, so a fusion regression that swaps cheap primitives for a
# materializing pattern can hide under them — the exact primitive mix
# cannot drift silently: any change fails with a named per-primitive
# diff.  The ballast burner has no Tier-2 entry (its geometry is gated
# by the Tier-3 kernel checks); it stays FLOPs/bytes-only here.
KERNEL_PRIMITIVES = {
    "sliding_goertzel": ("kernels.sliding_bin_power", {
        "add": 20, "broadcast_in_dim": 6, "concatenate": 10, "cond": 1,
        "convert_element_type": 2, "cumsum": 8, "device_put": 3, "div": 2,
        "eq": 1, "get": 30, "iota": 2, "min": 1, "mul": 42, "neg": 4,
        "pallas_call": 1, "pjit": 9, "program_id": 1, "reduce_sum": 1,
        "reshape": 2, "slice": 25, "sqrt": 4, "sub": 13, "swap": 16}),
    "monitor_fused": ("kernels.monitor_fused", {
        "add": 35, "and": 17, "broadcast_in_dim": 16, "concatenate": 11,
        "cond": 2, "convert_element_type": 21, "cumsum": 8, "device_put": 3,
        "div": 4, "eq": 8, "ge": 6, "get": 33, "gt": 7, "iota": 5, "le": 2,
        "lt": 5, "max": 5, "min": 5, "mul": 46, "ne": 5, "neg": 4, "not": 4,
        "pallas_call": 1, "program_id": 1, "pjit": 39, "reduce_and": 2,
        "reduce_max": 6, "reduce_sum": 2, "rem": 2, "reshape": 6, "scan": 2,
        "select_n": 27, "sign": 4, "slice": 30, "sqrt": 4, "squeeze": 2,
        "sub": 28, "swap": 19}),
    "goertzel_fingerprint": ("serve.fingerprint", {
        "add": 1, "div": 2, "dot_general": 2, "mul": 3, "reduce_sum": 1,
        "sqrt": 1, "sub": 1}),
    "warmstart_mlp": ("serve.warmstart_mlp", {
        "add": 3, "broadcast_in_dim": 1, "concatenate": 1, "dot_general": 4,
        "integer_pow": 1, "mul": 4, "tanh": 1}),
}

SUGGEST = {
    "compute": ("cut non-useful FLOPs: triangular-chunk attention schedule, "
                "remat policy 'dots' instead of 'full'"),
    "memory": ("raise arithmetic intensity: larger microbatch per pass, fuse "
               "loss chunks, widen attention KV chunks"),
    "collective": ("reshard: sequence-parallel activations to turn TP "
                   "all-reduces into reduce-scatter+all-gather; overlap "
                   "grad reduce with ballast/compute; int8 grad compression"),
}


def model_flops(cell: Dict) -> float:
    cfg = get_config(cell["arch"])
    n_act = cell["active_params"]
    if cell["kind"] == "train":
        tokens = 4096 * 256
        return 6.0 * n_act * tokens
    if cell["kind"] == "prefill":
        tokens = 32768 * 32
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    bsz = {"decode_32k": 128, "long_500k": 1}[cell["shape"]]
    return 2.0 * n_act * bsz


def analyze(cell: Dict) -> Dict:
    chips = cell["n_chips"]
    t_comp = cell["exact"]["flops"] / chips / PEAK
    t_mem = cell["exact"]["bytes"] / chips / HBM
    coll = sum(cell.get("collectives", {}).values())
    t_coll = coll / LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(cell)
    useful_t = mf / chips / PEAK
    frac = useful_t / max(terms[dom], 1e-30)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": cell["kind"], "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": mf / cell["exact"]["flops"],
        "roofline_fraction": frac,
        "hbm_state_gb": cell["memory"]["state_bytes_per_device"] / 1e9,
        "suggestion": SUGGEST[dom],
    }


def kernel_costs() -> Dict[str, Dict[str, float]]:
    """jaxpr-exact FLOPs/bytes of the serve + backstop hot kernels at
    fixed reference shapes: the backstop's lane-major v2 sliding
    Goertzel kernel and its fused worst-bin/escalation monitor
    (1e5-sample trace, 2000-sample window, 4 bins), the serve feature
    extractor's spectral fingerprint (2e4 samples, 7 grid-critical
    bins), the warm-start MLP (batch 64), and the ballast burn tile
    (1024x256x256, 64 iterations)."""
    import jax
    import jax.numpy as jnp

    from repro.core.spectrum import GRID_CRITICAL_HZ, goertzel_bin_amplitudes_jax
    from repro.kernels.ballast.ref import ballast_ref
    from repro.kernels.goertzel.ops import (sliding_bin_power,
                                            sliding_monitor_fused)
    from repro.launch.hlo_analysis import jaxpr_costs
    from repro.serve.warmstart import (N_FEATURES, init_warmstart,
                                       warmstart_forward)

    x = jnp.zeros(100_000, jnp.float32)
    xf = jnp.zeros(20_000, jnp.float32)
    params = init_warmstart(jax.random.PRNGKey(0))
    xb = jnp.zeros((64, N_FEATURES), jnp.float32)
    a = jnp.zeros((1024, 256), jnp.float32)
    b = jnp.zeros((256, 256), jnp.float32)
    costs = {
        "sliding_goertzel": jaxpr_costs(
            lambda x: sliding_bin_power(x, 0.001, (0.5, 1.0, 2.0, 9.0),
                                        win=2000, interpret=True), x),
        "monitor_fused": jaxpr_costs(
            lambda x: sliding_monitor_fused(
                x, 0.001, (0.5, 1.0, 2.0, 9.0), win=2000,
                threshold=jnp.float32(1e6), sustain_n=50, cool_n=80,
                interpret=True), x),
        "goertzel_fingerprint": jaxpr_costs(
            lambda x: goertzel_bin_amplitudes_jax(x, 0.002,
                                                  GRID_CRITICAL_HZ), xf),
        "warmstart_mlp": jaxpr_costs(warmstart_forward, params, xb),
        "ballast": jaxpr_costs(lambda a, b: ballast_ref(a, b, 64), a, b),
    }
    for name, c in costs.items():
        c["intensity_flops_per_byte"] = round(c["flops"] / c["bytes"], 3)
    return costs


def check_primitives() -> Dict[str, Dict[str, int]]:
    """Assert the registered hot paths' jaxpr primitive mixes match the
    pinned histograms; a mismatch fails with a named primitive diff."""
    from repro.analysis.jaxpr_checks import primitive_counts, primitive_diff
    from repro.analysis.registry import ENTRY_BY_NAME

    got_all: Dict[str, Dict[str, int]] = {}
    failures = []
    for name, (entry, expected) in KERNEL_PRIMITIVES.items():
        got = dict(primitive_counts(ENTRY_BY_NAME[entry]))
        got_all[name] = dict(sorted(got.items()))
        diff = primitive_diff(expected, got)
        if diff:
            failures.append(f"{name} ({entry}):\n    " + "\n    ".join(diff))
        emit(f"roofline/prims_{name}", 0.0, {
            "primitives": sum(got.values()), "distinct": len(got),
            "drift": len(diff)})
    assert not failures, (
        "hot-path primitive-mix regression (fusion structure changed; "
        "re-pin KERNEL_PRIMITIVES only if the change is intentional):\n  "
        + "\n  ".join(failures))
    return got_all


def measured_bandwidth(merged: Dict) -> Dict:
    """The before/after roofline for the monitor fusion, at the exact
    shape ``kernels_bench`` times (1e6 samples, win=8000, 4 bins): derive
    the jaxpr-exact bytes each monitor arm moves — the fused v2 Pallas
    path (worst/levels straight from VMEM) vs the two-pass jnp path
    (materialize the [n, K] amplitude matrix, then a separate
    amps -> escalation scan) — and divide by the wall times recorded in
    ``BENCH_kernels.json`` to get achieved bandwidth.  Matching achieved
    GB/s with ~2x fewer bytes is the attribution the fused speedup
    claims: less data moved, not a faster pipe."""
    import jax.numpy as jnp

    from benchmarks.kernels_bench import _monitor_two_pass
    from repro.kernels.goertzel.ops import sliding_monitor_fused
    from repro.launch.hlo_analysis import jaxpr_costs

    n, win, freqs = 1_000_000, 8000, (0.5, 1.0, 2.0, 9.0)
    thr, rel = jnp.float32(2e5), jnp.float32(1.5e5)
    sustain_n, cool_n = max(win // 40, 1), max(win // 25, 1)
    x = jnp.zeros(n, jnp.float32)
    fused = jaxpr_costs(
        lambda x: sliding_monitor_fused(
            x, 0.001, freqs, win=win, threshold=thr, release=rel,
            sustain_n=sustain_n, cool_n=cool_n, interpret=True), x)
    two_pass = jaxpr_costs(
        lambda x: _monitor_two_pass(
            x, dt=0.001, freqs=freqs, win=win, threshold=thr, release=rel,
            sustain_n=sustain_n, cool_n=cool_n, interpret=True,
            use_jnp_amps=True), x)
    fm = merged.get("fused_monitor", {})
    out = {
        "shape": {"n_samples": n, "win": win, "bins": len(freqs)},
        "fused_bytes": fused["bytes"],
        "two_pass_jnp_bytes": two_pass["bytes"],
        "bytes_ratio_two_pass_over_fused":
            round(two_pass["bytes"] / fused["bytes"], 2),
    }
    for arm, bts, key in (("fused", fused["bytes"], "pallas_ms"),
                          ("two_pass_jnp", two_pass["bytes"], "jnp_path_ms")):
        ms = fm.get(key)
        if ms:                      # wall times come from the full bench run
            out[f"{arm}_wall_ms"] = ms
            out[f"{arm}_achieved_gb_per_s"] = round(bts / (ms / 1e3) / 1e9, 3)
    emit("roofline/measured_bandwidth", 0.0, {
        "bytes_ratio": out["bytes_ratio_two_pass_over_fused"],
        "fused_gbps": out.get("fused_achieved_gb_per_s", "n/a"),
        "two_pass_gbps": out.get("two_pass_jnp_achieved_gb_per_s", "n/a")})
    return out


def check_kernels() -> None:
    """Derive the hot-kernel costs, gate them against the budgets and the
    pinned primitive mixes (a breach fails CI), merge into
    BENCH_kernels.json."""
    costs = kernel_costs()
    failures = []
    for name, c in costs.items():
        budget = KERNEL_BUDGETS[name]
        if c["flops"] > budget["max_flops"]:
            failures.append(f"{name}: flops {c['flops']:.3g} > budget "
                            f"{budget['max_flops']:.3g}")
        if c["bytes"] > budget["max_bytes"]:
            failures.append(f"{name}: bytes {c['bytes']:.3g} > budget "
                            f"{budget['max_bytes']:.3g}")
        emit(f"roofline/kernel_{name}", 0.0, {
            "flops": f"{c['flops']:.4g}", "bytes": f"{c['bytes']:.4g}",
            "intensity": c["intensity_flops_per_byte"]})
    assert not failures, "hot-path cost regression:\n  " + \
        "\n  ".join(failures)
    prims = check_primitives()

    merged: Dict = {}
    if os.path.exists(KERNELS_OUT):
        with open(KERNELS_OUT) as fh:
            merged = json.load(fh)
    merged["per_kernel"] = costs
    merged["per_kernel_primitives"] = prims
    merged["measured_bandwidth"] = measured_bandwidth(merged)
    with open(KERNELS_OUT, "w") as fh:
        json.dump(merged, fh, indent=2)
        fh.write("\n")
    print(f"kernels OK: {len(costs)} hot paths inside budget, "
          f"{len(prims)} primitive mixes pinned; merged into "
          f"{os.path.abspath(KERNELS_OUT)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernels", action="store_true",
                    help="hot-path FLOPs/bytes regression gate (CI mode); "
                         "skips the dry-run roofline table")
    args = ap.parse_args()
    if args.kernels:
        check_kernels()
        return
    rows = []
    for mesh in ("single", "multi"):
        for key, cell in sorted(load_cells(mesh).items()):
            r = analyze(cell)
            rows.append(r)
            if mesh == "single":  # the roofline table is single-pod only
                emit(f"roofline/{key}", 0.0, {
                    "comp_s": f"{r['t_compute_s']:.4f}",
                    "mem_s": f"{r['t_memory_s']:.4f}",
                    "coll_s": f"{r['t_collective_s']:.4f}",
                    "dom": r["dominant"],
                    "useful": f"{r['useful_ratio']:.3f}",
                    "roofline_frac": f"{r['roofline_fraction']:.3f}"})
    out = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    emit("roofline/written", 0.0, {"cells": len(rows), "path": out})


if __name__ == "__main__":
    main()

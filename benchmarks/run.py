"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV lines (see each module's docstring
for the paper mapping). Run: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (design_bench, fig1_waveform, fig2_breakdown,
                        fig3_fft, fig5_squarewave, fig6_mpf, fig7_battery,
                        kernels_bench, roofline, sweep_bench, table1_matrix)

MODULES = [
    ("fig1", fig1_waveform),
    ("fig2", fig2_breakdown),
    ("fig3", fig3_fft),
    ("fig5", fig5_squarewave),
    ("fig6", fig6_mpf),
    ("fig7", fig7_battery),
    ("table1", table1_matrix),
    ("sweep", sweep_bench),
    ("design", design_bench),
    ("kernels", kernels_bench),
    ("roofline", roofline),
]


def main() -> None:
    failures = []
    for name, mod in MODULES:
        print(f"# --- {name}: {mod.__doc__.strip().splitlines()[0]}")
        try:
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()

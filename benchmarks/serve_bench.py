"""Amortized serve path — BENCH_serve.json.

Measures the three serve-layer amortization levels against the cold
solver they replace:

  warm-start design   ``design(method="warmstart")`` (learned seed +
                      one vmapped hard ladder evaluation) vs the full
                      ``hybrid`` solver, cold (incl. compile) and warm;
                      verdicts must be identical and every answer hard
                      tau=0 re-validated.
  answer cache        repeated-query latency through the service's
                      lock-protected LRU (p50/p99), plus single-flight:
                      N identical concurrent queries -> ONE Study run.
  coalescing + reuse  ``query_many`` fusing N distinct misses into one
                      streaming execution, and the (length, spec family,
                      structure) jit keying holding the compiled-
                      executable count flat across new fleet sizes and
                      spec thresholds.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]

Hard invariants (asserted, also under ``--smoke``): warm-started and
hybrid designs agree on feasibility and both pass the spec; cache-hit
p50 is sub-millisecond; N identical concurrent queries run the Study
exactly once; N distinct coalesced queries run it exactly once; no new
executables compile when fleet size or spec thresholds change.  The
full run additionally asserts warm warm-start design is >= 5x faster
than the cold hybrid solve it amortizes.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time
from typing import Dict

import numpy as np

import repro.core as core
from repro.core import engine
from repro.serve.power import PowerComplianceService
from benchmarks.common import emit
from benchmarks.warmstart_data import build_dataset, sweep_scenarios

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
N_CHIPS = 512


def train_predictor(cfg, epochs: int):
    """A small predictor trained on the 4-cell sweep (the bench needs a
    representative warm-start, not a production one)."""
    from repro.serve.warmstart import train_warmstart
    X, Y, _ = build_dataset(sweep_scenarios(smoke=True), cfg, verbose=False)
    pred, hist = train_warmstart(X, Y, epochs=epochs)
    return pred, float(hist["loss"][-1])


def bench_design(cfg, pred, smoke: bool) -> Dict:
    """Cold/warm hybrid vs warm-start on a sweep-adjacent problem, with
    verdict parity."""
    tl = core.synthetic_timeline(period_s=1.8, comm_frac=0.28)
    w = core.aggregate(core.chip_waveform(tl, cfg), N_CHIPS, cfg)
    spec = core.example_specs(job_mw=float(w.mean()) / 1e6)["tight"]

    t0 = time.perf_counter()
    sol_h = engine.design(spec, w, cfg.dt, N_CHIPS, method="hybrid")
    cold_h = time.perf_counter() - t0
    t0 = time.perf_counter()
    sol_h = engine.design(spec, w, cfg.dt, N_CHIPS, method="hybrid")
    warm_h = time.perf_counter() - t0

    t0 = time.perf_counter()
    sol_w = engine.design(spec, w, cfg.dt, N_CHIPS, method="warmstart",
                          warmstart=pred)
    cold_w = time.perf_counter() - t0
    t0 = time.perf_counter()
    sol_w = engine.design(spec, w, cfg.dt, N_CHIPS, method="warmstart",
                          warmstart=pred)
    warm_w = time.perf_counter() - t0

    # verdict parity: the warm-start path must agree with the solver it
    # amortizes, and both answers carry a hard tau=0 validation report
    assert (sol_h is None) == (sol_w is None), \
        "warmstart and hybrid disagree on feasibility"
    assert sol_h is not None and sol_h["report"].ok and sol_w["report"].ok, \
        "a returned design failed hard re-validation"
    if not smoke:
        assert warm_w * 5.0 <= cold_h, (
            f"warm warmstart {warm_w:.3f}s not >=5x faster than cold "
            f"hybrid {cold_h:.3f}s")
    emit("serve/design_hybrid", warm_h * 1e6, {"cold_s": round(cold_h, 2)})
    emit("serve/design_warmstart", warm_w * 1e6,
         {"cold_s": round(cold_w, 2), "path": sol_w["aux"]["warmstart_path"]})
    return {
        "hybrid": {"cold_s": round(cold_h, 3), "warm_s": round(warm_h, 3),
                   "energy_overhead": round(sol_h["energy_overhead"], 5)},
        "warmstart": {"cold_s": round(cold_w, 3), "warm_s": round(warm_w, 3),
                      "energy_overhead": round(sol_w["energy_overhead"], 5),
                      "path": sol_w["aux"]["warmstart_path"]},
        "speedup_warm_vs_cold_hybrid": round(cold_h / warm_w, 1),
        "speedup_warm_vs_warm_hybrid": round(warm_h / warm_w, 1),
    }


def bench_service(cfg, smoke: bool) -> Dict:
    """Cache-hit latency, single-flight, coalescing, compiled reuse.

    ``stream_chunk=4`` = the 4-config catalog row count, so single and
    coalesced executions share one compiled batch shape and the
    executable-count assertion isolates *content* changes (fleet size,
    spec thresholds, workloads) from batch-shape changes."""
    svc = PowerComplianceService(wave_cfg=cfg, mpf_grid=(0.8,),
                                 cap_fracs=(1.0,), stream_chunk=4)
    tl = core.synthetic_timeline(period_s=1.0, comm_frac=0.25)
    svc.query(tl, N_CHIPS, "moderate")          # populate

    reps = 50 if smoke else 300
    lat = []
    for _ in range(reps):
        t0 = time.perf_counter()
        svc.query(tl, N_CHIPS, "moderate")
        lat.append(time.perf_counter() - t0)
    p50 = float(np.percentile(lat, 50)) * 1e6
    p99 = float(np.percentile(lat, 99)) * 1e6
    assert p50 < 1000.0, f"cache-hit p50 {p50:.0f}us not sub-millisecond"
    emit("serve/cache_hit", p50, {"p99_us": round(p99, 1), "reps": reps})

    # single-flight: N identical concurrent misses -> exactly one Study run
    sf = PowerComplianceService(wave_cfg=cfg, mpf_grid=(0.8,),
                                cap_fracs=(1.0,), stream_chunk=4)
    n_threads, errs = 8, []

    def hammer():
        try:
            sf.query(tl, N_CHIPS, "moderate")
        except Exception as e:     # pragma: no cover - surfaced below
            errs.append(e)

    ts = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs, errs
    assert sf.stats["study_runs"] == 1, \
        f"single-flight ran the Study {sf.stats['study_runs']}x"

    # coalescing + compiled reuse: distinct (workload, fleet, spec
    # threshold) misses share one execution and the already-compiled
    # (length, family, structure) executables
    n_exec_before = engine._mitigate_vmapped._cache_size()
    co = PowerComplianceService(wave_cfg=cfg, mpf_grid=(0.8,),
                                cap_fracs=(1.0,), stream_chunk=4)
    queries = [{"workload": tl, "n_chips": n, "spec": s}
               for n, s in ((256, "moderate"), (1024, "lenient"),
                            (4096, "tight"))]
    t0 = time.perf_counter()
    answers = co.query_many(queries)
    coalesce_s = time.perf_counter() - t0
    assert co.stats["study_runs"] == 1, \
        f"coalescing ran the Study {co.stats['study_runs']}x"
    assert all(a is not None and "error" not in a for a in answers)
    n_exec_after = engine._mitigate_vmapped._cache_size()
    assert n_exec_after == n_exec_before, (
        f"new fleet sizes / spec thresholds retraced: "
        f"{n_exec_before} -> {n_exec_after} executables")
    emit("serve/coalesce3", coalesce_s * 1e6,
         {"study_runs": co.stats["study_runs"],
          "executables": n_exec_after})
    return {
        "cache_hit_p50_us": round(p50, 1),
        "cache_hit_p99_us": round(p99, 1),
        "singleflight": {"threads": n_threads,
                         "study_runs": sf.stats["study_runs"],
                         "waits": sf.stats["singleflight_waits"]},
        "coalesce": {"queries": len(queries),
                     "study_runs": co.stats["study_runs"],
                     "wall_s": round(coalesce_s, 3)},
        "compiled_executables": {"before": n_exec_before,
                                 "after": n_exec_after},
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small problem, invariants only, no JSON artifact")
    args = ap.parse_args(argv)

    cfg = core.WaveformConfig(dt=0.005, steps=4 if args.smoke else 8,
                              jitter_s=0.005)
    t0 = time.perf_counter()
    pred, loss = train_predictor(cfg, epochs=120 if args.smoke else 400)
    train_s = time.perf_counter() - t0
    print(f"# predictor trained in {train_s:.1f}s (final loss {loss:.2e})")

    design = bench_design(cfg, pred, args.smoke)
    service = bench_service(cfg, args.smoke)

    if args.smoke:
        print(f"smoke OK: verdict parity, cache-hit p50 "
              f"{service['cache_hit_p50_us']:.0f}us, single-flight "
              f"{service['singleflight']['study_runs']} run, coalesce "
              f"{service['coalesce']['study_runs']} run, executables "
              f"{service['compiled_executables']['before']} -> "
              f"{service['compiled_executables']['after']}")
        return

    result = {
        "n_chips": N_CHIPS,
        "predictor": {"train_s": round(train_s, 1), "final_loss": loss},
        "design": design,
        "service": service,
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    print("wrote", os.path.abspath(OUT_PATH))


if __name__ == "__main__":
    main()

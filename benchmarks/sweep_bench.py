"""Serial vs batched scenario-sweep wall-clock — writes BENCH_sweep.json.

The workload is the operator's pre-dispatch question: across a matrix of
workloads and (MPF, battery) configurations, which pass the utility spec
and at what energy overhead?  The serial path answers it one ``simulate``
call at a time (the pre-engine architecture); the batched path runs each
workload's 25-config grid as ONE jit/vmap call via ``engine.sweep``.

  PYTHONPATH=src python -m benchmarks.sweep_bench

Reported timings: ``serial_s`` is the full Python loop; ``batched_warm_s``
is a steady-state sweep (compiled functions cached — the regime every
sweep after the first runs in); ``batched_cold_s`` includes compilation.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

import repro.core as core
from benchmarks.common import emit

N_CHIPS = 512
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")


def scenario_matrix():
    """4 workloads x 25 (MPF x battery) configs — the acceptance grid."""
    workloads = {
        "dense_2s": core.synthetic_timeline(period_s=2.0, comm_frac=0.19),
        "dense_1s": core.synthetic_timeline(period_s=1.0, comm_frac=0.30),
        "moe_3s": core.synthetic_timeline(period_s=3.0, comm_frac=0.25,
                                          moe_notch=True),
        "ckpt_heavy": core.synthetic_timeline(period_s=1.5, comm_frac=0.40),
    }
    cfg = core.WaveformConfig(dt=0.002, steps=12, jitter_s=0.002)
    # swing scale for battery sizing: one representative aggregate
    w = core.aggregate(core.chip_waveform(workloads["dense_2s"], cfg),
                       N_CHIPS, cfg)
    swing = float(w.max() - w.min())
    configs = []
    for mpf in (0.5, 0.65, 0.8, 0.85, 0.9):
        for cap_f in (0.25, 0.5, 1.0, 2.0, 4.0):
            gpu = core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=2000,
                                         ramp_down_w_per_s=2000,
                                         stop_delay_s=1.0)
            bat = core.RackBattery(capacity_j=cap_f * swing,
                                   max_discharge_w=swing, max_charge_w=swing,
                                   target_tau_s=10.0)
            configs.append((gpu, bat))
    spec = core.example_specs(job_mw=w.mean() / 1e6)["moderate"]
    return workloads, configs, cfg, spec


def run_serial(workloads, configs, cfg, spec):
    records = []
    for name, tl in workloads.items():
        for gpu, bat in configs:
            res = core.simulate(tl, N_CHIPS, cfg, device_mitigation=gpu,
                                rack_mitigation=bat, spec=spec)
            records.append((name, res.spec_report.ok, res.energy_overhead))
    return records


def run_batched(workloads, configs, cfg, spec):
    recs = core.sweep(workloads, [N_CHIPS], configs, cfg, spec=spec)
    return [(r["workload"], r["spec_ok"], r["energy_overhead"]) for r in recs]


def main() -> None:
    workloads, configs, cfg, spec = scenario_matrix()
    n_scen = len(workloads) * len(configs)

    # warm the per-shape scan/FFT caches for EVERY workload length (they
    # compile separately) so the serial loop is measured in its own steady
    # state, symmetric with the batched warm timing
    run_serial(workloads, configs[:1], cfg, spec)
    t0 = time.perf_counter()
    serial = run_serial(workloads, configs, cfg, spec)
    serial_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    batched_first = run_batched(workloads, configs, cfg, spec)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched = run_batched(workloads, configs, cfg, spec)
    warm_s = time.perf_counter() - t0

    # verdict parity: same pass/fail for every scenario
    agree = sum(int(a[1] == b[1]) for a, b in zip(serial, batched))
    result = {
        "n_scenarios": n_scen,
        "n_workloads": len(workloads),
        "n_configs": len(configs),
        "serial_s": round(serial_s, 3),
        "batched_cold_s": round(cold_s, 3),
        "batched_warm_s": round(warm_s, 3),
        "speedup_warm": round(serial_s / warm_s, 1),
        "speedup_cold": round(serial_s / cold_s, 1),
        "verdict_agreement": f"{agree}/{n_scen}",
        "passing_configs": sum(int(ok) for _, ok, _ in batched),
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    emit("sweep/serial", serial_s * 1e6 / n_scen, {"total_s": round(serial_s, 2)})
    emit("sweep/batched_warm", warm_s * 1e6 / n_scen,
         {"total_s": round(warm_s, 2), "speedup": result["speedup_warm"]})
    emit("sweep/batched_cold", cold_s * 1e6 / n_scen,
         {"total_s": round(cold_s, 2), "speedup": result["speedup_cold"]})
    assert agree == n_scen, "serial and batched spec verdicts disagree"
    # the speedup target is advisory (wall-clock is environment-dependent);
    # correctness (verdict parity) is the hard invariant above
    if serial_s / warm_s < 5.0:
        print(f"# WARNING: batched sweep only {serial_s / warm_s:.1f}x "
              "serial on this machine (target >=5x)")
    print("wrote", os.path.abspath(OUT_PATH))


if __name__ == "__main__":
    main()

"""Serial vs bucketed vs padded scenario-sweep wall-clock — BENCH_sweep.json.

The workload is the operator's pre-dispatch question: across a matrix of
workloads and (MPF, battery) configurations, which pass the utility spec
and at what energy overhead?  Three ways to answer it:

  serial    one ``simulate`` call per scenario (the pre-engine architecture);
  bucketed  ``engine.sweep`` — one jit/vmap call per workload *length*
            (PR 1's batched engine path, 4 compiled pipelines here);
  padded    ``Study(padding="pad").run()`` — mixed-length workloads
            edge-padded + masked into ONE fused pipeline call (the
            declarative Study API's scale lever), frequency/spec analysis
            per true length afterwards.

  PYTHONPATH=src python -m benchmarks.sweep_bench [--smoke | --scale]

Reported timings: ``*_warm_s`` are steady-state sweeps (compiled functions
cached — the regime every sweep after the first runs in); ``*_cold_s``
include compilation.  ``--smoke`` runs a small matrix for CI: it checks
three-way verdict parity plus chunked-vs-one-shot streaming bit-parity
and skips the JSON artifact.

``--scale`` is the streaming-executor section: a 10^4-scenario grid
(4 workloads x 25 configs x 100 seeds) run twice in *subprocess
isolation* — once materializing (``Study.run()``: every scenario's
waveforms resident at once) and once streaming
(``Study.run(stream=512)``: fixed O(chunk) waveform memory) — recording
wall-clock and peak RSS per process into the ``scale`` section of
BENCH_sweep.json.  Verdict counts must agree between the two runs.
``--scale`` also writes the ``distributed`` section: the same grid run
under the 2-process ``jax.distributed`` scenario mesh (per-process RSS,
scaling efficiency vs the single-process streaming wall) plus resume
overhead — a checkpointed run and a complete-restore pass against the
plain streaming wall, per chunk.

``--resume-smoke`` is the CI kill-and-resume check: a 500-scenario
resumable streamed run is SIGKILLed at a chunk boundary in a worker
subprocess, resumed in a second worker, and the resumed records must be
bit-identical to an uninterrupted in-process reference.

``--million`` runs the 10^6-scenario grid to completion on a single
host via resumable streaming (``Study.run(stream=512, resume=...)``)
and records wall / peak RSS into ``scale.million``; the acceptance
budget is peak RSS within 1.5x the 10^4 streaming figure.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import repro.core as core
from benchmarks.common import emit

N_CHIPS = 512
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweep.json")
SCALE_N = 10_000
SCALE_CHUNK = 512


def scenario_matrix(smoke: bool = False):
    """4 workloads x 25 (MPF x battery) configs — the acceptance grid
    (2 x 4 under ``--smoke``)."""
    workloads = {
        "dense_2s": core.synthetic_timeline(period_s=2.0, comm_frac=0.19),
        "dense_1s": core.synthetic_timeline(period_s=1.0, comm_frac=0.30),
        "moe_3s": core.synthetic_timeline(period_s=3.0, comm_frac=0.25,
                                          moe_notch=True),
        "ckpt_heavy": core.synthetic_timeline(period_s=1.5, comm_frac=0.40),
    }
    mpfs, caps = (0.5, 0.65, 0.8, 0.85, 0.9), (0.25, 0.5, 1.0, 2.0, 4.0)
    if smoke:
        workloads = {k: workloads[k] for k in ("dense_1s", "moe_3s")}
        mpfs, caps = (0.65, 0.9), (0.5, 2.0)
    cfg = core.WaveformConfig(dt=0.002, steps=12 if not smoke else 6,
                              jitter_s=0.002)
    # swing scale for battery sizing: one representative aggregate
    w = core.aggregate(core.chip_waveform(next(iter(workloads.values())), cfg),
                       N_CHIPS, cfg)
    swing = float(w.max() - w.min())
    configs = []
    for mpf in mpfs:
        for cap_f in caps:
            gpu = core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=2000,
                                         ramp_down_w_per_s=2000,
                                         stop_delay_s=1.0)
            bat = core.RackBattery(capacity_j=cap_f * swing,
                                   max_discharge_w=swing, max_charge_w=swing,
                                   target_tau_s=10.0)
            configs.append((gpu, bat))
    spec = core.example_specs(job_mw=w.mean() / 1e6)["moderate"]
    return workloads, configs, cfg, spec


def run_serial(workloads, configs, cfg, spec):
    records = []
    for name, tl in workloads.items():
        for gpu, bat in configs:
            res = core.simulate(tl, N_CHIPS, cfg, device_mitigation=gpu,
                                rack_mitigation=bat, spec=spec)
            records.append((name, res.spec_report.ok, res.energy_overhead))
    return records


def run_bucketed(workloads, configs, cfg, spec):
    recs = core.sweep(workloads, [N_CHIPS], configs, cfg, spec=spec)
    return [(r["workload"], r["spec_ok"], r["energy_overhead"]) for r in recs]


def make_study(workloads, configs, cfg, spec) -> core.Study:
    # key=None: the serial reference above has no keyed randomness
    return core.Study(workloads, fleets=[N_CHIPS], configs=list(configs),
                      specs=spec, wave_cfg=cfg, key=None, padding="pad")


def run_padded(study):
    res = study.run()
    return [(r["workload"], r["spec_ok"], r["energy_overhead"])
            for r in res.records]


def _agreement(a, b):
    return sum(int(x[1] == y[1]) for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# --scale: 10^4-scenario streaming vs materializing (subprocess-isolated)
# ---------------------------------------------------------------------------

def scale_matrix(n_target: int):
    """The --scale grid: the 4-workload x 25-config acceptance matrix
    crossed with enough jitter seeds to reach ``n_target`` scenarios, on
    a shorter waveform config (dt=4 ms, 6 iterations) so the
    *materializing* reference stays runnable at 10^4 rows."""
    workloads = {
        "dense_2s": core.synthetic_timeline(period_s=2.0, comm_frac=0.19),
        "dense_1s": core.synthetic_timeline(period_s=1.0, comm_frac=0.30),
        "moe_3s": core.synthetic_timeline(period_s=3.0, comm_frac=0.25,
                                          moe_notch=True),
        "ckpt_heavy": core.synthetic_timeline(period_s=1.5, comm_frac=0.40),
    }
    cfg = core.WaveformConfig(dt=0.004, steps=6, jitter_s=0.004)
    w = core.aggregate(core.chip_waveform(next(iter(workloads.values())), cfg),
                       N_CHIPS, cfg)
    swing = float(w.max() - w.min())
    configs = []
    for mpf in (0.5, 0.65, 0.8, 0.85, 0.9):
        for cap_f in (0.25, 0.5, 1.0, 2.0, 4.0):
            gpu = core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=2000,
                                         ramp_down_w_per_s=2000,
                                         stop_delay_s=1.0)
            bat = core.RackBattery(capacity_j=cap_f * swing,
                                   max_discharge_w=swing, max_charge_w=swing,
                                   target_tau_s=10.0)
            configs.append((gpu, bat))
    seeds = list(range(max(1, n_target // (len(workloads) * len(configs)))))
    spec = core.example_specs(job_mw=w.mean() / 1e6)["moderate"]
    return workloads, configs, cfg, spec, seeds


def run_scale_worker(mode: str, n_target: int, chunk: int) -> None:
    """One measured run in this process: build the scale grid, run it
    streaming or materializing, print a JSON result line.  Peak RSS is
    meaningful because each mode runs in its own subprocess."""
    import resource

    workloads, configs, cfg, spec, seeds = scale_matrix(n_target)
    study = core.Study(workloads, fleets=[N_CHIPS], configs=list(configs),
                       specs=spec, seeds=seeds, wave_cfg=cfg, key=None,
                       padding="pad")
    last = [0.0]

    def progress(done: int, total: int, elapsed: float) -> None:
        if done == total or elapsed - last[0] > 10.0:
            last[0] = elapsed
            print(f"# {mode}: {done}/{total} scenarios in {elapsed:.0f}s",
                  file=sys.stderr, flush=True)

    t0 = time.perf_counter()
    res = study.run(stream=chunk if mode == "streaming" else None,
                    on_chunk=progress)
    wall = time.perf_counter() - t0
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(json.dumps({
        "mode": mode,
        "n_scenarios": study.n_rows,
        "chunk": chunk if mode == "streaming" else None,
        "wall_s": round(wall, 2),
        "peak_rss_mb": round(peak_mb, 1),
        "n_pass": len(res.passing()),
    }))


def _scale_study(n_target: int) -> core.Study:
    workloads, configs, cfg, spec, seeds = scale_matrix(n_target)
    return core.Study(workloads, fleets=[N_CHIPS], configs=list(configs),
                      specs=spec, seeds=seeds, wave_cfg=cfg, key=None,
                      padding="pad")


def run_resume_worker(n_target: int, chunk: int, resume_dir: str,
                      out_path: str | None, die_after: int | None) -> None:
    """Resumable streamed run in this process.  With ``die_after=k`` the
    worker SIGKILLs *itself* at the k-th chunk boundary — a real kill -9,
    no teardown, the checkpoint directory is all that survives."""
    import resource

    study = _scale_study(n_target)
    emits: list = []
    t0 = time.perf_counter()

    def progress(done: int, total: int, elapsed: float) -> None:
        emits.append((done, time.perf_counter() - t0))
        if die_after is not None and done >= die_after * chunk:
            os.kill(os.getpid(), signal.SIGKILL)
        if done == total or len(emits) % 50 == 0:
            print(f"# resume-worker: {done}/{total} scenarios "
                  f"in {elapsed:.0f}s", file=sys.stderr, flush=True)

    res = study.run(stream=chunk, resume=resume_dir, on_chunk=progress)
    wall = time.perf_counter() - t0
    if out_path:
        res.to_json(out_path)
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(json.dumps({
        "mode": "resume",
        "n_scenarios": study.n_rows,
        "chunk": chunk,
        "wall_s": round(wall, 2),
        "peak_rss_mb": round(peak_mb, 1),
        "n_pass": len(res.passing()),
        # the first emission covers the whole restored prefix in one jump;
        # its timestamp is the cost of restoring that many chunks from disk
        "first_emit_rows": emits[0][0] if emits else 0,
        "first_emit_s": round(emits[0][1], 3) if emits else None,
        "n_emits": len(emits),
    }))


def run_dist_worker(n_target: int, chunk: int) -> None:
    """One process of the 2-process distributed scale run (launched under
    the REPRO_DIST_* env contract).  Each process prints its own JSON
    line: per-process RSS is meaningful, wall is the synchronized sweep."""
    import resource

    from repro.parallel import distributed as D

    assert D.initialize(), "REPRO_DIST_* contract missing"
    study = _scale_study(n_target)
    study.plan = D.distributed_plan()
    last = [0.0]

    def progress(done: int, total: int, elapsed: float) -> None:
        if done == total or elapsed - last[0] > 10.0:
            last[0] = elapsed
            print(f"# dist p{D.process_index()}: {done}/{total} scenarios "
                  f"in {elapsed:.0f}s", file=sys.stderr, flush=True)

    t0 = time.perf_counter()
    res = study.run(stream=chunk, on_chunk=progress)
    wall = time.perf_counter() - t0
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(json.dumps({
        "mode": "distributed",
        "process": D.process_index(),
        "n_processes": D.process_count(),
        "n_scenarios": study.n_rows,
        "chunk": chunk,
        "wall_s": round(wall, 2),
        "peak_rss_mb": round(peak_mb, 1),
        # the merged result is replicated: every process can count passes
        "n_pass": len(res.passing()),
    }), flush=True)


def _worker_json(cmd: list, **kwargs) -> dict:
    """Run a bench worker subprocess, return its JSON result line
    (stderr inherits the terminal so heartbeats stay visible)."""
    out = subprocess.run(cmd, stdout=subprocess.PIPE, text=True, **kwargs)
    assert out.returncode == 0, f"worker {cmd} exited {out.returncode}"
    return json.loads(out.stdout.strip().splitlines()[-1])


def _resume_cmd(n_target: int, chunk: int, resume_dir: str,
                out_path: str | None = None,
                die_after: int | None = None) -> list:
    cmd = [sys.executable, "-m", "benchmarks.sweep_bench",
           "--resume-worker", "--scale-n", str(n_target),
           "--scale-chunk", str(chunk), "--resume-dir", resume_dir]
    if out_path:
        cmd += ["--out", out_path]
    if die_after is not None:
        cmd += ["--die-after", str(die_after)]
    return cmd


def run_scale(n_target: int, chunk: int) -> None:
    """Drive both --scale-worker modes in subprocesses and merge the
    section into BENCH_sweep.json."""
    results = {}
    for mode in ("materializing", "streaming"):
        cmd = [sys.executable, "-m", "benchmarks.sweep_bench",
               "--scale-worker", mode, "--scale-n", str(n_target),
               "--scale-chunk", str(chunk)]
        print(f"# running {mode} worker ({n_target} scenarios)...",
              flush=True)
        # stderr inherits the terminal so the worker's progress heartbeats
        # stay visible during the multi-minute run; only stdout (the JSON
        # result line) is captured
        out = subprocess.run(cmd, stdout=subprocess.PIPE, text=True)
        assert out.returncode == 0, f"{mode} worker exited {out.returncode}"
        results[mode] = json.loads(out.stdout.strip().splitlines()[-1])
    st, mat = results["streaming"], results["materializing"]
    assert st["n_pass"] == mat["n_pass"], \
        f"streaming/materializing verdicts disagree: {st} vs {mat}"
    section = {
        "n_scenarios": st["n_scenarios"],
        "chunk": st["chunk"],
        "streaming_wall_s": st["wall_s"],
        "streaming_peak_rss_mb": st["peak_rss_mb"],
        "materializing_wall_s": mat["wall_s"],
        "materializing_peak_rss_mb": mat["peak_rss_mb"],
        "rss_ratio": round(mat["peak_rss_mb"] / st["peak_rss_mb"], 2),
        "wall_ratio": round(mat["wall_s"] / st["wall_s"], 2),
        "n_pass": st["n_pass"],
        "verdict_agreement": f'{st["n_pass"]}=={mat["n_pass"]}',
    }
    n_chunks = (n_target + chunk - 1) // chunk
    chunk_wall = st["wall_s"] / n_chunks

    # -- resume overhead: checkpointed run + complete-restore pass -----------
    ck = tempfile.mkdtemp(prefix="sweep_resume_bench_")
    print(f"# running checkpointed streaming worker (resume={ck})...",
          flush=True)
    ckpt = _worker_json(_resume_cmd(n_target, chunk, ck))
    print("# running complete-restore worker (recomputes nothing)...",
          flush=True)
    restored = _worker_json(_resume_cmd(n_target, chunk, ck))
    assert restored["n_pass"] == st["n_pass"], \
        f"restored verdicts disagree: {restored} vs {st}"
    assert restored["first_emit_rows"] == n_target, \
        f"complete restore recomputed rows: {restored}"
    write_ovh = max(0.0, ckpt["wall_s"] - st["wall_s"]) / n_chunks
    restore_per_chunk = restored["first_emit_s"] / n_chunks
    resume = {
        "n_chunks": n_chunks,
        "chunk_wall_s": round(chunk_wall, 3),
        "checkpointed_wall_s": ckpt["wall_s"],
        "checkpoint_overhead_per_chunk_s": round(write_ovh, 4),
        "restore_wall_s": restored["first_emit_s"],
        "restore_per_chunk_s": round(restore_per_chunk, 4),
        # steady-state cost of running with resume= on, per chunk computed
        "overhead_ratio": round(write_ovh / chunk_wall, 4),
        # cost of restoring a chunk relative to recomputing it
        "restore_ratio": round(restore_per_chunk / chunk_wall, 4),
    }

    # -- 2-process scenario mesh: per-process RSS, scaling efficiency --------
    from repro.parallel import distributed as D

    print("# running 2-process distributed workers...", flush=True)
    done = D.launch_workers(
        [sys.executable, "-m", "benchmarks.sweep_bench", "--dist-worker",
         "--scale-n", str(n_target), "--scale-chunk", str(chunk)],
        num_processes=2, timeout=3600)
    per_proc = sorted((json.loads(r.stdout.strip().splitlines()[-1])
                       for r in done), key=lambda d: d["process"])
    assert all(p["n_pass"] == st["n_pass"] for p in per_proc), \
        f"distributed verdicts disagree: {per_proc} vs {st}"
    dist_wall = max(p["wall_s"] for p in per_proc)
    distributed = {
        "n_scenarios": n_target,
        "chunk": chunk,
        "n_processes": 2,
        "wall_s": dist_wall,
        "per_process_wall_s": [p["wall_s"] for p in per_proc],
        "per_process_rss_mb": [p["peak_rss_mb"] for p in per_proc],
        "single_process_wall_s": st["wall_s"],
        # speedup / n_processes; bounded by physical cores — on a 1-core
        # host two processes time-share and ~0.5 is the ceiling
        "scaling_efficiency": round(st["wall_s"] / (2 * dist_wall), 3),
        "host_cpu_count": os.cpu_count(),
        "n_pass": per_proc[0]["n_pass"],
        "verdict_agreement": f'{per_proc[0]["n_pass"]}=={st["n_pass"]}',
        "resume": resume,
    }

    data = {}
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as fh:
            data = json.load(fh)
    data["scale"] = dict(section, million=data.get("scale", {}).get("million"))
    if data["scale"]["million"] is None:
        del data["scale"]["million"]
    data["distributed"] = distributed
    with open(OUT_PATH, "w") as fh:
        json.dump(data, fh, indent=2)
        fh.write("\n")
    emit("sweep/scale_streaming", st["wall_s"] * 1e6 / st["n_scenarios"],
         {"peak_rss_mb": st["peak_rss_mb"], "rss_ratio": section["rss_ratio"]})
    emit("sweep/distributed_2proc", dist_wall * 1e6 / n_target,
         {"scaling_efficiency": distributed["scaling_efficiency"],
          "resume_overhead_ratio": resume["overhead_ratio"]})
    print("wrote scale + distributed sections to", os.path.abspath(OUT_PATH))
    print(json.dumps({"scale": data["scale"], "distributed": distributed},
                     indent=2))


# ---------------------------------------------------------------------------
# --resume-smoke: kill-and-resume bit-parity (CI)
# ---------------------------------------------------------------------------

def run_resume_smoke(n_target: int = 500, chunk: int = 100) -> None:
    """SIGKILL a resumable streamed run at a chunk boundary in a worker
    subprocess, resume it in a second worker, and require the resumed
    records to be bit-identical to an uninterrupted in-process run."""
    import glob

    study = _scale_study(n_target)
    ref = study.run(stream=chunk).to_records()

    ck = tempfile.mkdtemp(prefix="sweep_resume_smoke_")
    out_path = os.path.join(ck, "records.json")
    die_after = 2
    kill = subprocess.run(_resume_cmd(n_target, chunk, ck,
                                      die_after=die_after),
                          stdout=subprocess.PIPE, text=True, timeout=600)
    assert kill.returncode == -signal.SIGKILL, \
        f"worker survived its own SIGKILL: rc={kill.returncode}"
    survivors = glob.glob(os.path.join(ck, "chunks", "*", "chunk_*"))
    assert len(survivors) >= die_after, \
        f"kill before checkpoints were written: {survivors}"

    res = _worker_json(_resume_cmd(n_target, chunk, ck, out_path=out_path),
                       timeout=600)
    with open(out_path) as fh:
        got = json.load(fh)
    assert got == ref, \
        "resumed records differ from the uninterrupted reference"
    assert res["first_emit_rows"] >= die_after * chunk, res
    print(f"RESUME_SMOKE_OK: killed at chunk {die_after}/"
          f"{(n_target + chunk - 1) // chunk}, resumed bit-identical "
          f"({len(got)} records, {res['first_emit_rows']} rows restored "
          f"from checkpoint in {res['first_emit_s']}s)")


# ---------------------------------------------------------------------------
# --million: 10^6 scenarios, single host, resumable streaming
# ---------------------------------------------------------------------------

def run_million(n_target: int, chunk: int) -> None:
    """Complete a 10^6-scenario grid on one host via resumable streaming
    and record wall / peak RSS into ``scale.million``.  The RSS budget is
    1.5x the 10^4 streaming figure: O(chunk) waveform memory means only
    the columnar metric store grows with the grid."""
    data = {}
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as fh:
            data = json.load(fh)
    base_rss = data.get("scale", {}).get("streaming_peak_rss_mb", 1294.4)
    budget = round(1.5 * base_rss, 1)

    ck = tempfile.mkdtemp(prefix="sweep_million_")
    print(f"# running 10^6-scenario resumable streaming worker "
          f"(resume={ck}, rss budget {budget} MB)...", flush=True)
    res = _worker_json(_resume_cmd(n_target, chunk, ck))
    million = {
        "n_scenarios": res["n_scenarios"],
        "chunk": chunk,
        "wall_s": res["wall_s"],
        "scenarios_per_s": round(res["n_scenarios"] / res["wall_s"], 1),
        "peak_rss_mb": res["peak_rss_mb"],
        "rss_budget_mb": budget,
        "within_budget": res["peak_rss_mb"] <= budget,
        "n_pass": res["n_pass"],
        "n_chunks": (res["n_scenarios"] + chunk - 1) // chunk,
    }
    data.setdefault("scale", {})["million"] = million
    with open(OUT_PATH, "w") as fh:
        json.dump(data, fh, indent=2)
        fh.write("\n")
    emit("sweep/million_streaming", res["wall_s"] * 1e6 / res["n_scenarios"],
         {"peak_rss_mb": res["peak_rss_mb"], "rss_budget_mb": budget})
    assert million["within_budget"], \
        f"10^6-scenario peak RSS {res['peak_rss_mb']} MB over {budget} MB"
    print("wrote scale.million to", os.path.abspath(OUT_PATH))
    print(json.dumps(million, indent=2))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrix, parity checks only, no JSON artifact")
    ap.add_argument("--scale", action="store_true",
                    help="10^4-scenario streaming-vs-materializing section "
                         "(subprocess-isolated wall-clock + peak RSS)")
    ap.add_argument("--resume-smoke", action="store_true",
                    help="CI kill-and-resume check: SIGKILL a resumable "
                         "streamed run mid-sweep, resume, assert bit-parity")
    ap.add_argument("--million", action="store_true",
                    help="10^6-scenario single-host resumable streaming run "
                         "(writes scale.million; multi-hour on small hosts)")
    ap.add_argument("--million-n", type=int, default=1_000_000)
    ap.add_argument("--scale-n", type=int, default=SCALE_N)
    ap.add_argument("--scale-chunk", type=int, default=SCALE_CHUNK)
    ap.add_argument("--scale-worker", choices=("streaming", "materializing"),
                    default=None, help=argparse.SUPPRESS)
    ap.add_argument("--resume-worker", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--dist-worker", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--resume-dir", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--die-after", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.scale_worker:
        run_scale_worker(args.scale_worker, args.scale_n, args.scale_chunk)
        return
    if args.resume_worker:
        run_resume_worker(args.scale_n, args.scale_chunk, args.resume_dir,
                          args.out, args.die_after)
        return
    if args.dist_worker:
        run_dist_worker(args.scale_n, args.scale_chunk)
        return
    if args.resume_smoke:
        run_resume_smoke()
        return
    if args.million:
        run_million(args.million_n, args.scale_chunk)
        return
    if args.scale:
        run_scale(args.scale_n, args.scale_chunk)
        return

    workloads, configs, cfg, spec = scenario_matrix(args.smoke)
    study = make_study(workloads, configs, cfg, spec)
    n_scen = len(workloads) * len(configs)

    if args.smoke:
        serial = run_serial(workloads, configs, cfg, spec)
        bucketed = run_bucketed(workloads, configs, cfg, spec)
        padded = run_padded(study)
        assert _agreement(serial, bucketed) == n_scen, \
            "bucketed verdicts disagree with serial"
        assert _agreement(serial, padded) == n_scen, \
            "padded verdicts disagree with serial"
        # streaming executor: a chunked run (chunk smaller than the grid,
        # splitting dedup prefix groups) must be bit-identical to one-shot
        chunks = []
        chunked = study.run(stream=3,
                            on_chunk=lambda d, t, e: chunks.append((d, t)))
        oneshot = study.run()
        assert chunked.records == oneshot.records, \
            "chunked records differ from one-shot"
        assert chunks and chunks[-1][0] == chunks[-1][1] == study.n_rows
        print(f"smoke OK: {n_scen} scenarios, serial == bucketed == padded "
              "spec verdicts; chunked stream bit-identical to one-shot "
              f"({len(chunks)} chunks)")
        return

    # warm the per-shape scan/FFT caches for EVERY workload length (they
    # compile separately) so the serial loop is measured in its own steady
    # state, symmetric with the batched warm timings
    run_serial(workloads, configs[:1], cfg, spec)
    t0 = time.perf_counter()
    serial = run_serial(workloads, configs, cfg, spec)
    serial_s = time.perf_counter() - t0

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        return out, time.perf_counter() - t0

    def best_of(fn, n=3):
        # warm timings are noise-prone at this scale; best-of-n is the
        # steady-state number (both paths measured identically)
        out, best = timed(fn)
        for _ in range(n - 1):
            out, t = timed(fn)
            best = min(best, t)
        return out, best

    _, bucketed_cold_s = timed(
        lambda: run_bucketed(workloads, configs, cfg, spec))
    bucketed, bucketed_warm_s = best_of(
        lambda: run_bucketed(workloads, configs, cfg, spec))

    _, padded_cold_s = timed(lambda: run_padded(study))
    padded, padded_warm_s = best_of(lambda: run_padded(study))

    # verdict parity: same pass/fail for every scenario, all three paths
    agree_b = _agreement(serial, bucketed)
    agree_p = _agreement(serial, padded)
    result = {
        "n_scenarios": n_scen,
        "n_workloads": len(workloads),
        "n_configs": len(configs),
        "serial_s": round(serial_s, 3),
        "bucketed_cold_s": round(bucketed_cold_s, 3),
        "bucketed_warm_s": round(bucketed_warm_s, 3),
        "padded_cold_s": round(padded_cold_s, 3),
        "padded_warm_s": round(padded_warm_s, 3),
        "speedup_warm_bucketed": round(serial_s / bucketed_warm_s, 1),
        "speedup_warm_padded": round(serial_s / padded_warm_s, 1),
        "padded_vs_bucketed_warm": round(bucketed_warm_s / padded_warm_s, 2),
        "padded_vs_bucketed_cold": round(bucketed_cold_s / padded_cold_s, 2),
        "verdict_agreement_bucketed": f"{agree_b}/{n_scen}",
        "verdict_agreement_padded": f"{agree_p}/{n_scen}",
        "passing_configs": sum(int(ok) for _, ok, _ in padded),
    }
    with open(OUT_PATH, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    emit("sweep/serial", serial_s * 1e6 / n_scen, {"total_s": round(serial_s, 2)})
    emit("sweep/bucketed_warm", bucketed_warm_s * 1e6 / n_scen,
         {"total_s": round(bucketed_warm_s, 2),
          "speedup": result["speedup_warm_bucketed"]})
    emit("sweep/padded_warm", padded_warm_s * 1e6 / n_scen,
         {"total_s": round(padded_warm_s, 2),
          "speedup": result["speedup_warm_padded"],
          "vs_bucketed": result["padded_vs_bucketed_warm"]})
    assert agree_b == n_scen, "serial and bucketed spec verdicts disagree"
    assert agree_p == n_scen, "serial and padded spec verdicts disagree"
    # the speedup targets are advisory (wall-clock is environment-dependent);
    # correctness (verdict parity) is the hard invariant above
    if serial_s / padded_warm_s < 5.0:
        print(f"# WARNING: padded sweep only {serial_s / padded_warm_s:.1f}x "
              "serial on this machine (target >=5x)")
    if padded_warm_s > 1.1 * bucketed_warm_s:
        print(f"# WARNING: padded single-bucket path "
              f"{padded_warm_s / bucketed_warm_s:.2f}x slower than "
              "per-length buckets on this machine (target: parity; "
              "the fusion win is compile amortization, see *_cold_s)")
    print("wrote", os.path.abspath(OUT_PATH))


if __name__ == "__main__":
    main()

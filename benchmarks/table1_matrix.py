"""Table I — computed comparison of the three mitigation classes.

Unlike the paper's qualitative table, every entry here is *measured* on the
calibrated waveform: energy overhead, residual in-band energy, ability to
meet the tight spec (10% dynamic range), perf overhead, and reaction
latency. The four candidate outputs are spec-checked in ONE vmapped
``engine.validate_many`` call (batched scenario engine); the qualitative
orderings of Table I are then asserted.
"""
from __future__ import annotations

import numpy as np

import repro.core as core
from benchmarks.common import emit, paper_waveform, us_per_call


def main() -> None:
    chip, dc, cfg = paper_waveform(steps=40)
    n_chips = 512
    spec_tight = core.example_specs(job_mw=dc.mean() / 1e6)["tight"]
    swing = float(dc.max() - dc.min())
    rows = {}
    outs = {}

    # --- software-only (Firefly)
    ff = core.Firefly(engage_frac=0.95, threshold_frac=0.9)
    out, aux = ff.apply(chip, cfg.dt)
    outs["firefly"] = core.aggregate(out, n_chips, cfg)
    rows["firefly"] = {
        "energy_overhead": aux["energy_overhead"],
        "perf_overhead": aux["perf_overhead"],
        "extra_hardware": False, "developer_dependency": "high",
    }

    # --- GPU power smoothing (MPF 90%)
    gf = core.GpuPowerSmoothing(mpf_frac=0.9, ramp_up_w_per_s=2000,
                                ramp_down_w_per_s=2000, stop_delay_s=1.0)
    out, aux = gf.apply(chip, cfg.dt)
    outs["gpu_smoothing"] = core.aggregate(out, n_chips, cfg)
    rows["gpu_smoothing"] = {
        "energy_overhead": aux["energy_overhead"],
        "perf_overhead": 0.0,
        "extra_hardware": False, "developer_dependency": "medium",
    }

    # --- rack-level storage
    bat = core.RackBattery(capacity_j=3.0 * swing, max_discharge_w=swing,
                           max_charge_w=swing, target_tau_s=10.0)
    out_b, aux_b = bat.apply(dc, cfg.dt)
    outs["battery"] = out_b
    rows["battery"] = {
        "energy_overhead": aux_b["energy_overhead"],
        "perf_overhead": 0.0,
        "extra_hardware": True, "developer_dependency": "low",
    }

    # --- the paper's combined proposal
    gf_lo = core.GpuPowerSmoothing(mpf_frac=0.65, ramp_up_w_per_s=2000,
                                   ramp_down_w_per_s=2000, stop_delay_s=1.0)
    comb = core.CombinedMitigation(gf_lo, bat, n_chips)
    out_c, aux_c = comb.apply(dc, cfg.dt)
    outs["combined"] = out_c
    rows["combined"] = {
        "energy_overhead": aux_c["energy_overhead"],
        "perf_overhead": 0.0,
        "extra_hardware": True, "developer_dependency": "low",
    }

    # one vmapped spec+band evaluation across all four candidates
    names = list(rows.keys())
    ok, reports = core.validate_many(np.stack([outs[n] for n in names]),
                                     spec_tight, cfg.dt)
    for i, name in enumerate(names):
        rows[name]["meets_tight_spec"] = bool(ok[i])
        rows[name]["inband_residual"] = reports[i].metrics[
            "band_energy_fraction"]

    for name, r in rows.items():
        emit(f"table1/{name}", 0.0,
             {k: (round(v, 4) if isinstance(v, float) else v)
              for k, v in r.items()})

    # paper's qualitative orderings hold quantitatively:
    assert rows["battery"]["energy_overhead"] < 0.02           # storage: low energy
    assert rows["firefly"]["energy_overhead"] > 0.05           # software: high energy
    assert rows["gpu_smoothing"]["energy_overhead"] > 0.05     # hw floor: high energy
    assert rows["firefly"]["perf_overhead"] <= 0.05            # <5% (paper)
    assert rows["combined"]["energy_overhead"] < rows["gpu_smoothing"]["energy_overhead"]
    emit("table1/orderings_hold", 0.0, {"ok": True})


if __name__ == "__main__":
    main()

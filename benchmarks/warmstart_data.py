"""Warm-start training data + predictor checkpoint — the amortization
sweep behind ``engine.design(method="warmstart")``.

A Study-style grid of (workload period/comm-mix/MoE-notch, fleet size,
spec tier) cells is solved with the full ``hybrid`` designer (hard
tau=0 validated), each solution's battery latency is refined over a
small tau ladder with ONE vmapped ``_eval_candidates`` call, and each
cell contributes one (spectral feature vector, (MPF, capacity, tau))
training pair.  ``train_warmstart`` fits the MLP predictor on the
scale-free targets and the checkpoint lands under ``--ckpt-dir`` via
``ckpt/checkpoint.py`` — the artifact ``PowerComplianceService(
warmstart=<dir>)`` and ``serve_bench`` load.

  PYTHONPATH=src python -m benchmarks.warmstart_data [--smoke] \
      [--ckpt-dir warmstart_ckpt] [--epochs 400]

The hard invariants (asserted, also under ``--smoke``): training loss
decreases; the trained predictor's ``design(method="warmstart")``
answer on a sweep cell passes its spec under hard tau=0 re-validation
(the train -> predict -> revalidate round-trip).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import repro.core as core
from repro.core import engine
from repro.core.hardware import DEFAULT_HW
from benchmarks.common import emit

TAU_LADDER = (5.0, 10.0, 15.0, 30.0)
DEFAULT_CKPT = os.path.join(os.path.dirname(__file__), "..", "warmstart_ckpt")


def sweep_scenarios(smoke: bool = False) -> List[Dict]:
    """The (workload, fleet, spec) training grid: square-wave periods and
    comm mixes spanning the paper band, MoE-notch variants, three fleet
    scales, all three spec tiers."""
    if smoke:
        return [
            {"period_s": 2.0, "comm_frac": 0.25, "moe_notch": False,
             "n_chips": 512, "spec": "moderate"},
            {"period_s": 0.8, "comm_frac": 0.3, "moe_notch": False,
             "n_chips": 512, "spec": "tight"},
            {"period_s": 1.4, "comm_frac": 0.2, "moe_notch": True,
             "n_chips": 1024, "spec": "moderate"},
            {"period_s": 2.0, "comm_frac": 0.35, "moe_notch": False,
             "n_chips": 1024, "spec": "tight"},
        ]
    out = []
    for period_s in (0.6, 1.0, 1.6, 2.4):
        for comm_frac, moe in ((0.2, False), (0.35, False), (0.25, True)):
            for n_chips in (512, 2048):
                for spec in ("lenient", "moderate", "tight"):
                    out.append({"period_s": period_s, "comm_frac": comm_frac,
                                "moe_notch": moe, "n_chips": n_chips,
                                "spec": spec})
    return out


def _refine_tau(spec, w, dt: float, n_chips: int, mpf: float, cap: float,
                swing: float, hw) -> float:
    """Cheapest passing battery latency for a solved (MPF, capacity):
    one vmapped hard evaluation over the tau ladder."""
    if cap <= 0:
        return TAU_LADDER[1]
    cands = [(mpf, cap)] * len(TAU_LADDER)
    _, ok, overhead, _, _ = engine._eval_candidates(
        spec, w, dt, n_chips, cands, swing=swing, hw=hw,
        target_tau_s=list(TAU_LADDER))
    ok, overhead = np.asarray(ok), np.asarray(overhead)
    if not ok.any():
        return TAU_LADDER[1]
    best = int(np.flatnonzero(ok)[np.argmin(overhead[ok])])
    return TAU_LADDER[best]


def build_dataset(scenarios: Sequence[Dict], cfg, *, hw=DEFAULT_HW,
                  method: str = "hybrid", verbose: bool = True
                  ) -> Tuple[np.ndarray, np.ndarray, List[Dict]]:
    """Solve each sweep cell and return (features [N,F], targets [N,3]
    as physical (mpf_frac, capacity_j, tau_s), per-cell meta).  Cells the
    solver finds infeasible are skipped (logged)."""
    from repro.serve.warmstart import extract_features

    X, Y, meta = [], [], []
    for i, sc in enumerate(scenarios):
        tl = core.synthetic_timeline(period_s=sc["period_s"],
                                     comm_frac=sc["comm_frac"],
                                     moe_notch=sc["moe_notch"])
        w = core.aggregate(core.chip_waveform(tl, cfg, hw),
                           sc["n_chips"], cfg, hw)
        spec = core.example_specs(job_mw=float(w.mean()) / 1e6)[sc["spec"]]
        swing = float(w.max() - w.min())
        t0 = time.perf_counter()
        sol = engine.design(spec, w, cfg.dt, sc["n_chips"], method=method,
                            hw=hw)
        if sol is None or not sol["report"].ok:
            if verbose:
                print(f"# cell {i}: infeasible, skipped ({sc})")
            continue
        mpf = float(sol["mpf_frac"])
        cap = float(sol["battery_capacity_j"])
        tau = _refine_tau(spec, w, cfg.dt, sc["n_chips"], mpf, cap, swing,
                          hw)
        X.append(extract_features(spec, w, cfg.dt, sc["n_chips"]))
        Y.append([mpf, cap, tau])
        meta.append(dict(sc, mpf_frac=mpf, battery_capacity_j=cap,
                         target_tau_s=tau,
                         solve_s=round(time.perf_counter() - t0, 2)))
        if verbose:
            print(f"# cell {i}: mpf={mpf:.3f} cap={cap / 1e6:.3f}MJ "
                  f"tau={tau:g}s in {meta[-1]['solve_s']}s")
    if not X:
        raise RuntimeError("sweep produced no feasible training cells")
    return (np.stack(X).astype(np.float32),
            np.asarray(Y, np.float32), meta)


def train_and_check(X: np.ndarray, Y: np.ndarray, scenarios, cfg, *,
                    hw=DEFAULT_HW, epochs: int = 400,
                    ckpt_dir: Optional[str] = None):
    """Fit the predictor, checkpoint it, and run the train -> predict ->
    revalidate round-trip on the first sweep cell."""
    from repro.serve.warmstart import WarmStartPredictor, train_warmstart

    pred, hist = train_warmstart(X, Y, epochs=epochs)
    losses = hist["loss"]
    assert losses[-1] < losses[0], \
        f"training loss did not decrease: {losses[0]} -> {losses[-1]}"
    if ckpt_dir:
        pred.save(ckpt_dir)
        pred = WarmStartPredictor.load(ckpt_dir)

    sc = scenarios[0]
    tl = core.synthetic_timeline(period_s=sc["period_s"],
                                 comm_frac=sc["comm_frac"],
                                 moe_notch=sc["moe_notch"])
    w = core.aggregate(core.chip_waveform(tl, cfg, hw), sc["n_chips"],
                       cfg, hw)
    spec = core.example_specs(job_mw=float(w.mean()) / 1e6)[sc["spec"]]
    sol = engine.design(spec, w, cfg.dt, sc["n_chips"], method="warmstart",
                        warmstart=pred, hw=hw)
    assert sol is not None and sol["report"].ok, \
        "warm-started design failed hard re-validation"
    return pred, hist, sol


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="4-cell sweep, short training, temp checkpoint")
    ap.add_argument("--ckpt-dir", default=None,
                    help=f"checkpoint directory (default {DEFAULT_CKPT}; "
                         "a temp dir under --smoke)")
    ap.add_argument("--epochs", type=int, default=400)
    ap.add_argument("--method", default="hybrid",
                    choices=("grid", "gradient", "hybrid"),
                    help="target-generating solver")
    args = ap.parse_args(argv)

    cfg = core.WaveformConfig(dt=0.005, steps=4 if args.smoke else 8,
                              jitter_s=0.005)
    scenarios = sweep_scenarios(args.smoke)
    epochs = 120 if args.smoke else args.epochs
    ckpt_dir = args.ckpt_dir or (tempfile.mkdtemp(prefix="warmstart_")
                                 if args.smoke else DEFAULT_CKPT)

    t0 = time.perf_counter()
    X, Y, meta = build_dataset(scenarios, cfg, method=args.method)
    sweep_s = time.perf_counter() - t0
    print(f"# dataset: {len(X)}/{len(scenarios)} feasible cells "
          f"in {sweep_s:.1f}s")

    t0 = time.perf_counter()
    pred, hist, sol = train_and_check(X, Y, scenarios, cfg, epochs=epochs,
                                      ckpt_dir=ckpt_dir)
    train_s = time.perf_counter() - t0
    emit("warmstart/train", train_s * 1e6, {
        "cells": len(X), "epochs": epochs,
        "loss0": round(float(hist["loss"][0]), 6),
        "loss": round(float(hist["loss"][-1]), 6)})
    print(f"# round-trip: warmstart path={sol['aux']['warmstart_path']} "
          f"mpf={sol['mpf_frac']:.3f} "
          f"cap={sol['battery_capacity_j'] / 1e6:.3f}MJ -> spec ok")
    print(f"{'smoke OK' if args.smoke else 'wrote'}: checkpoint at "
          f"{os.path.abspath(ckpt_dir)} "
          f"(loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.6f})")


if __name__ == "__main__":
    main()

"""Closed-loop replay: watch a 9 Hz amplitude ramp get caught and killed.

Synthesizes the canonical escalating trace (a fleet-scale operating
point whose 9 Hz bin amplitude ramps toward the moderate spec's breach
level), replays it through the grid-interactive control loop, and prints
the ``ControlLog`` decision timeline: tick, detected bin, margin,
chosen intervention, dispatch latency — then the before/after margins
that show the loop actually closed.

  PYTHONPATH=src python examples/control_loop_demo.py [--max-ticks N]
"""
import argparse
import sys

sys.path.insert(0, "src")
from repro import api, control


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--max-ticks", type=int, default=None,
                    help="truncate the replay (CI smoke)")
    ap.add_argument("--duration-s", type=float, default=48.0)
    args = ap.parse_args()

    dt = 0.002
    spec = api.example_specs(job_mw=500.0)["moderate"]
    w = control.synthesize_ramp(dt=dt, duration_s=args.duration_s)
    print(f"trace: {len(w)} samples @ {dt*1e3:g} ms "
          f"({len(w)*dt:g} s), dc {w.mean()/1e6:.0f} MW, "
          f"9 Hz amplitude ramping to 80 MW")
    print(f"spec:  {spec.name} -> breach at "
          f"{0.5*spec.time.dynamic_range_w/1e6:.0f} MW per-bin amplitude\n")

    log = control.watch_trace(w, dt, spec=spec, n_chips=512,
                              max_ticks=args.max_ticks)

    print("decision timeline (tick, bin, amp, margin, level, latency):")
    print(log.timeline() or "  (no decisions — trace too short?)")

    s = log.summary()
    print("\nclosed-loop summary:")
    print(f"  first escalation        t={s['first_escalate_t_s']} s")
    print(f"  uncontrolled breach at  t={s['counterfactual_breach_t_s']} s"
          f"  (detection lead {s['detection_lead_s']} s)")
    print(f"  interventions dispatched: {s['n_dispatches']} "
          f"(warm latency p50 "
          f"{(s['dispatch_latency_s']['p50'] or 0)*1e3:.0f} ms)")
    if s["recession_t_s"] is not None:
        print(f"  amplitude back below release ({log.release_w/1e6:.0f} MW) "
              f"at t={s['recession_t_s']} s")
    # margin before the first dispatch vs after the last recession
    disp = log.first("dispatch:")
    if disp is not None:
        after = max(log.series[-1]["amps_w"])
        print(f"  worst-bin margin: {disp.margin_w/1e6:+.1f} MW at dispatch "
              f"-> {(log.trigger_w - after)/1e6:+.1f} MW at end of replay")
    assert s["n_dispatches"] >= 1 or args.max_ticks is not None


if __name__ == "__main__":
    main()

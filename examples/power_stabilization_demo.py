"""The paper end-to-end: dry-run artifact -> waveform -> FFT -> mitigation
stack -> utility-spec report, plus the batched scenario engine: the
(MPF x battery) design search and a fleet-size sweep each run as ONE
jit/vmap call. Pure analysis; runs in seconds.

  PYTHONPATH=src python examples/power_stabilization_demo.py \
      [--cell artifacts/dryrun/granite-3-8b__train_4k__single.json]
"""
import argparse
import os
import sys

import numpy as np

sys.path.insert(0, "src")
import repro.core as core


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell",
                    default="artifacts/dryrun/granite-3-8b__train_4k__single.json")
    args = ap.parse_args()

    if os.path.exists(args.cell):
        cell = core.load_cell(args.cell)
        tl = core.from_dryrun_cell(cell)
        n_chips = cell["n_chips"]
        print(f"cell: {cell['arch']} x {cell['shape']} on {n_chips} chips")
    else:
        print("no dry-run artifact found; using the calibrated Fig.-1 timeline")
        tl, n_chips = core.synthetic_timeline(2.0, 0.19), 512
    print("phases:", [(p.name, f"{p.duration_s:.3f}s", p.mode) for p in tl.phases])

    cfgw = core.WaveformConfig(dt=0.002, steps=25, jitter_s=0.002)
    res = core.simulate(tl, n_chips, cfgw)
    print(f"\nFig.1  swing {res.swing['swing_w']/1e6:.3f} MW on mean "
          f"{res.swing['mean_w']/1e6:.3f} MW")
    print("Fig.3  bands:", {k: round(v, 3) for k, v in res.bands.items()})

    spec = core.example_specs(job_mw=res.dc_raw.mean() / 1e6)["moderate"]
    print(f"\nraw vs '{spec.name}' spec:",
          spec.validate(res.dc_raw, cfgw.dt).violations or "PASS")

    # batched design: all 30 (MPF x battery) candidates in one vmapped call
    sol = core.design_mitigation(spec, res.dc_raw, cfgw.dt, n_chips)
    if sol is None:
        print("no passing configuration in the search grid")
        return
    n_cand = sol["grid_ok"].size
    print(f"designed mitigation ({n_cand} candidates, one vmapped call): "
          f"MPF={sol['mpf_frac']:.0%} TDP, battery "
          f"{sol['battery_capacity_j']/1e6:.2f} MJ")
    print(f"  -> spec PASS, energy overhead {sol['energy_overhead']:.2%}; "
          f"passing grid cells {int(sol['grid_ok'].sum())}/{n_cand}")

    # fleet-size sweep through the same engine: the spec (and the designed
    # config) stay sized for the ORIGINAL job, so growing the fleet shows
    # where the fixed design stops passing
    gpu, bat = sol["device_mitigation"], sol["rack_mitigation"]
    swing = float(res.dc_raw.max() - res.dc_raw.min())
    fleets = [n_chips // 2, n_chips, n_chips * 2]
    recs = core.sweep({"job": tl}, fleets, [(gpu, bat)], cfgw, spec=spec)
    print("\nfleet sweep (batched):")
    for r in recs:
        verdict = "PASS" if r["spec_ok"] else ",".join(r["violations"])
        print(f"  {r['n_chips']:>5} chips  mean {r['mean_mw']:7.2f} MW  "
              f"swing {r['swing_mitigated_mw']:6.3f} MW  "
              f"overhead {r['energy_overhead']:+.2%}  {verdict}")

    # backstop watches the mitigated feed
    bs = core.TelemetryBackstop(critical_hz=(0.5, 1.0, 2.0),
                                amp_threshold_w=0.5 * swing)
    _, aux = bs.apply(res.dc_mitigated, cfgw.dt)
    print(f"\nbackstop: max level {aux['max_level']} (0 = never triggered)")


if __name__ == "__main__":
    main()

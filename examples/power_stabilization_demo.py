"""The paper end-to-end through the Study API: declare -> run -> query.

dry-run artifact -> phase timeline -> one declarative Study (baseline +
mitigation grid x fleet sizes, noisy telemetry keyed per scenario) -> spec
verdict table -> the batched (MPF x battery) design search -> a serve-path
compliance query.  Pure analysis; runs in seconds.

  PYTHONPATH=src python examples/power_stabilization_demo.py \
      [--cell artifacts/dryrun/granite-3-8b__train_4k__single.json]
"""
import argparse
import os
import sys

sys.path.insert(0, "src")
from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell",
                    default="artifacts/dryrun/granite-3-8b__train_4k__single.json")
    args = ap.parse_args()

    if os.path.exists(args.cell):
        cell = api.load_cell(args.cell)
        tl = api.from_dryrun_cell(cell)
        n_chips = cell["n_chips"]
        print(f"cell: {cell['arch']} x {cell['shape']} on {n_chips} chips")
    else:
        print("no dry-run artifact found; using the calibrated Fig.-1 timeline")
        tl, n_chips = api.synthetic_timeline(2.0, 0.19), 512
    print("phases:", [(p.name, f"{p.duration_s:.3f}s", p.mode) for p in tl.phases])

    # ---- Fig. 1/3 context: the raw waveform (serial reference, one call)
    cfgw = api.WaveformConfig(dt=0.002, steps=25, jitter_s=0.002)
    res = api.simulate(tl, n_chips, cfgw)
    print(f"\nFig.1  swing {res.swing['swing_w']/1e6:.3f} MW on mean "
          f"{res.swing['mean_w']/1e6:.3f} MW")
    print("Fig.3  bands:", {k: round(v, 3) for k, v in res.bands.items()})

    spec = api.example_specs(job_mw=res.dc_raw.mean() / 1e6)["moderate"]
    swing = float(res.dc_raw.max() - res.dc_raw.min())

    # ---- declare: baseline + mitigation grid x fleet sizes, one Study.
    # The fleet axis keeps the spec (and configs) sized for the ORIGINAL
    # job, so growing the fleet shows where the fixed design stops passing.
    gpu = api.GpuPowerSmoothing(mpf_frac=0.9, ramp_up_w_per_s=2000,
                                ramp_down_w_per_s=2000, stop_delay_s=1.0)
    bat = api.RackBattery(capacity_j=2.0 * swing, max_discharge_w=swing,
                          max_charge_w=swing, target_tau_s=10.0)
    study = api.Study(
        {"job": tl},
        fleets=[n_chips // 2, n_chips, n_chips * 2],
        configs={"none": None, "mpf90": (gpu, None), "bat2x": (None, bat),
                 "mpf90+bat2x": (gpu, bat)},
        specs=spec, wave_cfg=cfgw, key=0)
    print(f"\n{study.describe()}")

    # ---- run: the whole grid compiles to the batched engine
    result = study.run()
    print(result.filter(n_chips=n_chips).table(
        ["config", "swing_mitigated_mw", "energy_overhead", "spec_ok"]))
    print("\nfleet sweep (per-config spec verdicts as the job grows):")
    for cfg_name, row in result.pivot("config", "n_chips").items():
        cells = "  ".join(f"{n}: {'PASS' if ok else 'fail'}"
                          for n, ok in row.items())
        print(f"  {cfg_name:>12}  {cells}")

    # ---- design: all (MPF x battery) candidates in one vmapped call
    sol = api.design_mitigation(spec, res.dc_raw, cfgw.dt, n_chips)
    if sol is not None:
        n_cand = sol["grid_ok"].size
        print(f"\ndesigned mitigation ({n_cand} candidates, one vmapped "
              f"call): MPF={sol['mpf_frac']:.0%} TDP, battery "
              f"{sol['battery_capacity_j']/1e6:.2f} MJ -> spec PASS, "
              f"overhead {sol['energy_overhead']:.2%}; passing cells "
              f"{int(sol['grid_ok'].sum())}/{n_cand}")

    # ---- query: the serve-path compliance answer
    service = api.PowerComplianceService(wave_cfg=cfgw)
    answer = service.query(tl, n_chips, spec)
    print(f"\ncompliance query ({answer['n_configs']} catalog configs): "
          f"compliant={answer['compliant']}, "
          f"recommended={answer['recommended']}")
    for p in answer["passing"][:5]:
        print(f"  {p['config']:>16}  overhead {p['energy_overhead']:+.2%}  "
              f"swing {p['swing_mitigated_mw']:.3f} MW")

    # backstop watches the mitigated feed
    bs = api.TelemetryBackstop(critical_hz=(0.5, 1.0, 2.0),
                               amp_threshold_w=0.5 * swing)
    _, aux = bs.apply(res.dc_mitigated, cfgw.dt)
    print(f"\nbackstop: max level {aux['max_level']} (0 = never triggered)")


if __name__ == "__main__":
    main()

"""Quickstart: end-to-end training with checkpointing + power telemetry.

Trains a transformer of the granite family on the synthetic pipeline,
checkpoints, and reports the job's simulated power profile + utility-spec
compliance after mitigation. CPU defaults finish in ~2 minutes; pass
--params 100m for the full-size example on real hardware.

  PYTHONPATH=src python examples/quickstart.py
"""
import argparse
import dataclasses
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

import repro.core as core
from repro.configs import AttentionConfig, LayerSpec, ModelConfig, TrainConfig
from repro.data import SyntheticLM
from repro.train import init_train_state, make_train_step


def make_cfg(size: str) -> ModelConfig:
    if size == "100m":
        dims = dict(d_model=640, n_repeats=10, d_ff=2560, heads=10, kv=5,
                    vocab=32000)
    else:  # cpu-friendly ~8M
        dims = dict(d_model=192, n_repeats=4, d_ff=768, heads=6, kv=2,
                    vocab=2048)
    return ModelConfig(
        name=f"quickstart-{size}", family="dense",
        d_model=dims["d_model"], vocab_size=dims["vocab"], d_ff=dims["d_ff"],
        mlp_kind="swiglu", unit=(LayerSpec("attn", "dense"),),
        n_repeats=dims["n_repeats"],
        attention=AttentionConfig(n_heads=dims["heads"], n_kv_heads=dims["kv"],
                                  head_dim=64, chunk_size=256))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", default="8m", choices=["8m", "100m"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = make_cfg(args.params)
    print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.1f}M")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20,
                       total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0)
    for i in range(args.steps):
        state, m = step(state, {k: jnp.asarray(v) for k, v in data(i).items()})
        if (i + 1) % 25 == 0:
            print(f"step {i+1:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")

    # --- power profile of this job at hypothetical 512-chip scale
    tl = core.synthetic_timeline(period_s=1.0, comm_frac=0.22)
    res = core.simulate(tl, 512, core.WaveformConfig(dt=0.002, steps=20))
    spec = core.example_specs(job_mw=res.dc_raw.mean() / 1e6)["moderate"]
    raw_ok = spec.validate(res.dc_raw, 0.002).ok
    sol = core.design_mitigation(spec, res.dc_raw, 0.002, 512)
    print(f"\npower: swing {res.swing['swing_w']/1e3:.1f} kW "
          f"({res.swing['swing_frac']:.0%}); raw spec ok={raw_ok}")
    if sol:
        print(f"mitigation: MPF={sol['mpf_frac']:.0%}, battery "
              f"{sol['battery_capacity_j']/1e3:.0f} kJ -> spec ok, "
              f"energy overhead {sol['energy_overhead']:.1%}")


if __name__ == "__main__":
    main()

"""Batched serving demo: prefill once, decode with a KV cache, compare
MoE (DeepSeek-MLA) and dense backends.

  PYTHONPATH=src python examples/serve_demo.py
"""
import sys
import time

import jax

sys.path.insert(0, "src")
from repro.configs import get_config, reduced
from repro.models import Model
from repro.serve import ServeEngine


def run(arch: str, gen: int = 24):
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 4, 12
    eng = ServeEngine(cfg, params, max_seq=L + gen + 1, batch=B)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompts, gen, temperature=0.7,
                       key=jax.random.PRNGKey(2))
    dt = time.time() - t0
    print(f"{arch:24s} {B}x{gen} tokens in {dt:5.2f}s "
          f"({B*gen/dt:6.1f} tok/s)  sample: {list(map(int, out[0,:8]))}")


def main():
    for arch in ("granite-3-8b", "deepseek-v2-lite-16b", "rwkv6-3b"):
        run(arch)


if __name__ == "__main__":
    main()

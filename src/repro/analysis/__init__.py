"""repro-lint: three-tier JAX/Pallas correctness analyzer.

Tier 1 (``rules``): stdlib-AST source rules RPR001-006 over src/repro.
Tier 2 (``jaxpr_checks`` + ``registry``): traced-program analyzers and a
jit-cache recompile gate over the registered compiled entry points.
Tier 3 (``kernel_checks``): Pallas BlockSpec/grid/VMEM geometry checks.
Plus ``deadmods``: static import-reachability report from the tests.

CLI: ``repro-lint`` (``repro.analysis.cli:main``); baseline suppressions
with justifications live in ``lint_baseline.json`` at the repo root.
"""
from repro.analysis.findings import (Baseline, Finding, apply_baseline,
                                     sort_findings)
from repro.analysis.rules import RULE_CATALOG, lint_paths, lint_source

__all__ = [
    "Baseline", "Finding", "apply_baseline", "sort_findings",
    "RULE_CATALOG", "lint_paths", "lint_source",
]

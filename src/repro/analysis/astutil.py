"""Shared AST machinery for the Tier-1 rules (stdlib ``ast`` only).

The rules need three repo-specific facts about any function they walk:

1. **Is it traced?**  A function body runs under JAX tracing when it is
   ``@jax.jit``-decorated (directly or via ``functools.partial(jax.jit,
   static_argnames=...)``), or follows the repo's naming contract for
   pure JAX code: ``apply_jax`` methods and ``*_jax`` functions
   (``core/smoothing/base.py`` docstring — "jnp arrays in, jnp arrays
   out, no host sync").

2. **Which expressions are traced values?**  Roots are (a) parameters
   annotated as arrays (``jnp.ndarray`` / ``jax.Array`` / ``w`` without
   annotation is NOT assumed), (b) names assigned from ``jnp.*`` /
   ``jax.*`` calls or from expressions containing traced names, and
   (c) ``self.<field>`` where ``field`` is a registered pytree *data*
   field (leaves are traced under jit/vmap; meta fields are static).
   Parameters listed in the jit's ``static_argnames`` are never traced.

3. **Pytree registrations.**  Module-level
   ``register_mitigation(Cls, data_fields=..., meta_fields=...)`` and
   ``jax.tree_util.register_dataclass(Cls, data_fields=...,
   meta_fields=...)`` calls, mapped back to the class definition.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

ARRAY_ANNOTATIONS = {
    "jnp.ndarray", "jax.Array", "jnp.array", "chex.Array", "Array",
    "jax.numpy.ndarray",
}

JAX_VALUE_PREFIXES = ("jnp.", "jax.", "lax.", "jax.lax.", "jax.nn.")

#: attribute accesses on traced values that are nonetheless static
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}

#: builtin calls whose results are host/static regardless of arguments
STATIC_CALLS = {"len", "range", "enumerate", "isinstance", "getattr",
                "hasattr", "type", "str", "repr", "id", "zip", "min", "max",
                "tuple", "list", "dict", "round", "abs"}

#: builtin casts: host-sync on traced args (RPR001's business), but the
#: *result* is a host scalar — never a traced value
HOST_CAST_CALLS = {"float", "int", "bool", "complex"}


def walk_shallow(root: ast.AST):
    """``ast.walk`` that does not descend into nested function/class
    definitions — each of those gets its own ``FunctionContext``, so a
    rule walking the outer body would double-report the inner one."""
    stack = list(ast.iter_child_nodes(root))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def dotted_name(node: ast.AST) -> Optional[str]:
    """``ast.Attribute``/``ast.Name`` chain -> "a.b.c" (else None)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _const_str_tuple(node: ast.AST) -> Tuple[str, ...]:
    """('a', 'b') / ['a'] / 'a' literal -> tuple of strings (else ())."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
        return tuple(out)
    return ()


@dataclasses.dataclass
class JitInfo:
    jitted: bool = False
    static_argnames: Tuple[str, ...] = ()
    donate_argnums: Tuple[int, ...] = ()


def decorator_jit_info(fn: ast.AST) -> JitInfo:
    """Inspect decorators for jax.jit / functools.partial(jax.jit, ...)."""
    info = JitInfo()
    for dec in getattr(fn, "decorator_list", []):
        name = dotted_name(dec)
        if name in ("jax.jit", "jit"):
            info.jitted = True
            continue
        if isinstance(dec, ast.Call):
            callee = dotted_name(dec.func)
            if callee in ("jax.jit", "jit"):
                info.jitted = True
            elif callee in ("functools.partial", "partial") and dec.args:
                target = dotted_name(dec.args[0])
                if target in ("jax.jit", "jit"):
                    info.jitted = True
                else:
                    continue
            else:
                continue
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    info.static_argnames += _const_str_tuple(kw.value)
    return info


@dataclasses.dataclass
class Registration:
    """One pytree dataclass registration found at module level."""
    class_name: str
    data_fields: Tuple[str, ...]
    meta_fields: Tuple[str, ...]
    line: int


def find_registrations(tree: ast.Module) -> Dict[str, Registration]:
    regs: Dict[str, Registration] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func)
        if callee not in ("register_mitigation", "base.register_mitigation",
                          "jax.tree_util.register_dataclass",
                          "tree_util.register_dataclass",
                          "register_dataclass"):
            continue
        if not node.args:
            continue
        cls = dotted_name(node.args[0])
        if cls is None:
            continue
        data: Tuple[str, ...] = ()
        meta: Tuple[str, ...] = ()
        for kw in node.keywords:
            if kw.arg == "data_fields":
                data = _const_str_tuple(kw.value)
            elif kw.arg == "meta_fields":
                meta = _const_str_tuple(kw.value)
        regs[cls] = Registration(cls, data, meta, node.lineno)
    return regs


def is_dataclass_def(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        name = dotted_name(dec if not isinstance(dec, ast.Call) else dec.func)
        if name in ("dataclasses.dataclass", "dataclass"):
            return True
    return False


@dataclasses.dataclass
class FunctionContext:
    """One function/method plus everything the rules need about it."""
    node: ast.AST                     # FunctionDef | AsyncFunctionDef
    qualname: str                     # "Class.method" or "fn"
    class_name: Optional[str]
    jit: JitInfo
    registration: Optional[Registration]   # enclosing class's, if any
    parent_traced: bool = False       # defined inside a traced function

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def is_traced(self) -> bool:
        """Body runs under JAX tracing (jit decorator, *_jax contract, or
        nested inside a traced function — scan/cond bodies and helpers)."""
        return (self.jit.jitted or self.name == "apply_jax"
                or self.name.endswith("_jax") or self.parent_traced)

    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names

    def array_params(self) -> Set[str]:
        """Parameters annotated as arrays, minus static_argnames."""
        out: Set[str] = set()
        a = self.node.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            ann = p.annotation
            if ann is not None and dotted_name(ann) in ARRAY_ANNOTATIONS:
                out.add(p.arg)
        return out - set(self.jit.static_argnames)


def collect_functions(tree: ast.Module,
                      regs: Dict[str, Registration]
                      ) -> List[FunctionContext]:
    out: List[FunctionContext] = []

    def visit(node: ast.AST, class_name: Optional[str], prefix: str,
              parent_traced: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, child.name, f"{prefix}{child.name}.",
                      parent_traced)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ctx = FunctionContext(
                    node=child, qualname=f"{prefix}{child.name}",
                    class_name=class_name,
                    jit=decorator_jit_info(child),
                    registration=regs.get(class_name) if class_name else None,
                    parent_traced=parent_traced)
                out.append(ctx)
                visit(child, class_name, f"{prefix}{child.name}.",
                      ctx.is_traced)
    visit(tree, None, "", False)
    return out


class TracedVars:
    """Flow-insensitive traced-value inference inside one function.

    Seeds: array-annotated params + registered ``self.<data_field>``
    accesses.  One forward pass per statement list propagates through
    assignments: a target becomes traced when its RHS mentions a traced
    name, a ``self.<data_field>``, or calls into ``jnp.* / jax.*``
    value-producing APIs (minus the key-handling and host-boundary
    entry points).  Deliberately conservative: a miss means a missed
    lint, never a false positive on static values.
    """

    #: jax.* calls whose results are NOT device values in the traced sense
    NON_VALUE_CALLS = {
        "jax.device_get", "jax.tree_util.tree_structure", "jax.make_jaxpr",
        "jnp.ndim", "jnp.shape", "jnp.result_type",
    }

    def __init__(self, fn: FunctionContext,
                 module_returns: Optional[Dict[str, ast.AST]] = None):
        self.fn = fn
        self.data_fields: Set[str] = set(
            fn.registration.data_fields) if fn.registration else set()
        #: same-module function name -> return annotation AST, used to
        #: untaint tuple-unpack targets with non-array annotations
        self.module_returns = module_returns or {}
        self.traced: Set[str] = set(fn.array_params())
        self._propagate(fn.node)

    def _propagate(self, node: ast.AST) -> None:
        # two passes so later-defined helpers feeding earlier uses in
        # loops still converge for the common cases
        for _ in range(2):
            before = set(self.traced)
            for stmt in walk_shallow(node):
                if isinstance(stmt, ast.Assign):
                    if self.expr_is_traced(stmt.value):
                        if self._mark_by_annotation(stmt):
                            continue
                        for tgt in stmt.targets:
                            self._mark_target(tgt)
                elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                    if self.expr_is_traced(stmt.value):
                        self._mark_target(stmt.target)
                elif isinstance(stmt, ast.AugAssign):
                    if self.expr_is_traced(stmt.value):
                        self._mark_target(stmt.target)
                elif isinstance(stmt, ast.For):
                    if self.expr_is_traced(stmt.iter):
                        self._mark_target(stmt.target)
            if self.traced == before:
                break

    def _mark_by_annotation(self, stmt: ast.Assign) -> bool:
        """``freqs, mag = spectrum_jax(x, dt)`` where ``spectrum_jax`` is a
        same-module function annotated ``-> Tuple[np.ndarray, jnp.ndarray]``:
        mark only the targets whose annotation element is an array type.
        Returns True when the statement was fully handled this way."""
        if (len(stmt.targets) != 1
                or not isinstance(stmt.targets[0], (ast.Tuple, ast.List))
                or not isinstance(stmt.value, ast.Call)):
            return False
        callee = dotted_name(stmt.value.func)
        ann = self.module_returns.get(callee)
        if ann is None or not isinstance(ann, ast.Subscript):
            return False
        if dotted_name(ann.value) not in ("Tuple", "tuple", "typing.Tuple"):
            return False
        elts = getattr(ann.slice, "elts", None)
        targets = stmt.targets[0].elts
        if elts is None or len(elts) != len(targets):
            return False
        for tgt, el in zip(targets, elts):
            if dotted_name(el) in ARRAY_ANNOTATIONS:
                self._mark_target(tgt)
        return True

    def _mark_target(self, tgt: ast.AST) -> None:
        if isinstance(tgt, ast.Name):
            self.traced.add(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for elt in tgt.elts:
                self._mark_target(elt)

    def expr_is_traced(self, expr: ast.AST) -> bool:
        """Recursive traced-value test with the static escape hatches:
        ``x.shape`` arithmetic, builtin casts/aggregates, ``is None`` and
        string-key membership tests never count as traced."""
        node = expr
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            if isinstance(node.value, ast.Name) and node.value.id == "self":
                return node.attr in self.data_fields
            return self.expr_is_traced(node.value)
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Compare):
            ops = node.ops
            if all(isinstance(o, (ast.Is, ast.IsNot)) for o in ops):
                return False          # identity tests are host-safe
            if (all(isinstance(o, (ast.In, ast.NotIn)) for o in ops)
                    and isinstance(node.left, ast.Constant)
                    and isinstance(node.left.value, str)):
                return False          # "key" in metrics_dict is static
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func) or ""
            if (callee in STATIC_CALLS or callee in HOST_CAST_CALLS
                    or callee in self.NON_VALUE_CALLS):
                return False          # host-valued even on traced args
            if callee.startswith(JAX_VALUE_PREFIXES):
                return True
            # x.sum() / x.astype(...): a method call on a traced receiver
            # is a traced value even with no traced arguments
            return (self.expr_is_traced(node.func)
                    or any(self.expr_is_traced(a) for a in node.args)
                    or any(self.expr_is_traced(kw.value)
                           for kw in node.keywords))
        return any(self.expr_is_traced(child)
                   for child in ast.iter_child_nodes(node))

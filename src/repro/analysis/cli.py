"""``repro-lint`` — the three-tier JAX/Pallas correctness analyzer.

Tiers (all on by default; select with ``--tiers``):

- ``ast``       Tier-1 source rules RPR001-006 over the given paths.
- ``jaxpr``     Tier-2 traced-program checks (RPR100-102) over the
                registered entry points.
- ``recompile`` Tier-2 jit-cache gate (RPR103) — actually runs the
                registered workloads twice, so it is the slow tier.
- ``kernels``   Tier-3 Pallas launch-geometry checks (RPR200-205).
- ``deadmods``  untested-module report (RPR300).

Exit status is 1 when any non-baselined error or warning remains (info
findings never gate).  Intentional patterns are suppressed by the
checked-in ``lint_baseline.json``; every entry must carry a one-line
justification, and stale entries are reported so suppressions rot
loudly.  ``--write-baseline`` emits a fresh baseline covering the
current findings for a human to justify.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

from repro.analysis.findings import (Baseline, Finding, apply_baseline,
                                     render_json, render_text)
from repro.analysis.rules import RULE_CATALOG, lint_paths

ALL_TIERS = ("ast", "jaxpr", "recompile", "kernels", "deadmods")


def _find_root(start: str) -> str:
    cur = os.path.abspath(start)
    while True:
        if os.path.exists(os.path.join(cur, "pyproject.toml")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX/Pallas correctness analyzer (AST + jaxpr + kernel "
                    "tiers) for the power-stabilization repro")
    p.add_argument("paths", nargs="*",
                   help="files/dirs for the ast tier (default: src/repro)")
    p.add_argument("--root", default=None,
                   help="repo root (default: nearest pyproject.toml upward)")
    p.add_argument("--tiers", default=",".join(ALL_TIERS),
                   help=f"comma list of {'/'.join(ALL_TIERS)}")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--output", default=None,
                   help="write the report here as well as stdout")
    p.add_argument("--baseline", default=None,
                   help="baseline file (default: <root>/lint_baseline.json)")
    p.add_argument("--write-baseline", action="store_true",
                   help="write a baseline covering current findings and exit")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def collect_findings(tiers: List[str], paths: List[str],
                     root: str) -> List[Finding]:
    findings: List[Finding] = []
    if "ast" in tiers:
        findings.extend(lint_paths(paths, root))
    if "jaxpr" in tiers:
        from repro.analysis.jaxpr_checks import check_entry_points
        findings.extend(check_entry_points())
    if "recompile" in tiers:
        from repro.analysis.jaxpr_checks import recompile_gate
        findings.extend(recompile_gate())
    if "kernels" in tiers:
        from repro.analysis.kernel_checks import check_kernels
        findings.extend(check_kernels())
    if "deadmods" in tiers:
        from pathlib import Path

        from repro.analysis.deadmods import check_dead_modules
        findings.extend(check_dead_modules(Path(root)))
    return findings


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for spec in RULE_CATALOG.values():
            print(f"{spec.rule}  {spec.title}  [{spec.severity}]")
            print(f"        {spec.rationale}")
        return 0

    root = args.root or _find_root(os.getcwd())
    paths = args.paths or [os.path.join(root, "src", "repro")]
    tiers = [t.strip() for t in args.tiers.split(",") if t.strip()]
    bad = set(tiers) - set(ALL_TIERS)
    if bad:
        print(f"repro-lint: unknown tier(s) {sorted(bad)}", file=sys.stderr)
        return 2

    findings = collect_findings(tiers, paths, root)

    baseline_path = args.baseline or os.path.join(root, "lint_baseline.json")
    if args.write_baseline:
        gating = [f for f in findings if f.severity in ("error", "warning")]
        Baseline.write(baseline_path, gating)
        print(f"repro-lint: wrote {len(gating)} entr(ies) to "
              f"{baseline_path}; fill in the justifications")
        return 0

    baseline = Baseline.load(baseline_path)
    active, suppressed = apply_baseline(findings, baseline)
    # a tier subset can't see the other tiers' findings — only a full run
    # can judge a baseline entry stale
    stale = baseline.unused() if set(tiers) == set(ALL_TIERS) else []

    render = render_json if args.format == "json" else render_text
    report = render(active, suppressed, stale)
    print(report)
    if args.output:
        with open(args.output, "w") as fh:
            fh.write(report)
            fh.write("\n")

    gating = [f for f in active if f.severity in ("error", "warning")]
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())

"""Dead/untested-module report: static import reachability from tests/.

Parses every test module, resolves its (recursive) ``repro.*`` imports
through the src tree, and reports any ``src/repro`` module that no test
reaches — code the suite cannot possibly exercise.  ``launch/`` and
``models/`` are demonstration/config surfaces that are driven from the
CLI rather than the test suite, so their entries are informational;
anywhere else an unreachable module is an error (the gate the ISSUE
requires: zero untested modules outside launch//models).

Resolution is import-syntax only (``import repro.x``, ``from repro.x
import y`` — including the ``y`` being a submodule, and package
``__init__`` re-exports), which matches the repo's absolute-import
style.  Dynamic imports would be invisible, so this over-reports rather
than under-reports dead modules — the safe direction for a gate.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Set

from repro.analysis.findings import Finding

PACKAGE = "repro"
INFO_ONLY_PREFIXES = ("repro.launch", "repro.models")


def _module_name(py: Path, src_root: Path) -> str:
    rel = py.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def package_modules(src_root: Path) -> Dict[str, Path]:
    """All modules under src_root/repro, name -> file."""
    out: Dict[str, Path] = {}
    for py in sorted((src_root / PACKAGE).rglob("*.py")):
        out[_module_name(py, src_root)] = py
    return out


def module_imports(py: Path) -> Set[str]:
    """Dotted names this file imports (repro.* only, unresolved)."""
    try:
        tree = ast.parse(py.read_text(), filename=str(py))
    except SyntaxError:
        return set()
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            # the configs-registry idiom: importlib.import_module(
            # f"repro.configs.{name}") loads every submodule dynamically —
            # mark the whole subpackage reachable via a "prefix.*" entry
            callee = node.func
            name = ""
            while isinstance(callee, ast.Attribute):
                name = f".{callee.attr}{name}"
                callee = callee.value
            if isinstance(callee, ast.Name):
                name = callee.id + name
            if name in ("importlib.import_module", "import_module") \
                    and node.args:
                arg = node.args[0]
                if (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)
                        and arg.value.split(".")[0] == PACKAGE):
                    out.add(arg.value)
                elif (isinstance(arg, ast.JoinedStr) and arg.values
                        and isinstance(arg.values[0], ast.Constant)
                        and isinstance(arg.values[0].value, str)
                        and arg.values[0].value.split(".")[0] == PACKAGE):
                    out.add(arg.values[0].value.rstrip(".") + ".*")
            continue
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == PACKAGE:
                    out.add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:            # relative import: anchor at repro pkg
                base = PACKAGE        # repo style is absolute; be lenient
            elif node.module and node.module.split(".")[0] == PACKAGE:
                base = node.module
            else:
                continue
            out.add(base)
            for alias in node.names:
                out.add(f"{base}.{alias.name}")   # may be a submodule
    return out


def reachable_modules(roots: Iterable[Path], src_root: Path) -> Set[str]:
    """Transitive closure of repro.* imports starting from ``roots``."""
    mods = package_modules(src_root)
    seen: Set[str] = set()
    frontier: List[str] = []

    def enqueue(names: Set[str]) -> None:
        for name in names:
            if name.endswith(".*"):       # dynamic subpackage load
                prefix = name[:-2]
                for cand in mods:
                    if (cand == prefix or cand.startswith(prefix + ".")) \
                            and cand not in seen:
                        seen.add(cand)
                        frontier.append(cand)
                continue
            # "from repro.a import b" may name module repro.a.b or an
            # attribute of repro.a — accept whichever exists; either way
            # the parent package __init__ chain is imported too.
            parts = name.split(".")
            for i in range(len(parts), 0, -1):
                cand = ".".join(parts[:i])
                if cand in mods and cand not in seen:
                    seen.add(cand)
                    frontier.append(cand)

    for root in roots:
        enqueue(module_imports(root))
    while frontier:
        mod = frontier.pop()
        enqueue(module_imports(mods[mod]))
    return seen


def check_dead_modules(repo_root: Path) -> List[Finding]:
    src_root = repo_root / "src"
    mods = package_modules(src_root)
    test_files = sorted((repo_root / "tests").glob("test_*.py"))
    bench_files = sorted((repo_root / "benchmarks").glob("*.py"))
    reached = reachable_modules(test_files + bench_files, src_root)
    out: List[Finding] = []
    for name, py in sorted(mods.items()):
        if name in reached or name == PACKAGE:
            continue
        info = any(name == p or name.startswith(p + ".")
                   for p in INFO_ONLY_PREFIXES)
        out.append(Finding(
            rule="RPR300",
            path=str(py.relative_to(repo_root)), line=1,
            message=(f"module {name} is not imported (transitively) by any "
                     f"test or benchmark — "
                     + ("CLI-driven surface, informational"
                        if info else "untested code")),
            severity="info" if info else "error",
            context=name, tier="deadmods"))
    return out

"""Structured findings + checked-in baseline for ``repro-lint``.

A ``Finding`` is one rule hit: (rule id, path:line, message, severity,
context).  ``context`` is the enclosing symbol (``Class.method`` /
function qualname / kernel name) — the *line-number-independent* part of
a finding's identity, so baselines survive unrelated edits to the file.

The baseline file (``lint_baseline.json``, checked in at the repo root)
suppresses findings that are intentional: each entry carries a one-line
``justification`` explaining why the pattern is kept.  Matching is by
``(rule, path, context)``; a baseline entry suppresses every finding
with that key (a segmented cumsum that is safe once is safe at both its
re/im call sites).  Unused baseline entries are reported as warnings so
stale suppressions rot loudly, not silently.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str              # "RPR001"
    path: str              # repo-relative, forward slashes
    line: int              # 1-based; 0 = whole-file / whole-callable
    message: str
    severity: str = "error"
    context: str = ""      # enclosing symbol (baseline identity)
    tier: str = "ast"      # "ast" | "jaxpr" | "kernel" | "deadmod"

    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.context)

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        ctx = f" [{self.context}]" if self.context else ""
        return f"{loc}: {self.rule} {self.severity}: {self.message}{ctx}"

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.message))


class Baseline:
    """Suppression list keyed by (rule, path, context)."""

    def __init__(self, entries: Optional[Sequence[Dict]] = None,
                 path: Optional[str] = None):
        self.path = path
        self.entries: List[Dict] = list(entries or [])
        self._used = [False] * len(self.entries)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls(path=path)
        with open(path) as fh:
            data = json.load(fh)
        entries = data.get("entries", [])
        for e in entries:
            missing = {"rule", "path", "context", "justification"} - set(e)
            if missing:
                raise ValueError(
                    f"{path}: baseline entry {e} missing {sorted(missing)}")
        return cls(entries, path=path)

    def suppresses(self, finding: Finding) -> bool:
        rule, path, context = finding.key()
        hit = False
        for i, e in enumerate(self.entries):
            if (e["rule"] == rule and e["path"] == path
                    and e["context"] == context):
                self._used[i] = True
                hit = True
        return hit

    def unused(self) -> List[Dict]:
        return [e for e, u in zip(self.entries, self._used) if not u]

    @staticmethod
    def write(path: str, findings: Sequence[Finding],
              justification: str = "TODO: justify") -> None:
        """Emit a baseline covering ``findings`` (dedup by key) for a human
        to fill in justifications — the ``--write-baseline`` flow."""
        seen = {}
        for f in sort_findings(findings):
            seen.setdefault(f.key(), f)
        entries = [{"rule": f.rule, "path": f.path, "context": f.context,
                    "justification": justification}
                   for f in seen.values()]
        with open(path, "w") as fh:
            json.dump({"version": 1, "entries": entries}, fh, indent=2)
            fh.write("\n")


def apply_baseline(findings: Sequence[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[Finding]]:
    """-> (active, suppressed)."""
    active, suppressed = [], []
    for f in findings:
        (suppressed if baseline.suppresses(f) else active).append(f)
    return active, suppressed


def render_text(active: Sequence[Finding], suppressed: Sequence[Finding],
                unused_baseline: Sequence[Dict]) -> str:
    lines = [f.render() for f in sort_findings(active)]
    if suppressed:
        lines.append(f"-- {len(suppressed)} finding(s) suppressed by baseline")
    for e in unused_baseline:
        lines.append(f"-- stale baseline entry (no matching finding): "
                     f"{e['rule']} {e['path']} [{e['context']}]")
    n_err = sum(1 for f in active if f.severity == "error")
    n_warn = sum(1 for f in active if f.severity == "warning")
    lines.append(f"repro-lint: {n_err} error(s), {n_warn} warning(s), "
                 f"{len(suppressed)} baselined")
    return "\n".join(lines)


def render_json(active: Sequence[Finding], suppressed: Sequence[Finding],
                unused_baseline: Sequence[Dict]) -> str:
    return json.dumps({
        "findings": [f.to_json() for f in sort_findings(active)],
        "suppressed": [f.to_json() for f in sort_findings(suppressed)],
        "stale_baseline_entries": list(unused_baseline),
        "counts": {
            "error": sum(1 for f in active if f.severity == "error"),
            "warning": sum(1 for f in active if f.severity == "warning"),
            "info": sum(1 for f in active if f.severity == "info"),
            "suppressed": len(suppressed),
        },
    }, indent=2)

"""Tier-2 jaxpr analyzers over the registered entry points.

Three checks per entry (``registry.ENTRY_POINTS``):

1. **f32 long-axis accumulation** — walk the jaxpr (recursing into
   scan/cond/pjit/closed-call bodies AND Pallas kernel bodies) and flag
   any ``cumsum`` over an axis longer than ``LONG_AXIS_CUMSUM`` whose
   dtype is f32/c64: sequential prefix sums lose low bits linearly in
   length — the exact shape of the PR-3 bug, where a trace-length f32
   cumsum on a MW-scale DC offset buried a 1e5 W oscillation.  The
   fixed product path segments its cumsums at window length (2000), so
   it passes; re-introduce a trace-length accumulation anywhere on a
   registered path and CI fails.  Tree reductions (``reduce_sum``) lose
   only ~log2(n) bits, so they gate at a far higher threshold.

2. **host callbacks** — no ``pure_callback``/``io_callback``/
   ``debug_callback`` may appear in a compiled hot path (a callback is a
   per-call host round-trip; on the serve path that is a latency cliff).

3. **recompile gate** — run each ``registry.RECOMPILE_PAIRS`` workload
   twice with different data in the same shape bucket and assert the
   tracked jit caches (``_cache_size``) did not grow on the second call:
   re-calling within a bucket must hit the cache.  A miss means a shape
   or static-arg leaked into the jit key — the recompile-storm class.

``primitive_counts`` exposes the per-entry primitive histogram; the
deterministic counts are pinned by ``benchmarks/roofline.py --kernels``
so kernel fusion regressions fail CI with a named primitive diff.
"""
from __future__ import annotations

from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.registry import (ENTRY_POINTS, LONG_AXIS_CUMSUM,
                                     LONG_AXIS_REDUCE, RECOMPILE_PAIRS,
                                     EntryPoint, _tracked_jit_fns)

HOST_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback",
                       "callback", "outside_call", "host_callback_call"}

_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                       "body_jaxpr", "branches")

_NARROW_DTYPES = {"float32", "complex64", "bfloat16", "float16"}


def _iter_eqns(jaxpr, scope: str = ""):
    """Yield (eqn, scope) over a jaxpr and every inner jaxpr it closes
    over (scan/while/cond bodies, pjit calls, Pallas kernel bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn, scope
        prim = eqn.primitive.name
        for pname in _INNER_JAXPR_PARAMS:
            sub = eqn.params.get(pname)
            if sub is None:
                continue
            subs = sub if isinstance(sub, (tuple, list)) else (sub,)
            for s in subs:
                inner = s.jaxpr if hasattr(s, "jaxpr") else s
                yield from _iter_eqns(inner, f"{scope}/{prim}")


def check_jaxpr(closed_jaxpr, *, name: str,
                cumsum_axis_limit: int = LONG_AXIS_CUMSUM,
                reduce_axis_limit: int = LONG_AXIS_REDUCE) -> List[Finding]:
    """Structural findings for one traced program."""
    out: List[Finding] = []
    for eqn, scope in _iter_eqns(closed_jaxpr.jaxpr):
        prim = eqn.primitive.name
        if prim in HOST_CALLBACK_PRIMS:
            out.append(Finding(
                rule="RPR102", path=f"jaxpr:{name}", line=0,
                message=f"host callback '{prim}' inside compiled entry "
                        f"point (scope {scope or 'top'}): per-call host "
                        f"round-trip on a hot path",
                severity="error", context=name, tier="jaxpr"))
            continue
        if prim in ("cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp"):
            aval = eqn.invars[0].aval
            axis = eqn.params.get("axis", 0)
            length = aval.shape[axis] if aval.shape else 0
            if (length > cumsum_axis_limit
                    and str(aval.dtype) in _NARROW_DTYPES | {"complex64"}):
                out.append(Finding(
                    rule="RPR101", path=f"jaxpr:{name}", line=0,
                    message=f"{prim} over axis of length {length} in "
                            f"{aval.dtype} (scope {scope or 'top'}): "
                            f"sequential narrow-precision accumulation over "
                            f"a sample-length axis — the PR-3 cancellation "
                            f"class; segment it or promote to f64",
                    severity="error", context=name, tier="jaxpr"))
        elif prim == "reduce_sum":
            aval = eqn.invars[0].aval
            axes = eqn.params.get("axes", ())
            red = 1
            for a in axes:
                red *= aval.shape[a] if a < len(aval.shape) else 1
            if (red > reduce_axis_limit
                    and str(aval.dtype) in _NARROW_DTYPES):
                out.append(Finding(
                    rule="RPR101", path=f"jaxpr:{name}", line=0,
                    message=f"reduce_sum over {red} elements in "
                            f"{aval.dtype} (scope {scope or 'top'}): even a "
                            f"tree reduction this wide deserves f64 or a "
                            f"compensated scheme",
                    severity="warning", context=name, tier="jaxpr"))
    return out


def trace_entry(ep: EntryPoint):
    import jax
    fn, args, kwargs = ep.build()
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)


def check_entry_points(names: Optional[Sequence[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    for ep in ENTRY_POINTS:
        if names and ep.name not in names:
            continue
        try:
            closed = trace_entry(ep)
        except Exception as exc:          # registry rot is itself a finding
            out.append(Finding(
                rule="RPR100", path=f"jaxpr:{ep.name}", line=0,
                message=f"entry point failed to trace: {exc!r} — the Tier-2 "
                        f"registry no longer matches the code; update "
                        f"analysis/registry.py",
                severity="error", context=ep.name, tier="jaxpr"))
            continue
        out.extend(check_jaxpr(closed, name=ep.name))
    return out


def primitive_counts(ep: EntryPoint) -> Counter:
    """Histogram of primitive names over the entry's full jaxpr (inner
    bodies included, NOT multiplied by trip counts — fusion structure,
    not cost).  Deterministic for a fixed jax version + code state."""
    closed = trace_entry(ep)
    counts: Counter = Counter()
    for eqn, _ in _iter_eqns(closed.jaxpr):
        counts[eqn.primitive.name] += 1
    return counts


def primitive_diff(expected: Dict[str, int], got: Dict[str, int]
                   ) -> List[str]:
    """Named per-primitive diff lines; empty when identical."""
    lines = []
    for prim in sorted(set(expected) | set(got)):
        e, g = expected.get(prim, 0), got.get(prim, 0)
        if e != g:
            lines.append(f"{prim}: expected {e}, got {g:+d} delta {g - e:+d}"
                         .replace(f"got {g:+d}", f"got {g}"))
    return lines


# ---------------------------------------------------------------------------
# recompile gate
# ---------------------------------------------------------------------------

def _cache_sizes() -> Dict[str, int]:
    sizes = {}
    for name, fn in _tracked_jit_fns().items():
        try:
            sizes[name] = fn._cache_size()
        except Exception:
            sizes[name] = -1
    return sizes


def recompile_gate() -> List[Finding]:
    """Warm each registered workload, re-run it in the same shape bucket,
    and fail on any tracked jit-cache growth (= a compile miss where the
    cache must hit)."""
    out: List[Finding] = []
    for label, run in RECOMPILE_PAIRS:
        try:
            run(0)                      # warm: compiles are expected here
            before = _cache_sizes()
            run(1)                      # same bucket, different data
            after = _cache_sizes()
        except Exception as exc:
            out.append(Finding(
                rule="RPR100", path=f"jaxpr:{label}", line=0,
                message=f"recompile-gate workload failed to run: {exc!r}",
                severity="error", context=label, tier="jaxpr"))
            continue
        for name in sorted(before):
            if after[name] > before[name] >= 0:
                out.append(Finding(
                    rule="RPR103", path=f"jaxpr:{label}", line=0,
                    message=f"recompile storm: {name} jit cache grew "
                            f"{before[name]} -> {after[name]} on a second "
                            f"call in the same shape bucket; a shape or "
                            f"static arg is leaking into the jit key",
                    severity="error", context=f"{label}:{name}",
                    tier="jaxpr"))
    return out

"""Tier-3 static validation of Pallas kernel launch geometry.

Rather than re-deriving BlockSpecs from source text, each registered
kernel wrapper is *invoked* at a small representative shape with
``pl.pallas_call`` intercepted: the interceptor records the grid,
Block/out specs, out_shape, and scratch shapes, then returns a stub that
captures the real operand shapes/dtypes and yields zeros — no kernel
body ever executes, so this runs on any host.  The captured geometry is
checked against the TPU constraints in the Pallas guide:

- **RPR201 divisibility** — every block dim must divide its operand dim
  (a non-dividing block silently reads/writes out-of-bounds pads).
- **RPR202 grid coverage** — enumerating the grid through each output
  index_map must tile the output exactly once: a gap is uninitialized
  output, a duplicate is a write race across grid cells.
- **RPR203 narrow lanes** — a block whose minor (lane) dim is < 128
  wastes (128-K)/128 of every vector register and VMEM tile.  The v1
  sliding-Goertzel layout (K=4 bins on lanes) was the ROADMAP-known
  offender; the lane-major v2 kernels put win on lanes and retired the
  baseline entries, so any new narrow-lane block fails outright.
- **RPR204 sublane alignment** — f32 blocks of rank >= 2 at or above one
  (8, 128) tile should keep the second-minor dim a multiple of 8, else
  every block row pads to the next sublane boundary.
- **RPR205 VMEM budget** — resident bytes (all in/out blocks + scratch)
  must fit the per-core VMEM budget; overflow is a compile- or run-time
  failure on real hardware that interpret-mode tests never see.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple
from unittest import mock

from repro.analysis.findings import Finding

#: per-core VMEM (TPU v4/v5 class, see /opt/skills/guides: ~16 MiB)
VMEM_BUDGET_BYTES = 16 * 1024 * 1024
LANE = 128
SUBLANE_F32 = 8
#: blocks smaller than one (8, 128) f32 tile are scalar-ish operands
#: (phase tables, rotation rows) — layout rules don't bite there
MIN_TILE_ELEMS = SUBLANE_F32 * LANE
#: cap on grid enumeration for the coverage check
MAX_GRID_CELLS = 65536


@dataclasses.dataclass
class PallasCapture:
    """One intercepted ``pl.pallas_call`` launch."""
    grid: Tuple[int, ...]
    in_specs: Sequence[object]
    out_specs: Sequence[object]
    out_shapes: Sequence[object]          # ShapeDtypeStruct(s)
    scratch_shapes: Sequence[object]
    operands: Sequence[object] = ()       # ShapeDtypeStruct-likes of args


@dataclasses.dataclass
class KernelCase:
    name: str                             # e.g. "goertzel.sliding"
    path: str                             # source file, for findings
    run: Callable[[], None]               # invokes the wrapper (patched)


def _as_tuple(x) -> tuple:
    if x is None:
        return ()
    if isinstance(x, (tuple, list)):
        return tuple(x)
    return (x,)


def capture_kernel(case: KernelCase) -> List[PallasCapture]:
    """Run one wrapper with pallas_call intercepted; return its launches."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas

    captures: List[PallasCapture] = []

    def fake_pallas_call(kernel, *, grid=None, in_specs=None, out_specs=None,
                         out_shape=None, scratch_shapes=(), **kw):
        cap = PallasCapture(
            grid=_as_tuple(grid), in_specs=_as_tuple(in_specs),
            out_specs=_as_tuple(out_specs), out_shapes=_as_tuple(out_shape),
            scratch_shapes=_as_tuple(scratch_shapes))
        captures.append(cap)

        def stub(*operands):
            cap.operands = tuple(
                jax.ShapeDtypeStruct(o.shape, o.dtype) for o in operands)
            outs = tuple(jnp.zeros(s.shape, s.dtype) for s in cap.out_shapes)
            return outs[0] if len(outs) == 1 else outs
        return stub

    with mock.patch.object(pallas, "pallas_call", fake_pallas_call):
        case.run()
    return captures


def _dtype_bytes(dtype) -> int:
    import numpy as np
    try:
        return int(np.dtype(dtype).itemsize)
    except Exception:
        return 4


def _block_shape(spec) -> Optional[Tuple[int, ...]]:
    bs = getattr(spec, "block_shape", None)
    if bs is None:
        return None
    return tuple(int(d) for d in bs)


def _scratch_geom(s) -> Tuple[Tuple[int, ...], object]:
    shape = tuple(int(d) for d in getattr(s, "shape", ()))
    dtype = getattr(s, "dtype", "float32")
    return shape, dtype


def check_capture(case: KernelCase, cap: PallasCapture) -> List[Finding]:
    out: List[Finding] = []

    def finding(rule, msg, severity, what):
        out.append(Finding(
            rule=rule, path=case.path, line=0, message=msg,
            severity=severity, context=f"{case.name}:{what}", tier="kernels"))

    pairs = (list(zip(cap.in_specs, cap.operands,
                      [f"in{i}" for i in range(len(cap.in_specs))]))
             + list(zip(cap.out_specs, cap.out_shapes,
                        [f"out{i}" for i in range(len(cap.out_specs))])))

    resident = 0
    for spec, operand, what in pairs:
        block = _block_shape(spec)
        shape = tuple(int(d) for d in operand.shape)
        if block is None:          # whole-array spec: block = operand
            block = shape
        if len(block) != len(shape):
            finding("RPR201",
                    f"{what}: block rank {len(block)} != operand rank "
                    f"{len(shape)} (block {block} vs array {shape})",
                    "error", what)
            continue
        for d, (b, n) in enumerate(zip(block, shape)):
            if b <= 0 or n % b != 0:
                finding("RPR201",
                        f"{what}: block dim {d} = {b} does not divide "
                        f"array dim {n} (block {block}, array {shape}) — "
                        f"partial edge blocks read/write padding",
                        "error", what)
        resident += _dtype_bytes(operand.dtype) * _prod(block)
        if _prod(block) >= MIN_TILE_ELEMS and len(block) >= 1:
            if block[-1] < LANE:
                finding("RPR203",
                        f"{what}: minor (lane) block dim is {block[-1]} "
                        f"< {LANE} — each tile wastes "
                        f"{100 * (1 - block[-1] / LANE):.0f}% of its lanes; "
                        f"consider moving a longer axis minor-most",
                        "warning", what)
            elif (len(block) >= 2 and str(operand.dtype) == "float32"
                    and block[-2] % SUBLANE_F32 != 0):
                finding("RPR204",
                        f"{what}: second-minor block dim {block[-2]} is not "
                        f"a multiple of {SUBLANE_F32} (f32 sublane) — rows "
                        f"pad to the next sublane boundary",
                        "warning", what)

    for i, s in enumerate(cap.scratch_shapes):
        shape, dtype = _scratch_geom(s)
        resident += _dtype_bytes(dtype) * _prod(shape)

    if resident > VMEM_BUDGET_BYTES:
        finding("RPR205",
                f"resident VMEM estimate {resident / 2**20:.1f} MiB "
                f"(blocks + scratch) exceeds the {VMEM_BUDGET_BYTES // 2**20}"
                f" MiB per-core budget", "error", "vmem")

    out.extend(_check_coverage(case, cap))
    return out


def _prod(shape: Sequence[int]) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _check_coverage(case: KernelCase, cap: PallasCapture) -> List[Finding]:
    """Enumerate the grid through each output index_map: the mapped block
    positions must tile the output exactly once."""
    out: List[Finding] = []
    cells = _prod(cap.grid) if cap.grid else 1
    if cells == 0 or cells > MAX_GRID_CELLS:
        return out
    grid_points = list(itertools.product(*(range(g) for g in cap.grid))) \
        if cap.grid else [()]
    for oi, (spec, oshape) in enumerate(zip(cap.out_specs, cap.out_shapes)):
        block = _block_shape(spec)
        index_map = getattr(spec, "index_map", None)
        shape = tuple(int(d) for d in oshape.shape)
        if block is None or index_map is None or len(block) != len(shape):
            continue
        if any(b <= 0 or n % b for b, n in zip(block, shape)):
            continue                      # divisibility already reported
        want = set(itertools.product(*(range(n // b)
                                       for n, b in zip(shape, block))))
        seen: Dict[Tuple[int, ...], int] = {}
        try:
            for pt in grid_points:
                idx = tuple(int(v) for v in index_map(*pt))
                seen[idx] = seen.get(idx, 0) + 1
        except Exception as exc:
            out.append(Finding(
                rule="RPR202", path=case.path, line=0,
                message=f"out{oi}: index_map not evaluable on host ints "
                        f"({exc!r}) — coverage unverifiable",
                severity="warning", context=f"{case.name}:out{oi}",
                tier="kernels"))
            continue
        missing = want - set(seen)
        extra = set(seen) - want
        dups = {k: v for k, v in seen.items() if v > 1 and k in want}
        if missing:
            out.append(Finding(
                rule="RPR202", path=case.path, line=0,
                message=f"out{oi}: {len(missing)} output block(s) never "
                        f"written (e.g. {sorted(missing)[0]}) — "
                        f"uninitialized output regions",
                severity="error", context=f"{case.name}:out{oi}",
                tier="kernels"))
        if extra:
            out.append(Finding(
                rule="RPR202", path=case.path, line=0,
                message=f"out{oi}: index_map maps outside the output block "
                        f"grid (e.g. {sorted(extra)[0]})",
                severity="error", context=f"{case.name}:out{oi}",
                tier="kernels"))
        if dups:
            k, v = next(iter(sorted(dups.items())))
            out.append(Finding(
                rule="RPR202", path=case.path, line=0,
                message=f"out{oi}: {len(dups)} output block(s) written by "
                        f"multiple grid cells (e.g. {k} x{v}) — racy unless "
                        f"the grid dim is a sequential reduction axis",
                severity="warning", context=f"{case.name}:out{oi}",
                tier="kernels"))
    return out


# ---------------------------------------------------------------------------
# registered kernel cases (small shapes, real structure)
# ---------------------------------------------------------------------------

def _run_goertzel_windows():
    import jax.numpy as jnp
    from repro.kernels.goertzel.goertzel import goertzel_pallas
    goertzel_pallas(jnp.zeros((32, 2000), jnp.float32),
                    jnp.zeros((4,), jnp.float32), block_w=8)


def _run_sliding_goertzel_v2():
    import jax.numpy as jnp
    from repro.kernels.goertzel.goertzel import sliding_goertzel_v2_pallas
    # block_s=8 matches the production default in _sliding_bin_power_full;
    # KP=8 is K=4 padded to the f32 sublane count (lane-major [KP, win])
    win, K, KP = 2000, 4, 8
    tables = jnp.zeros((KP, win), jnp.float32)
    sliding_goertzel_v2_pallas(
        jnp.zeros((16, win), jnp.float32), tables, tables,
        jnp.zeros((KP, 2), jnp.float32), jnp.zeros((1, 4), jnp.float32),
        tables, tables, k=K, block_s=8)


def _run_sliding_monitor():
    import jax.numpy as jnp
    from repro.kernels.goertzel.goertzel import sliding_monitor_pallas
    # the fused monitor: same operand layout as the v2 amps kernel, plus
    # worst/class/peak outputs reduced in VMEM
    win, K, KP = 2000, 4, 8
    tables = jnp.zeros((KP, win), jnp.float32)
    sliding_monitor_pallas(
        jnp.zeros((16, win), jnp.float32), tables, tables,
        jnp.zeros((KP, 2), jnp.float32), jnp.zeros((1, 4), jnp.float32),
        tables, tables, k=K, block_s=8)


def _run_ballast():
    import jax.numpy as jnp
    from repro.kernels.ballast.ballast import ballast_pallas
    ballast_pallas(jnp.zeros((512, 256), jnp.float32),
                   jnp.zeros((256, 256), jnp.float32), 4, bm=256)


def _run_flash():
    import jax.numpy as jnp
    from repro.kernels.flash.flash import flash_pallas
    B, S, KV, G, D, T = 1, 2048, 2, 2, 128, 2048
    flash_pallas(jnp.zeros((B, S, KV, G, D), jnp.bfloat16),
                 jnp.zeros((B, T, KV, D), jnp.bfloat16),
                 jnp.zeros((B, T, KV, D), jnp.bfloat16),
                 q_block=1024, kv_chunk=1024)


KERNEL_CASES: List[KernelCase] = [
    KernelCase("goertzel.windows", "src/repro/kernels/goertzel/goertzel.py",
               _run_goertzel_windows),
    KernelCase("goertzel.sliding_v2", "src/repro/kernels/goertzel/goertzel.py",
               _run_sliding_goertzel_v2),
    KernelCase("goertzel.monitor", "src/repro/kernels/goertzel/goertzel.py",
               _run_sliding_monitor),
    KernelCase("ballast.gemm", "src/repro/kernels/ballast/ballast.py",
               _run_ballast),
    KernelCase("flash.fwd", "src/repro/kernels/flash/flash.py", _run_flash),
]


def check_kernels(names: Optional[Sequence[str]] = None) -> List[Finding]:
    out: List[Finding] = []
    for case in KERNEL_CASES:
        if names and case.name not in names:
            continue
        try:
            caps = capture_kernel(case)
        except Exception as exc:
            out.append(Finding(
                rule="RPR200", path=case.path, line=0,
                message=f"kernel case failed to launch under capture: "
                        f"{exc!r} — update analysis/kernel_checks.py",
                severity="error", context=case.name, tier="kernels"))
            continue
        if not caps:
            out.append(Finding(
                rule="RPR200", path=case.path, line=0,
                message="wrapper made no pallas_call — registry stale",
                severity="error", context=case.name, tier="kernels"))
        for cap in caps:
            out.extend(check_capture(case, cap))
    return out

"""Tier-2 registry: the repo's jitted entry points at representative shapes.

Every entry names one *compiled hot path* plus a builder that returns
``(fn, args, kwargs)`` ready for ``jax.make_jaxpr`` — the shapes are the
smallest ones that still exhibit the path's real structure (full-window
segments for the monitor, a multi-start lattice for the designer, a
padded scenario batch for the engine).  The jaxpr analyzers
(``jaxpr_checks``) walk these programs for f32 long-axis accumulation
and host callbacks, pin their primitive mix (``primitive_counts`` —
consumed by ``benchmarks/roofline.py``), and the recompile gate re-runs
the *callable* pairs registered in ``RECOMPILE_PAIRS`` to prove a second
same-shape-bucket call hits the jit cache.

Deliberately NOT registered: ``kernels/goertzel/ref.py``'s
``sliding_bin_power_jnp`` — the analysis-side cumsum oracle carries a
trace-length f32/c64 prefix sum by design (it is f64-gold-checked in
tests, and the product path is the segmented Pallas kernel).  Register
it and the long-axis gate fires — which is exactly the regression test
``tests/test_analysis.py`` runs against a deliberately reverted copy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

#: reduced-axis lengths above which a sequential f32/c64 cumsum is a finding
LONG_AXIS_CUMSUM = 4096
#: reduce_sum threshold (tree reductions lose ~log2(n) bits, far safer —
#: only flag genuinely enormous f32 reductions)
LONG_AXIS_REDUCE = 1 << 22


@dataclasses.dataclass
class EntryPoint:
    name: str
    build: Callable[[], Tuple[Callable, tuple, dict]]
    description: str


def _monitor_shapes():
    import jax.numpy as jnp
    x = jnp.asarray(__import__("numpy").random.default_rng(0)
                    .normal(5e8, 1e5, 100_000), jnp.float32)
    return x, 0.001, (0.5, 1.0, 2.0, 9.0), 2000


def _build_sliding_bin_power():
    """The backstop/product monitor: segmented Pallas path (interpret mode
    off-TPU), 1e5 samples / 2000-sample windows / 4 bins."""
    from repro.kernels.goertzel.ops import _sliding_bin_power_full
    x, dt, freqs, win = _monitor_shapes()
    return (_sliding_bin_power_full, (x,),
            dict(dt=dt, freqs=freqs, win=win, interpret=True))


def _build_detector_step():
    """Control-plane online detector: one segment step of the carry API
    (lane-major v2 kernel, prefix state streamed through [KP, win])."""
    import jax.numpy as jnp
    from repro.kernels.goertzel.ops import _phase_tables_v2, _sliding_seg_v2
    _, dt, freqs, win = _monitor_shapes()
    cosp, sinp, rot = (jnp.asarray(t) for t in
                       _phase_tables_v2(freqs, dt, win))
    seg = jnp.zeros((win,), jnp.float32)
    zeros = jnp.zeros_like(cosp)
    return (_sliding_seg_v2, (seg, zeros, zeros, cosp, sinp, rot,
                              jnp.float32(0.0)),
            dict(win=win, k=len(freqs), interpret=True))


def _build_monitor_fused():
    """The fused v2 monitor (backstop/detector fast path): worst bin +
    escalation class reduced in VMEM, blocked escalation scan on top."""
    import jax.numpy as jnp
    from repro.kernels.goertzel.ops import _sliding_monitor_full
    x, dt, freqs, win = _monitor_shapes()
    return (_sliding_monitor_full,
            (x, jnp.float32(1e6), jnp.float32(8e5)),
            dict(dt=dt, freqs=freqs, win=win, sustain_n=50, cool_n=80,
                 max_level=3, block_s=0, interpret=True, use_pallas=True))


def _sim_inputs(B: int = 2, spec=None):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import synthetic_timeline
    from repro.core.hardware import DEFAULT_HW
    from repro.core.smoothing.battery import RackBattery
    from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
    from repro.core.waveform import WaveformConfig, jitter_shifts, phase_levels
    from repro.core.engine import stack_mitigations

    cfg = WaveformConfig(dt=0.002, steps=4, jitter_s=0.002)
    hw = DEFAULT_HW
    tl = synthetic_timeline(period_s=1.0, comm_frac=0.3)
    levels = phase_levels(tl, cfg, hw)
    n = levels.shape[-1]
    shifts = np.stack([jitter_shifts(cfg, seed=s, sample_chips=64)
                       for s in range(B)])
    swing = 1e6
    gpus = stack_mitigations([
        GpuPowerSmoothing(mpf_frac=0.3 + 0.1 * i, ramp_up_w_per_s=2000.0,
                          ramp_down_w_per_s=2000.0, hw=hw)
        for i in range(B)])
    bats = stack_mitigations([
        RackBattery(capacity_j=swing * (i + 1), max_discharge_w=swing,
                    max_charge_w=swing) for i in range(B)])
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
    return dict(cfg=cfg, hw=hw, levels=jnp.asarray(
        np.broadcast_to(levels, (B, n)).copy(), jnp.float32),
        shifts=jnp.asarray(shifts), gpus=gpus, bats=bats, keys=keys, B=B, n=n)


def _build_simulate_step():
    """The engine's compiled scenario step (synthesis -> mitigation ->
    metrics -> spec verdicts), B=2 scenarios, spec validation on."""
    import jax.numpy as jnp
    from repro.core import engine
    from repro.core.spec import example_specs

    spec = example_specs(job_mw=1.0)["moderate"]
    si = _sim_inputs()
    B = si["B"]
    on = jnp.ones((B,), jnp.float32)
    limits = spec.limits()
    fn = engine._simulate_vmapped.__wrapped__   # trace the pre-jit function
    return (fn, (si["levels"], si["shifts"],
                 jnp.full((B,), 256.0, jnp.float32), si["gpus"], si["bats"],
                 on, on, si["keys"], None, limits),
            dict(cfg=si["cfg"], hw=si["hw"], spec=spec.family(),
                 spectra=False))


def _build_design_gradient_step():
    """One vmapped multi-start Adam descent of ``design_gradient`` (the
    compiled solver core), 4 starts x 12 steps on a 1e6 W square wave."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import engine
    from repro.core.hardware import DEFAULT_HW
    from repro.core.smoothing.battery import RackBattery
    from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
    from repro.core.spec import example_specs

    dt = 0.002
    n = 2000
    w = np.where((np.arange(n) // 250) % 2, 2e6, 1e6).astype(np.float32)
    spec = example_specs(job_mw=1.0)["moderate"]
    swing = 1e6
    cap_scale = swing * 2.0
    hw = DEFAULT_HW
    gpu_t = GpuPowerSmoothing(
        mpf_frac=0.5, hw=hw,
        ramp_up_w_per_s=spec.time.ramp_up_w_per_s / 256,
        ramp_down_w_per_s=spec.time.ramp_down_w_per_s / 256,
        smooth_tau=0.05)
    bat_t = RackBattery(capacity_j=cap_scale, max_discharge_w=swing,
                        max_charge_w=swing, smooth_tau=0.05)
    x0 = {"mpf": jnp.asarray([0.3, 0.6, 0.85, 0.5], jnp.float32),
          "cap": jnp.asarray([0.25, 1.0, 0.5, 0.75], jnp.float32)}
    lo = {"mpf": jnp.asarray(0.0, jnp.float32),
          "cap": jnp.asarray(1e-3, jnp.float32)}
    hi = {"mpf": jnp.asarray(hw.chip.mpf_max, jnp.float32),
          "cap": jnp.asarray(4.0, jnp.float32)}
    hyper = {"lr": jnp.asarray(0.08, jnp.float32),
             "margin": jnp.asarray(0.05, jnp.float32),
             "overhead_weight": jnp.asarray(0.5, jnp.float32),
             "size_weight": jnp.asarray(0.02, jnp.float32),
             "cap_scale": jnp.asarray(cap_scale, jnp.float32)}
    fn = engine._design_descend.__wrapped__
    return (fn, (x0, gpu_t, bat_t, jnp.asarray(w),
                 jnp.asarray(256.0, jnp.float32), lo, hi, hyper,
                 spec.limits()),
            dict(spec=spec.family(), dt=dt, steps=12))


def _build_serve_fingerprint():
    """Serve feature extractor: grid-critical Goertzel fingerprint."""
    import jax.numpy as jnp
    from repro.core.spectrum import (GRID_CRITICAL_HZ,
                                     goertzel_bin_amplitudes_jax)
    x = jnp.zeros((20_000,), jnp.float32)
    return (lambda x: goertzel_bin_amplitudes_jax(x, 0.002, GRID_CRITICAL_HZ),
            (x,), {})


def _build_warmstart_mlp():
    """Serve warm-start predictor forward pass (batch 8)."""
    import jax
    import jax.numpy as jnp
    from repro.serve.warmstart import (N_FEATURES, init_warmstart,
                                      warmstart_forward)
    params = init_warmstart(jax.random.PRNGKey(0))
    xb = jnp.zeros((8, N_FEATURES), jnp.float32)
    return (warmstart_forward, (params, xb), {})


ENTRY_POINTS: List[EntryPoint] = [
    EntryPoint("engine.simulate_step", _build_simulate_step,
               "batched scenario pipeline (synthesis->mitigation->spec)"),
    EntryPoint("engine.design_gradient_step", _build_design_gradient_step,
               "vmapped multi-start Adam descent on the smooth design stack"),
    EntryPoint("kernels.sliding_bin_power", _build_sliding_bin_power,
               "segmented sliding-Goertzel monitor (backstop hot path)"),
    EntryPoint("control.detector_step", _build_detector_step,
               "online monitor segment step (carry API, v2 kernel)"),
    EntryPoint("kernels.monitor_fused", _build_monitor_fused,
               "fused worst-bin + escalation monitor (v2 kernel)"),
    EntryPoint("serve.fingerprint", _build_serve_fingerprint,
               "grid-critical spectral fingerprint (serve features)"),
    EntryPoint("serve.warmstart_mlp", _build_warmstart_mlp,
               "warm-start MLP forward"),
]

ENTRY_BY_NAME: Dict[str, EntryPoint] = {e.name: e for e in ENTRY_POINTS}


# ---------------------------------------------------------------------------
# recompile gate registrations: (label, warm callable) pairs.  Each thunk
# invokes a *public* path twice with different data in the SAME shape
# bucket; between the two calls the tracked jit caches must not grow.
# ---------------------------------------------------------------------------

def _tracked_jit_fns() -> Dict[str, object]:
    """The jitted callables whose caches the gate watches."""
    from repro.core import engine
    from repro.kernels.goertzel import ops
    from repro.serve import warmstart
    return {
        "engine._simulate_vmapped": engine._simulate_vmapped,
        "engine._synth_vmapped": engine._synth_vmapped,
        "engine._mitigate_vmapped": engine._mitigate_vmapped,
        "engine._analyze_vmapped": engine._analyze_vmapped,
        "engine._validate_vmapped": engine._validate_vmapped,
        "engine._design_eval": engine._design_eval,
        "ops._sliding_bin_power_full": ops._sliding_bin_power_full,
        "ops._sliding_seg_v2": ops._sliding_seg_v2,
        "ops._monitor_seg_v2": ops._monitor_seg_v2,
        "ops._monitor_tail": ops._monitor_tail,
        "ops._sliding_monitor_full": ops._sliding_monitor_full,
        "ops._amps_at": ops._amps_at,
        "warmstart._predict_normalized": warmstart._predict_normalized,
    }


def _gate_monitor(seed: int) -> None:
    import numpy as np
    from repro.kernels.goertzel.ops import sliding_bin_power
    x = np.random.default_rng(seed).normal(5e8, 1e5, 30_000)
    sliding_bin_power(x.astype(np.float32), 0.001, (0.5, 1.0, 2.0, 9.0),
                      win=2000, interpret=True)


def _gate_engine(seed: int) -> None:
    from repro.core import engine, synthetic_timeline
    from repro.core.spec import example_specs
    from repro.core.waveform import WaveformConfig
    tl = synthetic_timeline(period_s=1.0, comm_frac=0.3)
    cfg = WaveformConfig(dt=0.002, steps=4, jitter_s=0.002)
    engine.simulate_batch(tl, 256, cfg, spec=example_specs(job_mw=1.0)["moderate"],
                          seeds=seed, sample_chips=64)


def _gate_monitor_fused(seed: int) -> None:
    import numpy as np
    from repro.kernels.goertzel.ops import (monitor_carry_init,
                                            sliding_monitor_fused)
    freqs = (0.5, 1.0, 2.0, 9.0)
    x = np.random.default_rng(seed).normal(5e8, 1e5, 30_000)
    x = x.astype(np.float32)
    sliding_monitor_fused(x, 0.001, freqs, win=2000, threshold=1e6,
                          sustain_n=50, cool_n=80, interpret=True)
    carry = monitor_carry_init(0.001, freqs, win=2000)
    for lo in range(0, 6000, 3000):
        _, _, _, carry = sliding_monitor_fused(
            x[lo:lo + 3000], 0.001, freqs, win=2000, threshold=1e6,
            sustain_n=50, cool_n=80, interpret=True, carry=carry)


RECOMPILE_PAIRS: List[Tuple[str, Callable[[int], None]]] = [
    ("monitor.sliding_bin_power", _gate_monitor),
    ("monitor.sliding_monitor_fused", _gate_monitor_fused),
    ("engine.simulate_batch", _gate_engine),
]

"""Tier-1 AST lint: rule catalog + engine (stdlib ``ast``, no deps).

The bug classes here are the ones that have either already cost this
repo a silent failure (RPR004 is the PR-3 f32-cumsum class) or that the
jit/vmap architecture makes easy to introduce and hard to see in review:

RPR001  host-sync-in-traced-code — ``float()``/``.item()``/
        ``np.asarray()`` on a traced value inside jitted / ``*_jax``
        code forces a device sync per call (or a tracer error that only
        fires on an untested path).
RPR002  prng-key-reuse — one key consumed by two sinks without an
        intervening ``split``/``fold_in`` silently correlates
        "independent" randomness.
RPR003  pytree-meta-mismatch — a registered dataclass field that is
        Python-branched on must be a ``meta_fields`` (static) entry;
        as a leaf it becomes a tracer under jit/vmap and the branch
        either crashes or (worse) freezes to the traced value.
RPR004  f32-long-axis-accumulation — sequential prefix sums
        (``cumsum``) accumulate rounding error linearly; over
        sample-length axes at MW scale this buried a 1e5 W oscillation
        (PR 3).  Safe forms: f64 promotion, or the segmented /
        mean-removed scheme the kernels use (baseline with a
        justification).
RPR005  python-branch-on-tracer — ``if``/``while`` on a traced value
        inside traced code is a ConcretizationTypeError waiting for the
        first caller that actually jits the path.
RPR006  mutable-default-in-pytree-dataclass — array/list/dict defaults
        are shared across instances; on a registered pytree they also
        alias leaves across configs in a stacked grid.
RPR007  process-identity-in-traced-code — ``jax.process_index()`` /
        ``jax.process_count()`` inside traced code (or stored as a
        pytree data field) bakes per-process values into what must be a
        single SPMD program: every process must trace the *same*
        computation over the global scenario mesh, so process identity
        is host-side control flow only (pick local rows, gate side
        effects), never a traced value.

Each rule reports structured ``Finding`` records; the engine runs every
rule over every file and the CLI applies the checked-in baseline.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.astutil import (HOST_CAST_CALLS, STATIC_ATTRS,
                                    STATIC_CALLS, FunctionContext,
                                    Registration, TracedVars,
                                    collect_functions, dotted_name,
                                    find_registrations, is_dataclass_def,
                                    walk_shallow)
from repro.analysis.findings import Finding

#: explicit host materializers (the casts live in astutil.HOST_CAST_CALLS)
HOST_MATERIALIZE_CALLS = {"np.asarray", "np.array", "numpy.asarray",
                          "numpy.array", "jax.device_get", "np.float32",
                          "np.float64", "np.int32", "np.int64"}
HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}

#: jax.random calls that *derive* keys rather than consuming entropy
KEY_DERIVATIONS = {"PRNGKey", "key", "split", "fold_in", "clone",
                   "key_data", "wrap_key_data"}

CUMSUM_CALLS = {"jnp.cumsum", "np.cumsum", "jnp.nancumsum", "jax.numpy.cumsum",
                "lax.cumsum", "jax.lax.cumsum", "lax.associative_scan"}

F64_NAMES = {"jnp.float64", "np.float64", "numpy.float64", "float64",
             "jnp.complex128", "np.complex128"}


@dataclasses.dataclass(frozen=True)
class RuleSpec:
    rule: str
    title: str
    severity: str
    rationale: str


RULE_CATALOG: Dict[str, RuleSpec] = {r.rule: r for r in [
    RuleSpec("RPR001", "host-sync-in-traced-code", "error",
             "float()/.item()/np.asarray() on traced values forces a device "
             "sync per call or a tracer error inside jit"),
    RuleSpec("RPR002", "prng-key-reuse", "error",
             "a PRNG key consumed by two sinks without split/fold_in "
             "correlates 'independent' randomness"),
    RuleSpec("RPR003", "pytree-meta-mismatch", "error",
             "Python-branched dataclass fields must be meta_fields (static), "
             "not vmappable leaves"),
    RuleSpec("RPR004", "f32-long-axis-accumulation", "warning",
             "sequential cumsum in f32 accumulates rounding linearly; the "
             "PR-3 bug class (use f64, or a segmented/mean-removed scheme "
             "and baseline it with a justification)"),
    RuleSpec("RPR005", "python-branch-on-tracer", "error",
             "if/while on a traced value is a ConcretizationTypeError on "
             "the first jitted caller"),
    RuleSpec("RPR006", "mutable-default-in-pytree-dataclass", "error",
             "array/list defaults are shared across instances and alias "
             "leaves across stacked configs"),
    RuleSpec("RPR007", "process-identity-in-traced-code", "error",
             "jax.process_index()/process_count() in traced code or pytree "
             "data fields bakes per-process values into the single SPMD "
             "program; process identity is host-side only"),
]}


@dataclasses.dataclass
class ModuleContext:
    path: str                     # repo-relative
    tree: ast.Module
    registrations: Dict[str, Registration]
    functions: List[FunctionContext]


def _finding(mod: ModuleContext, rule: str, node: ast.AST, message: str,
             context: str, severity: Optional[str] = None) -> Finding:
    spec = RULE_CATALOG[rule]
    return Finding(rule=rule, path=mod.path,
                   line=getattr(node, "lineno", 0),
                   message=f"{spec.title}: {message}",
                   severity=severity or spec.severity,
                   context=context, tier="ast")


# ---------------------------------------------------------------------------
# traced-expression classification (shared by RPR001 / RPR005)
# ---------------------------------------------------------------------------

def expr_traced(node: ast.AST, tv: TracedVars) -> bool:
    """Traced-value test (see ``TracedVars.expr_is_traced`` for the
    escape-hatch semantics — one classifier serves inference and rules)."""
    return tv.expr_is_traced(node)


def _module_returns(mod: ModuleContext) -> Dict[str, ast.AST]:
    """Top-level function name -> return annotation AST (used by the
    traced-value inference to untaint mixed tuple-unpack targets)."""
    return {fn.name: fn.node.returns for fn in mod.functions
            if fn.class_name is None and fn.node.returns is not None}


# ---------------------------------------------------------------------------
# RPR001 host-sync-in-traced-code
# ---------------------------------------------------------------------------

def rule_rpr001(mod: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for fn in mod.functions:
        if not fn.is_traced:
            continue
        tv = TracedVars(fn, _module_returns(mod))
        for node in walk_shallow(fn.node):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            hit = None
            if callee in HOST_CAST_CALLS and node.args:
                if expr_traced(node.args[0], tv):
                    hit = f"{callee}() on a traced value"
            elif callee in HOST_MATERIALIZE_CALLS and node.args:
                if expr_traced(node.args[0], tv):
                    hit = f"{callee}() materializes a traced value"
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in HOST_SYNC_METHODS
                  and expr_traced(node.func.value, tv)):
                hit = f".{node.func.attr}() on a traced value"
            if hit:
                out.append(_finding(
                    mod, "RPR001", node,
                    f"{hit} inside traced function", fn.qualname))
    return out


# ---------------------------------------------------------------------------
# RPR002 prng-key-reuse
# ---------------------------------------------------------------------------

def _key_id(node: ast.AST) -> Optional[Tuple[str, Optional[str]]]:
    """A key expression's identity: bare name, or name[int-literal].
    ``ks[i]`` with a loop variable is per-iteration unique -> None."""
    if isinstance(node, ast.Name):
        return (node.id, None)
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        idx = node.slice
        if isinstance(idx, ast.Constant):
            return (node.value.id, repr(idx.value))
        return None   # dynamic index: assume per-iteration unique
    return None


class _KeyReuse(ast.NodeVisitor):
    """Statement-order walk counting sink consumptions per key identity.

    Sinks: ``jax.random.<sampler>(key, ...)`` (anything outside
    KEY_DERIVATIONS) and ``key=<key>`` keyword passes into arbitrary
    calls.  ``split``/``fold_in`` are derivations, not sinks — they are
    exactly how a key is *supposed* to fan out.  An ``if``/``else``
    branch pair is exclusive, so counts merge as max across branches; a
    sink inside a loop on a key defined outside it fires immediately
    (every iteration would replay the same entropy).
    """

    def __init__(self, mod: ModuleContext, fn: FunctionContext):
        self.mod, self.fn = mod, fn
        self.counts: Dict[Tuple[str, Optional[str]], int] = {}
        self.key_vars: Set[str] = set()
        self.loop_depth = 0
        self.defined_in_loop: Set[str] = set()
        self.findings: List[Finding] = []
        for p in fn.params():
            if p in ("key", "rng", "rng_key", "prng_key"):
                self.key_vars.add(p)

    def _is_key_producer(self, call: ast.Call) -> bool:
        callee = dotted_name(call.func) or ""
        return (callee.startswith(("jax.random.", "random."))
                and callee.rsplit(".", 1)[-1] in KEY_DERIVATIONS)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        is_key = False
        if isinstance(node.value, ast.Call) and self._is_key_producer(node.value):
            is_key = True
        elif (isinstance(node.value, ast.Subscript)
              and isinstance(node.value.value, ast.Name)
              and node.value.value.id in self.key_vars):
            is_key = True
        elif (isinstance(node.value, ast.Name)
              and node.value.id in self.key_vars):
            is_key = True
        for tgt in node.targets:
            names = []
            if isinstance(tgt, ast.Name):
                names = [tgt.id]
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                names = [e.id for e in tgt.elts if isinstance(e, ast.Name)]
            for name in names:
                # rebinding resets consumption for that identity
                for k in [k for k in self.counts if k[0] == name]:
                    self.counts.pop(k)
                if is_key:
                    self.key_vars.add(name)
                    if self.loop_depth:
                        self.defined_in_loop.add(name)

    def _sink(self, key_expr: ast.AST, node: ast.AST, what: str) -> None:
        kid = _key_id(key_expr)
        if kid is None or kid[0] not in self.key_vars:
            return
        if self.loop_depth and kid[0] not in self.defined_in_loop:
            self.findings.append(_finding(
                self.mod, "RPR002", node,
                f"key '{kid[0]}' consumed by {what} inside a loop without a "
                f"per-iteration split/fold_in", self.fn.qualname))
            return
        self.counts[kid] = self.counts.get(kid, 0) + 1
        if self.counts[kid] == 2:
            label = kid[0] if kid[1] is None else f"{kid[0]}[{kid[1]}]"
            self.findings.append(_finding(
                self.mod, "RPR002", node,
                f"key '{label}' consumed twice (second sink: {what}) without "
                f"an intervening split/fold_in", self.fn.qualname))

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        callee = dotted_name(node.func) or ""
        if callee.startswith(("jax.random.", "random.")):
            leaf = callee.rsplit(".", 1)[-1]
            if leaf not in KEY_DERIVATIONS and node.args:
                self._sink(node.args[0], node, f"jax.random.{leaf}")
            return
        for kw in node.keywords:
            if kw.arg == "key":
                self._sink(kw.value, node, callee or "call")

    def visit_If(self, node: ast.If) -> None:
        # exclusive branches: each starts from the pre-branch counts and
        # the merged state keeps the per-key max
        base = dict(self.counts)
        branch_counts = []
        for body in (node.body, node.orelse):
            self.counts = dict(base)
            for stmt in body:
                self.visit(stmt)
            branch_counts.append(self.counts)
        merged = dict(base)
        for bc in branch_counts:
            for k, v in bc.items():
                merged[k] = max(merged.get(k, 0), v)
        self.counts = merged

    def _visit_loop(self, node) -> None:
        self.loop_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.loop_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_For(self, node: ast.For) -> None:
        self._visit_loop(node)

    def visit_While(self, node: ast.While) -> None:
        self._visit_loop(node)

    def visit_FunctionDef(self, node) -> None:
        if node is not self.fn.node:
            return            # nested defs get their own FunctionContext
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef


def rule_rpr002(mod: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for fn in mod.functions:
        walker = _KeyReuse(mod, fn)
        walker.visit(fn.node)
        out.extend(walker.findings)
    return out


# ---------------------------------------------------------------------------
# RPR003 pytree-meta-mismatch
# ---------------------------------------------------------------------------

def _self_data_fields(expr: ast.AST, data_fields: Set[str]) -> List[str]:
    hits = []
    for node in ast.walk(expr):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and node.attr in data_fields):
            hits.append(node.attr)
    return hits


def _isinstance_guarded_fields(test: ast.AST,
                               data_fields: Set[str]) -> Set[str]:
    """Fields F for which ``test`` is an ``isinstance(self.F, ...)`` check
    — the repo's sanctioned "only enforceable on concrete params" guard
    (isinstance on a tracer is False, never a concretization error)."""
    out: Set[str] = set()
    for node in ast.walk(test):
        if (isinstance(node, ast.Call)
                and dotted_name(node.func) == "isinstance" and node.args):
            arg = node.args[0]
            if (isinstance(arg, ast.Attribute)
                    and isinstance(arg.value, ast.Name)
                    and arg.value.id == "self" and arg.attr in data_fields):
                out.add(arg.attr)
    return out


def rule_rpr003(mod: ModuleContext) -> List[Finding]:
    out: List[Finding] = []

    def emit(fn: FunctionContext, node: ast.AST, test: ast.AST,
             data: Set[str], concrete: Set[str]) -> None:
        if any(isinstance(n, ast.Call)
               and dotted_name(n.func) == "isinstance"
               for n in ast.walk(test)):
            return                    # the guard itself is always safe
        for field in _self_data_fields(test, data - concrete):
            out.append(_finding(
                mod, "RPR003", node,
                f"'{field}' is a pytree data field (leaf) of "
                f"{fn.registration.class_name} but is Python-"
                f"branched on; move it to meta_fields, branch with "
                f"jnp.where/lax.cond, or guard with isinstance",
                fn.qualname))

    def walk(fn: FunctionContext, node: ast.AST, data: Set[str],
             concrete: Set[str]) -> None:
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)) and node is not fn.node):
            return                    # nested defs have their own context
        if isinstance(node, ast.If):
            emit(fn, node, node.test, data, concrete)
            inner = concrete | _isinstance_guarded_fields(node.test, data)
            for stmt in node.body:
                walk(fn, stmt, data, inner)
            for stmt in node.orelse:
                walk(fn, stmt, data, concrete)
            return
        if isinstance(node, (ast.While, ast.IfExp)):
            emit(fn, node, node.test, data, concrete)
        elif isinstance(node, ast.Assert):
            emit(fn, node, node.test, data, concrete)
        elif isinstance(node, ast.comprehension):
            for t in node.ifs:
                emit(fn, node, t, data, concrete)
        for child in ast.iter_child_nodes(node):
            walk(fn, child, data, concrete)

    for fn in mod.functions:
        if fn.registration is None:
            continue
        data = set(fn.registration.data_fields)
        walk(fn, fn.node, data, set())
    return out


# ---------------------------------------------------------------------------
# RPR004 f32-long-axis-accumulation (AST tier; exact lengths are Tier 2)
# ---------------------------------------------------------------------------

def _has_f64_dtype(call: ast.Call) -> bool:
    for kw in call.keywords:
        if kw.arg == "dtype" and (dotted_name(kw.value) or "") in F64_NAMES:
            return True
    return False


def rule_rpr004(mod: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for fn in mod.functions:
        for node in walk_shallow(fn.node):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func)
            if callee in CUMSUM_CALLS and not _has_f64_dtype(node):
                out.append(_finding(
                    mod, "RPR004", node,
                    f"{callee}() without f64 promotion — sequential f32 "
                    f"prefix sums over sample-length axes lose low bits "
                    f"(PR-3 class); promote, segment, or baseline with "
                    f"justification", fn.qualname))
    return out


# ---------------------------------------------------------------------------
# RPR005 python-branch-on-tracer
# ---------------------------------------------------------------------------

def rule_rpr005(mod: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for fn in mod.functions:
        if not fn.is_traced:
            continue
        tv = TracedVars(fn, _module_returns(mod))
        for node in walk_shallow(fn.node):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                if expr_traced(node.test, tv):
                    kind = type(node).__name__.lower()
                    out.append(_finding(
                        mod, "RPR005", node,
                        f"Python {kind} on a traced value inside traced "
                        f"function; use jnp.where / lax.cond / lax.select",
                        fn.qualname))
    return out


# ---------------------------------------------------------------------------
# RPR006 mutable-default-in-pytree-dataclass
# ---------------------------------------------------------------------------

_ARRAY_CTORS = ("np.", "numpy.", "jnp.", "jax.numpy.")


def rule_rpr006(mod: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if not (is_dataclass_def(node) or node.name in mod.registrations):
            continue
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign) and stmt.value is not None):
                continue
            bad = None
            if isinstance(stmt.value, (ast.List, ast.Dict, ast.Set)):
                bad = "mutable literal"
            elif isinstance(stmt.value, ast.Call):
                callee = dotted_name(stmt.value.func) or ""
                if callee.startswith(_ARRAY_CTORS):
                    bad = f"array constructor {callee}()"
            if bad:
                field = (stmt.target.id if isinstance(stmt.target, ast.Name)
                         else "<field>")
                out.append(_finding(
                    mod, "RPR006", stmt,
                    f"field '{field}' defaults to a {bad}, shared across "
                    f"every instance (and aliased across stacked pytree "
                    f"configs); use dataclasses.field(default_factory=...)",
                    node.name))
    return out


# ---------------------------------------------------------------------------
# RPR007 process-identity-in-traced-code
# ---------------------------------------------------------------------------

PROCESS_IDENTITY_CALLS = {"jax.process_index", "jax.process_count",
                          "process_index", "process_count"}


def _process_calls(node: ast.AST) -> List[ast.Call]:
    return [n for n in ast.walk(node)
            if isinstance(n, ast.Call)
            and dotted_name(n.func) in PROCESS_IDENTITY_CALLS]


def rule_rpr007(mod: ModuleContext) -> List[Finding]:
    out: List[Finding] = []
    # (a) calls inside traced code: the value becomes a compile-time
    # constant that differs per process -> divergent SPMD programs
    for fn in mod.functions:
        if fn.is_traced:
            for node in walk_shallow(fn.node):
                if (isinstance(node, ast.Call)
                        and dotted_name(node.func) in PROCESS_IDENTITY_CALLS):
                    out.append(_finding(
                        mod, "RPR007", node,
                        f"{dotted_name(node.func)}() inside traced function "
                        f"— every process must trace the same program; "
                        f"compute process identity on host and pass values "
                        f"in", fn.qualname))
        # (b) stored into a registered pytree's *data* field: the leaf
        # rides into jit as a per-process tracer value
        if fn.registration is not None:
            data = set(fn.registration.data_fields)
            for node in walk_shallow(fn.node):
                if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                    continue
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if (isinstance(tgt, ast.Attribute)
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self" and tgt.attr in data
                            and node.value is not None
                            and _process_calls(node.value)):
                        out.append(_finding(
                            mod, "RPR007", node,
                            f"pytree data field '{tgt.attr}' of "
                            f"{fn.registration.class_name} assigned from "
                            f"process identity — per-process leaf values "
                            f"desync the SPMD program; keep it host-side "
                            f"(or a meta field)", fn.qualname))
    # (c) class-body defaults on dataclasses / registered pytrees
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        if not (is_dataclass_def(node) or node.name in mod.registrations):
            continue
        reg = mod.registrations.get(node.name)
        data = set(reg.data_fields) if reg is not None else None
        for stmt in node.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and stmt.value is not None
                    and _process_calls(stmt.value)):
                continue
            field = (stmt.target.id if isinstance(stmt.target, ast.Name)
                     else "<field>")
            if data is not None and field not in data:
                continue              # meta/static field: host-side, fine
            out.append(_finding(
                mod, "RPR007", stmt,
                f"field '{field}' defaults to process identity — stacked "
                f"configs would carry per-process values into the single "
                f"SPMD program", node.name))
    return out


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

RULES: Dict[str, Callable[[ModuleContext], List[Finding]]] = {
    "RPR001": rule_rpr001,
    "RPR002": rule_rpr002,
    "RPR003": rule_rpr003,
    "RPR004": rule_rpr004,
    "RPR005": rule_rpr005,
    "RPR006": rule_rpr006,
    "RPR007": rule_rpr007,
}


def lint_source(src: str, path: str,
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the rule catalog over one file's source text."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as exc:
        return [Finding(rule="RPR000", path=path, line=exc.lineno or 0,
                        message=f"syntax error: {exc.msg}",
                        severity="error", context="", tier="ast")]
    regs = find_registrations(tree)
    mod = ModuleContext(path=path, tree=tree, registrations=regs,
                        functions=collect_functions(tree, regs))
    out: List[Finding] = []
    for rule_id in (rules or RULES):
        out.extend(RULES[rule_id](mod))
    return out


def iter_python_files(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = [d for d in dirs if not d.startswith((".", "__pycache"))]
            files.extend(os.path.join(root, n) for n in names
                         if n.endswith(".py"))
    return sorted(files)


def lint_paths(paths: Sequence[str], root: str,
               rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every .py under ``paths``; finding paths are ``root``-relative."""
    out: List[Finding] = []
    for fp in iter_python_files(paths):
        with open(fp) as fh:
            src = fh.read()
        rel = os.path.relpath(os.path.abspath(fp), root).replace(os.sep, "/")
        out.extend(lint_source(src, rel, rules))
    return out

"""repro.api — the one-stop public surface: declare -> run -> query.

Everything a study of the paper's scenario matrix needs, in one import:

    from repro import api

    study = api.Study(
        workloads={"dense": api.synthetic_timeline(2.0, 0.19),
                   "moe":   api.synthetic_timeline(3.0, 0.25, moe_notch=True)},
        fleets=[256, 512],
        configs={"none": None,
                 "mpf90": (api.GpuPowerSmoothing(mpf_frac=0.9), None)},
        specs=api.example_specs(job_mw=100.0),
        key=0)
    result = study.run()                      # compiled batched engine
    result.passing().pivot("workload", "config", "energy_overhead")

    service = api.PowerComplianceService()    # the serve path
    service.query(api.synthetic_timeline(2.0, 0.25), 512, "moderate")

The engine functions behind this (``repro.core.engine``) remain available
for direct use; the Study layer is the supported surface.
"""
from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import (IterationTimeline, Phase, from_dryrun_cell,
                               load_cell, synthetic_timeline)
from repro.core.engine import (StreamChunk, design, design_gradient,
                               design_grid, stream_batches)
from repro.parallel.sharding import ScenarioShardPlan, scenario_plan
from repro.core.smoothing import (CombinedMitigation, Firefly,
                                  GpuPowerSmoothing, RackBattery, Stack,
                                  TelemetryBackstop, design_mitigation)
from repro.core.spec import (FrequencyDomainSpec, SpecReport, TimeDomainSpec,
                             UtilitySpec, example_specs)
from repro.core.stratosim import SimResult, simulate, simulate_jit
from repro.core.study import (MitigationConfig, Scenario, Study, StudyResult)
from repro.core.telemetry import TelemetrySource
from repro.core.waveform import WaveformConfig
from repro.control import (ControlLog, ControlLoop, GridController,
                           InterventionLadder, OnlineGoertzelDetector,
                           ReplaySource, synthesize_ramp, watch_trace)
from repro.serve.power import PowerComplianceService, default_catalog
from repro.serve.warmstart import WarmStartPredictor, train_warmstart

__all__ = [
    # the declarative study surface
    "Study", "StudyResult", "Scenario", "MitigationConfig",
    # streaming execution + scenario-axis sharding
    "stream_batches", "StreamChunk", "ScenarioShardPlan", "scenario_plan",
    # the serve path
    "PowerComplianceService", "default_catalog",
    "WarmStartPredictor", "train_warmstart",
    # the grid-interactive control plane
    "ControlLoop", "ControlLog", "GridController", "InterventionLadder",
    "OnlineGoertzelDetector", "ReplaySource", "synthesize_ramp",
    "watch_trace",
    # scenario ingredients
    "IterationTimeline", "Phase", "synthetic_timeline", "from_dryrun_cell",
    "load_cell", "WaveformConfig", "TelemetrySource",
    "Hardware", "DEFAULT_HW",
    # mitigations
    "GpuPowerSmoothing", "RackBattery", "Firefly", "TelemetryBackstop",
    "CombinedMitigation", "Stack", "design_mitigation",
    "design", "design_gradient", "design_grid",
    # specs + serial reference
    "UtilitySpec", "TimeDomainSpec", "FrequencyDomainSpec", "SpecReport",
    "example_specs", "SimResult", "simulate", "simulate_jit",
]

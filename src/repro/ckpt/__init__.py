from repro.ckpt.checkpoint import (CheckpointManager, load_pytree_numpy,
                                   restore_pytree, save_pytree)
from repro.ckpt.resume import ResumeError, SweepCheckpoint

"""Checkpointing + fault-tolerance utilities.

- Leaves saved as .npy keyed by tree path; JSON manifest carries step,
  shapes, dtypes, and the *logical sharding spec* of every leaf.
- Async mode hands the (host-local) arrays to a writer thread so the train
  loop doesn't block on I/O — the paper's "checkpoint phases" are exactly
  these windows, and the power simulator consumes their timing.
- ``restore_pytree(..., shardings=...)`` re-device_puts onto a *different*
  mesh than the one that saved — elastic re-meshing for fault recovery
  (restore onto fewer/more pods after a failure).
- Atomic rename-based commit; retention GC keeps the newest ``keep`` steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _path_key(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_pytree(directory: str, tree, step: int, extra: Optional[Dict] = None):
    tmp = directory + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {},
                "time": time.time()}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for keypath, leaf in flat:
        key = _path_key(keypath)
        arr = np.asarray(jax.device_get(leaf))
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape),
                                   "object": bool(arr.dtype == object)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.replace(tmp, directory)  # atomic commit
    return directory


def restore_pytree(directory: str, template, shardings=None):
    """Restore into the structure of ``template``; optionally reshard.

    ``shardings``: matching pytree of jax.sharding.Sharding — leaves are
    device_put with the *new* sharding (elastic re-meshing), regardless of
    the mesh shape at save time.
    """
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_flat = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(flat))
    leaves = []
    for (keypath, leaf), sh in zip(flat, sh_flat):
        key = _path_key(keypath)
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(directory, meta["file"]),
                      allow_pickle=meta.get("object", False))
        if arr.dtype == object:
            leaves.append(arr)  # host-only payload (sweep-resume columns)
        elif sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), manifest


def load_pytree_numpy(directory: str):
    """Load every leaf of a saved pytree as host numpy, keyed by tree
    path (no template, no device placement) — the sweep-resume reader:
    restored metric columns scatter straight into the columnar record
    store.  Returns ``(leaves, manifest)``."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = {}
    for key, meta in manifest["leaves"].items():
        leaves[key] = np.load(os.path.join(directory, meta["file"]),
                              allow_pickle=meta.get("object", False))
    return leaves, manifest


class CheckpointManager:
    """Retention + async commit + latest-step discovery."""

    def __init__(self, root: str, keep: int = 3, async_save: bool = False):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def steps(self):
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: Optional[Dict] = None):
        # materialize on host *before* handing to the writer thread so the
        # train loop can donate/overwrite device buffers immediately
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _commit():
            save_pytree(self._dir(step), host_tree, step, extra)
            self._gc()

        if self.async_save:
            self.wait()
            self._thread = threading.Thread(target=_commit, daemon=True)
            self._thread.start()
        else:
            _commit()

    def restore_latest(self, template, shardings=None):
        steps = self.steps()
        if not steps:
            return None, None
        return restore_pytree(self._dir(steps[-1]), template, shardings)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._dir(s), ignore_errors=True)

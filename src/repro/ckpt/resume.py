"""Resumable sweep streams: per-chunk checkpoints of the columnar
``StudyResult`` through ``ckpt/checkpoint.py``.

``run_rows(..., resume=dir)`` threads a ``SweepCheckpoint`` through the
streaming loop.  Between chunks the primary process saves that chunk's
slice of the record columns (``save_chunk``); on restart the contiguous
prefix of valid chunk checkpoints is scattered back into the columns
(``restore_call``) and ``engine.stream_batches(skip_rows=...)`` never
dispatches the covered chunks.  Because per-row values are
chunk-composition independent (the PR-5 streaming invariant), a resumed
run is bit-identical to an uninterrupted one.

Identity is a two-level fingerprint in ``sweep.json``:

* ``config_sig`` — digest of everything row-independent (waveform
  config, hardware, spec names + limits, padding mode, sample_chips).
* ``rows_digest`` — a *rolling* sha256 chain over per-row signatures
  (workload content, fleet, mitigation config content, seed, PRNG key
  bytes).  Storing the chain value at ``n_rows`` means a finished sweep
  can be **extended**: a longer row list whose prefix chain matches is
  the same sweep plus new rows, so old chunks restore and only new rows
  compute.  Any other change breaks the chain and fails loudly.

Corruption never degrades to a silently-wrong merged result: a
truncated/unreadable chunk, a fingerprint mismatch, or a chunk-size
mismatch each raise ``ResumeError`` with the offending path and the fix.

Multi-process runs assume the resume dir is on a filesystem every
process can read (true for the subprocess-simulated harness and typical
multi-host setups); only process 0 writes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.ckpt.checkpoint import load_pytree_numpy, save_pytree

VERSION = 2  # v2: spec metrics stored as numeric "metrics:<name>" columns


class ResumeError(RuntimeError):
    """A resume directory that cannot safely continue this sweep."""


# ---------------------------------------------------------------------------
# fingerprints
# ---------------------------------------------------------------------------

def _update(h, obj) -> None:
    """Feed ``obj`` into hash ``h`` structurally: dataclasses by field,
    arrays by dtype/shape/bytes — no reliance on ``repr`` truncation."""
    if obj is None:
        h.update(b"\x00N")
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        h.update(type(obj).__name__.encode())
        for f in dataclasses.fields(obj):
            h.update(f.name.encode())
            _update(h, getattr(obj, f.name))
    elif isinstance(obj, Mapping):
        for k in obj:
            h.update(str(k).encode())
            _update(h, obj[k])
    elif isinstance(obj, (list, tuple)):
        for v in obj:
            _update(h, v)
    elif isinstance(obj, bytes):
        h.update(obj)
    elif isinstance(obj, str):
        h.update(obj.encode())
    elif isinstance(obj, (bool, int, float, np.bool_, np.integer,
                          np.floating)):
        h.update(repr(obj).encode() if not isinstance(obj, float)
                 else np.float64(obj).tobytes())
    elif hasattr(obj, "__array__"):
        a = np.asarray(obj)
        h.update(str(a.dtype).encode() + str(a.shape).encode() + a.tobytes())
    else:
        h.update(repr(obj).encode())


def digest(obj) -> str:
    h = hashlib.sha256()
    _update(h, obj)
    return h.hexdigest()


def config_signature(*, cfg, hw, specs, mode: str,
                     sample_chips: int) -> str:
    """Digest of the row-independent sweep identity.  The spec list is
    part of it because spec order fixes record positions."""
    h = hashlib.sha256()
    _update(h, ("v", VERSION, cfg, hw, mode, sample_chips))
    for name, sp in specs:
        _update(h, (name, sp))
    return h.hexdigest()


def rows_chain(workloads, rows, keys, at: Sequence[int]) -> Dict[int, str]:
    """Rolling sha256 over per-row signatures; returns the chain value at
    each requested prefix length (one pass, ``h.copy()`` snapshots).
    A match at prefix ``n`` proves the first ``n`` rows are the same
    sweep — the extension check."""
    want = set(at)
    wl = {w: digest(workloads[w]) for w in {r[0] for r in rows}}
    cfg_cache: Dict[int, str] = {}
    h = hashlib.sha256()
    out: Dict[int, str] = {}
    if 0 in want:
        out[0] = h.hexdigest()
    for r, (w, n, config, seed) in enumerate(rows):
        cd = cfg_cache.get(id(config))
        if cd is None:
            cd = cfg_cache[id(config)] = digest(config)
        h.update(f"{w}|{wl[w]}|{n}|{cd}|{seed}|".encode())
        if keys is None or keys[r] is None:
            h.update(b"nokey")
        else:
            h.update(np.asarray(keys[r]).tobytes())
        if r + 1 in want:
            out[r + 1] = h.hexdigest()
    return out


# ---------------------------------------------------------------------------
# record-position helpers
# ---------------------------------------------------------------------------

def record_positions(rows_global: np.ndarray, n_specs: int) -> np.ndarray:
    """Columnar positions of the given pipeline rows: record position =
    row * n_specs + spec index (the ``_fill_chunk`` layout)."""
    rows_global = np.asarray(rows_global, np.int64)
    return (np.repeat(rows_global * n_specs, n_specs)
            + np.tile(np.arange(n_specs, dtype=np.int64), len(rows_global)))


# ---------------------------------------------------------------------------
# the sweep checkpoint
# ---------------------------------------------------------------------------

class SweepCheckpoint:
    """Layout::

        <dir>/sweep.json                      fingerprint manifest
        <dir>/chunks/<call>/chunk_<lo>/       one save_pytree dir per chunk

    ``call`` is the call-stream key (structure group x length bucket) and
    ``lo`` the chunk's start offset inside that call's row-index list.
    """

    def __init__(self, directory: str):
        self.dir = directory
        self.manifest_path = os.path.join(directory, "sweep.json")

    def _chunk_dir(self, call: str, lo: int) -> str:
        return os.path.join(self.dir, "chunks", call, f"chunk_{lo:08d}")

    # -- fingerprint validation ---------------------------------------------

    def validate_or_init(self, *, workloads, rows, specs, keys, cfg, hw,
                         mode: str, sample_chips: int, chunk_size: int,
                         write: bool = True) -> None:
        """Check this directory continues the given sweep (raising
        ``ResumeError`` otherwise) and bring ``sweep.json`` up to date
        with the current row count (``write=False`` on non-primary
        processes)."""
        csig = config_signature(cfg=cfg, hw=hw, specs=specs, mode=mode,
                                sample_chips=sample_chips)
        old = None
        if os.path.exists(self.manifest_path):
            try:
                with open(self.manifest_path) as fh:
                    old = json.load(fh)
            except (json.JSONDecodeError, OSError) as e:
                raise ResumeError(
                    f"unreadable sweep manifest {self.manifest_path}: {e}; "
                    "delete the resume dir to start over") from e
        at = [len(rows)] + ([old["n_rows"]] if old else [])
        chain = rows_chain(workloads, rows, keys, at)
        if old is not None:
            if old.get("version") != VERSION:
                raise ResumeError(
                    f"{self.manifest_path}: version {old.get('version')} != "
                    f"{VERSION}; delete the resume dir to start over")
            if old["chunk_size"] != chunk_size:
                raise ResumeError(
                    f"resume dir {self.dir} was written with "
                    f"stream={old['chunk_size']} but this run uses "
                    f"stream={chunk_size}; chunk boundaries would not line "
                    f"up — rerun with stream={old['chunk_size']} or use a "
                    "fresh resume dir")
            if old["config_sig"] != csig:
                raise ResumeError(
                    f"resume dir {self.dir} fingerprint mismatch: waveform "
                    "config / hardware / specs / padding changed since the "
                    "checkpointed sweep — results would not be comparable; "
                    "use a fresh resume dir")
            if old["n_rows"] > len(rows):
                raise ResumeError(
                    f"resume dir {self.dir} checkpointed {old['n_rows']} "
                    f"pipeline rows but this run declares only {len(rows)}; "
                    "a sweep can be extended, not shrunk — use a fresh "
                    "resume dir")
            if chain[old["n_rows"]] != old["rows_digest"]:
                raise ResumeError(
                    f"resume dir {self.dir} fingerprint mismatch: the first "
                    f"{old['n_rows']} scenario rows differ from the "
                    "checkpointed grid (workload, fleet, config, seed, or "
                    "key change) — extending a sweep may only append rows; "
                    "use a fresh resume dir")
        if write and (old is None or old["n_rows"] != len(rows)):
            os.makedirs(self.dir, exist_ok=True)
            tmp = self.manifest_path + ".tmp"
            with open(tmp, "w") as fh:
                json.dump({"version": VERSION, "config_sig": csig,
                           "chunk_size": chunk_size, "n_rows": len(rows),
                           "n_specs": len(specs),
                           "rows_digest": chain[len(rows)]}, fh)
            os.replace(tmp, self.manifest_path)

    # -- per-chunk save / restore -------------------------------------------

    def save_chunk(self, call: str, idx: List[int], lo: int, hi: int,
                   cols: Dict[str, np.ndarray], n_specs: int) -> None:
        """Checkpoint rows ``idx[lo:hi]``'s records out of the columnar
        store (called right after ``_fill_chunk`` wrote them)."""
        rows_global = np.asarray(idx[lo:hi], np.int64)
        pos = record_positions(rows_global, n_specs)
        tree = {"rows": rows_global,
                "cols": {k: np.copy(v[pos]) for k, v in cols.items()
                         if k != "index"}}
        save_pytree(self._chunk_dir(call, lo), tree, step=lo,
                    extra={"call": call, "lo": lo, "hi": hi})

    def restore_call(self, call: str, idx: List[int], chunk_size: int,
                     cols: Dict[str, np.ndarray], n_specs: int) -> int:
        """Scatter the contiguous prefix of valid chunk checkpoints of
        this call stream back into ``cols``; returns the number of rows
        covered (the ``skip_rows`` for ``stream_batches``).

        A chunk checkpoint is valid iff its saved global row ids equal
        ``idx[lo:hi]`` for the current chunk boundaries — after an
        extension, a formerly-partial tail chunk that gained rows simply
        stops the prefix and is recomputed.  An unreadable chunk under a
        matching manifest raises ``ResumeError`` (never a silent hole).
        """
        covered = 0
        for lo in range(0, len(idx), chunk_size):
            hi = min(lo + chunk_size, len(idx))
            d = self._chunk_dir(call, lo)
            if not os.path.isdir(d):
                break
            try:
                leaves, _ = load_pytree_numpy(d)
            except Exception as e:
                raise ResumeError(
                    f"corrupt chunk checkpoint {d}: {e}; delete that "
                    "chunk directory to recompute it") from e
            saved_rows = leaves.get("rows")
            if saved_rows is None or not np.array_equal(
                    saved_rows, np.asarray(idx[lo:hi], np.int64)):
                # stale boundary (extended call stream) — recompute from here
                break
            pos = record_positions(saved_rows, n_specs)
            for k in cols:
                if k != "index" and f"cols/{k}" not in leaves:
                    raise ResumeError(
                        f"chunk checkpoint {d} is missing column {k!r}; "
                        "delete that chunk directory to recompute it")
            n = len(cols["index"])
            for path, leaf in leaves.items():
                if not path.startswith("cols/"):
                    continue
                k = path[len("cols/"):]
                v = cols.get(k)
                if v is None:
                    # side columns (e.g. "metrics:<name>") are created
                    # lazily by the fill path; a restore that runs first
                    # creates them here with the same NaN/empty default
                    v = cols[k] = (np.empty(n, dtype=object)
                                   if leaf.dtype == object
                                   else np.full(n, np.nan, dtype=leaf.dtype))
                v[pos] = leaf
            covered = hi
        return covered

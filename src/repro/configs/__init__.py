"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (AttentionConfig, LayerSpec, MLAConfig,
                                MambaConfig, ModelConfig, MoEConfig,
                                RWKVConfig, ShapeConfig, TrainConfig,
                                VisionStubConfig, LM_SHAPES, reduced,
                                shapes_for)

_MODULES: Dict[str, str] = {
    "granite-3-8b": "granite_3_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen1.5-110b": "qwen1_5_110b",
    "minitron-4b": "minitron_4b",
    "musicgen-medium": "musicgen_medium",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "dbrx-132b": "dbrx_132b",
    "jamba-v0.1-52b": "jamba_v0_1",
    "rwkv6-3b": "rwkv6_3b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


__all__ = [
    "ARCH_IDS", "get_config", "get_shape", "reduced", "shapes_for",
    "ModelConfig", "ShapeConfig", "TrainConfig", "LayerSpec",
    "AttentionConfig", "MLAConfig", "MoEConfig", "MambaConfig", "RWKVConfig",
    "VisionStubConfig", "LM_SHAPES",
]

"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``: a repeating
``unit`` of ``LayerSpec``s (mixer + ffn kind per position) applied
``n_repeats`` times, with optional non-repeated ``prefix`` layers.  The
repeating-unit representation is what lets the model apply layers with a
single ``lax.scan`` (compile time O(1) in depth) while still expressing
heterogeneous stacks (Jamba's 1:7 Mamba:attention interleave, Llama-vision's
every-5th cross-attention, DeepSeek's dense first layer).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer taxonomy
# ---------------------------------------------------------------------------

MIXERS = ("attn", "mla", "mamba", "rwkv", "xattn", "none")
FFNS = ("dense", "moe", "rwkv_cm", "none")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position inside the repeating unit."""

    mixer: str  # one of MIXERS
    ffn: str    # one of FFNS

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # Attention is computed with an online-softmax KV-chunked scan whenever
    # seq_len exceeds this (memory-roofline optimization); dense otherwise.
    chunk_size: int = 1024


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64  # rank of the data-dependent decay LoRA


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    """Precomputed-patch-embedding frontend stub (assignment: stub only)."""

    n_tokens: int = 1601
    dim: int = 7680


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    vocab_size: int
    d_ff: int
    mlp_kind: str  # swiglu | sq_relu | gelu
    unit: Tuple[LayerSpec, ...]
    n_repeats: int
    prefix: Tuple[LayerSpec, ...] = ()
    attention: Optional[AttentionConfig] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    vision: Optional[VisionStubConfig] = None
    # "tokens": int32 token ids in; "embeddings": precomputed frame
    # embeddings in (audio stub per assignment).
    input_mode: str = "tokens"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # cross-entropy computed in seq chunks of this size when set (avoids
    # materializing [B,S,V] logits — memory-roofline optimization)
    loss_chunk: int = 0
    # full attention? (pure full-attention archs skip long_500k per spec)
    sub_quadratic: bool = False

    # ------------------------------------------------------------------
    @property
    def n_layers(self) -> int:
        return len(self.prefix) + len(self.unit) * self.n_repeats

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND MODEL_FLOPS)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed top-k)."""
        return _param_count(self, active_only=True)

    def validate(self) -> None:
        for spec in self.prefix + self.unit:
            if spec.mixer in ("attn", "xattn"):
                assert self.attention is not None
            if spec.mixer == "mla":
                assert self.mla is not None and self.attention is not None
            if spec.mixer == "mamba":
                assert self.mamba is not None
            if spec.mixer == "rwkv":
                assert self.rwkv is not None
            if spec.ffn == "moe":
                assert self.moe is not None
        if any(s.mixer == "xattn" for s in self.unit + self.prefix):
            assert self.vision is not None


# ---------------------------------------------------------------------------
# Shapes (assignment-fixed input shape sets)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shape cells applicable to this arch (long_500k only if sub-quadratic)."""
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # skip noted in DESIGN.md §Shape-coverage
        out.append(s)
    return tuple(out)


# ---------------------------------------------------------------------------
# Training config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # memory knobs
    remat: str = "full"  # none | dots | full
    microbatches: int = 1
    moment_dtype: str = "float32"  # bf16 for the >=100B archs in dry-run
    # distributed-optimization tricks
    compress_grads: bool = False  # int8 error-feedback reduce
    # power-stabilization hook (the paper's technique, in-graph)
    ballast: bool = False
    ballast_gflops: float = 0.0


# ---------------------------------------------------------------------------
# Analytic parameter counting
# ---------------------------------------------------------------------------

def _mixer_params(cfg: ModelConfig, spec: LayerSpec) -> int:
    d = cfg.d_model
    if spec.mixer == "attn":
        a = cfg.attention
        q = d * a.n_heads * a.head_dim
        kv = 2 * d * a.n_kv_heads * a.head_dim
        o = a.n_heads * a.head_dim * d
        b = (a.n_heads + 2 * a.n_kv_heads) * a.head_dim if a.qkv_bias else 0
        return q + kv + o + b
    if spec.mixer == "xattn":
        a, v = cfg.attention, cfg.vision
        q = d * a.n_heads * a.head_dim
        kv = 2 * v.dim * a.n_kv_heads * a.head_dim
        o = a.n_heads * a.head_dim * d
        return q + kv + o + 2  # + gates
    if spec.mixer == "mla":
        a, m = cfg.attention, cfg.mla
        q = d * a.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        dkv = d * (m.kv_lora_rank + m.qk_rope_head_dim)
        uk = m.kv_lora_rank * a.n_heads * m.qk_nope_head_dim
        uv = m.kv_lora_rank * a.n_heads * m.v_head_dim
        o = a.n_heads * m.v_head_dim * d
        return q + dkv + uk + uv + o
    if spec.mixer == "mamba":
        m = cfg.mamba
        di = m.expand * d
        in_proj = d * 2 * di
        conv = m.d_conv * di
        x_proj = di * (m.d_state * 2 + _dt_rank(cfg))
        dt_proj = _dt_rank(cfg) * di
        a_d = di * m.d_state + di
        out = di * d
        return in_proj + conv + x_proj + dt_proj + a_d + out
    if spec.mixer == "rwkv":
        r = cfg.rwkv
        # r,k,v,g,o projections + decay/mix loras + per-head u
        return 5 * d * d + 2 * r.decay_lora * d + d + d
    return 0


def _ffn_params(cfg: ModelConfig, spec: LayerSpec, active_only: bool) -> int:
    d = cfg.d_model
    if spec.ffn == "dense":
        mult = 3 if cfg.mlp_kind == "swiglu" else 2
        return mult * d * cfg.d_ff
    if spec.ffn == "rwkv_cm":
        return 2 * d * cfg.d_ff + d * d  # k, v, receptance
    if spec.ffn == "moe":
        m = cfg.moe
        mult = 3  # routed experts are gated (swiglu) in all assigned MoEs
        per_expert = mult * d * m.d_ff_expert
        n = m.top_k if active_only else m.n_experts
        shared = m.n_shared * mult * d * m.d_ff_shared
        router = d * m.n_experts
        return n * per_expert + shared + router
    return 0


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab_size  # lm head
    layers = list(cfg.prefix) + list(cfg.unit) * cfg.n_repeats
    for spec in layers:
        total += _mixer_params(cfg, spec)
        total += _ffn_params(cfg, spec, active_only)
        total += 2 * cfg.d_model  # norms
    total += cfg.d_model  # final norm
    return total


# ---------------------------------------------------------------------------
# Reduced (smoke-test) configs
# ---------------------------------------------------------------------------

def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config: 1 unit repeat, small dims, for CPU smoke."""
    kw = {}
    if cfg.attention is not None:
        kw["attention"] = dataclasses.replace(
            cfg.attention, n_heads=4, n_kv_heads=2 if cfg.attention.n_kv_heads < cfg.attention.n_heads else 4,
            head_dim=16, chunk_size=32)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                              qk_rope_head_dim=8, v_head_dim=16)
        kw["attention"] = dataclasses.replace(cfg.attention, n_heads=4, n_kv_heads=4, head_dim=16)
    if cfg.moe is not None:
        # capacity_factor high enough to be dropless at smoke scale so
        # teacher-forced forward == token-by-token decode exactly
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_ff_expert=64,
            n_shared=cfg.moe.n_shared, d_ff_shared=64 if cfg.moe.n_shared else 0,
            capacity_factor=8.0)
    if cfg.mamba is not None:
        kw["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8)
    if cfg.vision is not None:
        kw["vision"] = VisionStubConfig(n_tokens=16, dim=48)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        d_model=64,
        vocab_size=256,
        d_ff=128,
        n_repeats=1,
        param_dtype="float32",
        compute_dtype="float32",
        loss_chunk=0,
        **kw,
    )

"""dbrx-132b — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
from repro.configs.base import (AttentionConfig, LayerSpec, MoEConfig,
                                ModelConfig)

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    d_model=6144,
    vocab_size=100352,
    d_ff=10752,
    mlp_kind="swiglu",
    unit=(LayerSpec("attn", "moe"),),
    n_repeats=40,
    attention=AttentionConfig(n_heads=48, n_kv_heads=8, head_dim=128),
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
    param_dtype="bfloat16",
    loss_chunk=512,
)

"""deepseek-v2-lite-16b — MLA + fine-grained MoE. [arXiv:2405.04434]

Assignment header says "MoE 64e top-6"; its trailing note says "160 routed".
We follow the header + the published model card: 64 routed + 2 shared
experts, top-6, MLA kv_lora_rank=512, first layer dense (see DESIGN.md §9).
"""
from repro.configs.base import (AttentionConfig, LayerSpec, MLAConfig,
                                MoEConfig, ModelConfig)

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    d_model=2048,
    vocab_size=102400,
    d_ff=10944,  # dense first-layer FFN width (model card)
    mlp_kind="swiglu",
    prefix=(LayerSpec("mla", "dense"),),
    unit=(LayerSpec("mla", "moe"),),
    n_repeats=26,
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=192),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared=2, d_ff_shared=1408),
    param_dtype="float32",
    loss_chunk=512,
)

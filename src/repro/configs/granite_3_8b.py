"""granite-3-8b — dense GQA transformer. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    d_model=4096,
    vocab_size=49155,
    d_ff=12800,
    mlp_kind="swiglu",
    unit=(LayerSpec("attn", "dense"),),
    n_repeats=40,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128),
    param_dtype="float32",
    loss_chunk=512,
)

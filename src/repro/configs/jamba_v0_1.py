"""jamba-v0.1-52b — hybrid Mamba+attention 1:7, MoE 16e top-2. [arXiv:2403.19887]

Repeating unit of 8 layers: attention at position 4, Mamba elsewhere; MoE on
odd positions (every other layer), dense FFN on even — matching the
published period-8 Jamba block. 4 repeats = 32 layers, 4 attention layers.
"""
from repro.configs.base import (AttentionConfig, LayerSpec, MambaConfig,
                                MoEConfig, ModelConfig)

_UNIT = tuple(
    LayerSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    vocab_size=65536,
    d_ff=14336,
    mlp_kind="swiglu",
    unit=_UNIT,
    n_repeats=4,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    param_dtype="bfloat16",
    loss_chunk=512,
    sub_quadratic=True,  # hybrid: Mamba state + only 4 attn layers -> long_500k runs
)

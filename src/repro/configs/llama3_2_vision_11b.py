"""llama-3.2-vision-11b — cross-attention image layers. [hf:meta-llama/...-Vision]

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (n_tokens x 7680). Repeating unit of 5 layers:
1 gated cross-attention + 4 self-attention, x8 = 40 layers / 8 xattn.
"""
from repro.configs.base import (AttentionConfig, LayerSpec, ModelConfig,
                                VisionStubConfig)

_UNIT = (LayerSpec("xattn", "dense"),) + (LayerSpec("attn", "dense"),) * 4

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    vocab_size=128256,
    d_ff=14336,
    mlp_kind="swiglu",
    unit=_UNIT,
    n_repeats=8,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    vision=VisionStubConfig(n_tokens=1601, dim=7680),
    param_dtype="float32",
    loss_chunk=512,
)

"""minitron-4b — pruned nemotron, squared-ReLU. [arXiv:2407.14679]"""
from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    d_model=3072,
    vocab_size=256000,
    d_ff=9216,
    mlp_kind="sq_relu",
    unit=(LayerSpec("attn", "dense"),),
    n_repeats=32,
    attention=AttentionConfig(n_heads=24, n_kv_heads=8, head_dim=128),
    param_dtype="float32",
    loss_chunk=512,
)

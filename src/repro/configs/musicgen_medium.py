"""musicgen-medium — decoder-only over EnCodec tokens. [arXiv:2306.05284]

Per the assignment, only the transformer BACKBONE is modeled; the EnCodec
modality frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (input_mode="embeddings"), and the head predicts one codebook of
2048 entries (the 4-codebook delay pattern lives in the stubbed frontend).
"""
from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    d_model=1536,
    vocab_size=2048,
    d_ff=6144,
    mlp_kind="gelu",
    unit=(LayerSpec("attn", "dense"),),
    n_repeats=48,
    attention=AttentionConfig(n_heads=24, n_kv_heads=24, head_dim=64),
    input_mode="embeddings",
    param_dtype="float32",
)

"""nemotron-4-340b — dense GQA, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    d_model=18432,
    vocab_size=256000,
    d_ff=73728,
    mlp_kind="sq_relu",
    unit=(LayerSpec("attn", "dense"),),
    n_repeats=96,
    attention=AttentionConfig(n_heads=96, n_kv_heads=8, head_dim=192),
    param_dtype="bfloat16",  # 340B: bf16 params + bf16 moments to fit v5e HBM
    loss_chunk=256,
)

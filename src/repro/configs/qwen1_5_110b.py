"""qwen1.5-110b — dense GQA with QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.base import AttentionConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    d_model=8192,
    vocab_size=152064,
    d_ff=49152,
    mlp_kind="swiglu",
    unit=(LayerSpec("attn", "dense"),),
    n_repeats=80,
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128, qkv_bias=True),
    param_dtype="bfloat16",
    loss_chunk=512,
)

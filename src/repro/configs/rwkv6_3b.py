"""rwkv6-3b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import LayerSpec, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    d_model=2560,
    vocab_size=65536,
    d_ff=8960,
    mlp_kind="gelu",  # unused by rwkv_cm; kept for completeness
    unit=(LayerSpec("rwkv", "rwkv_cm"),),
    n_repeats=32,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    param_dtype="float32",
    sub_quadratic=True,  # attn-free: O(1) state -> long_500k runs
)

"""repro.control — the grid-interactive control plane.

Closes the loop the paper's monitoring/mitigation sections describe:
a telemetry stream (live or replayed) flows through the online
sliding-Goertzel detector (bit-identical to the offline monitor via the
``sliding_bin_power`` carry API), a per-bin hysteresis controller with
slope-based early warning decides an escalation level, and an
intervention ladder (warm-started mitigation re-design → power cap +
ballast floor → job phase-stagger) is dispatched back into the stream.

    from repro import control

    w = control.synthesize_ramp()                 # 9 Hz amplitude ramp
    log = control.watch_trace(
        w, 0.002, spec=api.example_specs(500.0)["moderate"], n_chips=512)
    print(log.timeline())
    log.summary()["detection_lead_s"]             # detected before breach

Served via ``PowerComplianceService.watch()`` and
``repro-serve watch --replay ...``.
"""
from repro.control.controller import (ControlDecision, ControllerConfig,
                                      GridController)
from repro.control.detector import DetectorFrame, OnlineGoertzelDetector
from repro.control.interventions import (Intervention, InterventionLadder,
                                         power_cap_intervention,
                                         redesign_intervention,
                                         stagger_intervention)
from repro.control.log import ControlLog, ControlRecord
from repro.control.loop import ControlLoop, watch_trace
from repro.control.stream import ReplaySource, TelemetrySource, synthesize_ramp

__all__ = [
    "ControlDecision", "ControllerConfig", "GridController",
    "DetectorFrame", "OnlineGoertzelDetector",
    "Intervention", "InterventionLadder", "redesign_intervention",
    "power_cap_intervention", "stagger_intervention",
    "ControlLog", "ControlRecord",
    "ControlLoop", "watch_trace",
    "ReplaySource", "TelemetrySource", "synthesize_ramp",
]

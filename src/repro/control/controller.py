"""GridController: per-bin threshold-with-hysteresis + slope early warning.

The policy layer between detection and dispatch.  Each grid-critical bin
runs its own copy of the *shared* escalation state machine
(``core.telemetry.escalation_step`` — the exact gating the
``TelemetryBackstop`` runs offline, warm-up gate included), fed not with
the raw amplitude but with the slope-projected amplitude

    amp_eff = amp + max(slope, 0) * lead_s

so a bin trending toward its trigger escalates ``lead_s`` seconds early
— detection *before* breach, the whole point of a control plane.
Escalation triggers at ``trigger_frac`` of the breach amplitude and
releases with hysteresis at ``release_frac`` (sustained for
``release_ticks``), so a receding amplitude must fall well below the
trigger before interventions unwind.  The controller's target level is
the worst bin's level; the intervention ladder maps levels to actions.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.control.detector import DetectorFrame
from repro.core.telemetry import escalation_init, escalation_step

_NO_PAD = 2 ** 31 - 1      # streams have no trailing zero-pad to gate off


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    breach_w: float              # spec's per-bin breach amplitude
    trigger_frac: float = 0.85   # escalate at this fraction of breach
    release_frac: float = 0.60   # hysteresis release level
    lead_s: float = 2.0          # slope projection horizon (early warning)
    sustain_ticks: int = 2       # ticks above trigger before escalating
    release_ticks: int = 4       # ticks below release before de-escalating
    max_level: int = 3           # depth of the intervention ladder

    @property
    def trigger_w(self) -> float:
        return self.breach_w * self.trigger_frac

    @property
    def release_w(self) -> float:
        return self.breach_w * self.release_frac


@dataclasses.dataclass
class ControlDecision:
    tick: int
    t_s: float
    levels: np.ndarray           # [K] per-bin escalation level
    target_level: int            # max over bins → ladder depth to hold
    amps_eff: np.ndarray         # [K] slope-projected amplitudes
    margins_w: np.ndarray        # [K] trigger_w - amp_eff (negative = over)
    worst_bin: int               # index of the most-escalated/closest bin


class GridController:
    """Per-bin hysteresis escalation over detector frames."""

    def __init__(self, cfg: ControllerConfig, freqs, win: int):
        self.cfg = cfg
        self.freqs = tuple(float(f) for f in freqs)
        self.win = int(win)
        self._carries: List[Tuple] = [escalation_init() for _ in self.freqs]

    def decide(self, frame: DetectorFrame) -> ControlDecision:
        cfg = self.cfg
        amps_eff = frame.amps + np.maximum(frame.slopes, 0.0) * cfg.lead_s
        levels = np.zeros(len(self.freqs), np.int32)
        for k in range(len(self.freqs)):
            carry, level = escalation_step(
                self._carries[k], jnp.float32(amps_eff[k]),
                jnp.int32(frame.sample_idx),
                threshold=cfg.trigger_w, win=self.win, n=_NO_PAD,
                sustain_n=cfg.sustain_ticks, cool_n=cfg.release_ticks,
                max_level=cfg.max_level, release=cfg.release_w)
            self._carries[k] = carry
            levels[k] = int(level)
        margins = cfg.trigger_w - amps_eff
        # worst bin: highest level, margin as the tiebreak
        worst = int(np.lexsort((margins, -levels))[0])
        return ControlDecision(tick=frame.tick, t_s=frame.t_s, levels=levels,
                               target_level=int(levels.max()),
                               amps_eff=np.asarray(amps_eff, np.float32),
                               margins_w=np.asarray(margins, np.float32),
                               worst_bin=worst)

"""Online sliding-Goertzel detector: the offline monitor, run per tick.

``OnlineGoertzelDetector`` runs the *fused* v2 monitor kernel by default
(``fused=True``): each ``step(chunk)`` consumes one control tick of
samples through ``sliding_monitor_fused(..., carry=)`` — the lane-major
Pallas kernel reduces per-bin amplitudes to the per-sample worst bin and
its escalation class in VMEM, the blocked
``core.telemetry.escalation_scan`` advances the shared escalation
machine, and the per-bin amplitudes the controller consumes are
recombined in O(K) from the kernel's streamed prefix state — no
``[m, K]`` amplitude block is ever materialized.  The per-sample worst
stream and escalation level ride along in the frame as extra telemetry.

``fused=False`` selects the amps-materializing path on the same v2
kernel (``sliding_bin_power(..., carry=)``): every per-sample per-bin
amplitude is emitted (``frame.tick_amps``), *bit-identical* to one
offline ``sliding_bin_power`` call on the concatenated trace (the parity
test in ``tests/test_control.py`` asserts this across uneven tick
boundaries) — the replay/counterfactual path.

On top of the amplitudes the detector maintains per-bin trend slopes
over a short trailing horizon — the signal the controller's slope-based
early warning projects forward to act *before* a breach.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.goertzel.ops import (monitor_carry_init, sliding_bin_power,
                                        sliding_carry_init,
                                        sliding_monitor_fused)


@dataclasses.dataclass
class DetectorFrame:
    """One tick of detector output, consumed by ``GridController``."""
    tick: int
    t_s: float                 # time of the tick's last sample
    sample_idx: int            # global index of the tick's last sample
    amps: np.ndarray           # [K] bin amplitudes at the last sample
    slopes: np.ndarray         # [K] amplitude trend, W/s
    warm: bool                 # one full window has streamed
    # amps-materializing path (fused=False) only:
    tick_amps: Optional[np.ndarray] = None   # [m, K] per-sample amplitudes
    # fused path (fused=True) only:
    tick_worst: Optional[np.ndarray] = None  # [m] per-sample worst-bin amp
    level: int = 0             # shared escalation machine's level after tick


class OnlineGoertzelDetector:
    """Incremental per-bin amplitude monitor with trend estimation.

    ``mean`` is the DC operating point removed before accumulation
    (see ``sliding_carry_init``); ``slope_window_s`` bounds the trailing
    horizon the per-bin slope is estimated over (endpoint difference of
    tick-end amplitudes — cheap and robust for the controller's
    project-forward early warning).

    ``fused=True`` (default) runs the fused monitor kernel (worst bin +
    escalation class in VMEM; see module docstring); ``threshold_w`` /
    ``release_w`` / ``sustain_s`` / ``cooldown_s`` configure its shared
    escalation machine (default threshold ``+inf``: the machine idles
    and the fused path is a pure fast monitor).  ``fused=False`` keeps
    the amps-materializing path with full ``tick_amps``.
    """

    def __init__(self, dt: float, freqs: Sequence[float], *,
                 window_s: float = 4.0, mean: float = 0.0,
                 slope_window_s: Optional[float] = None,
                 fused: bool = True, threshold_w: Optional[float] = None,
                 release_w: Optional[float] = None,
                 sustain_s: float = 1.0, cooldown_s: float = 2.0,
                 max_level: int = 3):
        self.dt = float(dt)
        self.freqs = tuple(float(f) for f in freqs)
        self.win = max(int(window_s / dt), 8)
        self.fused = bool(fused)
        self.threshold_w = float(threshold_w if threshold_w is not None
                                 else np.inf)
        self.release_w = float(release_w if release_w is not None
                               else self.threshold_w)
        self.sustain_n = max(int(sustain_s / dt), 1)
        self.cool_n = max(int(cooldown_s / dt), 1)
        self.max_level = int(max_level)
        if self.fused:
            self.carry = monitor_carry_init(self.dt, self.freqs,
                                            win=self.win, mean=mean)
        else:
            self.carry = sliding_carry_init(self.dt, self.freqs,
                                            win=self.win, mean=mean)
        horizon = slope_window_s if slope_window_s is not None else window_s / 2
        self._hist: Deque[Tuple[float, np.ndarray]] = collections.deque()
        self._horizon_s = max(float(horizon), self.dt)
        self._tick = 0

    @property
    def n_bins(self) -> int:
        return len(self.freqs)

    def step(self, chunk: np.ndarray) -> DetectorFrame:
        tick_amps = tick_worst = None
        level = 0
        if self.fused:
            worst, levels, latest, self.carry = sliding_monitor_fused(
                chunk, self.dt, self.freqs, win=self.win,
                threshold=self.threshold_w, release=self.release_w,
                sustain_n=self.sustain_n, cool_n=self.cool_n,
                max_level=self.max_level, carry=self.carry)
            tick_worst = np.asarray(worst, np.float32)
            level = int(levels[-1]) if len(levels) else int(self.carry.esc[0])
            offset = int(self.carry.sliding.offset)
        else:
            amps, self.carry = sliding_bin_power(chunk, self.dt, self.freqs,
                                                 win=self.win,
                                                 carry=self.carry)
            tick_amps = np.asarray(amps, np.float32)
            latest = (amps[-1] if len(amps)
                      else np.zeros(self.n_bins, np.float32))
            offset = int(self.carry.offset)
        last_idx = offset - 1
        t_s = last_idx * self.dt
        self._hist.append((t_s, latest))
        while (len(self._hist) > 2
               and t_s - self._hist[0][0] > self._horizon_s):
            self._hist.popleft()
        t0, a0 = self._hist[0]
        span = t_s - t0
        slopes = ((latest - a0) / span if span > 0
                  else np.zeros(self.n_bins, np.float32))
        frame = DetectorFrame(tick=self._tick, t_s=t_s, sample_idx=last_idx,
                              amps=np.asarray(latest, np.float32),
                              slopes=np.asarray(slopes, np.float32),
                              warm=last_idx >= self.win - 1,
                              tick_amps=tick_amps, tick_worst=tick_worst,
                              level=level)
        self._tick += 1
        return frame

"""Online sliding-Goertzel detector: the offline monitor, run per tick.

``OnlineGoertzelDetector`` wraps the ``sliding_bin_power`` carry API:
each ``step(chunk)`` consumes one control tick of samples and advances
the same modulated-prefix-sum state the Pallas kernel carries in VMEM
scratch, so the amplitudes it reports are *bit-identical* to one offline
``sliding_bin_power`` call on the concatenated trace (the parity test in
``tests/test_control.py`` asserts this across uneven tick boundaries).
On top of the raw amplitudes it maintains per-bin trend slopes over a
short trailing horizon — the signal the controller's slope-based early
warning projects forward to act *before* a breach.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.goertzel.ops import sliding_bin_power, sliding_carry_init


@dataclasses.dataclass
class DetectorFrame:
    """One tick of detector output, consumed by ``GridController``."""
    tick: int
    t_s: float                 # time of the tick's last sample
    sample_idx: int            # global index of the tick's last sample
    amps: np.ndarray           # [K] bin amplitudes at the last sample
    slopes: np.ndarray         # [K] amplitude trend, W/s
    tick_amps: np.ndarray      # [m, K] per-sample amplitudes of this tick
    warm: bool                 # one full window has streamed


class OnlineGoertzelDetector:
    """Incremental per-bin amplitude monitor with trend estimation.

    ``mean`` is the DC operating point removed before accumulation
    (see ``sliding_carry_init``); ``slope_window_s`` bounds the trailing
    horizon the per-bin slope is estimated over (endpoint difference of
    tick-end amplitudes — cheap and robust for the controller's
    project-forward early warning).
    """

    def __init__(self, dt: float, freqs: Sequence[float], *,
                 window_s: float = 4.0, mean: float = 0.0,
                 slope_window_s: Optional[float] = None):
        self.dt = float(dt)
        self.freqs = tuple(float(f) for f in freqs)
        self.win = max(int(window_s / dt), 8)
        self.carry = sliding_carry_init(self.dt, self.freqs, win=self.win,
                                        mean=mean)
        horizon = slope_window_s if slope_window_s is not None else window_s / 2
        self._hist: Deque[Tuple[float, np.ndarray]] = collections.deque()
        self._horizon_s = max(float(horizon), self.dt)
        self._tick = 0

    @property
    def n_bins(self) -> int:
        return len(self.freqs)

    def step(self, chunk: np.ndarray) -> DetectorFrame:
        amps, self.carry = sliding_bin_power(chunk, self.dt, self.freqs,
                                             win=self.win, carry=self.carry)
        last_idx = int(self.carry.offset) - 1
        t_s = last_idx * self.dt
        latest = amps[-1] if len(amps) else np.zeros(self.n_bins, np.float32)
        self._hist.append((t_s, latest))
        while (len(self._hist) > 2
               and t_s - self._hist[0][0] > self._horizon_s):
            self._hist.popleft()
        t0, a0 = self._hist[0]
        span = t_s - t0
        slopes = ((latest - a0) / span if span > 0
                  else np.zeros(self.n_bins, np.float32))
        frame = DetectorFrame(tick=self._tick, t_s=t_s, sample_idx=last_idx,
                              amps=np.asarray(latest, np.float32),
                              slopes=np.asarray(slopes, np.float32),
                              tick_amps=np.asarray(amps, np.float32),
                              warm=last_idx >= self.win - 1)
        self._tick += 1
        return frame

"""The intervention ladder: what the controller dispatches, per level.

Each rung produces an ``Intervention`` — a named, parameterized
transform over the fleet's *future* power trace (what a ``ReplaySource``
applies to its not-yet-streamed suffix; on a live fleet the same three
knobs are config pushes):

  level 1  redesign   — re-run the warm-started ``design()`` path on the
                        recent observed history (scaled by a headroom
                        factor so the config covers where the trend is
                        going) and apply the resulting device + rack
                        mitigation pair exactly as the design engine
                        evaluates candidates.
  level 2  power cap  — clamp the aggregate into a band around the
                        operating point tight enough that the residual
                        bin amplitude sits below the release-hysteresis
                        level; the trough side is backed by a Firefly
                        ballast sized via ``ballast_gflops_for_floor``.
  level 3  stagger    — phase-stagger job groups with a ``1/(G*f)`` comb
                        of start offsets (a ``core.stagger``
                        ``StaggerSchedule``), which nulls the offending
                        bin: sum_g e^{-2*pi*i*f*g/(G*f)} = 0.

Rungs are cumulative — level 2 holds both the redesign and the cap —
mirroring how the paper layers mitigations (Sec. IV) and how the
Emerald Conductor escalates orchestrator actions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.ballast_inject import ballast_gflops_for_floor
from repro.core.engine import design
from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.stagger import StaggerSchedule


@dataclasses.dataclass
class Intervention:
    """A dispatched action: a transform over future aggregate power plus
    a JSON-safe parameter summary for the ``ControlLog``."""
    name: str
    params: Dict
    transform: Callable[[np.ndarray, float], np.ndarray]
    build_latency_s: float = 0.0


def redesign_intervention(spec, history_w: np.ndarray, dt: float,
                          n_chips: int, *, hw: Hardware = DEFAULT_HW,
                          method: str = "grid", warmstart=None,
                          headroom: float = 1.25) -> Optional[Intervention]:
    """Rung 1: warm-started mitigation re-design on observed history.

    The design target is the history with its AC component scaled by
    ``headroom`` — the config must cover where the amplitude trend is
    going, not where it was.  Returns None when the design path finds no
    feasible config or a do-nothing config (nothing to dispatch — the
    controller escalates to the next rung on its own)."""
    w = np.asarray(history_w, np.float32)
    mean = float(w.mean())
    target = (mean + headroom * (w - mean)).astype(np.float32)
    t0 = time.perf_counter()
    sol = design(spec, target, dt, n_chips, method=method, hw=hw,
                 warmstart=warmstart)
    latency = time.perf_counter() - t0
    if sol is None:
        return None
    gpu = sol.get("device_mitigation")
    bat = sol.get("rack_mitigation")
    if gpu is None and bat is None:
        return None

    def transform(future: np.ndarray, dt_: float) -> np.ndarray:
        out = jnp.asarray(future, jnp.float32)
        if gpu is not None:
            # per-chip device mitigation, exactly as _design_eval applies it
            out = gpu.apply_jax(out / n_chips, dt_)[0] * n_chips
        if bat is not None:
            out = bat.apply_jax(out, dt_)[0]
        return np.asarray(out, np.float32)

    return Intervention(
        name="redesign",
        params={"mpf_frac": float(sol.get("mpf_frac") or 0.0),
                "battery_capacity_j": float(sol.get("battery_capacity_j")
                                            or 0.0),
                "energy_overhead": float(sol.get("energy_overhead", 0.0)),
                "method": sol.get("method", method),
                "headroom": headroom},
        transform=transform, build_latency_s=latency)


def power_cap_intervention(history_w: np.ndarray, dt: float, *,
                           release_amp_w: float, n_chips: int,
                           hw: Hardware = DEFAULT_HW,
                           band_frac: float = 0.5) -> Intervention:
    """Rung 2: clamp the aggregate into ``mean ± band_frac*release_amp_w``.

    A hard clamp turns a large oscillation into a square-ish residual
    whose fundamental is ``4/pi`` times the half-band, so ``band_frac=0.5``
    keeps the residual bin amplitude at most ``0.64 * release_amp_w`` —
    safely below the release-hysteresis level.  The floor side is what
    the Firefly ballast provides; its required size is reported in the
    params so the orchestrator can schedule the burn."""
    w = np.asarray(history_w, np.float64)
    mean = float(w.mean())
    half_band = band_frac * float(release_amp_w)
    cap_w = mean + half_band
    floor_w = mean - half_band
    gflops = ballast_gflops_for_floor(w, dt, floor_w, n_chips, hw=hw)

    def transform(future: np.ndarray, dt_: float) -> np.ndarray:
        return np.clip(future, np.float32(floor_w),
                       np.float32(cap_w)).astype(np.float32)

    return Intervention(
        name="power_cap",
        params={"cap_w": cap_w, "floor_w": floor_w,
                "ballast_gflops": float(gflops)},
        transform=transform)


def stagger_intervention(f_hz: float, dt: float, *, n_groups: int = 4,
                         history_w: Optional[np.ndarray] = None
                         ) -> Intervention:
    """Rung 3: phase-stagger ``n_groups`` job groups by a ``1/(G*f)``
    offset comb (a ``StaggerSchedule``), decohering the offending bin.

    The aggregate becomes the mean of time-shifted replicas
    (edge-padded, like ``waveform.aggregate``); at ``f_hz`` the comb
    factor ``|sum_g e^{-2*pi*i*f*g/(G*f)}| / G`` is exactly zero, and
    the reported ``comb_attenuation`` gives the residual at any other
    frequency."""
    G = max(int(n_groups), 2)
    offsets = np.arange(G) / (G * float(f_hz))
    shifts = np.round(offsets / dt).astype(np.int64)
    atten = float(abs(np.exp(-2j * np.pi * f_hz * offsets).mean()))
    if history_w is not None and len(history_w):
        ramp = float(np.ptp(np.asarray(history_w, np.float64)) / G
                     / max(float(offsets[1]), dt))
    else:
        ramp = 0.0
    sched = StaggerSchedule(offsets_s=offsets.astype(np.float64),
                            rack_ramp_w_per_s=ramp)

    def transform(future: np.ndarray, dt_: float) -> np.ndarray:
        n = len(future)
        if n == 0:
            return future
        idx = np.clip(np.arange(n)[None, :] - shifts[:, None], 0, n - 1)
        return np.asarray(future, np.float32)[idx].mean(axis=0) \
            .astype(np.float32)

    return Intervention(
        name="stagger",
        params={"f_hz": float(f_hz), "n_groups": G,
                "offsets_s": [float(o) for o in offsets],
                "comb_attenuation": atten,
                "total_s": sched.total_s},
        transform=transform)


class InterventionLadder:
    """Level → cumulative intervention stack, with per-level caching so a
    re-dispatch at a higher level doesn't re-run lower rungs' solvers."""

    RUNGS = ("redesign", "power_cap", "stagger")

    def __init__(self, *, spec, n_chips: int, dt: float,
                 release_amp_w: float, hw: Hardware = DEFAULT_HW,
                 design_method: str = "grid", warmstart=None,
                 headroom: float = 1.25, stagger_groups: int = 4):
        self.spec = spec
        self.n_chips = int(n_chips)
        self.dt = float(dt)
        self.release_amp_w = float(release_amp_w)
        self.hw = hw
        self.design_method = design_method
        self.warmstart = warmstart
        self.headroom = headroom
        self.stagger_groups = int(stagger_groups)
        self._cache: Dict[int, Optional[Intervention]] = {}

    def build(self, rung: int, history_w: np.ndarray,
              f_hz: float) -> Optional[Intervention]:
        """Build (or fetch) the intervention for ladder rung 1..3,
        measuring wall-clock build latency."""
        if rung in self._cache:
            return self._cache[rung]
        t0 = time.perf_counter()
        if rung == 1:
            iv = redesign_intervention(
                self.spec, history_w, self.dt, self.n_chips, hw=self.hw,
                method=self.design_method, warmstart=self.warmstart,
                headroom=self.headroom)
        elif rung == 2:
            iv = power_cap_intervention(
                history_w, self.dt, release_amp_w=self.release_amp_w,
                n_chips=self.n_chips, hw=self.hw)
        else:
            iv = stagger_intervention(f_hz, self.dt,
                                      n_groups=self.stagger_groups,
                                      history_w=history_w)
        if iv is not None:
            iv.build_latency_s = time.perf_counter() - t0
        self._cache[rung] = iv
        return iv

    def release(self, rung: int) -> None:
        """Forget a rung's cached config so a future re-escalation
        re-solves against fresh history."""
        self._cache.pop(rung, None)

"""Structured decision record of the control loop.

Every escalation, dispatch, release, and failure lands in a
``ControlRecord``; per-tick amplitude/level samples land in the
``series`` list (the amplitude-recession plot data in EXPERIMENTS.md).
``summary()`` reduces a run to the numbers the acceptance criteria and
``BENCH_control.json`` care about: detection lead before breach,
dispatch latency percentiles, and post-intervention recession time.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence


def _pctl(values: Sequence[float], q: float) -> Optional[float]:
    if not values:
        return None
    import numpy as np
    return float(np.percentile(np.asarray(values, np.float64), q))


@dataclasses.dataclass
class ControlRecord:
    tick: int
    t_s: float
    action: str                # escalate | dispatch:<rung> | release:<rung>
                               # | dispatch_failed:<rung>
    level: int                 # controller target level after the action
    bin_hz: Optional[float] = None
    amplitude_w: float = 0.0   # worst-bin slope-projected amplitude
    margin_w: float = 0.0      # trigger_w - amplitude (negative = over)
    latency_s: float = 0.0     # wall-clock build/dispatch latency
    params: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ControlLog:
    freqs: tuple = ()
    trigger_w: float = 0.0
    release_w: float = 0.0
    breach_w: float = 0.0
    # when the *uncontrolled* trace would have breached (offline monitor
    # on the raw replay) — the baseline detection lead is measured against
    counterfactual_breach_t_s: Optional[float] = None
    records: List[ControlRecord] = dataclasses.field(default_factory=list)
    series: List[Dict] = dataclasses.field(default_factory=list)

    def record(self, **kw) -> ControlRecord:
        rec = ControlRecord(**kw)
        self.records.append(rec)
        return rec

    def sample(self, *, tick: int, t_s: float, level: int, amps,
               amps_eff) -> None:
        self.series.append({
            "tick": tick, "t_s": round(float(t_s), 6), "level": int(level),
            "amps_w": [float(a) for a in amps],
            "amps_eff_w": [float(a) for a in amps_eff],
        })

    # -- reductions ---------------------------------------------------------

    def dispatch_latencies(self) -> List[float]:
        return [r.latency_s for r in self.records
                if r.action.startswith("dispatch:")]

    def first(self, prefix: str) -> Optional[ControlRecord]:
        for r in self.records:
            if r.action.startswith(prefix):
                return r
        return None

    def breach_t(self) -> Optional[float]:
        """First time the *raw* worst-bin amplitude crosses the breach
        level (the spec threshold the controller must beat)."""
        for row in self.series:
            if max(row["amps_w"]) > self.breach_w:
                return row["t_s"]
        return None

    def recession_t(self) -> Optional[float]:
        """First time after the last dispatch that the raw worst-bin
        amplitude sits below the release-hysteresis level."""
        last = None
        for r in self.records:
            if r.action.startswith("dispatch:"):
                last = r.t_s
        if last is None:
            return None
        for row in self.series:
            if row["t_s"] > last and max(row["amps_w"]) < self.release_w:
                return row["t_s"]
        return None

    def summary(self) -> Dict:
        esc = self.first("escalate")
        disp = self.first("dispatch:")
        breach = self.breach_t()
        recede = self.recession_t()
        lats = self.dispatch_latencies()
        # detected-before-breach margin: against the observed breach if one
        # happened, else against the counterfactual (uncontrolled) breach
        ref_breach = breach if breach is not None \
            else self.counterfactual_breach_t_s
        return {
            "n_ticks": len(self.series),
            "n_records": len(self.records),
            "n_dispatches": len(lats),
            "final_level": (self.series[-1]["level"] if self.series else 0),
            "first_escalate_t_s": (esc.t_s if esc else None),
            "first_dispatch_t_s": (disp.t_s if disp else None),
            "breach_t_s": breach,
            "counterfactual_breach_t_s": self.counterfactual_breach_t_s,
            "detection_lead_s": (ref_breach - esc.t_s
                                 if esc is not None and ref_breach is not None
                                 else None),
            "recession_t_s": recede,
            "dispatch_latency_s": {
                "p50": _pctl(lats, 50), "p90": _pctl(lats, 90),
                "max": (max(lats) if lats else None),
            },
            "interventions": [
                {"action": r.action, "t_s": r.t_s, "bin_hz": r.bin_hz,
                 "latency_s": r.latency_s, "params": r.params}
                for r in self.records if ":" in r.action],
        }

    # -- rendering ----------------------------------------------------------

    def timeline(self) -> str:
        """Human-readable decision timeline (the demo's output)."""
        lines = [f"{'tick':>5} {'t[s]':>8} {'bin[Hz]':>8} {'amp[W]':>12} "
                 f"{'margin[W]':>12} {'lvl':>3} {'lat[ms]':>8}  action"]
        for r in self.records:
            lines.append(
                f"{r.tick:>5} {r.t_s:>8.2f} "
                f"{('-' if r.bin_hz is None else f'{r.bin_hz:g}'):>8} "
                f"{r.amplitude_w:>12.4g} {r.margin_w:>12.4g} {r.level:>3} "
                f"{r.latency_s * 1e3:>8.2f}  {r.action}")
        return "\n".join(lines)

    def to_json(self) -> Dict:
        return {
            "freqs_hz": list(self.freqs),
            "trigger_w": self.trigger_w, "release_w": self.release_w,
            "breach_w": self.breach_w,
            "records": [dataclasses.asdict(r) for r in self.records],
            "series": self.series,
            "summary": self.summary(),
        }

    def dumps(self, **kw) -> str:
        return json.dumps(self.to_json(), **kw)

"""The closed loop: detection → decision → intervention dispatch.

``ControlLoop`` drives one ``TelemetrySource`` through the online
detector and controller, and when the controller's target level moves it
builds the ladder rungs (within a configurable ``dispatch_ticks``
budget) and pushes the cumulative intervention stack back into the
source — so the next tick's samples already reflect the dispatched
mitigation, the monitored amplitude recedes, and the hysteresis
machinery releases the rungs again.  Everything observable lands in the
``ControlLog``.

``watch_trace`` is the one-call assembly used by
``PowerComplianceService.watch()``, the CLI, the benchmark, and the
tests.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

import numpy as np

from repro.control.controller import (ControlDecision, ControllerConfig,
                                      GridController)
from repro.control.detector import OnlineGoertzelDetector
from repro.control.interventions import InterventionLadder
from repro.control.log import ControlLog
from repro.control.stream import ReplaySource, TelemetrySource
from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.spectrum import GRID_CRITICAL_HZ
from repro.kernels.goertzel.ops import sliding_bin_power, trace_mean


class ControlLoop:
    """Run a controller over a stream, dispatching ladder interventions.

    ``dispatch_ticks`` is the dispatch budget: a level change decided at
    tick t is applied to the source after at most that many ticks
    (1 = at the end of the deciding tick, before the next chunk
    streams).  Rungs are cumulative; a release drops rungs above the new
    target and clears their ladder cache so a re-escalation re-solves on
    fresh history.
    """

    def __init__(self, source: TelemetrySource,
                 detector: OnlineGoertzelDetector,
                 controller: GridController, ladder: InterventionLadder, *,
                 log: Optional[ControlLog] = None, dispatch_ticks: int = 1,
                 history_s: float = 8.0):
        self.source = source
        self.detector = detector
        self.controller = controller
        self.ladder = ladder
        self.log = log if log is not None else ControlLog(
            freqs=detector.freqs,
            trigger_w=controller.cfg.trigger_w,
            release_w=controller.cfg.release_w,
            breach_w=controller.cfg.breach_w)
        self.dispatch_ticks = max(int(dispatch_ticks), 1)
        self.history_n = max(int(history_s / detector.dt), detector.win)
        self.applied_level = 0
        self.active: Dict[int, object] = {}       # rung -> Intervention
        self._due: Optional[int] = None           # tick the dispatch is due

    # -- dispatch -----------------------------------------------------------

    def _dispatch(self, decision: ControlDecision) -> None:
        target = decision.target_level
        f_hz = self.controller.freqs[decision.worst_bin]
        history = self.source.history(self.history_n)
        t0 = time.perf_counter()
        for rung in range(1, target + 1):
            if rung in self.active:
                continue
            iv = self.ladder.build(rung, history, f_hz)
            name = InterventionLadder.RUNGS[rung - 1]
            if iv is None:
                self.log.record(
                    tick=decision.tick, t_s=decision.t_s,
                    action=f"dispatch_failed:{name}", level=target,
                    bin_hz=f_hz,
                    amplitude_w=float(decision.amps_eff[decision.worst_bin]),
                    margin_w=float(decision.margins_w[decision.worst_bin]),
                    latency_s=time.perf_counter() - t0)
                continue
            self.active[rung] = iv
            self.log.record(
                tick=decision.tick, t_s=decision.t_s,
                action=f"dispatch:{iv.name}", level=target, bin_hz=f_hz,
                amplitude_w=float(decision.amps_eff[decision.worst_bin]),
                margin_w=float(decision.margins_w[decision.worst_bin]),
                latency_s=iv.build_latency_s, params=dict(iv.params))
        for rung in [r for r in self.active if r > target]:
            iv = self.active.pop(rung)
            self.ladder.release(rung)
            self.log.record(
                tick=decision.tick, t_s=decision.t_s,
                action=f"release:{iv.name}", level=target, bin_hz=f_hz,
                amplitude_w=float(decision.amps_eff[decision.worst_bin]),
                margin_w=float(decision.margins_w[decision.worst_bin]))
        self.source.apply_interventions(
            [self.active[r] for r in sorted(self.active)])
        self.applied_level = target

    # -- the loop -----------------------------------------------------------

    def run(self, max_ticks: Optional[int] = None) -> ControlLog:
        ticks = 0
        while max_ticks is None or ticks < max_ticks:
            chunk = self.source.next_tick()
            if chunk is None:
                break
            frame = self.detector.step(chunk)
            decision = self.controller.decide(frame)
            self.log.sample(tick=frame.tick, t_s=frame.t_s,
                            level=decision.target_level, amps=frame.amps,
                            amps_eff=decision.amps_eff)
            target = decision.target_level
            if target != self.applied_level:
                if target > self.applied_level and self._due is None:
                    k = decision.worst_bin
                    self.log.record(
                        tick=frame.tick, t_s=frame.t_s, action="escalate",
                        level=target, bin_hz=self.controller.freqs[k],
                        amplitude_w=float(decision.amps_eff[k]),
                        margin_w=float(decision.margins_w[k]))
                if self._due is None:
                    self._due = frame.tick + self.dispatch_ticks - 1
                if frame.tick >= self._due:
                    self._dispatch(decision)
                    self._due = None
            else:
                self._due = None
            ticks += 1
        return self.log


def watch_trace(w: np.ndarray, dt: float, *, spec, n_chips: int,
                freqs: Optional[Sequence[float]] = None,
                window_s: float = 4.0, tick_s: float = 0.5,
                tick_sizes: Optional[Sequence[int]] = None,
                breach_w: Optional[float] = None,
                trigger_frac: float = 0.85, release_frac: float = 0.60,
                lead_s: float = 2.0, sustain_ticks: int = 2,
                release_ticks: int = 4, dispatch_ticks: int = 1,
                design_method: str = "grid", warmstart=None,
                hw: Hardware = DEFAULT_HW, history_s: float = 8.0,
                stagger_groups: int = 4, mean: Optional[float] = None,
                max_ticks: Optional[int] = None, sensor=None) -> ControlLog:
    """Close the loop over one replayed trace; returns the ``ControlLog``.

    ``breach_w`` defaults to the spec's per-bin amplitude limit, or half
    its dynamic-range window when no explicit bin limit is set (a bin of
    amplitude a contributes 2a of peak-to-trough).  ``mean`` defaults to
    the trace's own f32 mean — the offline monitor's convention.
    """
    w = np.asarray(w, np.float32)
    if freqs is None:
        freqs = GRID_CRITICAL_HZ
    if breach_w is None:
        breach_w = (spec.freq.max_bin_amplitude_w
                    if spec.freq.max_bin_amplitude_w is not None
                    else 0.5 * spec.time.dynamic_range_w)
    if mean is None:
        mean = float(trace_mean(w))
    source = ReplaySource(w, dt, tick_s=tick_s, tick_sizes=tick_sizes,
                          sensor=sensor)
    cfg = ControllerConfig(breach_w=float(breach_w),
                           trigger_frac=trigger_frac,
                           release_frac=release_frac, lead_s=lead_s,
                           sustain_ticks=sustain_ticks,
                           release_ticks=release_ticks)
    # fused detector path: the kernel's shared escalation machine mirrors
    # the controller's trigger/release band (per-sample telemetry riding
    # along in the frames; the controller still decides from amps+slopes)
    detector = OnlineGoertzelDetector(dt, freqs, window_s=window_s,
                                      mean=mean, threshold_w=cfg.trigger_w,
                                      release_w=cfg.release_w,
                                      sustain_s=sustain_ticks * tick_s,
                                      cooldown_s=release_ticks * tick_s)
    controller = GridController(cfg, freqs, detector.win)
    ladder = InterventionLadder(spec=spec, n_chips=n_chips, dt=dt,
                                release_amp_w=cfg.release_w, hw=hw,
                                design_method=design_method,
                                warmstart=warmstart,
                                stagger_groups=stagger_groups)
    loop = ControlLoop(source, detector, controller, ladder,
                       dispatch_ticks=dispatch_ticks, history_s=history_s)
    log = loop.run(max_ticks=max_ticks)
    # counterfactual breach: when the *uncontrolled* trace would have
    # crossed the breach amplitude (offline monitor on the raw replay) —
    # the reference the detection lead is measured against when the
    # controller successfully prevents the observed breach
    raw_amps = np.asarray(sliding_bin_power(
        source.raw, float(dt), tuple(detector.freqs), win=detector.win,
        interpret=True))
    over = np.nonzero(raw_amps.max(axis=1) > cfg.breach_w)[0]
    if len(over):
        log.counterfactual_breach_t_s = float(over[0] * dt)
    return log

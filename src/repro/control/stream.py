"""Telemetry stream sources for the grid-interactive control plane.

The control loop consumes any ``TelemetrySource`` — an object that hands
out power samples one control tick at a time and accepts dispatched
interventions that reshape its *future* samples.  ``ReplaySource`` is
the shipped implementation: it replays a recorded or synthesized
waveform (the paper's traces, `make_experiments` artifacts, or
``synthesize_ramp`` below), chunked at a configurable control tick, and
applies interventions to the not-yet-streamed suffix so the loop is
observably closed — dispatch at tick t changes what the detector sees
from tick t+1 on, exactly as capping or re-configuring a live fleet
would.

Distinct from ``core.telemetry.TelemetrySource`` (the sensor *model*:
period/latency/noise/quantization); a sensor model can be attached here
to degrade the replayed stream the same way.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core import telemetry as core_telemetry


@runtime_checkable
class TelemetrySource(Protocol):
    """What the control loop needs from a stream: tick-sized chunks of
    power samples and a way to re-shape the future when it dispatches."""
    dt: float

    def next_tick(self) -> Optional[np.ndarray]:
        """Next chunk of power samples, or None when the stream ends."""
        ...

    def apply_interventions(self, interventions: Sequence) -> None:
        """Replace the active intervention set (applied to future samples)."""
        ...


class ReplaySource:
    """Replay a waveform as a control-tick stream with closed-loop physics.

    ``tick_s`` fixes the default chunk size; ``tick_sizes`` (sample
    counts) overrides the first ticks for uneven-tick tests, falling back
    to the default afterwards.  ``sensor`` optionally degrades chunks
    through the ``core.telemetry.TelemetrySource`` sensor model.

    Interventions are composed over the *pristine* future — each
    ``apply_interventions`` call recomputes ``raw[cursor:]`` through the
    current transform stack, so releasing an intervention genuinely
    removes its effect rather than leaving it baked in.
    """

    def __init__(self, w: np.ndarray, dt: float, *, tick_s: float = 0.5,
                 tick_sizes: Optional[Iterable[int]] = None,
                 sensor: Optional["core_telemetry.TelemetrySource"] = None,
                 seed: int = 0):
        self.raw = np.array(w, np.float32)
        self._w = self.raw.copy()
        self.dt = float(dt)
        self._tick_n = max(int(round(tick_s / dt)), 1)
        self._tick_sizes = list(tick_sizes) if tick_sizes is not None else []
        self.sensor = sensor
        self.seed = seed
        self.cursor = 0
        self.tick = 0
        self.active: List = []

    @property
    def n(self) -> int:
        return int(self.raw.shape[0])

    def next_tick(self) -> Optional[np.ndarray]:
        if self.cursor >= self.n:
            return None
        k = (self._tick_sizes[self.tick] if self.tick < len(self._tick_sizes)
             else self._tick_n)
        chunk = self._w[self.cursor:self.cursor + k]
        if self.sensor is not None:
            chunk = self.sensor.measure(np.asarray(chunk, np.float64),
                                        self.dt,
                                        seed=self.seed + self.tick)
            chunk = chunk.astype(np.float32)
        self.cursor += len(chunk)
        self.tick += 1
        return chunk

    def apply_interventions(self, interventions: Sequence) -> None:
        self.active = list(interventions)
        future = self.raw[self.cursor:].copy()
        if not len(future):
            return
        for iv in interventions:
            future = np.asarray(iv.transform(future, self.dt), np.float32)
        self._w[self.cursor:] = future

    def history(self, n_samples: int) -> np.ndarray:
        """The last ``n_samples`` already-streamed (post-intervention)
        samples — what a live fleet's telemetry archive would hold."""
        return self._w[max(0, self.cursor - n_samples):self.cursor]

    def observed(self) -> np.ndarray:
        """Everything streamed so far (post-intervention)."""
        return self._w[:self.cursor]


def synthesize_ramp(*, dc_w: float = 5e8, f_hz: float = 9.0,
                    peak_amp_w: float = 8e7, duration_s: float = 48.0,
                    ramp_start_s: float = 8.0, ramp_end_s: float = 32.0,
                    dt: float = 0.002, noise_w: float = 0.0,
                    seed: int = 0) -> np.ndarray:
    """The canonical control-plane trace: a fleet-scale DC operating
    point with an ``f_hz`` oscillation whose amplitude ramps linearly
    from zero (at ``ramp_start_s``) to ``peak_amp_w`` (at ``ramp_end_s``)
    and then holds — the slow drift toward a grid-critical breach the
    controller must catch before it crosses the spec threshold."""
    n = int(round(duration_s / dt))
    t = np.arange(n) * dt
    env = peak_amp_w * np.clip((t - ramp_start_s)
                               / max(ramp_end_s - ramp_start_s, dt), 0.0, 1.0)
    w = dc_w + env * np.sin(2.0 * np.pi * f_hz * t)
    if noise_w > 0:
        rng = np.random.default_rng(seed)
        w = w + rng.normal(0.0, noise_w, size=n)
    return w.astype(np.float32)

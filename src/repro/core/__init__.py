"""Power-stabilization core — the paper's contribution as a JAX subsystem.

Analysis objects (specs, spectra, phase timelines), the StratoSim-analogue
datacenter power simulator, and the mitigation stack (Firefly software
smoothing, GB200-style device power floor, rack-level energy storage,
telemetry backstop, combined design solver).
"""
from repro.core.hardware import ChipSpec, DatacenterTopology, DEFAULT_HW, Hardware, ServerSpec
from repro.core.phases import (IterationTimeline, Phase, checkpoint_phase,
                               from_dryrun_cell, load_cell, synthetic_timeline)
from repro.core.spec import (FrequencyDomainSpec, SpecReport, TimeDomainSpec,
                             UtilitySpec, example_specs)
from repro.core.spectrum import (band_energy_fraction, critical_band_report,
                                 dominant_frequency, spectrum)
from repro.core.stratosim import SimResult, simulate, simulate_cell, simulate_jit
from repro.core.telemetry import TelemetrySource
from repro.core.waveform import (WaveformConfig, aggregate, chip_waveform,
                                 job_waveform, swing_stats)
from repro.core.smoothing import (CombinedMitigation, Firefly, GpuPowerSmoothing,
                                  RackBattery, Stack, TelemetryBackstop,
                                  design_mitigation, energy_overhead)
from repro.core.engine import (BatchResult, StreamChunk, analyze_batch,
                               apply_batch, design, design_gradient,
                               design_grid, simulate_batch,
                               stack_mitigations, stream_batches, sweep,
                               validate_many)
from repro.core.study import MitigationConfig, Scenario, Study, StudyResult
from repro.core.ballast_inject import attach_ballast, ballast_gflops_for_cell
from repro.core.stagger import StaggerSchedule, max_ramp, plan_stagger, ramp_waveform

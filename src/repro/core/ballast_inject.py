"""In-graph Firefly for TPU: DCE-proof ballast co-scheduled with collectives.

On GPUs the paper injects the secondary workload as a separate MPS process;
XLA owns the whole TPU, so the idiomatic equivalent is *in-graph*: a chain
of optimization-barrier-protected GEMMs attached to the loss value. Because
the ballast chain has no data dependency on the gradient collectives, XLA's
latency-hiding scheduler is free to overlap it with the exposed all-reduce /
reduce-scatter tail — exactly where the power trough lives. Sizing comes
from the phase timeline: exposed-comm seconds x target floor FLOP rate.

The numeric tie-in is ``loss + 1e-30 * checksum``: materially zero (< 1 ulp
of any realistic loss) but opaque enough that XLA cannot fold the chain
away (verified in tests by counting dots in the optimized HLO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware


def ballast_chain(gflops: float, d: int = 256, dtype=jnp.bfloat16):
    """Pure-XLA ballast chain (pjit-friendly on any mesh; replicated)."""
    per_iter = 2.0 * d * d * d
    n_iter = max(int(gflops * 1e9 / per_iter), 1)
    a = (jnp.ones((d, d), dtype) + jnp.eye(d, dtype=dtype)) * 0.01
    b = jnp.eye(d, dtype=dtype) * 0.999

    def body(_, c):
        c = jax.lax.optimization_barrier(c)
        return jnp.dot(c, b, preferred_element_type=jnp.float32).astype(dtype)

    out = jax.lax.fori_loop(0, n_iter, body, a)
    return jnp.sum(out.astype(jnp.float32))


def attach_ballast(loss: jax.Array, gflops: float, d: int = 256) -> jax.Array:
    """Return loss' == loss numerically, carrying ~gflops of MXU ballast."""
    if gflops <= 0:
        return loss
    checksum = ballast_chain(gflops, d)
    return loss + 1e-30 * checksum.astype(loss.dtype)


def ballast_gflops_for_cell(cell: dict, hw: Hardware = DEFAULT_HW,
                            floor_frac: float = 0.9,
                            overlap: float = 0.0) -> float:
    """Size the per-step ballast from a dry-run artifact: enough FLOPs to
    hold the MXU at ``floor_frac`` of peak for the exposed-comm window."""
    coll_bytes = sum(cell.get("collectives", {}).values())
    t_comm = coll_bytes / (hw.chip.ici_bw_per_link * hw.chip.ici_links)
    t_exposed = t_comm * (1.0 - overlap)
    return floor_frac * hw.chip.peak_flops_bf16 * t_exposed / 1e9


def ballast_gflops_for_floor(w, dt: float, floor_w: float, n_chips: int,
                             hw: Hardware = DEFAULT_HW,
                             burn_frac: float = 0.9) -> float:
    """Size the ballast that holds an observed aggregate trace at a power
    floor: total GFLOPs to burn the trough deficit (energy below
    ``floor_w`` over the trace), converted at the chip's FLOP-per-joule
    at TDP and derated by ``burn_frac`` (ballast GEMMs don't hit peak).
    This is the control plane's power-cap rung: the cap clamps peaks,
    this ballast fills the troughs so the clamp band holds from below."""
    deficit_j = float(np.clip(floor_w - np.asarray(w, np.float64),
                              0.0, None).sum() * dt)
    flop_per_j = hw.chip.peak_flops_bf16 / hw.chip.tdp_w
    return burn_frac * flop_per_j * deficit_j / 1e9

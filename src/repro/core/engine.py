"""Batched scenario engine: the whole waveform -> mitigation -> spec
pipeline as one jit/vmap-able JAX program.

The paper evaluates every mitigation "on the real waveform from Figure 1"
across a matrix of workloads, fleet sizes and (MPF, battery) configurations.
StratoSim's ``simulate`` runs one scenario at a time; this module runs a
*grid* of scenarios in a single compiled call:

  ``simulate_batch``  vmaps (timeline levels x n_chips x mitigation config
                      x jitter seed x PRNG key) through synthesis,
                      aggregation, mitigation scans, swing/band metrics and
                      utility-spec validation — no host round-trips inside.
  ``sweep``           cartesian product over workloads / fleet sizes /
                      configs / seeds, bucketed by waveform length (each
                      bucket is one compiled call), returning flat records.
  ``stream_batches``  chunked fixed-memory iteration of the scenario
                      axis: per-chunk compiled pipeline + in-jit
                      reduction to metrics (waveforms never leave the
                      device unless asked), donated input buffers,
                      chunk k+1 dispatched while chunk k transfers.
  ``apply_batch``     one waveform through a stack of mitigation configs
                      (the Fig. 6 MPF sweep in one call).
  ``analyze_batch``   frequency reports + spec validation for same-length
                      waveforms (the finalize stage behind ``core.study``).
  ``design_grid``     the batched grid search behind
                      ``smoothing.design_mitigation``.
  ``design_gradient`` jitted gradient descent on (MPF, capacity): Adam via
                      ``lax.scan`` through the smooth-relaxed mitigations
                      (``smooth_tau``) and the spec's hinge loss
                      (``UtilitySpec.loss_jax``), vmapped multi-start,
                      hard re-validation of every candidate.
  ``design``          the one design entry point:
                      method="grid" | "gradient" | "hybrid".

This module is the *compile target*; the declarative public surface is
``repro.core.study`` (``Study``/``StudyResult``), which drives it with
per-scenario PRNG keys, pad-and-mask fusion of mixed-length workloads
(``pad_to``), and optional sharding of the scenario axis across devices.

Only the timeline -> sample-count expansion (``phase_levels``) and the
jitter-shift draw stay in numpy: they fix array shapes.  Everything with a
static shape is traced, so mitigation parameter grids ride through ``vmap``
as stacked pytree leaves (see ``stack_mitigations``).  Mixed
enabled/disabled rows batch too: ``_normalize_mits`` carries disabled rows
as structural placeholders plus an on/off mask, and the pipeline selects
the unmitigated waveform for masked-off rows after the vmapped apply.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.optim import adam_init, adam_update, clip_by_global_norm
from repro.core.phases import IterationTimeline
from repro.parallel.collectives import gather_rows, host_allgather
from repro.parallel.sharding import ScenarioShardPlan, scenario_plan
from repro.core.smoothing.base import (Mitigation, apply_mitigation,
                                       energy_overhead_jax, materialize_aux)
from repro.core.smoothing.battery import RackBattery
from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
from repro.core.spec import SpecReport, UtilitySpec, report_from_arrays
from repro.core.spectrum import critical_band_report_jax
from repro.core.stratosim import SimResult
from repro.core.waveform import (WaveformConfig, aggregate_jax,
                                 chip_waveform_jax, jitter_shifts,
                                 phase_levels, swing_stats_jax)


# ---------------------------------------------------------------------------
# config batching
# ---------------------------------------------------------------------------

def stack_mitigations(mitigations: Sequence) -> object:
    """Stack structurally-identical mitigation pytrees into one batched
    pytree (leaves gain a leading config axis) for ``vmap``.

    All entries must be the same class with identical static metadata
    (hardware spec, telemetry config, windows); continuous parameters may
    differ per entry — that is the grid being swept.
    """
    mitigations = list(mitigations)
    if not mitigations:
        raise ValueError("empty mitigation list")
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
        *mitigations)


def _tile(values, B: int, what: str) -> list:
    values = list(values)
    if len(values) == 1:
        return values * B
    if len(values) != B:
        raise ValueError(f"{what}: got {len(values)} entries, expected 1 or {B}")
    return values


def _normalize_mits(mits, B: int, what: str):
    """None | Mitigation | sequence (None rows allowed) ->
    ``(batched pytree | None, on-mask [B] | None)``.

    Disabled (None) rows batch alongside enabled ones: they ride through
    the vmapped apply as a structural placeholder (a copy of the first
    enabled config — its parameters never reach the output) and the
    returned on-mask selects the *unmitigated* waveform for them
    afterwards.  The mask is None when every row is enabled.  This is the
    generalization of the design-grid gpu_on/bat_on masking: one batch can
    mix baselines and mitigated configs (the Table-I matrix in one call).
    """
    if mits is None:
        return None, None
    if not isinstance(mits, (list, tuple)):
        mits = [mits]
    mits = _tile(mits, B, what)
    enabled = [m for m in mits if m is not None]
    if not enabled:
        return None, None
    if len(enabled) == len(mits):
        return stack_mitigations(mits), None
    placeholder = enabled[0]
    on = jnp.asarray([0.0 if m is None else 1.0 for m in mits], jnp.float32)
    return stack_mitigations([placeholder if m is None else m for m in mits]), on


def _normalize_keys(keys, B: int):
    """None | key | sequence of keys | stacked [B, ...] array -> [B] keys."""
    if keys is None:
        return None
    if isinstance(keys, (list, tuple)):
        rows = list(keys)
    else:
        arr = jnp.asarray(keys)
        rows = [keys] if arr.ndim <= 1 else list(arr)
    rows = _tile(rows, B, "keys")
    return jnp.stack([jnp.asarray(k) for k in rows])


# ---------------------------------------------------------------------------
# the compiled pipeline
# ---------------------------------------------------------------------------

def _mask_helpers(n: int, n_valid):
    """(fill_edge, fill_mean, msum, mask) for pad-and-mask mode; identity
    functions when ``n_valid`` is None (unpadded)."""
    if n_valid is None:
        ident = lambda w: w
        return ident, ident, jnp.sum, None
    mask = jnp.arange(n) < n_valid
    last = jnp.asarray(n_valid, jnp.int32) - 1

    def fill_edge(w):
        return jnp.where(mask, w, w[last])

    def msum(w):
        return jnp.sum(jnp.where(mask, w, 0.0))

    def fill_mean(w):
        return jnp.where(mask, w, msum(w) / n_valid)

    return fill_edge, fill_mean, msum, mask


def _synth_one(levels, shifts, n_chips, n_valid, cfg: WaveformConfig,
               hw: Hardware) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mitigation-independent prefix: levels -> (chip, dc_raw).  Depends
    only on (workload, fleet, seed) — the Study layer dedupes it across
    the config axis (``simulate_grid``)."""
    fill_edge, _, _, _ = _mask_helpers(levels.shape[-1], n_valid)
    chip = fill_edge(chip_waveform_jax(levels, cfg.dt, hw,
                                       edp_spikes=cfg.edp_spikes,
                                       include_host=cfg.include_host))
    return chip, aggregate_jax(chip, n_chips, shifts, hw)


def _mitigate_one(chip, dc_raw, shifts, n_chips, dev, rack, dev_on, rack_on,
                  key, n_valid, limits, cfg: WaveformConfig, hw: Hardware,
                  spec: Optional[UtilitySpec], spectra: bool,
                  chip_outputs: bool = True) -> Dict:
    """Per-config suffix of one scenario inside vmap.

    ``n_valid`` (traced scalar or None) activates pad-and-mask mode: the
    row's true waveform occupies the first ``n_valid`` samples of a padded
    array.  Masking keeps the valid region *exact* against an unpadded run:
    levels arrive edge-padded, mitigated chip waveforms are re-filled with
    their boundary sample (so the jittered aggregation gather sees the same
    clip-to-edge semantics as an unpadded call), mean-sensitive rack
    stages see the pad region filled with the valid-region mean, and every
    scalar metric is a masked reduction.  Frequency metrics need the true
    FFT length, so padded calls defer them to ``analyze_batch``.
    """
    n = chip.shape[-1]
    fill_edge, fill_mean, msum, mask = _mask_helpers(n, n_valid)

    k_dev = k_rack = None
    if key is not None:
        k_dev = jax.random.fold_in(key, 0)
        k_rack = jax.random.fold_in(key, 1)

    out: Dict = {"dc_raw": dc_raw}
    if chip_outputs:
        out["chip_raw"] = chip
    aux: Dict = {}
    dc = dc_raw
    if dev is not None:
        chip_m, aux_d = apply_mitigation(dev, chip, cfg.dt, k_dev)
        chip_m = fill_edge(chip_m)
        if dev_on is not None:
            chip_m = jnp.where(dev_on > 0, chip_m, chip)
        aux["device"] = aux_d
        if chip_outputs:
            out["chip_mitigated"] = chip_m
        dc = aggregate_jax(chip_m, n_chips, shifts, hw)
    if rack is not None:
        rack_in = fill_mean(dc)
        dc_r, aux_r = apply_mitigation(rack, rack_in, cfg.dt, k_rack)
        if rack_on is not None:
            dc_r = jnp.where(rack_on > 0, dc_r, rack_in)
        aux["rack"] = aux_r
        dc = dc_r
    out["dc_mitigated"] = dc

    if mask is not None:
        e_in = msum(dc_raw)
        out["energy_overhead"] = (msum(dc) - e_in) / jnp.maximum(e_in, 1e-12)
        out["swing"] = _swing_stats_masked(dc_raw, mask, n_valid)
        out["swing_mitigated"] = _swing_stats_masked(dc, mask, n_valid)
    else:
        out["energy_overhead"] = energy_overhead_jax(dc_raw, dc)
        out["swing"] = swing_stats_jax(dc_raw)
        out["swing_mitigated"] = swing_stats_jax(dc)
    if spectra:
        out["bands"] = critical_band_report_jax(dc_raw, cfg.dt)
        out["bands_mitigated"] = critical_band_report_jax(dc, cfg.dt)
    if spec is not None:
        ok, flags, metrics = spec.validate_jax(dc, cfg.dt, limits)
        out["spec_ok"] = ok
        out["spec_flags"] = flags
        out["spec_metrics"] = metrics
    out["aux"] = aux
    return out


def _swing_stats_masked(w, mask, n_valid) -> Dict[str, jnp.ndarray]:
    """``swing_stats_jax`` over the valid prefix of a padded waveform."""
    peak = jnp.max(jnp.where(mask, w, -jnp.inf))
    trough = jnp.min(jnp.where(mask, w, jnp.inf))
    return {
        "peak_w": peak,
        "trough_w": trough,
        "swing_w": peak - trough,
        "mean_w": jnp.sum(jnp.where(mask, w, 0.0)) / n_valid,
        "swing_frac": (peak - trough) / jnp.maximum(peak, 1e-9),
    }


def _simulate_one(levels, shifts, n_chips, dev, rack, dev_on, rack_on, key,
                  n_valid, limits, cfg, hw, spec, spectra) -> Dict:
    chip, dc_raw = _synth_one(levels, shifts, n_chips, n_valid, cfg, hw)
    return _mitigate_one(chip, dc_raw, shifts, n_chips, dev, rack, dev_on,
                         rack_on, key, n_valid, limits, cfg, hw, spec,
                         spectra)


# ``levels`` (argnum 0) is the one O(B*n) host->device input of every
# pipeline call; donating it lets XLA reuse its buffer for the same-shape
# waveform outputs, so a streaming chunk holds one buffer fewer in flight.
# ``spec`` is the spec's *family* (shape structure only — static) and
# ``limits`` its traced thresholds, so same-family specs share the
# executable (see UtilitySpec.family()).
@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("cfg", "hw", "spec", "spectra"))
def _simulate_vmapped(levels, shifts, n_chips, dev, rack, dev_on, rack_on,
                      keys, n_valid, limits, *, cfg: WaveformConfig,
                      hw: Hardware, spec: Optional[UtilitySpec],
                      spectra: bool):
    return jax.vmap(
        lambda L, S, N, D, R, Do, Ro, K, V: _simulate_one(
            L, S, N, D, R, Do, Ro, K, V, limits, cfg, hw, spec, spectra)
    )(levels, shifts, n_chips, dev, rack, dev_on, rack_on, keys, n_valid)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("cfg", "hw"))
def _synth_vmapped(levels, shifts, n_chips, n_valid, *, cfg: WaveformConfig,
                   hw: Hardware):
    return jax.vmap(
        lambda L, S, N, V: _synth_one(L, S, N, V, cfg, hw)
    )(levels, shifts, n_chips, n_valid)


@functools.partial(jax.jit, static_argnames=("cfg", "hw", "spec", "spectra",
                                             "chip_outputs"))
def _mitigate_vmapped(chip_u, dcraw_u, u_idx, shifts, n_chips, dev, rack,
                      dev_on, rack_on, keys, n_valid, limits, *,
                      cfg: WaveformConfig, hw: Hardware,
                      spec: Optional[UtilitySpec], spectra: bool,
                      chip_outputs: bool):
    """Per-scenario suffix over rows that *share* synthesized prefixes:
    ``chip_u``/``dcraw_u`` hold one entry per unique (workload, fleet,
    seed) and ``u_idx`` maps each scenario row to its prefix."""
    return jax.vmap(
        lambda U, S, N, D, R, Do, Ro, K, V: _mitigate_one(
            chip_u[U], dcraw_u[U], S, N, D, R, Do, Ro, K, V, limits, cfg,
            hw, spec, spectra, chip_outputs)
    )(u_idx, shifts, n_chips, dev, rack, dev_on, rack_on, keys, n_valid)


# ---------------------------------------------------------------------------
# scenario-axis sharding
# ---------------------------------------------------------------------------

def _resolve_plan(plan: Optional[ScenarioShardPlan],
                  shard_devices: bool) -> Optional[ScenarioShardPlan]:
    """An explicit mesh plan wins; ``shard_devices=True`` keeps its old
    meaning as shorthand for the all-local-devices plan."""
    if plan is not None:
        return plan
    return scenario_plan() if shard_devices else None


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchResult:
    """One row per scenario; waveforms are [B, n], metrics are [B].

    In pad-and-mask mode (``pad_to``), row ``i``'s true waveform is the
    first ``n_valid[i]`` samples (the remainder is padding); scalar metrics
    are already masked, and frequency/spec analysis is deferred to
    ``analyze_batch`` on the sliced rows.
    """
    t: np.ndarray
    dc_raw: np.ndarray
    dc_mitigated: np.ndarray
    chip_raw: Optional[np.ndarray]
    chip_mitigated: Optional[np.ndarray]
    energy_overhead: np.ndarray
    swing: Dict[str, np.ndarray]
    swing_mitigated: Dict[str, np.ndarray]
    bands: Optional[Dict[str, np.ndarray]]
    bands_mitigated: Optional[Dict[str, np.ndarray]]
    spec_ok: Optional[np.ndarray]
    spec_flags: Optional[Dict[str, np.ndarray]]
    spec_metrics: Optional[Dict[str, np.ndarray]]
    aux: Dict
    n_valid: Optional[np.ndarray] = None
    dev_on: Optional[np.ndarray] = None
    rack_on: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return self.dc_raw.shape[0]

    def length(self, i: int) -> int:
        return (self.dc_raw.shape[1] if self.n_valid is None
                else int(self.n_valid[i]))

    def report(self, i: int) -> Optional[SpecReport]:
        if self.spec_ok is None:
            return None
        row = jax.tree.map(lambda a: a[i], (self.spec_flags, self.spec_metrics))
        return report_from_arrays(self.spec_ok[i], row[0], row[1])

    def scenario(self, i: int) -> SimResult:
        """Rebuild the per-scenario ``SimResult`` (API compat with
        ``stratosim.simulate``) for row ``i``; padded rows are sliced back
        to their true length."""
        n = self.length(i)
        row = lambda d: {k: float(v[i]) for k, v in d.items()}
        chip_m = self.chip_mitigated
        aux_row = jax.tree.map(lambda a: a[i], self.aux)
        # masked-off rows ran a structural placeholder config whose output
        # was discarded — drop its aux too, matching the serial reference
        if self.dev_on is not None and not self.dev_on[i]:
            chip_m = None
            aux_row.pop("device", None)
        if self.rack_on is not None and not self.rack_on[i]:
            aux_row.pop("rack", None)
        return SimResult(
            t=self.t[:n],
            dc_raw=self.dc_raw[i, :n], dc_mitigated=self.dc_mitigated[i, :n],
            chip_raw=(None if self.chip_raw is None
                      else self.chip_raw[i, :n]),
            chip_mitigated=(None if chip_m is None else chip_m[i, :n]),
            energy_overhead=float(self.energy_overhead[i]),
            swing=row(self.swing), swing_mitigated=row(self.swing_mitigated),
            bands=(row(self.bands) if self.bands is not None else {}),
            bands_mitigated=(row(self.bands_mitigated)
                             if self.bands_mitigated is not None else {}),
            spec_report=self.report(i),
            aux=materialize_aux(aux_row))


def _prepare_rows(timelines, n_chips, seeds, device_mitigation,
                  rack_mitigation, levels, cfg: WaveformConfig, hw: Hardware):
    """Broadcast every batched argument to a common row count B and expand
    timelines to per-row ``phase_levels`` arrays (once per distinct
    timeline — rows are usually a small set of workloads tiled across a
    big config grid).  The shared prologue of ``simulate_batch`` and the
    chunked ``stream_batches`` executor."""
    tls = timelines if isinstance(timelines, (list, tuple)) else [timelines]
    chips = n_chips if isinstance(n_chips, (list, tuple)) else [n_chips]
    seed_list = seeds if isinstance(seeds, (list, tuple)) else [seeds]
    dev_list = (device_mitigation if isinstance(device_mitigation, (list, tuple))
                else [device_mitigation])
    rack_list = (rack_mitigation if isinstance(rack_mitigation, (list, tuple))
                 else [rack_mitigation])

    B = max(len(tls), len(chips), len(seed_list), len(dev_list), len(rack_list))
    tls = _tile(tls, B, "timelines")
    chips = _tile(chips, B, "n_chips")
    seed_list = _tile(seed_list, B, "seeds")
    dev_list = _tile(dev_list, B, "device_mitigation")
    rack_list = _tile(rack_list, B, "rack_mitigation")

    if levels is not None:
        level_rows = _tile(list(levels), B, "levels")
    else:
        level_cache: Dict[int, np.ndarray] = {}
        level_rows = [
            level_cache.setdefault(id(tl), phase_levels(tl, cfg, hw))
            for tl in tls]
    return tls, chips, seed_list, dev_list, rack_list, level_rows, B


def simulate_batch(
        timelines: Union[IterationTimeline, Sequence[IterationTimeline]],
        n_chips: Union[int, Sequence[int]],
        wave_cfg: Optional[WaveformConfig] = None,
        *, device_mitigation=None, rack_mitigation=None,
        spec: Optional[UtilitySpec] = None, hw: Hardware = DEFAULT_HW,
        seeds: Union[int, Sequence[int]] = 0,
        keys=None,
        sample_chips: int = 64,
        levels: Optional[Sequence[np.ndarray]] = None,
        pad_to: Optional[int] = None,
        spectra: bool = True,
        shard_devices: bool = False,
        plan: Optional[ScenarioShardPlan] = None,
        dedup: bool = False,
        chip_outputs: bool = True,
        host_arrays: bool = True) -> BatchResult:
    """Simulate a batch of scenarios in one compiled call.

    Each batched argument (timelines, n_chips, device/rack mitigation
    configs, seeds, keys) is a singleton (broadcast) or a length-B
    sequence.  Mitigation rows may mix None (disabled) and enabled configs
    — disabled rows produce the unmitigated waveform.  ``keys`` threads a
    per-scenario PRNG key into mitigations that consume randomness
    (telemetry noise), so noisy rows get independent draws.

    Without ``pad_to``, all timelines must expand to the same sample count
    (``sweep`` buckets mixed-length workloads).  With ``pad_to=N``, rows
    are edge-padded to N and masked — mixed lengths fuse into ONE compiled
    call; frequency/spec analysis then runs per true length via
    ``analyze_batch`` (``spec`` must be None and ``spectra`` False).

    ``levels`` optionally supplies per-row ``phase_levels`` arrays
    precomputed; ``plan`` (a ``ScenarioShardPlan``) partitions the
    scenario axis across its mesh — ``shard_devices=True`` is shorthand
    for the default all-local-devices plan.  ``dedup`` splits the
    pipeline in two: the mitigation-
    independent prefix (chip synthesis + raw aggregation) runs once per
    unique (workload, fleet, seed) and the per-config suffix gathers it —
    the declarative Study layer enables this because it knows which axes a
    row's physics actually depends on.
    """
    cfg = wave_cfg or WaveformConfig()
    (tls, chips, seed_list, dev_list, rack_list, level_rows,
     B) = _prepare_rows(timelines, n_chips, seeds, device_mitigation,
                        rack_mitigation, levels, cfg, hw)

    src_ids = [id(r) for r in level_rows]   # pre-padding row identity
    n_valid_arr = None
    if pad_to is not None:
        if spec is not None or spectra:
            raise ValueError(
                "pad_to defers frequency/spec analysis to analyze_batch on "
                "the sliced rows: call with spec=None, spectra=False")
        lens = [len(r) for r in level_rows]
        if max(lens) > pad_to:
            raise ValueError(f"pad_to={pad_to} < longest workload {max(lens)}")
        n_valid_arr = jnp.asarray(lens, jnp.float32)
        level_rows = [np.pad(r, (0, pad_to - len(r)), mode="edge")
                      for r in level_rows]
    else:
        n0 = len(level_rows[0])
        if any(len(r) != n0 for r in level_rows):
            raise ValueError(
                "all timelines in one simulate_batch call must expand to the "
                f"same sample count (got {sorted({len(r) for r in level_rows})}); "
                "use sweep()/Study to bucket, or pad_to to fuse")
    n = len(level_rows[0])
    shifts = jnp.asarray(np.stack(
        [jitter_shifts(cfg, s, sample_chips) for s in seed_list]))
    chips_f = jnp.asarray(np.asarray(chips, np.float32))
    dev, dev_on = _normalize_mits(dev_list, B, "device_mitigation")
    rack, rack_on = _normalize_mits(rack_list, B, "rack_mitigation")
    keys_arr = _normalize_keys(keys, B)
    # family/limits split: the spec's *structure* is the static jit key,
    # its numeric thresholds ride in as traced scalars — every same-family
    # spec (lenient/moderate/tight at any job power) shares one executable
    family = None if spec is None else spec.family()
    limits = None if spec is None else spec.limits()

    shard = _resolve_plan(plan, shard_devices)
    out_B = B
    if dedup:
        # synthesis once per unique (workload, fleet, seed); the per-config
        # suffix gathers its prefix by index
        uniq: Dict[Tuple, int] = {}
        u_rows: List[int] = []
        u_idx: List[int] = []
        for i, k in enumerate(zip(src_ids, chips, seed_list)):
            if k not in uniq:
                uniq[k] = len(u_rows)
                u_rows.append(i)
            u_idx.append(uniq[k])
        sel = np.asarray(u_rows)
        synth_in = (jnp.asarray(np.stack([level_rows[i] for i in u_rows]),
                                jnp.float32),
                    shifts[sel], chips_f[sel],
                    None if n_valid_arr is None else n_valid_arr[sel])
        if shard is not None and shard.n_processes > 1:
            # global arrays only compose with global arrays in one SPMD
            # program: commit the unique-row prefix to the scenario mesh
            # too (pad rows are duplicates no ``u_idx`` ever references)
            synth_in, _ = shard.shard_batch(synth_in, len(u_rows))
        chip_u, dcraw_u = _synth_vmapped(*synth_in, cfg=cfg, hw=hw)
        row_args = (jnp.asarray(u_idx, jnp.int32), shifts, chips_f, dev,
                    rack, dev_on, rack_on, keys_arr, n_valid_arr)
        if shard is not None:
            row_args, out_B = shard.shard_batch(row_args, B)
        res = _mitigate_vmapped(chip_u, dcraw_u, *row_args, limits,
                                cfg=cfg, hw=hw, spec=family, spectra=spectra,
                                chip_outputs=chip_outputs)
    else:
        args = (jnp.asarray(np.stack(level_rows), jnp.float32), shifts,
                chips_f, dev, rack, dev_on, rack_on, keys_arr, n_valid_arr)
        if shard is not None:
            args, out_B = shard.shard_batch(args, B)
        res = _simulate_vmapped(*args, limits, cfg=cfg, hw=hw, spec=family,
                                spectra=spectra)
    if host_arrays:
        # single-process this is the plain np.asarray(+slice) host pull;
        # multi-process it is one replicate-all collective first
        res = host_allgather(res, shard, take=None if out_B == B else B)
    elif out_B != B and (shard is None or shard.n_processes <= 1):
        # keep waveforms on device (callers like Study slice them straight
        # into the analysis jit without a host round-trip).  Multi-process
        # keeps the shard padding too — an eager slice would re-replicate
        # the array; downstream gathers never touch the pad rows.
        res = jax.tree.map(lambda a: a[:B], res)
    return BatchResult(
        t=np.arange(n) * cfg.dt,
        dc_raw=res["dc_raw"], dc_mitigated=res["dc_mitigated"],
        chip_raw=res.get("chip_raw"),
        chip_mitigated=res.get("chip_mitigated"),
        energy_overhead=res["energy_overhead"],
        swing=res["swing"], swing_mitigated=res["swing_mitigated"],
        bands=res.get("bands"), bands_mitigated=res.get("bands_mitigated"),
        spec_ok=res.get("spec_ok"), spec_flags=res.get("spec_flags"),
        spec_metrics=res.get("spec_metrics"), aux=res["aux"],
        n_valid=(None if n_valid_arr is None
                 else np.asarray(n_valid_arr, np.int64)),
        dev_on=(None if dev_on is None else np.asarray(dev_on) > 0),
        rack_on=(None if rack_on is None else np.asarray(rack_on) > 0))


# ---------------------------------------------------------------------------
# streaming chunked execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StreamChunk:
    """Per-chunk *metrics* of a ``stream_batches`` run.

    Rows ``start:stop`` of the stream's scenario axis.  Everything here
    is a small host array of one entry per row — the waveforms stayed on
    device and were reduced to metrics inside jit; they are only present
    (``dc_raw``/``dc_mitigated``) when the stream was asked to keep them.
    ``spec_ok`` / ``spec_flags`` / ``spec_metrics`` align with the
    stream's ``specs`` sequence (None entries for a None spec);
    ``spec_metrics`` rows are per-row dicts because the metric key set
    depends on each row's true waveform length.
    """
    start: int
    stop: int
    n: int                                   # common (padded) sample count
    n_valid: Optional[np.ndarray]            # [C] true lengths (None = n)
    energy_overhead: np.ndarray              # [C]
    swing: Dict[str, np.ndarray]             # each [C]
    swing_mitigated: Dict[str, np.ndarray]
    bands_mitigated: Optional[Dict[str, np.ndarray]]
    spec_ok: List[Optional[np.ndarray]]      # per spec: [C] bool
    spec_flags: List[Optional[Dict[str, np.ndarray]]]
    spec_metrics: List[Optional[List[Dict[str, float]]]]
    dc_raw: Optional[np.ndarray] = None      # [C, n] (keep_waveforms only)
    dc_mitigated: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return self.stop - self.start

    def length(self, i: int) -> int:
        return self.n if self.n_valid is None else int(self.n_valid[i])

    def report(self, si: int, i: int) -> Optional[SpecReport]:
        """SpecReport of row ``i`` under spec ``si`` (None if that spec
        slot was None)."""
        if self.spec_ok[si] is None:
            return None
        flags = {k: v[i] for k, v in self.spec_flags[si].items()}
        return report_from_arrays(self.spec_ok[si][i], flags,
                                  self.spec_metrics[si][i])


def _pow2_pad(idx: List[int]) -> List[int]:
    """Pad an index list to the next power of two (repeating the last
    entry) so vmapped analysis calls compile for O(log B) distinct batch
    sizes instead of one per chunk composition."""
    m = 1
    while m < len(idx):
        m <<= 1
    return idx + [idx[-1]] * (m - len(idx))


def stream_batches(
        timelines: Union[IterationTimeline, Sequence[IterationTimeline]],
        n_chips: Union[int, Sequence[int]],
        wave_cfg: Optional[WaveformConfig] = None,
        *, device_mitigation=None, rack_mitigation=None,
        specs=None, hw: Hardware = DEFAULT_HW,
        seeds: Union[int, Sequence[int]] = 0,
        keys=None,
        sample_chips: int = 64,
        levels: Optional[Sequence[np.ndarray]] = None,
        pad_to: Optional[int] = None,
        chunk_size: int = 1024,
        bands: bool = True,
        keep_waveforms: bool = False,
        dedup: bool = True,
        chip_outputs: bool = True,
        shard_devices: bool = False,
        plan: Optional[ScenarioShardPlan] = None,
        skip_rows: int = 0):
    """Iterate a scenario batch in fixed-size chunks of compiled work,
    yielding one metrics-only ``StreamChunk`` per chunk.

    The streaming core behind ``Study.run(stream=...)``: each chunk runs
    the ``simulate_batch`` pipeline (waveforms kept on device, the
    chunk's stacked ``levels`` buffer donated to XLA) and then reduces
    straight to metrics inside jit — per-row swing/overhead from the
    pipeline, plus frequency bands and spec verdicts via vmapped
    analysis calls grouped by true waveform length (analysis batches are
    padded to powers of two so compile count stays O(log chunk) however
    lengths mix).  Only O(chunk)-sized metric arrays ever reach the
    host; device memory is O(chunk_size * n) regardless of how many
    scenarios the grid declares.

    Chunk ``k+1`` is dispatched *before* chunk ``k``'s metrics are
    pulled to host, so host transfer overlaps device compute.  Tail
    chunks are padded to ``chunk_size`` by repeating the last row (and
    sliced back), keeping every chunk the same compiled shape.

    ``specs`` is None, one ``UtilitySpec``, or a sequence (None entries
    allowed — that slot yields no verdicts); all specs judge every row.
    ``pad_to`` fixes the padded length (defaults to the longest row when
    lengths mix); ``plan`` / ``shard_devices`` compose scenario-axis
    sharding with the chunking — each chunk is padded to a shard
    multiple and committed to the plan's mesh.  Per-row results are
    bit-identical to a one-shot ``simulate_batch`` over the same rows:
    chunking, tail padding, analysis-batch padding and sharding only
    ever add rows that are sliced away.

    ``skip_rows`` drops every chunk whose rows are entirely below it
    without dispatching any work — the resume fast-path (``ckpt/resume``
    restores those chunks from disk).  It must land on a chunk boundary;
    because per-row values are chunk-composition independent, the
    surviving chunks are bit-identical to the same chunks of a full run.
    """
    cfg = wave_cfg or WaveformConfig()
    (tls, chips, seed_list, dev_list, rack_list, level_rows,
     B) = _prepare_rows(timelines, n_chips, seeds, device_mitigation,
                        rack_mitigation, levels, cfg, hw)
    spec_list = list(specs) if isinstance(specs, (list, tuple)) else [specs]
    # per-slot family/limits split, computed once for the whole stream
    fam_lims = [(None, None) if sp is None else (sp.family(), sp.limits())
                for sp in spec_list]
    keys_arr = _normalize_keys(keys, B)

    lens = [len(r) for r in level_rows]
    if pad_to is None and len(set(lens)) > 1:
        pad_to = max(lens)
    chunk_size = max(1, min(chunk_size, B))
    n_chunks = -(-B // chunk_size)
    shard = _resolve_plan(plan, shard_devices)

    def dispatch(lo: int, hi: int):
        C = hi - lo
        tail = chunk_size - C if n_chunks > 1 else 0

        def sl(xs):
            return xs[lo:hi] + [xs[hi - 1]] * tail

        ks = None
        if keys_arr is not None:
            ks = keys_arr[lo:hi]
            if tail:
                ks = jnp.concatenate([ks, jnp.repeat(ks[-1:], tail, axis=0)])
        res = simulate_batch(
            sl(tls), sl(chips), cfg,
            device_mitigation=sl(dev_list), rack_mitigation=sl(rack_list),
            spec=None, hw=hw, seeds=sl(seed_list), keys=ks,
            sample_chips=sample_chips, levels=sl(level_rows),
            pad_to=pad_to, spectra=False, plan=shard, dedup=dedup,
            chip_outputs=chip_outputs, host_arrays=False)
        # in-jit reduction to metrics: one vmapped analysis call per
        # (true length, spec) group on device-resident waveform slices
        groups: Dict[int, List[int]] = {}
        for i in range(C):
            groups.setdefault(lens[lo + i], []).append(i)
        gres = []
        mult = (shard.n_shards
                if shard is not None and shard.n_processes > 1 else 1)
        for L, g in sorted(groups.items()):
            # pow2 padding buys bounded compile counts across chunks; a
            # single-chunk (one-shot) run has one fixed shape either way,
            # so analyze at exact size and skip the wasted lanes
            sel = list(_pow2_pad(g) if n_chunks > 1 else g)
            if len(sel) % mult:
                # multi-process analysis stays sharded: pad the gather to
                # a shard multiple (pow2 sizes usually already are)
                sel += [sel[-1]] * (mult - len(sel) % mult)
            mit = gather_rows(res.dc_mitigated, sel, shard, length=L)
            per_spec = []
            for si, sp in enumerate(spec_list):
                do_bands = bands and si == 0
                if sp is None and not do_bands:
                    per_spec.append(None)
                    continue
                fam, lim = fam_lims[si]
                per_spec.append(_analyze_vmapped(None, mit, lim, spec=fam,
                                                 dt=cfg.dt, bands=do_bands))
            gres.append((g, per_spec))
        return lo, hi, res, gres

    def materialize(pending) -> StreamChunk:
        lo, hi, res, gres = pending
        C = hi - lo
        S = len(spec_list)
        # one host pull for all per-row metric fields; multi-process this
        # is the cross-process merge (replicate-all, then np.asarray)
        direct = host_allgather(
            {"eo": res.energy_overhead, "sw": res.swing,
             "swm": res.swing_mitigated,
             "raw": res.dc_raw if keep_waveforms else None,
             "mit": res.dc_mitigated if keep_waveforms else None},
            shard, take=C)
        chunk = StreamChunk(
            start=lo, stop=hi,
            n=res.dc_mitigated.shape[1],
            n_valid=None if res.n_valid is None else res.n_valid[:C],
            energy_overhead=direct["eo"],
            swing=direct["sw"],
            swing_mitigated=direct["swm"],
            bands_mitigated=None,
            spec_ok=[None] * S, spec_flags=[None] * S,
            spec_metrics=[None] * S,
            dc_raw=direct["raw"], dc_mitigated=direct["mit"])
        bands_cols: Dict[str, np.ndarray] = {}
        for g, per_spec in gres:
            G = len(g)
            for si, a in enumerate(per_spec):
                if a is None:
                    continue
                a = host_allgather(a, shard, take=G)
                if "bands_mitigated" in a:
                    for k, v in a["bands_mitigated"].items():
                        bands_cols.setdefault(
                            k, np.empty(C, v.dtype))[g] = v
                if spec_list[si] is None:
                    continue
                if chunk.spec_ok[si] is None:
                    chunk.spec_ok[si] = np.zeros(C, bool)
                    chunk.spec_flags[si] = {
                        k: np.zeros(C, bool) for k in a["spec_flags"]}
                    chunk.spec_metrics[si] = [None] * C
                chunk.spec_ok[si][g] = a["spec_ok"]
                for k, v in a["spec_flags"].items():
                    chunk.spec_flags[si][k][g] = v
                for j, i in enumerate(g):
                    chunk.spec_metrics[si][i] = {
                        k: float(v[j]) for k, v in a["spec_metrics"].items()}
        if bands_cols:
            chunk.bands_mitigated = bands_cols
        return chunk

    if skip_rows % chunk_size and skip_rows < B:
        raise ValueError(
            f"skip_rows={skip_rows} is not a chunk boundary of "
            f"chunk_size={chunk_size}")
    pending = None
    for lo in range(0, B, chunk_size):
        hi = min(lo + chunk_size, B)
        if hi <= skip_rows:
            continue
        cur = dispatch(lo, hi)
        if pending is not None:
            yield materialize(pending)
        pending = cur
    if pending is not None:
        yield materialize(pending)


# ---------------------------------------------------------------------------
# cartesian sweep
# ---------------------------------------------------------------------------

def sweep(workloads,
          n_chips: Sequence[int],
          configs: Sequence[Tuple[Optional[Mitigation], Optional[Mitigation]]],
          wave_cfg: Optional[WaveformConfig] = None,
          *, spec: Optional[UtilitySpec] = None, hw: Hardware = DEFAULT_HW,
          seeds: Sequence[int] = (0,), sample_chips: int = 64) -> List[Dict]:
    """Cartesian (workload x fleet size x config x seed) sweep.

    ``workloads`` is a dict name -> IterationTimeline (or a sequence, named
    by index); each config is a ``(device_mitigation, rack_mitigation)``
    pair (either side may be None — including per-row, so baselines batch
    with mitigated configs).  Workloads are bucketed by sample count; each
    bucket runs as ONE compiled vmapped call.  Returns one flat record dict
    per scenario.  (The declarative front-end over this is ``core.study``.)
    """
    cfg = wave_cfg or WaveformConfig()
    if isinstance(workloads, dict):
        names, tls = list(workloads.keys()), list(workloads.values())
    else:
        tls = list(workloads)
        names = [f"workload{i}" for i in range(len(tls))]
    combos = [(ti, ni, ci, si)
              for ti in range(len(tls)) for ni in n_chips
              for ci in range(len(configs)) for si in seeds]
    tl_levels = [phase_levels(tl, cfg, hw) for tl in tls]  # once per workload
    buckets: Dict[int, List[Tuple[int, Tuple]]] = {}
    for pos, combo in enumerate(combos):
        buckets.setdefault(len(tl_levels[combo[0]]), []).append((pos, combo))

    records: List[Optional[Dict]] = [None] * len(combos)
    for _, items in sorted(buckets.items()):
        idxs = [combo for _, combo in items]
        res = simulate_batch(
            [tls[ti] for ti, _, _, _ in idxs],
            [ni for _, ni, _, _ in idxs],
            cfg,
            device_mitigation=[configs[ci][0] for _, _, ci, _ in idxs],
            rack_mitigation=[configs[ci][1] for _, _, ci, _ in idxs],
            spec=spec, hw=hw, seeds=[si for _, _, _, si in idxs],
            sample_chips=sample_chips,
            levels=[tl_levels[ti] for ti, _, _, _ in idxs])
        for b, (pos, (ti, ni, ci, si)) in enumerate(items):
            rec = {
                "workload": names[ti],
                "n_chips": ni,
                "config": ci,
                "seed": si,
                "period_s": tls[ti].period_s,
                "mean_mw": float(res.swing["mean_w"][b]) / 1e6,
                "swing_mw": float(res.swing["swing_w"][b]) / 1e6,
                "swing_mitigated_mw":
                    float(res.swing_mitigated["swing_w"][b]) / 1e6,
                "energy_overhead": float(res.energy_overhead[b]),
                "paper_band_frac":
                    float(res.bands_mitigated["paper_band_0p2_3hz"][b]),
            }
            if res.spec_ok is not None:
                rec["spec_ok"] = bool(res.spec_ok[b])
                rec["violations"] = res.report(b).violations
            records[pos] = rec
    return records


# ---------------------------------------------------------------------------
# chip-level config batches (Fig. 6 style sweeps)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("dt",))
def _apply_vmapped(mits, w, *, dt: float):
    return jax.vmap(lambda m: m.apply_jax(w, dt))(mits)


def apply_batch(mitigations: Sequence, w: np.ndarray, dt: float
                ) -> Tuple[np.ndarray, Dict]:
    """Apply B structurally-identical mitigation configs to ONE waveform in
    a single vmapped call: (outs [B, n], aux dict with leading B axis)."""
    batched = stack_mitigations(mitigations)
    outs, aux = _apply_vmapped(batched, jnp.asarray(w, jnp.float32), dt=dt)
    return np.asarray(outs), jax.tree.map(np.asarray, aux)


# ---------------------------------------------------------------------------
# batched spec validation + frequency reports
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("spec", "dt"))
def _validate_vmapped(ws, limits, *, spec: UtilitySpec, dt: float):
    return jax.vmap(lambda w: spec.validate_jax(w, dt, limits))(ws)


def validate_many(ws: np.ndarray, spec: UtilitySpec, dt: float
                  ) -> Tuple[np.ndarray, List[SpecReport]]:
    """Validate B same-length waveforms [B, n] against one spec in a single
    vmapped call: (ok [B], per-row SpecReports)."""
    ok, flags, metrics = _validate_vmapped(
        jnp.asarray(np.asarray(ws), jnp.float32), spec.limits(),
        spec=spec.family(), dt=dt)
    ok = np.asarray(ok)
    flags, metrics = jax.tree.map(np.asarray, (flags, metrics))
    reports = [report_from_arrays(ok[i],
                                  {k: v[i] for k, v in flags.items()},
                                  {k: v[i] for k, v in metrics.items()})
               for i in range(len(ok))]
    return ok, reports


@functools.partial(jax.jit, static_argnames=("spec", "dt", "bands"))
def _analyze_vmapped(raw, mit, limits, *, spec: Optional[UtilitySpec],
                     dt: float, bands: bool):
    """``spec`` is the family (static structure); ``limits`` the traced
    thresholds — see ``UtilitySpec.family()``."""
    def one(r, m):
        out: Dict = {}
        if bands:
            if r is not None:
                out["bands"] = critical_band_report_jax(r, dt)
            out["bands_mitigated"] = critical_band_report_jax(m, dt)
        if spec is not None:
            ok, flags, metrics = spec.validate_jax(m, dt, limits)
            out["spec_ok"], out["spec_flags"] = ok, flags
            out["spec_metrics"] = metrics
        return out

    return jax.vmap(one)(raw, mit)


def analyze_batch(dc_raw: Optional[np.ndarray], dc_mitigated: np.ndarray,
                  dt: float, spec: Optional[UtilitySpec] = None, *,
                  bands: bool = True) -> Dict:
    """Frequency reports (on raw + mitigated) and spec validation (on
    mitigated) for B same-length waveform pairs in one vmapped call — the
    finalize stage a padded pipeline run defers, grouped by true length.
    ``dc_raw=None`` skips the raw-waveform band report (callers that only
    consume mitigated bands, like the Study record table, save one FFT
    per row)."""
    res = _analyze_vmapped(
        None if dc_raw is None else jnp.asarray(dc_raw, jnp.float32),
        jnp.asarray(dc_mitigated, jnp.float32),
        None if spec is None else spec.limits(),
        spec=None if spec is None else spec.family(), dt=dt, bands=bands)
    return jax.tree.map(np.asarray, res)


# ---------------------------------------------------------------------------
# batched (MPF x battery) design search
# ---------------------------------------------------------------------------

def _select_on(on, yes, no):
    """Row-masked select; ``on`` None means the stage is always enabled."""
    return yes if on is None else jnp.where(on > 0, yes, no)


@functools.partial(jax.jit, static_argnames=("spec", "dt"))
def _design_eval(gpu_b, bat_b, gpu_on, bat_on, w, n_chips, limits, *,
                 spec: UtilitySpec, dt: float):
    """``spec`` is the family; ``limits`` the traced thresholds — one
    executable serves every same-structure spec the serve path designs
    against."""
    def one(gpu, bat, g_on, b_on):
        out = w
        if gpu is not None:
            smoothed, _ = gpu.apply_jax(w / n_chips, dt)
            out = _select_on(g_on, smoothed * n_chips, out)
        if bat is not None:
            out_b, _ = bat.apply_jax(out, dt)
            out = _select_on(b_on, out_b, out)
        ok, flags, metrics = spec.validate_jax(out, dt, limits)
        return out, ok, energy_overhead_jax(w, out), flags, metrics

    return jax.vmap(one)(gpu_b, bat_b, gpu_on, bat_on)


def _rank_feasible(ok: np.ndarray, overhead: np.ndarray,
                   candidates: Sequence[Tuple[float, float]]) -> np.ndarray:
    """Feasible candidate indices ranked by (energy overhead, capacity,
    MPF) — minimal waste first, then minimal capacity (cost / embodied
    carbon), the serial solver's preference order."""
    feasible = np.flatnonzero(np.asarray(ok))
    caps = np.asarray([candidates[i][1] for i in feasible])
    mpfs = np.asarray([candidates[i][0] for i in feasible])
    # round overhead so float noise cannot outrank a smaller battery
    oh = np.round(np.asarray(overhead)[feasible], 6)
    return feasible[np.lexsort((mpfs, caps, oh))]


def _design_pair(spec: UtilitySpec, mpf: float, cap: float, n_chips: int,
                 swing: float, hw: Hardware,
                 target_tau_s: Optional[float] = None
                 ) -> Tuple[Optional[GpuPowerSmoothing],
                            Optional[RackBattery]]:
    """The concrete (device, rack) mitigation objects a design candidate
    stands for — the single construction point shared by the grid search,
    the gradient refiner's hard re-validation, and the winner handed back
    to callers.  ``mpf`` / ``cap`` of 0 mean the stage is off.
    ``target_tau_s`` optionally overrides the battery's grid-target EMA
    horizon (the warm-start predictor's third output — response latency);
    it is a pytree leaf, so mixed-tau candidates still stack."""
    gpu = (GpuPowerSmoothing(
        mpf_frac=mpf, hw=hw,
        ramp_up_w_per_s=spec.time.ramp_up_w_per_s / n_chips,
        ramp_down_w_per_s=spec.time.ramp_down_w_per_s / n_chips)
        if mpf > 0 else None)
    tau_kw = {} if target_tau_s is None else {
        "target_tau_s": float(target_tau_s)}
    bat = (RackBattery(capacity_j=cap, max_discharge_w=swing,
                       max_charge_w=swing, **tau_kw) if cap > 0 else None)
    return gpu, bat


def _eval_candidates(spec: UtilitySpec, w: np.ndarray, dt: float,
                     n_chips: int, candidates: Sequence[Tuple[float, float]],
                     *, swing: float, hw: Hardware,
                     target_tau_s: Optional[Sequence[Optional[float]]] = None):
    """Hard (exact-semantics) evaluation of ``(mpf, cap)`` candidates in
    one vmapped call: ``(outs, ok, overhead, flags, metrics)``.
    ``target_tau_s`` optionally carries one battery-latency override per
    candidate (None entries keep the default)."""
    B = len(candidates)
    taus = [None] * B if target_tau_s is None else list(target_tau_s)
    pairs = [_design_pair(spec, m, c, n_chips, swing, hw, target_tau_s=t)
             for (m, c), t in zip(candidates, taus)]
    gpus, gpu_on = _normalize_mits([g for g, _ in pairs], B,
                                   "design gpu candidates")
    bats, bat_on = _normalize_mits([b for _, b in pairs], B,
                                   "design battery candidates")
    return _design_eval(gpus, bats, gpu_on, bat_on,
                        jnp.asarray(w, jnp.float32),
                        jnp.asarray(float(n_chips), jnp.float32),
                        spec.limits(), spec=spec.family(), dt=dt)


def design_grid(spec: UtilitySpec, w: np.ndarray, dt: float, n_chips: int,
                mpf_grid: Sequence[float], cap_grid: Sequence[float],
                *, swing: float, hw: Hardware = DEFAULT_HW,
                top_k: int = 1) -> Optional[Dict]:
    """Evaluate every (MPF, capacity) candidate in one vmapped call and
    return the first passing one in grid order (MPF-major ascending — the
    serial search's minimal-waste-then-minimal-capacity preference).

    Disabled stages (MPF or capacity 0) ride through ``_normalize_mits``
    masking, the same path that lets ``simulate_batch`` mix baseline and
    mitigated rows in one batch.

    ``top_k`` > 1 additionally ranks the feasible candidates by energy
    overhead and returns the best ``top_k`` under ``"alternatives"`` —
    the seeds for ``design_gradient`` multi-start and the ranked answer
    list the compliance service serves.  The winner stays the grid-order
    pick regardless of ``top_k``.
    """
    candidates = [(m, c) for m in mpf_grid for c in cap_grid]
    outs, ok, overhead, flags, metrics = _eval_candidates(
        spec, w, dt, n_chips, candidates, swing=swing, hw=hw)
    ok = np.asarray(ok)
    if not ok.any():
        return None
    idx = int(np.argmax(ok))
    mpf, cap = candidates[idx]
    overhead = np.asarray(overhead)
    ranked = _rank_feasible(ok, overhead, candidates)[:top_k]
    alternatives = [{
        "mpf_frac": candidates[i][0],
        "battery_capacity_j": candidates[i][1],
        "energy_overhead": float(overhead[i]),
    } for i in ranked]
    row = jax.tree.map(lambda a: np.asarray(a)[idx], (flags, metrics))
    # the winner as concrete mitigation objects — the single construction
    # point callers (design_mitigation, demos) reuse instead of rebuilding
    gpu_sel, bat_sel = _design_pair(spec, mpf, cap, n_chips, swing, hw)
    return {
        "mpf_frac": mpf,
        "battery_capacity_j": cap,
        "energy_overhead": float(overhead[idx]),
        "report": report_from_arrays(ok[idx], row[0], row[1]),
        "device_mitigation": gpu_sel,
        "rack_mitigation": bat_sel,
        "mitigated": np.asarray(outs)[idx],
        "grid_ok": ok.reshape(len(mpf_grid), len(cap_grid)),
        "alternatives": alternatives,
        "method": "grid",
        "aux": {},
    }


# ---------------------------------------------------------------------------
# gradient-based (MPF x battery) design
# ---------------------------------------------------------------------------

# below this fraction of mpf_max the relaxed device stage is (mostly)
# gated off and the hard re-validation snaps mpf to exactly 0 (stage off)
_GPU_GATE_PIVOT = 0.15


@functools.partial(jax.jit, static_argnames=("spec", "dt", "steps"))
def _design_descend(x0, gpu_t, bat_t, w, n_chips, lo, hi, hyper, limits, *,
                    spec: UtilitySpec, dt: float, steps: int):
    """Vmapped multi-start Adam descent on the smooth design objective.

    ``x0`` is ``{"mpf": [S], "cap": [S]}`` (capacity in units of
    ``hyper["cap_scale"]`` joules so both coordinates are O(1) and one
    learning rate conditions both); ``gpu_t``/``bat_t`` are smooth-relaxed
    (``smooth_tau`` > 0) templates whose (mpf_frac, capacity_j) leaves get
    replaced by the iterate each step.  The objective is the spec's hinge
    loss (margin-shrunk limits) plus an energy-overhead regularizer and an
    L1 sizing regularizer; each Adam step is followed by a projection onto
    the physical box ``[lo, hi]``.  Returns (final iterates [S], loss
    history [S, steps]).

    The grid search treats mpf=0 as "device stage off"; the relaxation
    mirrors that with a sigmoid on-gate driven by mpf itself (pivot at
    ``_GPU_GATE_PIVOT`` of mpf_max), so the battery-only design is inside
    the search space — without it the spec-derived per-chip ramp limiter
    flattens the waveform at *any* mpf and the landscape plateaus.  The
    battery's off-limit (cap -> 0 => passthrough) is already natural.
    """
    mpf_max = gpu_t.hw.chip.mpf_max
    tau = gpu_t.smooth_tau

    def objective(x):
        gpu = dataclasses.replace(gpu_t, mpf_frac=x["mpf"])
        bat = dataclasses.replace(bat_t,
                                  capacity_j=x["cap"] * hyper["cap_scale"])
        per_chip = w / n_chips
        smoothed, _ = gpu.apply_jax(per_chip, dt)
        g_on = jax.nn.sigmoid((x["mpf"] - _GPU_GATE_PIVOT * mpf_max)
                              / (tau * mpf_max))
        chip_out = g_on * smoothed + (1.0 - g_on) * per_chip
        out, _ = bat.apply_jax(chip_out * n_chips, dt)
        viol, _ = spec.loss_jax(out, dt, margin=hyper["margin"],
                                limits=limits)
        overhead = energy_overhead_jax(w, out)
        return (viol + hyper["overhead_weight"] * jnp.maximum(overhead, 0.0)
                + hyper["size_weight"] * (x["cap"] + 0.25 * x["mpf"]))

    value_and_grad = jax.value_and_grad(objective)

    def one_start(x0_row):
        def step(carry, _):
            x, st = carry
            loss, g = value_and_grad(x)
            g, _ = clip_by_global_norm(g, 100.0)      # blowup hygiene
            x2, st2 = adam_update(x, g, st, hyper["lr"])
            x2 = jax.tree.map(jnp.clip, x2, lo, hi)   # box projection
            return (x2, st2), loss

        (xf, _), losses = jax.lax.scan(step, (x0_row, adam_init(x0_row)),
                                       None, length=steps)
        return xf, losses

    return jax.vmap(one_start)(x0)


def design_gradient(spec: UtilitySpec, w: np.ndarray, dt: float,
                    n_chips: int, *, swing: Optional[float] = None,
                    hw: Hardware = DEFAULT_HW,
                    seeds: Optional[Sequence[Tuple[float, float]]] = None,
                    steps: int = 120, lr: float = 0.08,
                    smooth_tau: float = 0.05, margin: float = 0.05,
                    overhead_weight: float = 0.5,
                    size_weight: float = 0.02,
                    period_hint_s: float = 2.0,
                    top_k: int = 4,
                    cap_scale: Optional[float] = None,
                    mpf_bounds: Optional[Tuple[float, float]] = None,
                    cap_bounds_j: Optional[Tuple[float, float]] = None
                    ) -> Optional[Dict]:
    """Jitted gradient descent on (MPF fraction, battery capacity).

    The forward model is the same gated gpu->battery stack the grid search
    evaluates, but run through the mitigations' ``smooth_tau`` relaxation
    so every step gate carries a gradient; the objective is
    ``UtilitySpec.loss_jax`` (smooth hinge compliance, margin-shrunk) plus
    an energy-overhead regularizer.  ``seeds`` are (mpf_frac, capacity_j)
    starts — pass a coarse grid's ``alternatives`` to refine it (the
    ``design(method="hybrid")`` path); default is a fixed 6-point lattice
    over the box.  All starts descend in one vmapped ``lax.scan``.

    The *answer* is still exact: every final iterate (plus a small
    escalation ladder above it, plus the seeds) is re-validated under the
    hard tau=0 semantics in one vmapped call, and the minimal-overhead
    passing candidate wins.  Returns the same solution dict shape as
    ``design_grid`` (plus ``loss_history`` [S, steps]), or None when no
    candidate passes the hard spec.
    """
    w = np.asarray(w, np.float32)
    swing = float(w.max() - w.min()) if swing is None else float(swing)
    cap_scale = float(cap_scale or swing * period_hint_s)
    mpf_lo, mpf_hi = mpf_bounds or (0.0, hw.chip.mpf_max)
    cap_lo_j, cap_hi_j = cap_bounds_j or (0.0, 4.0 * cap_scale)
    # caller seeds (e.g. the grid's top-k) are augmented with a fixed
    # lattice over the box: a degenerate seed set — say, only MPF-only
    # configs with cap ~ 0, where the saturated battery's capacity
    # gradient vanishes — cannot climb out on its own, and extra vmapped
    # lanes are nearly free
    lattice = [(m, f * cap_scale) for m in (0.3, 0.6, 0.85)
               for f in (0.25, 1.0)]
    seeds = lattice if seeds is None else list(seeds) + lattice
    seeds = list(dict.fromkeys(
        (float(np.clip(m, mpf_lo, mpf_hi)),
         float(np.clip(c, cap_lo_j, cap_hi_j))) for m, c in seeds))
    # the descent itself stays above a small capacity floor: at cap -> 0
    # the SoC fraction's reverse-mode terms scale like 1/cap^2 and
    # overflow f32 (NaN-poisoning the lane).  A 0.1%-of-scale battery is
    # physically a passthrough, and the raw (possibly cap=0) seeds are
    # still hard-validated verbatim below.
    cap_floor_j = max(cap_lo_j, 1e-3 * cap_scale)

    gpu_t = GpuPowerSmoothing(
        mpf_frac=0.5, hw=hw,
        ramp_up_w_per_s=spec.time.ramp_up_w_per_s / n_chips,
        ramp_down_w_per_s=spec.time.ramp_down_w_per_s / n_chips,
        smooth_tau=smooth_tau)
    bat_t = RackBattery(capacity_j=cap_scale, max_discharge_w=swing,
                        max_charge_w=swing, smooth_tau=smooth_tau)
    x0 = {"mpf": jnp.asarray([m for m, _ in seeds], jnp.float32),
          "cap": jnp.asarray([max(c, cap_floor_j) / cap_scale
                              for _, c in seeds], jnp.float32)}
    lo = {"mpf": jnp.asarray(mpf_lo, jnp.float32),
          "cap": jnp.asarray(cap_floor_j / cap_scale, jnp.float32)}
    hi = {"mpf": jnp.asarray(mpf_hi, jnp.float32),
          "cap": jnp.asarray(cap_hi_j / cap_scale, jnp.float32)}
    hyper = {"lr": jnp.asarray(lr, jnp.float32),
             "margin": jnp.asarray(margin, jnp.float32),
             "overhead_weight": jnp.asarray(overhead_weight, jnp.float32),
             "size_weight": jnp.asarray(size_weight, jnp.float32),
             "cap_scale": jnp.asarray(cap_scale, jnp.float32)}
    xf, losses = _design_descend(
        x0, gpu_t, bat_t, jnp.asarray(w), jnp.asarray(float(n_chips),
                                                      jnp.float32),
        lo, hi, hyper, spec.limits(), spec=spec.family(), dt=dt, steps=steps)

    # hard re-validation: each final iterate with a geometric capacity
    # ladder around it (the margin leaves the iterate a little above the
    # true feasibility boundary — the sub-1.0 rungs walk back down to it
    # at ~7% resolution; the >1.0 rungs cover a too-thin margin), its
    # battery-only variant (the relaxed on-gate may sit between hard on
    # and off), and the seeds themselves (so a refined answer can never
    # be worse than its grid seed)
    finals = list(zip(np.asarray(xf["mpf"]).tolist(),
                      (np.asarray(xf["cap"]) * cap_scale).tolist()))
    candidates: List[Tuple[float, float]] = []
    for m, c in finals:
        for f in (0.75, 0.8, 0.87, 0.93, 1.0, 1.08, 1.25, 1.6):
            ck = float(np.clip(c * f, cap_lo_j, cap_hi_j))
            candidates.append((m, ck))
            candidates.append((0.0, ck))
    candidates += seeds
    # snap a mostly-gated-off device stage to an exactly-off one (the
    # same pivot the descent's on-gate uses, in hw units — not mpf_hi,
    # which a caller may have narrowed)
    candidates = [(0.0 if m < _GPU_GATE_PIVOT * hw.chip.mpf_max else m,
                   0.0 if c < 1e-6 * cap_scale else c)
                  for m, c in candidates]
    candidates = list(dict.fromkeys(candidates))
    outs, ok, overhead, flags, metrics = _eval_candidates(
        spec, w, dt, n_chips, candidates, swing=swing, hw=hw)
    ok = np.asarray(ok)
    if not ok.any():
        return None
    overhead = np.asarray(overhead)
    ranked = _rank_feasible(ok, overhead, candidates)
    idx = int(ranked[0])
    mpf, cap = candidates[idx]
    row = jax.tree.map(lambda a: np.asarray(a)[idx], (flags, metrics))
    gpu_sel, bat_sel = _design_pair(spec, mpf, cap, n_chips, swing, hw)
    return {
        "mpf_frac": mpf,
        "battery_capacity_j": cap,
        "energy_overhead": float(overhead[idx]),
        "report": report_from_arrays(ok[idx], row[0], row[1]),
        "device_mitigation": gpu_sel,
        "rack_mitigation": bat_sel,
        "mitigated": np.asarray(outs)[idx],
        "alternatives": [{
            "mpf_frac": candidates[i][0],
            "battery_capacity_j": candidates[i][1],
            "energy_overhead": float(overhead[i]),
        } for i in ranked[:top_k]],
        "loss_history": np.asarray(losses),
        "method": "gradient",
        "aux": {},
    }


# capacity rungs the warm-start fast path walks around a predicted seed:
# sub-1.0 rungs reclaim an over-provisioned prediction, the >1.0 rungs
# rescue an under-provisioned one without falling back to the polisher
_WARMSTART_CAP_LADDER = (0.8, 0.9, 1.0, 1.15, 1.4, 2.0)


def design_warmstart(spec: UtilitySpec, w: np.ndarray, dt: float,
                     n_chips: int, *, predictor,
                     swing: Optional[float] = None,
                     hw: Hardware = DEFAULT_HW,
                     features=None,
                     period_hint_s: float = 2.0,
                     top_k: int = 4,
                     polish_steps: int = 40,
                     **gradient_kwargs) -> Optional[Dict]:
    """Amortized (MPF, capacity, battery-latency) design from a learned
    seed — milliseconds warm instead of the solver's seconds, with the
    answer still exactly verified.

    ``predictor(spec, w, dt, n_chips, features=features)`` returns
    ``[(mpf_frac, capacity_j, target_tau_s), ...]`` seeds (the serve
    layer's ``WarmStartPredictor``).  The fast path expands each seed
    into a small capacity ladder (plus battery-only variants) and runs
    ONE vmapped hard tau=0 evaluation — a passing rung is ranked by the
    solvers' (overhead, capacity, mpf) preference and returned.  Only
    when the whole ladder misses does it escalate: a short gradient
    polish seeded from the predictions, then the full ``hybrid`` solver —
    so the verdict (feasible or not) always matches the solver this path
    replaces, and every returned config is hard-revalidated.
    ``aux["warmstart_path"]`` records which tier answered.
    """
    w = np.asarray(w, np.float32)
    swing = float(w.max() - w.min()) if swing is None else float(swing)
    preds = predictor(spec, w, dt, n_chips, features=features)
    dedup: Dict[Tuple[float, float], float] = {}
    for mpf, cap, tau in preds:
        mpf = float(np.clip(mpf, 0.0, hw.chip.mpf_max))
        if mpf < _GPU_GATE_PIVOT * hw.chip.mpf_max:
            mpf = 0.0                       # snap a gated-off device stage
        cap = max(float(cap), 0.0)
        tau = float(tau)
        for f in _WARMSTART_CAP_LADDER:
            ck = round(cap * f, 3)
            if mpf == 0.0 and ck <= 0.0:
                continue            # no-mitigation rung: nothing to verify
            dedup.setdefault((mpf, ck), tau)
            if mpf > 0 and ck > 0:          # battery-only variant
                dedup.setdefault((0.0, ck), tau)
    candidates = list(dedup)
    taus = [dedup[c] for c in candidates]
    if candidates:
        outs, ok, overhead, flags, metrics = _eval_candidates(
            spec, w, dt, n_chips, candidates, swing=swing, hw=hw,
            target_tau_s=taus)
        ok = np.asarray(ok)
        if ok.any():
            overhead = np.asarray(overhead)
            ranked = _rank_feasible(ok, overhead, candidates)
            idx = int(ranked[0])
            mpf, cap = candidates[idx]
            row = jax.tree.map(lambda a: np.asarray(a)[idx],
                               (flags, metrics))
            gpu_sel, bat_sel = _design_pair(spec, mpf, cap, n_chips, swing,
                                            hw, target_tau_s=taus[idx])
            return {
                "mpf_frac": mpf,
                "battery_capacity_j": cap,
                "target_tau_s": taus[idx],
                "energy_overhead": float(overhead[idx]),
                "report": report_from_arrays(ok[idx], row[0], row[1]),
                "device_mitigation": gpu_sel,
                "rack_mitigation": bat_sel,
                "mitigated": np.asarray(outs)[idx],
                "alternatives": [{
                    "mpf_frac": candidates[i][0],
                    "battery_capacity_j": candidates[i][1],
                    "energy_overhead": float(overhead[i]),
                } for i in ranked[:top_k]],
                "method": "warmstart",
                "aux": {"warmstart_path": "fast"},
            }
    # ladder missed: short polish from the predicted seeds, then the full
    # solver — feasibility verdicts stay identical to method="hybrid"
    sol = design_gradient(spec, w, dt, n_chips, swing=swing, hw=hw,
                          seeds=[(m, c) for m, c, _ in preds] or None,
                          steps=polish_steps, period_hint_s=period_hint_s,
                          top_k=top_k, **gradient_kwargs)
    path = "polish"
    if sol is None:
        sol = design(spec, w, dt, n_chips, method="hybrid", hw=hw,
                     period_hint_s=period_hint_s, top_k=top_k,
                     **gradient_kwargs)
        path = "hybrid_fallback"
    if sol is None:
        return None
    sol = dict(sol)
    sol["method"] = "warmstart"
    sol["aux"] = dict(sol.get("aux") or {}, warmstart_path=path)
    return sol


def design(spec: UtilitySpec, w: np.ndarray, dt: float, n_chips: int, *,
           method: str = "hybrid", hw: Hardware = DEFAULT_HW,
           period_hint_s: float = 2.0,
           mpf_grid: Optional[Sequence[float]] = None,
           cap_grid: Optional[Sequence[float]] = None,
           top_k: int = 4,
           warmstart=None,
           features=None,
           polish_steps: int = 40,
           **gradient_kwargs) -> Optional[Dict]:
    """The one (MPF, battery-capacity) design entry point.

    method="grid"      the batched coarse grid search (``design_grid``);
    method="gradient"  jitted Adam through the smooth-relaxed pipeline
                       (``design_gradient``), lattice-seeded;
    method="hybrid"    coarse grid first, gradient refinement seeded from
                       its top-k feasible configs — never worse than the
                       grid (the seeds are re-validated candidates), and
                       finds the compliance frontier *between* grid points;
    method="warmstart" learned-seed fast path (``design_warmstart``) —
                       pass the predictor via ``warmstart=`` (and
                       optionally precomputed ``features=``); falls back
                       through gradient polish to hybrid, so verdicts
                       match the solver it amortizes.

    ``smoothing.design_mitigation`` remains the public face over this.
    """
    w = np.asarray(w, np.float32)
    swing = float(w.max() - w.min())
    if method == "warmstart":
        if warmstart is None:
            raise ValueError(
                "method='warmstart' needs a predictor: design(..., "
                "warmstart=WarmStartPredictor.load(...))")
        return design_warmstart(spec, w, dt, n_chips, predictor=warmstart,
                                swing=swing, hw=hw, features=features,
                                period_hint_s=period_hint_s, top_k=top_k,
                                polish_steps=polish_steps, **gradient_kwargs)
    if mpf_grid is None:
        # the hardware caps how high a floor is programmable
        mpf_grid = [m for m in (0.0, 0.5, 0.65, 0.8, 0.9)
                    if m <= hw.chip.mpf_max + 1e-9]
    if cap_grid is None:
        cap_grid = [0.0] + [swing * period_hint_s * f for f in
                            (0.125, 0.25, 0.5, 1.0, 2.0)]
    if method == "grid":
        return design_grid(spec, w, dt, n_chips, mpf_grid, cap_grid,
                           swing=swing, hw=hw, top_k=top_k)
    if method == "gradient":
        return design_gradient(spec, w, dt, n_chips, swing=swing, hw=hw,
                               period_hint_s=period_hint_s, top_k=top_k,
                               **gradient_kwargs)
    if method != "hybrid":
        raise ValueError(f"method must be grid|gradient|hybrid, got {method!r}")
    grid_sol = design_grid(spec, w, dt, n_chips, mpf_grid, cap_grid,
                           swing=swing, hw=hw, top_k=top_k)
    seeds = None
    if grid_sol is not None:
        seeds = [(a["mpf_frac"], a["battery_capacity_j"])
                 for a in grid_sol["alternatives"]]
        seeds.append((grid_sol["mpf_frac"], grid_sol["battery_capacity_j"]))
    grad_sol = design_gradient(spec, w, dt, n_chips, swing=swing, hw=hw,
                               period_hint_s=period_hint_s, seeds=seeds,
                               top_k=top_k, **gradient_kwargs)
    sols = [s for s in (grad_sol, grid_sol) if s is not None]
    if not sols:
        return None
    # the same rounded (overhead, capacity, mpf) preference _rank_feasible
    # applies within a solver — raw-float overhead comparison would let
    # ~1e-7 noise hand the win back to the grid's bigger battery
    best = min(sols, key=lambda s: (round(s["energy_overhead"], 6),
                                    s["battery_capacity_j"], s["mpf_frac"]))
    best = dict(best)
    best["method"] = "hybrid"
    return best

"""Batched scenario engine: the whole waveform -> mitigation -> spec
pipeline as one jit/vmap-able JAX program.

The paper evaluates every mitigation "on the real waveform from Figure 1"
across a matrix of workloads, fleet sizes and (MPF, battery) configurations.
StratoSim's ``simulate`` runs one scenario at a time; this module runs a
*grid* of scenarios in a single compiled call:

  ``simulate_batch``  vmaps (timeline levels x n_chips x mitigation config
                      x jitter seed) through synthesis, aggregation,
                      mitigation scans, swing/band metrics and utility-spec
                      validation — no host round-trips inside.
  ``sweep``           cartesian product over workloads / fleet sizes /
                      configs / seeds, bucketed by waveform length (each
                      bucket is one compiled call), returning flat records.
  ``apply_batch``     one waveform through a stack of mitigation configs
                      (the Fig. 6 MPF sweep in one call).
  ``design_grid``     the batched grid search behind
                      ``smoothing.design_mitigation``.

Only the timeline -> sample-count expansion (``phase_levels``) and the
jitter-shift draw stay in numpy: they fix array shapes.  Everything with a
static shape is traced, so mitigation parameter grids ride through ``vmap``
as stacked pytree leaves (see ``stack_mitigations``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import IterationTimeline
from repro.core.smoothing.base import (Mitigation, energy_overhead_jax,
                                       materialize_aux)
from repro.core.smoothing.battery import RackBattery
from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
from repro.core.spec import SpecReport, UtilitySpec, report_from_arrays
from repro.core.spectrum import critical_band_report_jax
from repro.core.stratosim import SimResult
from repro.core.waveform import (WaveformConfig, aggregate_jax,
                                 chip_waveform_jax, jitter_shifts,
                                 phase_levels, swing_stats_jax)


# ---------------------------------------------------------------------------
# config batching
# ---------------------------------------------------------------------------

def stack_mitigations(mitigations: Sequence) -> object:
    """Stack structurally-identical mitigation pytrees into one batched
    pytree (leaves gain a leading config axis) for ``vmap``.

    All entries must be the same class with identical static metadata
    (hardware spec, telemetry config, windows); continuous parameters may
    differ per entry — that is the grid being swept.
    """
    mitigations = list(mitigations)
    if not mitigations:
        raise ValueError("empty mitigation list")
    return jax.tree.map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
        *mitigations)


def _tile(values, B: int, what: str) -> list:
    values = list(values)
    if len(values) == 1:
        return values * B
    if len(values) != B:
        raise ValueError(f"{what}: got {len(values)} entries, expected 1 or {B}")
    return values


def _normalize_mits(mits, B: int, what: str):
    """None | Mitigation | sequence -> (batched pytree | None)."""
    if mits is None:
        return None
    if not isinstance(mits, (list, tuple)):
        mits = [mits]
    mits = _tile(mits, B, what)
    if all(m is None for m in mits):
        return None
    if any(m is None for m in mits):
        raise ValueError(f"{what}: mixed None/mitigation rows are not "
                         "batchable — use a disabled config instead")
    return stack_mitigations(mits)


# ---------------------------------------------------------------------------
# the compiled pipeline
# ---------------------------------------------------------------------------

def _simulate_one(levels, shifts, n_chips, dev, rack,
                  cfg: WaveformConfig, hw: Hardware,
                  spec: Optional[UtilitySpec]) -> Dict:
    chip = chip_waveform_jax(levels, cfg.dt, hw, edp_spikes=cfg.edp_spikes,
                             include_host=cfg.include_host)
    dc_raw = aggregate_jax(chip, n_chips, shifts, hw)
    out: Dict = {"chip_raw": chip, "dc_raw": dc_raw}
    aux: Dict = {}
    dc = dc_raw
    if dev is not None:
        chip_m, aux_d = dev.apply_jax(chip, cfg.dt)
        aux["device"] = aux_d
        out["chip_mitigated"] = chip_m
        dc = aggregate_jax(chip_m, n_chips, shifts, hw)
    if rack is not None:
        dc, aux_r = rack.apply_jax(dc, cfg.dt)
        aux["rack"] = aux_r
    out["dc_mitigated"] = dc
    out["energy_overhead"] = energy_overhead_jax(dc_raw, dc)
    out["swing"] = swing_stats_jax(dc_raw)
    out["swing_mitigated"] = swing_stats_jax(dc)
    out["bands"] = critical_band_report_jax(dc_raw, cfg.dt)
    out["bands_mitigated"] = critical_band_report_jax(dc, cfg.dt)
    if spec is not None:
        ok, flags, metrics = spec.validate_jax(dc, cfg.dt)
        out["spec_ok"] = ok
        out["spec_flags"] = flags
        out["spec_metrics"] = metrics
    out["aux"] = aux
    return out


@functools.partial(jax.jit, static_argnames=("cfg", "hw", "spec"))
def _simulate_vmapped(levels, shifts, n_chips, dev, rack, *,
                      cfg: WaveformConfig, hw: Hardware,
                      spec: Optional[UtilitySpec]):
    return jax.vmap(
        lambda L, S, N, D, R: _simulate_one(L, S, N, D, R, cfg, hw, spec)
    )(levels, shifts, n_chips, dev, rack)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BatchResult:
    """One row per scenario; waveforms are [B, n], metrics are [B]."""
    t: np.ndarray
    dc_raw: np.ndarray
    dc_mitigated: np.ndarray
    chip_raw: np.ndarray
    chip_mitigated: Optional[np.ndarray]
    energy_overhead: np.ndarray
    swing: Dict[str, np.ndarray]
    swing_mitigated: Dict[str, np.ndarray]
    bands: Dict[str, np.ndarray]
    bands_mitigated: Dict[str, np.ndarray]
    spec_ok: Optional[np.ndarray]
    spec_flags: Optional[Dict[str, np.ndarray]]
    spec_metrics: Optional[Dict[str, np.ndarray]]
    aux: Dict

    def __len__(self) -> int:
        return self.dc_raw.shape[0]

    def report(self, i: int) -> Optional[SpecReport]:
        if self.spec_ok is None:
            return None
        row = jax.tree.map(lambda a: a[i], (self.spec_flags, self.spec_metrics))
        return report_from_arrays(self.spec_ok[i], row[0], row[1])

    def scenario(self, i: int) -> SimResult:
        """Rebuild the per-scenario ``SimResult`` (API compat with
        ``stratosim.simulate``) for row ``i``."""
        row = lambda d: {k: float(v[i]) for k, v in d.items()}
        return SimResult(
            t=self.t,
            dc_raw=self.dc_raw[i], dc_mitigated=self.dc_mitigated[i],
            chip_raw=self.chip_raw[i],
            chip_mitigated=(None if self.chip_mitigated is None
                            else self.chip_mitigated[i]),
            energy_overhead=float(self.energy_overhead[i]),
            swing=row(self.swing), swing_mitigated=row(self.swing_mitigated),
            bands=row(self.bands), bands_mitigated=row(self.bands_mitigated),
            spec_report=self.report(i),
            aux=materialize_aux(jax.tree.map(lambda a: a[i], self.aux)))


def simulate_batch(
        timelines: Union[IterationTimeline, Sequence[IterationTimeline]],
        n_chips: Union[int, Sequence[int]],
        wave_cfg: Optional[WaveformConfig] = None,
        *, device_mitigation=None, rack_mitigation=None,
        spec: Optional[UtilitySpec] = None, hw: Hardware = DEFAULT_HW,
        seeds: Union[int, Sequence[int]] = 0,
        sample_chips: int = 64,
        levels: Optional[Sequence[np.ndarray]] = None) -> BatchResult:
    """Simulate a batch of scenarios in one compiled call.

    Each batched argument (timelines, n_chips, device/rack mitigation
    configs, seeds) is a singleton (broadcast) or a length-B sequence; all
    timelines in one call must expand to the same sample count (``sweep``
    buckets mixed-length workloads automatically).  ``levels`` optionally
    supplies the per-row ``phase_levels`` arrays precomputed (callers like
    ``sweep`` that already expanded the timelines skip re-expansion).
    """
    cfg = wave_cfg or WaveformConfig()
    tls = timelines if isinstance(timelines, (list, tuple)) else [timelines]
    chips = n_chips if isinstance(n_chips, (list, tuple)) else [n_chips]
    seed_list = seeds if isinstance(seeds, (list, tuple)) else [seeds]
    dev_list = (device_mitigation if isinstance(device_mitigation, (list, tuple))
                else [device_mitigation])
    rack_list = (rack_mitigation if isinstance(rack_mitigation, (list, tuple))
                 else [rack_mitigation])

    B = max(len(tls), len(chips), len(seed_list), len(dev_list), len(rack_list))
    tls = _tile(tls, B, "timelines")
    chips = _tile(chips, B, "n_chips")
    seed_list = _tile(seed_list, B, "seeds")

    if levels is not None:
        level_rows = _tile(list(levels), B, "levels")
    else:
        # expand each distinct timeline once (rows are usually a small set
        # of workloads tiled across a big config grid)
        level_cache: Dict[int, np.ndarray] = {}
        level_rows = [
            level_cache.setdefault(id(tl), phase_levels(tl, cfg, hw))
            for tl in tls]
    n = len(level_rows[0])
    if any(len(r) != n for r in level_rows):
        raise ValueError(
            "all timelines in one simulate_batch call must expand to the "
            f"same sample count (got {sorted({len(r) for r in level_rows})}); "
            "use sweep() to bucket mixed-length workloads")
    levels = jnp.asarray(np.stack(level_rows), jnp.float32)
    shifts = jnp.asarray(np.stack(
        [jitter_shifts(cfg, s, sample_chips) for s in seed_list]))
    chips_f = jnp.asarray(np.asarray(chips, np.float32))
    dev = _normalize_mits(dev_list, B, "device_mitigation")
    rack = _normalize_mits(rack_list, B, "rack_mitigation")

    res = _simulate_vmapped(levels, shifts, chips_f, dev, rack,
                            cfg=cfg, hw=hw, spec=spec)
    res = jax.tree.map(np.asarray, res)
    return BatchResult(
        t=np.arange(n) * cfg.dt,
        dc_raw=res["dc_raw"], dc_mitigated=res["dc_mitigated"],
        chip_raw=res["chip_raw"],
        chip_mitigated=res.get("chip_mitigated"),
        energy_overhead=res["energy_overhead"],
        swing=res["swing"], swing_mitigated=res["swing_mitigated"],
        bands=res["bands"], bands_mitigated=res["bands_mitigated"],
        spec_ok=res.get("spec_ok"), spec_flags=res.get("spec_flags"),
        spec_metrics=res.get("spec_metrics"), aux=res["aux"])


# ---------------------------------------------------------------------------
# cartesian sweep
# ---------------------------------------------------------------------------

def sweep(workloads,
          n_chips: Sequence[int],
          configs: Sequence[Tuple[Optional[Mitigation], Optional[Mitigation]]],
          wave_cfg: Optional[WaveformConfig] = None,
          *, spec: Optional[UtilitySpec] = None, hw: Hardware = DEFAULT_HW,
          seeds: Sequence[int] = (0,), sample_chips: int = 64) -> List[Dict]:
    """Cartesian (workload x fleet size x config x seed) sweep.

    ``workloads`` is a dict name -> IterationTimeline (or a sequence, named
    by index); each config is a ``(device_mitigation, rack_mitigation)``
    pair (either side may be None, consistently across configs).  Workloads
    are bucketed by sample count; each bucket runs as ONE compiled vmapped
    call.  Returns one flat record dict per scenario.
    """
    cfg = wave_cfg or WaveformConfig()
    if isinstance(workloads, dict):
        names, tls = list(workloads.keys()), list(workloads.values())
    else:
        tls = list(workloads)
        names = [f"workload{i}" for i in range(len(tls))]
    combos = [(ti, ni, ci, si)
              for ti in range(len(tls)) for ni in n_chips
              for ci in range(len(configs)) for si in seeds]
    tl_levels = [phase_levels(tl, cfg, hw) for tl in tls]  # once per workload
    buckets: Dict[int, List[Tuple[int, Tuple]]] = {}
    for pos, combo in enumerate(combos):
        buckets.setdefault(len(tl_levels[combo[0]]), []).append((pos, combo))

    records: List[Optional[Dict]] = [None] * len(combos)
    for _, items in sorted(buckets.items()):
        idxs = [combo for _, combo in items]
        res = simulate_batch(
            [tls[ti] for ti, _, _, _ in idxs],
            [ni for _, ni, _, _ in idxs],
            cfg,
            device_mitigation=[configs[ci][0] for _, _, ci, _ in idxs],
            rack_mitigation=[configs[ci][1] for _, _, ci, _ in idxs],
            spec=spec, hw=hw, seeds=[si for _, _, _, si in idxs],
            sample_chips=sample_chips,
            levels=[tl_levels[ti] for ti, _, _, _ in idxs])
        for b, (pos, (ti, ni, ci, si)) in enumerate(items):
            rec = {
                "workload": names[ti],
                "n_chips": ni,
                "config": ci,
                "seed": si,
                "period_s": tls[ti].period_s,
                "mean_mw": float(res.swing["mean_w"][b]) / 1e6,
                "swing_mw": float(res.swing["swing_w"][b]) / 1e6,
                "swing_mitigated_mw":
                    float(res.swing_mitigated["swing_w"][b]) / 1e6,
                "energy_overhead": float(res.energy_overhead[b]),
                "paper_band_frac":
                    float(res.bands_mitigated["paper_band_0p2_3hz"][b]),
            }
            if res.spec_ok is not None:
                rec["spec_ok"] = bool(res.spec_ok[b])
                rec["violations"] = res.report(b).violations
            records[pos] = rec
    return records


# ---------------------------------------------------------------------------
# chip-level config batches (Fig. 6 style sweeps)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("dt",))
def _apply_vmapped(mits, w, *, dt: float):
    return jax.vmap(lambda m: m.apply_jax(w, dt))(mits)


def apply_batch(mitigations: Sequence, w: np.ndarray, dt: float
                ) -> Tuple[np.ndarray, Dict]:
    """Apply B structurally-identical mitigation configs to ONE waveform in
    a single vmapped call: (outs [B, n], aux dict with leading B axis)."""
    batched = stack_mitigations(mitigations)
    outs, aux = _apply_vmapped(batched, jnp.asarray(w, jnp.float32), dt=dt)
    return np.asarray(outs), jax.tree.map(np.asarray, aux)


# ---------------------------------------------------------------------------
# batched spec validation
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("spec", "dt"))
def _validate_vmapped(ws, *, spec: UtilitySpec, dt: float):
    return jax.vmap(lambda w: spec.validate_jax(w, dt))(ws)


def validate_many(ws: np.ndarray, spec: UtilitySpec, dt: float
                  ) -> Tuple[np.ndarray, List[SpecReport]]:
    """Validate B same-length waveforms [B, n] against one spec in a single
    vmapped call: (ok [B], per-row SpecReports)."""
    ok, flags, metrics = _validate_vmapped(
        jnp.asarray(np.asarray(ws), jnp.float32), spec=spec, dt=dt)
    ok = np.asarray(ok)
    flags, metrics = jax.tree.map(np.asarray, (flags, metrics))
    reports = [report_from_arrays(ok[i],
                                  {k: v[i] for k, v in flags.items()},
                                  {k: v[i] for k, v in metrics.items()})
               for i in range(len(ok))]
    return ok, reports


# ---------------------------------------------------------------------------
# batched (MPF x battery) design search
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("spec", "dt"))
def _design_eval(gpu_b, bat_b, gpu_on, bat_on, w, n_chips, *,
                 spec: UtilitySpec, dt: float):
    def one(gpu, bat, g_on, b_on):
        per_chip = w / n_chips
        smoothed, _ = gpu.apply_jax(per_chip, dt)
        agg = jnp.where(g_on > 0, smoothed, per_chip) * n_chips
        out_b, _ = bat.apply_jax(agg, dt)
        out = jnp.where(b_on > 0, out_b, agg)
        ok, flags, metrics = spec.validate_jax(out, dt)
        return out, ok, energy_overhead_jax(w, out), flags, metrics

    return jax.vmap(one)(gpu_b, bat_b, gpu_on, bat_on)


def design_grid(spec: UtilitySpec, w: np.ndarray, dt: float, n_chips: int,
                mpf_grid: Sequence[float], cap_grid: Sequence[float],
                *, swing: float, hw: Hardware = DEFAULT_HW) -> Optional[Dict]:
    """Evaluate every (MPF, capacity) candidate in one vmapped call and
    return the first passing one in grid order (MPF-major ascending — the
    serial search's minimal-waste-then-minimal-capacity preference)."""
    candidates = [(m, c) for m in mpf_grid for c in cap_grid]
    gpus = stack_mitigations([
        GpuPowerSmoothing(
            mpf_frac=m, hw=hw,
            ramp_up_w_per_s=spec.time.ramp_up_w_per_s / n_chips,
            ramp_down_w_per_s=spec.time.ramp_down_w_per_s / n_chips)
        for m, _ in candidates])
    # a disabled battery still runs through the scan (then gets deselected),
    # so give it a non-zero capacity to keep the SoC math finite
    bats = stack_mitigations([
        RackBattery(capacity_j=(c if c > 0 else 1.0),
                    max_discharge_w=swing, max_charge_w=swing)
        for _, c in candidates])
    gpu_on = jnp.asarray([1.0 if m > 0 else 0.0 for m, _ in candidates])
    bat_on = jnp.asarray([1.0 if c > 0 else 0.0 for _, c in candidates])

    outs, ok, overhead, flags, metrics = _design_eval(
        gpus, bats, gpu_on, bat_on, jnp.asarray(w, jnp.float32),
        jnp.asarray(float(n_chips), jnp.float32), spec=spec, dt=dt)
    ok = np.asarray(ok)
    if not ok.any():
        return None
    idx = int(np.argmax(ok))
    mpf, cap = candidates[idx]
    row = jax.tree.map(lambda a: np.asarray(a)[idx], (flags, metrics))
    # the winner as concrete mitigation objects — the single construction
    # point callers (design_mitigation, demos) reuse instead of rebuilding
    gpu_sel = (GpuPowerSmoothing(
        mpf_frac=mpf, hw=hw,
        ramp_up_w_per_s=spec.time.ramp_up_w_per_s / n_chips,
        ramp_down_w_per_s=spec.time.ramp_down_w_per_s / n_chips)
        if mpf > 0 else None)
    bat_sel = (RackBattery(capacity_j=cap, max_discharge_w=swing,
                           max_charge_w=swing) if cap > 0 else None)
    return {
        "mpf_frac": mpf,
        "battery_capacity_j": cap,
        "energy_overhead": float(np.asarray(overhead)[idx]),
        "report": report_from_arrays(ok[idx], row[0], row[1]),
        "device_mitigation": gpu_sel,
        "rack_mitigation": bat_sel,
        "mitigated": np.asarray(outs)[idx],
        "grid_ok": ok.reshape(len(mpf_grid), len(cap_grid)),
        "aux": {},
    }

"""Hardware constants: TPU v5e target + power model.

Roofline triple (197 TF bf16 / 819 GB/s HBM / ~50 GB/s/link ICI) is given by
the assignment. Power-model numbers marked (A) are stated assumptions (TPU
vendors do not publish chip TDP); numbers marked (P) come from the paper's
GB200 description and define the *feature model* (EDP=1.1x TDP, MPF<=90%).
The server-level breakdown mirrors the paper's Fig. 2 (accelerators >50% of
provisioned server power).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12       # FLOP/s   (assignment)
    hbm_bw: float = 819e9                 # B/s      (assignment)
    ici_bw_per_link: float = 50e9         # B/s/link (assignment)
    ici_links: int = 4                    # 2D torus (A)
    hbm_bytes: float = 16e9               # v5e HBM capacity
    tdp_w: float = 220.0                  # (A) chip+HBM board power
    idle_w: float = 60.0                  # (A)
    comm_w: float = 90.0                  # (A) power during ICI-bound phases
    hbm_bound_w: float = 160.0            # (A) power when HBM-bound
    edp_factor: float = 1.1               # (P) <=50 ms overshoot allowance
    edp_window_s: float = 0.050           # (P)
    mpf_max: float = 0.9                  # (P) max programmable power floor


@dataclasses.dataclass(frozen=True)
class ServerSpec:
    """Host overhead per Fig. 2 analogue: CPU+DRAM+NIC+fans+storage."""
    chips_per_host: int = 4
    host_overhead_w: float = 350.0        # (A) per host, all non-chip parts

    def overhead_per_chip_w(self) -> float:
        return self.host_overhead_w / self.chips_per_host


@dataclasses.dataclass(frozen=True)
class DatacenterTopology:
    chips_per_rack: int = 32              # v5e: 8 hosts x 4 chips
    racks_per_pod: int = 8                # 256-chip pod
    pods: int = 2                         # production dry-run: 2 pods
    # power-delivery conversion losses rack->utility (PSU/PDU/UPS chain)
    distribution_loss: float = 0.06       # (A)

    @property
    def chips(self) -> int:
        return self.chips_per_rack * self.racks_per_pod * self.pods


@dataclasses.dataclass(frozen=True)
class Hardware:
    chip: ChipSpec = ChipSpec()
    server: ServerSpec = ServerSpec()
    topo: DatacenterTopology = DatacenterTopology()

    def chip_share(self) -> float:
        """Fraction of server power provisioned for accelerators (Fig. 2)."""
        tot = self.chip.tdp_w + self.server.overhead_per_chip_w()
        return self.chip.tdp_w / tot


DEFAULT_HW = Hardware()

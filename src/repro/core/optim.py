"""Shared pure-JAX Adam core.

One moment-update kernel serves two very different callers:

* ``train/optimizer.py`` — the model-training AdamW (per-path weight-decay
  masks, bf16 moment storage, warmup+cosine schedule) wraps ``adam_leaf``
  per parameter leaf;
* ``core/engine.py`` ``design_gradient`` — the mitigation-design loop runs
  the tree-level ``adam_init``/``adam_update`` inside a ``lax.scan``,
  optimizing a handful of physical parameters (MPF fraction, battery
  capacity) instead of model weights.

Everything here is functional and trace-safe: no host sync, no Python
state, f32 update math with cast-back to the parameter dtype.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(
        lambda x: (x.astype(F32) * scale).astype(x.dtype), grads), g


def adam_leaf(p, g, m, v, count_f32, *, lr, b1, b2, eps,
              weight_decay=0.0) -> Tuple[jnp.ndarray, jnp.ndarray,
                                         jnp.ndarray]:
    """One Adam(W) moment update on a single leaf: returns
    ``(new_param, new_m, new_v)``.  Math in f32, results cast back to the
    input dtypes; ``count_f32`` is the 1-indexed step as f32 (bias
    correction).  ``weight_decay=0.0`` (exactly) skips the decoupled-decay
    term entirely, so decay-exempt leaves stay bit-identical to plain Adam.
    """
    gf = g.astype(F32)
    m2 = b1 * m.astype(F32) + (1 - b1) * gf
    v2 = b2 * v.astype(F32) + (1 - b2) * gf * gf
    mh = m2 / (1.0 - b1 ** count_f32)
    vh = v2 / (1.0 - b2 ** count_f32)
    step = mh / (jnp.sqrt(vh) + eps)
    if not (isinstance(weight_decay, (int, float)) and weight_decay == 0.0):
        step = step + weight_decay * p.astype(F32)
    p2 = p.astype(F32) - lr * step
    return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)


def adam_init(params) -> Dict:
    """Optimizer state for ``adam_update`` (f32 moments, scalar count)."""
    zeros = lambda p: jnp.zeros(jnp.shape(p), F32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, *, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0) -> Tuple[object, Dict]:
    """Tree-level Adam step (no per-leaf decay masks — the training-side
    AdamW handles those): ``(new_params, new_state)``."""
    count = state["count"] + 1
    c = count.astype(F32)
    flat = jax.tree.map(
        lambda p, g, m, v: adam_leaf(p, g, m, v, c, lr=lr, b1=b1, b2=b2,
                                     eps=eps, weight_decay=weight_decay),
        params, grads, state["m"], state["v"])
    outer = jax.tree.structure(params)
    inner = jax.tree.structure((0, 0, 0))
    new_params, new_m, new_v = jax.tree.transpose(outer, inner, flat)
    return new_params, {"m": new_m, "v": new_v, "count": count}

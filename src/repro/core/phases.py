"""Phase-timeline extraction: compiled-step costs -> per-iteration phases.

This is the bridge between the ML framework and the power domain. The same
dry-run artifact that feeds the roofline table (exact FLOPs / bytes /
collective bytes per chip per step, launch/dryrun.py) determines how long
each chip spends compute-bound vs. communication-bound per iteration — which
is precisely the power square wave of the paper's Fig. 1.

A timeline is a list of Phase(name, duration_s, util) where util is the
power *mode* of the chip during that phase; waveform.py maps modes to watts.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence

from repro.core.hardware import DEFAULT_HW, Hardware

# power modes
COMPUTE, MEMORY, COMM, IDLE, CKPT = "compute", "memory", "comm", "idle", "ckpt"


@dataclasses.dataclass(frozen=True)
class Phase:
    name: str
    duration_s: float
    mode: str  # compute | memory | comm | idle | ckpt


@dataclasses.dataclass(frozen=True)
class IterationTimeline:
    phases: Sequence[Phase]

    @property
    def period_s(self) -> float:
        return sum(p.duration_s for p in self.phases)

    def scaled(self, factor: float) -> "IterationTimeline":
        return IterationTimeline(tuple(
            dataclasses.replace(p, duration_s=p.duration_s * factor)
            for p in self.phases))


def from_dryrun_cell(cell: Dict, hw: Hardware = DEFAULT_HW, *,
                     overlap: float = 0.0,
                     mfu: float = 0.5) -> IterationTimeline:
    """Build a per-iteration timeline from a dry-run artifact dict.

    overlap: fraction of collective time hidden under compute (the paper's
             "techniques for overlapping communication and computation ...
             most workloads retain a significant synchronization step").
    mfu:     achieved fraction of peak FLOPs during compute phases.
    """
    chips = cell["n_chips"]
    flops_per_chip = cell["exact"]["flops"] / chips
    bytes_per_chip = cell["exact"]["bytes"] / chips
    coll = cell.get("collectives", {})
    coll_bytes = sum(coll.values())  # already per-chip

    t_flops = flops_per_chip / (hw.chip.peak_flops_bf16 * mfu)
    t_mem = bytes_per_chip / hw.chip.hbm_bw
    t_comm = coll_bytes / (hw.chip.ici_bw_per_link * hw.chip.ici_links)

    compute_mode = COMPUTE if t_flops >= t_mem else MEMORY
    t_compute = max(t_flops, t_mem)
    t_exposed = t_comm * (1.0 - overlap)

    # MoE all-to-all manifests as a mid-iteration comm notch; attention/FSDP
    # gathers overlap with compute. Split exposed comm: the gradient
    # all-reduce/reduce-scatter tail + a dispatch notch when present.
    a2a = coll.get("all-to-all", 0.0) * (1.0 - overlap)
    t_a2a = a2a / (hw.chip.ici_bw_per_link * hw.chip.ici_links)
    t_tail = max(t_exposed - t_a2a, 0.0)

    phases: List[Phase] = []
    if t_a2a > 0:
        phases.append(Phase("fwd", t_compute * 0.33, compute_mode))
        phases.append(Phase("moe-a2a", t_a2a, COMM))
        phases.append(Phase("bwd", t_compute * 0.67, compute_mode))
    else:
        phases.append(Phase("fwd+bwd", t_compute, compute_mode))
    phases.append(Phase("grad-sync", max(t_tail, 1e-4), COMM))
    return IterationTimeline(tuple(phases))


def checkpoint_phase(cell: Dict, hw: Hardware = DEFAULT_HW,
                     storage_bw_per_chip: float = 1e9) -> Phase:
    """Periodic checkpoint write: chips near-idle while state drains."""
    state_bytes = cell.get("memory", {}).get("state_bytes_per_device", 8e9)
    return Phase("checkpoint", state_bytes / storage_bw_per_chip, CKPT)


def load_cell(path_or_dir: str, arch: str = "", shape: str = "",
              mesh: str = "single") -> Dict:
    p = path_or_dir
    if os.path.isdir(path_or_dir):
        p = os.path.join(path_or_dir, f"{arch}__{shape}__{mesh}.json")
    with open(p) as f:
        return json.load(f)


def synthetic_timeline(period_s: float = 1.0, comm_frac: float = 0.25,
                       moe_notch: bool = False) -> IterationTimeline:
    """Fig.1-like timeline without a dry-run artifact (tests/benches)."""
    tc = period_s * (1 - comm_frac)
    phases = []
    if moe_notch:
        phases += [Phase("fwd", tc * 0.33, COMPUTE),
                   Phase("moe-a2a", period_s * comm_frac * 0.3, COMM),
                   Phase("bwd", tc * 0.67, COMPUTE),
                   Phase("grad-sync", period_s * comm_frac * 0.7, COMM)]
    else:
        phases += [Phase("fwd+bwd", tc, COMPUTE),
                   Phase("grad-sync", period_s * comm_frac, COMM)]
    return IterationTimeline(tuple(phases))

from repro.core.smoothing.base import Mitigation, Stack, energy_overhead
from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
from repro.core.smoothing.battery import RackBattery
from repro.core.smoothing.firefly import Firefly
from repro.core.smoothing.combined import CombinedMitigation, design_mitigation
from repro.core.smoothing.backstop import TelemetryBackstop

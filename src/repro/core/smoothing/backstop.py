"""Fast telemetry-based backstop (paper Sec. IV-E).

Streams the datacenter waveform through per-bin spectral monitors
(Goertzel resonators over a sliding window — the Pallas hot path lives in
kernels/goertzel) and escalates through tiered responses when a critical
bin's amplitude stays above threshold:

  level 0  observe
  level 1  soft throttle   (scale the AC component of the load by alpha1)
  level 2  power shed      (cap total power at shed_cap)
  level 3  disconnect      (drop to idle floor; coordinated breaker action)

De-escalation happens after the bin amplitude stays below threshold for
``cooldown_s``.

The spectral monitor runs on the *fused* lane-major sliding-Goertzel
Pallas kernel by default (``kernels/goertzel/ops.sliding_monitor_fused``;
compiled on TPU backends, interpret mode elsewhere so CPU CI and the
batched engine's vmap path keep working): per-bin amplitudes are
reduced to the worst bin and its escalation class *inside* the kernel,
so the ``[n, K]`` amplitude matrix never leaves VMEM, and the class
stream runs through the blocked closed-form
``core.telemetry.escalation_scan`` instead of a per-sample scan.
``use_pallas=False`` selects the structurally identical jnp
``lax.scan`` mirror of the same fused monitor (``fused_scan=True``, the
default — bitwise equal to the interpret-mode kernel and the
differentiable path), or, with ``fused_scan=False``, the cumsum oracle
(``sliding_bin_power_jnp``) + separate per-sample escalation scan as
the analysis-side reference.  Every path removes the trace mean before
accumulating — without that, MW-scale DC offsets bury the ~1e5 W
oscillations this monitor exists to catch (see kernels/goertzel/ref.py).

Escalation is gated until one full window has streamed: partial-window
amplitude estimates during warm-up are dominated by whatever transient
happens to sit in the first samples (a spike at t=0 used to escalate the
response before a single window of evidence existed).  A trace shorter
than one window therefore never escalates.

The escalation state machine runs as a lax.scan, so the whole monitor is
jit/vmap-able; thresholds and response gains are pytree leaves, while the
monitored bins, window/sustain/cooldown durations and the kernel switch
fix shapes and counter constants and stay static.

``smooth_tau`` (structure-static meta field) selects the gradient-design
relaxation: 0 is the exact hard path below.  Escalation is physically
discrete (level 3 is a coordinated breaker action), so tau > 0 keeps the
hard levels in the *forward* pass and attaches a straight-through sigmoid
engagement gate in the backward pass — ``amp_threshold_w`` and the
response gains (``alpha1``/``shed_frac``/``idle_frac``) become
differentiable without ever faking a fractional disconnect.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.smoothing.base import np_apply, register_mitigation
from repro.core.smoothing.relax import sigmoid_gate
from repro.core.telemetry import escalation_init, escalation_step
from repro.kernels.goertzel.ops import interpret_default, sliding_monitor_fused
from repro.kernels.goertzel.ref import sliding_bin_power_jnp

# historical name; the kernel-backend switch now lives next to the kernels
_interpret_default = interpret_default


@dataclasses.dataclass(frozen=True)
class TelemetryBackstop:
    critical_hz: Sequence[float] = (0.5, 1.0, 2.0, 9.0)
    window_s: float = 8.0
    amp_threshold_w: float = 1e6            # per-bin amplitude trigger
    sustain_s: float = 2.0                  # must persist before escalation
    cooldown_s: float = 4.0
    alpha1: float = 0.5                     # level-1 AC attenuation
    shed_frac: float = 0.7                  # level-2 cap (fraction of mean)
    idle_frac: float = 0.2                  # level-3 floor
    use_pallas: bool = True                 # structure-static kernel switch
    # jnp path only: fuse Goertzel recurrence + escalation into one scan
    fused_scan: bool = True
    # 0 = exact hard semantics; > 0 = straight-through gradient relaxation
    smooth_tau: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "critical_hz", tuple(self.critical_hz))

    def _esc_step(self, carry, worst_i, i, *, win: int, n: int,
                  sustain_n: int, cool_n: int):
        """One sample of the escalation state machine (shared by the
        post-hoc scan over a monitor's amplitude stream and the fused
        segment scan, whose trailing zero-pad samples ``i >= n`` must
        not trigger).  Delegates to the shared
        ``core.telemetry.escalation_step`` so the backstop and the online
        control-plane detector run identical gating."""
        return escalation_step(carry, worst_i, i,
                               threshold=self.amp_threshold_w, win=win, n=n,
                               sustain_n=sustain_n, cool_n=cool_n)

    @staticmethod
    def _esc_init():
        return escalation_init()

    def _escalate(self, worst, *, win: int, sustain_n: int, cool_n: int):
        """Escalation levels from a fully-materialized amplitude stream
        (the Pallas-kernel and cumsum-oracle monitor paths)."""
        n = worst.shape[-1]
        (_, _, _, detect), levels = jax.lax.scan(
            lambda c, inp: self._esc_step(c, inp[0], inp[1], win=win, n=n,
                                          sustain_n=sustain_n, cool_n=cool_n),
            self._esc_init(), (worst, jnp.arange(n, dtype=jnp.int32)))
        return worst, levels, detect

    def apply_jax(self, w: jnp.ndarray, dt: float) -> Tuple[jnp.ndarray, Dict]:
        w = jnp.asarray(w, jnp.float32)
        n = w.shape[-1]
        win = max(int(self.window_s / dt), 8)
        sustain_n = max(int(self.sustain_s / dt), 1)
        cool_n = max(int(self.cooldown_s / dt), 1)
        kw = dict(win=win, sustain_n=sustain_n, cool_n=cool_n)
        if self.use_pallas or self.fused_scan:
            # fused monitor: worst bin + escalation class in-kernel (or its
            # bitwise-equal jnp mirror), blocked escalation scan on top
            worst, levels, detect, _peaks = sliding_monitor_fused(
                w, float(dt), tuple(self.critical_hz), win=win,
                threshold=self.amp_threshold_w, sustain_n=sustain_n,
                cool_n=cool_n, interpret=_interpret_default(),
                use_pallas=self.use_pallas)
        else:
            amps = sliding_bin_power_jnp(w, dt, self.critical_hz, win)
            worst, levels, detect = self._escalate(amps.max(axis=1), **kw)

        mean = w.mean()
        r1 = mean + self.alpha1 * (w - mean)
        out = jnp.where(levels == 1, r1, w)
        out = jnp.where(levels == 2, jnp.minimum(w, self.shed_frac * mean),
                        out)
        out = jnp.where(levels == 3, self.idle_frac * mean, out)
        if self.smooth_tau:
            # forward: exactly the hard response above — the added term is
            # identically zero (soft - stop_gradient(soft)).  backward: the
            # sigmoid supplies d/d(amp_threshold_w) through the engagement
            # margin; the response gains already get theirs through the
            # selected jnp.where branches.  Off-path samples use the
            # level-1 soft throttle as the response proxy (the first
            # escalation any hit would trigger).
            resp = jnp.where(levels > 0, out, r1)
            soft = sigmoid_gate(worst - self.amp_threshold_w, self.smooth_tau,
                                jnp.maximum(self.amp_threshold_w, 1.0))
            out = out + (soft - jax.lax.stop_gradient(soft)) * (resp - w)
        aux = {
            "max_level": levels.max(),
            "detect_latency_s": jnp.where(detect >= 0, detect * dt, -1.0),
            "levels": levels,
            "worst_bin_amp": worst,
        }
        return out, aux

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        return np_apply(self, w, dt)


register_mitigation(
    TelemetryBackstop,
    data_fields=("amp_threshold_w", "alpha1", "shed_frac", "idle_frac"),
    meta_fields=("critical_hz", "window_s", "sustain_s", "cooldown_s",
                 "use_pallas", "fused_scan", "smooth_tau"))

"""Fast telemetry-based backstop (paper Sec. IV-E).

Streams the datacenter waveform through per-bin spectral monitors
(Goertzel resonators over a sliding window — the Pallas hot path lives in
kernels/goertzel) and escalates through tiered responses when a critical
bin's amplitude stays above threshold:

  level 0  observe
  level 1  soft throttle   (scale the AC component of the load by alpha1)
  level 2  power shed      (cap total power at shed_cap)
  level 3  disconnect      (drop to idle floor; coordinated breaker action)

De-escalation happens after the bin amplitude stays below threshold for
``cooldown_s``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.kernels.goertzel.ref import sliding_bin_power_ref


@dataclasses.dataclass(frozen=True)
class TelemetryBackstop:
    critical_hz: Sequence[float] = (0.5, 1.0, 2.0, 9.0)
    window_s: float = 8.0
    amp_threshold_w: float = 1e6            # per-bin amplitude trigger
    sustain_s: float = 2.0                  # must persist before escalation
    cooldown_s: float = 4.0
    alpha1: float = 0.5                     # level-1 AC attenuation
    shed_frac: float = 0.7                  # level-2 cap (fraction of mean)
    idle_frac: float = 0.2                  # level-3 floor

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        n = len(w)
        win = max(int(self.window_s / dt), 8)
        amps = sliding_bin_power_ref(
            np.asarray(w, np.float64), dt, np.asarray(self.critical_hz), win)
        worst = amps.max(axis=1)  # [n]

        sustain_n = max(int(self.sustain_s / dt), 1)
        cool_n = max(int(self.cooldown_s / dt), 1)
        level = 0
        above = below = 0
        levels = np.zeros(n, np.int8)
        detect_idx = -1
        for i in range(n):
            if worst[i] > self.amp_threshold_w:
                above += 1
                below = 0
                if above >= sustain_n and level < 3:
                    level += 1
                    above = 0
                    if detect_idx < 0:
                        detect_idx = i
            else:
                below += 1
                above = 0
                if below >= cool_n and level > 0:
                    level -= 1
                    below = 0
            levels[i] = level

        mean = float(w.mean())
        out = w.copy()
        l1 = levels == 1
        out[l1] = mean + self.alpha1 * (w[l1] - mean)
        l2 = levels == 2
        out[l2] = np.minimum(w[l2], self.shed_frac * mean)
        l3 = levels == 3
        out[l3] = self.idle_frac * mean
        aux = {
            "max_level": int(levels.max()),
            "detect_latency_s": float(detect_idx * dt) if detect_idx >= 0 else -1.0,
            "levels": levels,
            "worst_bin_amp": worst,
        }
        return out, aux

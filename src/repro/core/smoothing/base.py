"""Mitigation interface: a transform on sampled power waveforms.

Every mitigation exposes two entry points:

``apply_jax(w, dt) -> (w, aux)`` — the *pure* contract: jnp arrays in, jnp
arrays out, no host sync.  Mitigation dataclasses are registered as JAX
pytrees whose continuous parameters are leaves, so a grid of configurations
stacks into one batched pytree and the whole waveform->mitigation->spec
pipeline jits and vmaps (core/engine.py).  ``dt`` and any field that fixes
array shapes (windows, sampling periods) must stay concrete.

``apply(w, dt) -> (w, aux)`` — the numpy-facing wrapper kept for API
compatibility: delegates to ``apply_jax`` and materializes the outputs.

``apply`` consumes the power the load *wants* to draw and returns the power
the upstream level *sees*, plus an aux dict (state traces, overheads).
Mitigations compose with ``Stack`` in load->utility order.
"""
from __future__ import annotations

import inspect
from typing import Dict, Optional, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Mitigation(Protocol):
    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        ...

    def apply_jax(self, w: jnp.ndarray, dt: float) -> Tuple[jnp.ndarray, Dict]:
        ...


def accepts_key(mit) -> bool:
    """True when a mitigation's ``apply_jax`` takes a PRNG ``key`` (it
    consumes randomness — today: telemetry noise).  The check is on the
    class, so it is static under jit/vmap."""
    try:
        return "key" in inspect.signature(type(mit).apply_jax).parameters
    except (TypeError, ValueError):
        return False


def apply_mitigation(mit, w: jnp.ndarray, dt: float,
                     key: Optional[jax.Array] = None
                     ) -> Tuple[jnp.ndarray, Dict]:
    """``mit.apply_jax`` with the key threaded iff the mitigation takes one.
    Mitigations without randomness keep the two-argument contract."""
    if key is not None and accepts_key(mit):
        return mit.apply_jax(w, dt, key=key)
    return mit.apply_jax(w, dt)


def register_mitigation(cls, data_fields: Sequence[str],
                        meta_fields: Sequence[str]):
    """Register a mitigation dataclass as a pytree: ``data_fields`` are
    leaves (vmappable parameter grids), ``meta_fields`` are static aux data
    (hardware specs, telemetry configs, shape-fixing windows)."""
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=list(meta_fields))
    return cls


def materialize_aux(aux: Dict) -> Dict:
    """Convert an apply_jax aux tree to numpy/python for the np-facing API."""
    out: Dict = {}
    for k, v in aux.items():
        if isinstance(v, dict):
            out[k] = materialize_aux(v)
        elif isinstance(v, (jnp.ndarray, np.ndarray)):
            a = np.asarray(v)
            if a.ndim == 0:
                out[k] = int(a) if a.dtype.kind in "iub" else float(a)
            else:
                out[k] = a
        else:
            out[k] = v
    return out


def np_apply(mit, w: np.ndarray, dt: float,
             key: Optional[jax.Array] = None) -> Tuple[np.ndarray, Dict]:
    """Shared numpy-facing wrapper around a mitigation's ``apply_jax``."""
    out, aux = apply_mitigation(mit, jnp.asarray(w, jnp.float32), dt, key)
    return np.asarray(out), materialize_aux(aux)


class Stack:
    def __init__(self, stages: Sequence[Mitigation]):
        self.stages = list(stages)

    def apply_jax(self, w: jnp.ndarray, dt: float, key=None):
        aux_all: Dict = {}
        for i, s in enumerate(self.stages):
            k = None if key is None else jax.random.fold_in(key, i)
            w, aux = apply_mitigation(s, w, dt, k)
            aux_all[f"{i}:{type(s).__name__}"] = aux
        return w, aux_all

    def apply(self, w: np.ndarray, dt: float, key=None):
        return np_apply(self, w, dt, key)


def _stack_flatten(s: Stack):
    return tuple(s.stages), None


def _stack_unflatten(_, stages):
    return Stack(stages)


jax.tree_util.register_pytree_node(Stack, _stack_flatten, _stack_unflatten)


def energy_overhead(w_in: np.ndarray, w_out: np.ndarray) -> float:
    """(E_out - E_in) / E_in — the paper's 'wasted energy' metric."""
    e_in = float(np.sum(w_in))
    return (float(np.sum(w_out)) - e_in) / max(e_in, 1e-12)


def energy_overhead_jax(w_in: jnp.ndarray, w_out: jnp.ndarray) -> jnp.ndarray:
    e_in = jnp.sum(w_in)
    return (jnp.sum(w_out) - e_in) / jnp.maximum(e_in, 1e-12)

"""Mitigation interface: a transform on sampled power waveforms.

``apply(w, dt)`` consumes the power the load *wants* to draw and returns
the power the upstream level *sees*, plus an aux dict (state traces,
overheads). Mitigations compose with ``Stack`` in load->utility order.
"""
from __future__ import annotations

from typing import Dict, Protocol, Sequence, Tuple

import numpy as np


class Mitigation(Protocol):
    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        ...


class Stack:
    def __init__(self, stages: Sequence[Mitigation]):
        self.stages = list(stages)

    def apply(self, w: np.ndarray, dt: float):
        aux_all: Dict = {}
        for i, s in enumerate(self.stages):
            w, aux = s.apply(w, dt)
            aux_all[f"{i}:{type(s).__name__}"] = aux
        return w, aux_all


def energy_overhead(w_in: np.ndarray, w_out: np.ndarray) -> float:
    """(E_out - E_in) / E_in — the paper's 'wasted energy' metric."""
    e_in = float(np.sum(w_in))
    return (float(np.sum(w_out)) - e_in) / max(e_in, 1e-12)

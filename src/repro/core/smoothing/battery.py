"""Rack-level energy storage (paper Sec. IV-C), as a lax.scan SoC model.

The BESS tracks a slowly-moving grid target (EMA of load) by discharging
into compute peaks and recharging in comm valleys — Fig. 7. Limits modeled:
capacity (J), charge/discharge power (W), round-trip efficiency, and the
charge/discharge mode-switch latency (the paper's requirement 4: 'switch
modes quickly'). Energy is conserved up to efficiency losses (property
tested).

Every parameter is a pytree leaf, so a capacity/power grid vmaps through
``apply_jax`` in one compiled call (see core/engine.py).

``smooth_tau`` (structure-static meta field) selects the gradient-design
relaxation: 0 is the exact hard SoC model below; > 0 replaces the
``jnp.sign`` charge/discharge mode switch and the latency-hold step gate
with tanh/sigmoid blends at temperature tau (the SoC tapers and power
clips are piecewise linear and already carry subgradients, so they stay).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.smoothing.base import (energy_overhead_jax, np_apply,
                                       register_mitigation)
from repro.core.smoothing.relax import sigmoid_gate, soft_sign


@dataclasses.dataclass(frozen=True)
class RackBattery:
    capacity_j: float                    # usable energy per rack-equivalent
    max_discharge_w: float
    max_charge_w: float
    efficiency: float = 0.95             # one-way (sqrt of round-trip)
    target_tau_s: float = 30.0           # EMA horizon for the grid target
    initial_soc: float = 0.5
    switch_latency_s: float = 0.0        # mode-switch dead time
    # 0 = exact hard semantics; > 0 = gradient-design relaxation (static
    # so hard and smooth configs never stack into one vmapped grid)
    smooth_tau: float = 0.0

    def _latency_samples(self, dt: float) -> jnp.ndarray:
        """Mode-switch dead time in whole samples, computed ONCE per trace
        (hoisted out of the scan body).  ``jnp.round`` makes this a
        static-like quantity: it is a pytree leaf (grids over latency still
        vmap), but its gradient is zero almost everywhere, so it is pinned
        with ``stop_gradient`` and excluded from gradient design — treat it
        like hardware, not a design variable."""
        return jax.lax.stop_gradient(jnp.round(self.switch_latency_s / dt))

    def apply_jax(self, w: jnp.ndarray, dt: float) -> Tuple[jnp.ndarray, Dict]:
        if self.smooth_tau:
            return self._apply_smooth(w, dt)
        alpha = dt / jnp.maximum(self.target_tau_s, dt)
        lat_n = self._latency_samples(dt)
        # guard: capacity 0 must degrade to a passthrough (soc stays 0,
        # tapers close both ports), not 0/0-NaN the soc fraction — the
        # gradient designer's box projection can land on exactly 0
        cap_j = jnp.maximum(self.capacity_j, 1e-9)

        def step(carry, p):
            soc, tgt, mode, hold = carry
            tgt = tgt + alpha * (p - tgt)
            want = p - tgt                      # >0: discharge, <0: charge
            new_mode = jnp.sign(want)
            switching = (new_mode != mode) & (new_mode != 0) & (mode != 0)
            hold = jnp.where(switching, lat_n, jnp.maximum(hold - 1.0, 0.0))
            blocked = hold > 0
            # power limits, with anti-windup taper near the SoC bounds so a
            # saturating battery releases the load gradually (no grid steps)
            soc_frac = soc / cap_j
            taper_lo = jnp.clip(soc_frac / 0.10, 0.0, 1.0)
            taper_hi = jnp.clip((1.0 - soc_frac) / 0.10, 0.0, 1.0)
            dis = jnp.clip(want, 0.0, self.max_discharge_w * taper_lo)
            dis = jnp.minimum(dis, soc * self.efficiency / dt)
            chg = jnp.clip(-want, 0.0, self.max_charge_w * taper_hi)
            chg = jnp.minimum(chg, (cap_j - soc) / self.efficiency / dt)
            dis = jnp.where(blocked, 0.0, dis)
            chg = jnp.where(blocked, 0.0, chg)
            grid = p - dis + chg
            soc = soc - dis * dt / self.efficiency + chg * dt * self.efficiency
            soc = jnp.clip(soc, 0.0, cap_j)
            return (soc, tgt, new_mode, hold), (grid, soc)

        w = jnp.asarray(w, jnp.float32)
        # grid target starts at the trace mean (the scheduled steady-state
        # draw a real operator bids into the day-ahead market) — starting at
        # w[0] makes the battery burn capacity chasing the initial transient
        init = (jnp.asarray(self.initial_soc * cap_j, jnp.float32),
                jnp.mean(w), jnp.asarray(0.0, jnp.float32),
                jnp.asarray(0.0, jnp.float32))
        _, (grid, soc) = jax.lax.scan(step, init, w, unroll=8)
        aux = {
            "soc_trace": soc,
            "soc_min_frac": soc.min() / cap_j,
            "soc_max_frac": soc.max() / cap_j,
            "energy_overhead": energy_overhead_jax(w, grid),
            "peak_reduction_w": w.max() - grid.max(),
        }
        return grid, aux

    def _apply_smooth(self, w: jnp.ndarray, dt: float
                      ) -> Tuple[jnp.ndarray, Dict]:
        """Relaxed SoC model at temperature ``smooth_tau``: mode is a tanh
        of the power mismatch, the latency hold engages in proportion to
        the mode flip, and the blocked gate is a sigmoid of the remaining
        hold — everything else is the hard model unchanged."""
        tau = self.smooth_tau
        alpha = dt / jnp.maximum(self.target_tau_s, dt)
        lat_n = self._latency_samples(dt)
        cap_j = jnp.maximum(self.capacity_j, 1e-9)  # see apply_jax guard
        p_scale = 0.5 * (self.max_discharge_w + self.max_charge_w)
        # taper widths floored at ~2 power-limit samples of energy: the
        # hard 0.10*cap width makes the SoC recursion's reverse-mode
        # factor ~ max_W*dt / (0.10*cap*eff) — unbounded as cap -> 0, and
        # a scan-length product of that overflows f32 and NaNs the design
        # lane.  The floor keeps d(soc')/d(soc) >= 0.5 (contractive) at
        # any capacity; for realistically-sized batteries 0.10*cap
        # dominates and the forward matches the hard taper.
        w_lo = jnp.maximum(0.10 * cap_j,
                           2.0 * self.max_discharge_w * dt / self.efficiency)
        w_hi = jnp.maximum(0.10 * cap_j,
                           2.0 * self.max_charge_w * dt * self.efficiency)

        def step(carry, p):
            soc, tgt, mode, hold = carry
            tgt = tgt + alpha * (p - tgt)
            want = p - tgt
            new_mode = soft_sign(want, tau, p_scale)
            # opposing signs -> flip strength in (0, 1]
            switching = jnp.clip(-(new_mode * mode), 0.0, 1.0)
            hold = (switching * lat_n
                    + (1.0 - switching) * jnp.maximum(hold - 1.0, 0.0))
            open_f = sigmoid_gate(0.5 - hold, tau, lat_n + 1.0)
            taper_lo = jnp.clip(soc / w_lo, 0.0, 1.0)
            taper_hi = jnp.clip((cap_j - soc) / w_hi, 0.0, 1.0)
            dis = jnp.clip(want, 0.0, self.max_discharge_w * taper_lo)
            dis = jnp.minimum(dis, soc * self.efficiency / dt)
            chg = jnp.clip(-want, 0.0, self.max_charge_w * taper_hi)
            chg = jnp.minimum(chg, (cap_j - soc) / self.efficiency / dt)
            dis = open_f * dis
            chg = open_f * chg
            grid = p - dis + chg
            soc = soc - dis * dt / self.efficiency + chg * dt * self.efficiency
            soc = jnp.clip(soc, 0.0, cap_j)
            return (soc, tgt, new_mode, hold), (grid, soc)

        w = jnp.asarray(w, jnp.float32)
        init = (jnp.asarray(self.initial_soc * cap_j, jnp.float32),
                jnp.mean(w), jnp.asarray(0.0, jnp.float32),
                jnp.asarray(0.0, jnp.float32))
        _, (grid, soc) = jax.lax.scan(step, init, w, unroll=8)
        aux = {
            "soc_trace": soc,
            "soc_min_frac": soc.min() / cap_j,
            "soc_max_frac": soc.max() / cap_j,
            "energy_overhead": energy_overhead_jax(w, grid),
            "peak_reduction_w": w.max() - grid.max(),
        }
        return grid, aux

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        return np_apply(self, w, dt)


register_mitigation(
    RackBattery,
    data_fields=("capacity_j", "max_discharge_w", "max_charge_w",
                 "efficiency", "target_tau_s", "initial_soc",
                 "switch_latency_s"),
    meta_fields=("smooth_tau",))


def size_battery_for(job_w_swing: float, period_s: float, n_racks: int,
                     margin: float = 2.0) -> RackBattery:
    """Capacity to absorb half a swing cycle per rack, with margin."""
    per_rack_swing = job_w_swing / n_racks
    cap = margin * per_rack_swing * (period_s / 2)
    return RackBattery(capacity_j=cap * n_racks,
                       max_discharge_w=job_w_swing,
                       max_charge_w=job_w_swing)

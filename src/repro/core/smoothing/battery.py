"""Rack-level energy storage (paper Sec. IV-C), as a lax.scan SoC model.

The BESS tracks a slowly-moving grid target (EMA of load) by discharging
into compute peaks and recharging in comm valleys — Fig. 7. Limits modeled:
capacity (J), charge/discharge power (W), round-trip efficiency, and the
charge/discharge mode-switch latency (the paper's requirement 4: 'switch
modes quickly'). Energy is conserved up to efficiency losses (property
tested).

Every parameter is a pytree leaf, so a capacity/power grid vmaps through
``apply_jax`` in one compiled call (see core/engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.smoothing.base import (energy_overhead_jax, np_apply,
                                       register_mitigation)


@dataclasses.dataclass(frozen=True)
class RackBattery:
    capacity_j: float                    # usable energy per rack-equivalent
    max_discharge_w: float
    max_charge_w: float
    efficiency: float = 0.95             # one-way (sqrt of round-trip)
    target_tau_s: float = 30.0           # EMA horizon for the grid target
    initial_soc: float = 0.5
    switch_latency_s: float = 0.0        # mode-switch dead time

    def apply_jax(self, w: jnp.ndarray, dt: float) -> Tuple[jnp.ndarray, Dict]:
        alpha = dt / jnp.maximum(self.target_tau_s, dt)
        lat_n = jnp.round(self.switch_latency_s / dt)
        cap_j = self.capacity_j

        def step(carry, p):
            soc, tgt, mode, hold = carry
            tgt = tgt + alpha * (p - tgt)
            want = p - tgt                      # >0: discharge, <0: charge
            new_mode = jnp.sign(want)
            switching = (new_mode != mode) & (new_mode != 0) & (mode != 0)
            hold = jnp.where(switching, lat_n, jnp.maximum(hold - 1.0, 0.0))
            blocked = hold > 0
            # power limits, with anti-windup taper near the SoC bounds so a
            # saturating battery releases the load gradually (no grid steps)
            soc_frac = soc / cap_j
            taper_lo = jnp.clip(soc_frac / 0.10, 0.0, 1.0)
            taper_hi = jnp.clip((1.0 - soc_frac) / 0.10, 0.0, 1.0)
            dis = jnp.clip(want, 0.0, self.max_discharge_w * taper_lo)
            dis = jnp.minimum(dis, soc * self.efficiency / dt)
            chg = jnp.clip(-want, 0.0, self.max_charge_w * taper_hi)
            chg = jnp.minimum(chg, (cap_j - soc) / self.efficiency / dt)
            dis = jnp.where(blocked, 0.0, dis)
            chg = jnp.where(blocked, 0.0, chg)
            grid = p - dis + chg
            soc = soc - dis * dt / self.efficiency + chg * dt * self.efficiency
            soc = jnp.clip(soc, 0.0, cap_j)
            return (soc, tgt, new_mode, hold), (grid, soc)

        w = jnp.asarray(w, jnp.float32)
        # grid target starts at the trace mean (the scheduled steady-state
        # draw a real operator bids into the day-ahead market) — starting at
        # w[0] makes the battery burn capacity chasing the initial transient
        init = (jnp.asarray(self.initial_soc * cap_j, jnp.float32),
                jnp.mean(w), jnp.asarray(0.0, jnp.float32),
                jnp.asarray(0.0, jnp.float32))
        _, (grid, soc) = jax.lax.scan(step, init, w, unroll=8)
        aux = {
            "soc_trace": soc,
            "soc_min_frac": soc.min() / cap_j,
            "soc_max_frac": soc.max() / cap_j,
            "energy_overhead": energy_overhead_jax(w, grid),
            "peak_reduction_w": w.max() - grid.max(),
        }
        return grid, aux

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        return np_apply(self, w, dt)


register_mitigation(
    RackBattery,
    data_fields=("capacity_j", "max_discharge_w", "max_charge_w",
                 "efficiency", "target_tau_s", "initial_soc",
                 "switch_latency_s"),
    meta_fields=())


def size_battery_for(job_w_swing: float, period_s: float, n_racks: int,
                     margin: float = 2.0) -> RackBattery:
    """Capacity to absorb half a swing cycle per rack, with margin."""
    per_rack_swing = job_w_swing / n_racks
    cap = margin * per_rack_swing * (period_s / 2)
    return RackBattery(capacity_j=cap * n_racks,
                       max_discharge_w=job_w_swing,
                       max_charge_w=job_w_swing)

"""The paper's proposed combination (Sec. IV-D): GPU-level smoothing for
ramps + corner cases, rack-level storage for the dynamic range — optimal on
wasted energy, cost and space, but requires co-design (the battery state of
charge informs the GPU floor; modeled via the SoC-aware floor backoff).

``design_mitigation`` is the beyond-paper piece: given a UtilitySpec and a
workload waveform, grid-search the smallest (MPF, battery capacity) pair
that passes validation — the spec->configuration solver an operator would
actually run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.smoothing.base import Stack, energy_overhead
from repro.core.smoothing.battery import RackBattery
from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
from repro.core.spec import UtilitySpec


@dataclasses.dataclass(frozen=True)
class CombinedMitigation:
    gpu: GpuPowerSmoothing
    battery: RackBattery
    n_chips: int = 1      # gpu stage operates per chip; battery on aggregate

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        # device stage on the per-chip mean waveform, re-aggregated
        per_chip = w / self.n_chips
        smoothed, aux_g = self.gpu.apply(per_chip, dt)
        agg = smoothed * self.n_chips
        out, aux_b = self.battery.apply(agg, dt)
        return out, {"gpu": aux_g, "battery": aux_b,
                     "energy_overhead": energy_overhead(w, out)}


def design_mitigation(spec: UtilitySpec, w: np.ndarray, dt: float,
                      n_chips: int, hw: Hardware = DEFAULT_HW,
                      period_hint_s: float = 2.0) -> Optional[Dict]:
    """Smallest-overhead (MPF, battery) combo that passes ``spec``.

    Searches MPF fraction (0 = off) ascending and battery capacity
    geometric; returns the first passing configuration with its report —
    ordering guarantees minimal energy waste first, then minimal capacity
    (cost / embodied carbon, the paper's Sec. IV-C concern).
    """
    swing = float(w.max() - w.min())
    mpf_grid = [0.0, 0.5, 0.65, 0.8, 0.9]
    cap_grid = [0.0] + [swing * period_hint_s * f for f in
                        (0.125, 0.25, 0.5, 1.0, 2.0)]
    for mpf in mpf_grid:
        for cap in cap_grid:
            stages = []
            gpu = None
            if mpf > 0:
                gpu = GpuPowerSmoothing(
                    mpf_frac=mpf, hw=hw,
                    ramp_up_w_per_s=spec.time.ramp_up_w_per_s / n_chips,
                    ramp_down_w_per_s=spec.time.ramp_down_w_per_s / n_chips)
            bat = None
            if cap > 0:
                bat = RackBattery(capacity_j=cap,
                                  max_discharge_w=swing, max_charge_w=swing)
            if gpu and bat:
                mit = CombinedMitigation(gpu, bat, n_chips)
                out, aux = mit.apply(w, dt)
            elif gpu:
                per_chip, _ = gpu.apply(w / n_chips, dt)
                out, aux = per_chip * n_chips, {}
            elif bat:
                out, aux = bat.apply(w, dt)
            else:
                out, aux = w, {}
            rep = spec.validate(out, dt)
            if rep.ok:
                return {"mpf_frac": mpf, "battery_capacity_j": cap,
                        "energy_overhead": energy_overhead(w, out),
                        "report": rep, "aux": aux}
    return None

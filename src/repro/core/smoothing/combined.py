"""The paper's proposed combination (Sec. IV-D): GPU-level smoothing for
ramps + corner cases, rack-level storage for the dynamic range — optimal on
wasted energy, cost and space, but requires co-design (the battery state of
charge informs the GPU floor; modeled via the SoC-aware floor backoff).

``design_mitigation`` is the beyond-paper piece: given a UtilitySpec and a
workload waveform, find the smallest (MPF, battery capacity) pair that
passes validation — the spec->configuration solver an operator would
actually run.  It is implemented as a *batched* grid search: every (MPF x
capacity) candidate is evaluated in one jit/vmap call (core/engine.py),
then the minimal-overhead passing configuration is selected with the same
MPF-ascending / capacity-ascending preference the serial search had.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.smoothing.base import (energy_overhead_jax, np_apply,
                                       register_mitigation)
from repro.core.smoothing.battery import RackBattery
from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
from repro.core.spec import UtilitySpec


@dataclasses.dataclass(frozen=True)
class CombinedMitigation:
    gpu: GpuPowerSmoothing
    battery: RackBattery
    n_chips: int = 1      # gpu stage operates per chip; battery on aggregate

    def apply_jax(self, w: jnp.ndarray, dt: float) -> Tuple[jnp.ndarray, Dict]:
        # device stage on the per-chip mean waveform, re-aggregated
        w = jnp.asarray(w, jnp.float32)
        per_chip = w / self.n_chips
        smoothed, aux_g = self.gpu.apply_jax(per_chip, dt)
        agg = smoothed * self.n_chips
        out, aux_b = self.battery.apply_jax(agg, dt)
        return out, {"gpu": aux_g, "battery": aux_b,
                     "energy_overhead": energy_overhead_jax(w, out)}

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        return np_apply(self, w, dt)


register_mitigation(
    CombinedMitigation,
    data_fields=("gpu", "battery", "n_chips"),
    meta_fields=())


def design_mitigation(spec: UtilitySpec, w: np.ndarray, dt: float,
                      n_chips: int, hw: Hardware = DEFAULT_HW,
                      period_hint_s: float = 2.0, method: str = "grid",
                      **design_kwargs) -> Optional[Dict]:
    """Smallest-overhead (MPF, battery) combo that passes ``spec``.

    ``method`` selects the solver (the public face over
    ``engine.design``): "grid" evaluates the coarse candidate grid — MPF
    fraction (0 = off) ascending, battery capacity (0 = off) geometric —
    in ONE vmapped call and picks the first passing configuration in
    (MPF, capacity) order, preserving the serial solver's guarantee:
    minimal energy waste first, then minimal capacity (cost / embodied
    carbon, the paper's Sec. IV-C concern).  "gradient" descends on the
    smooth-relaxed pipeline instead of the grid; "hybrid" refines the
    grid's top-k feasible configs by gradient (never worse than the grid).
    """
    from repro.core.engine import design  # lazy: engine imports smoothing

    sol = design(spec, w, dt, n_chips, method=method, hw=hw,
                 period_hint_s=period_hint_s, **design_kwargs)
    if sol is None:
        return None
    # serial confirmation of the winner: exact aux traces for the caller
    gpu, bat = sol["device_mitigation"], sol["rack_mitigation"]
    if gpu and bat:
        _, aux = CombinedMitigation(gpu, bat, n_chips).apply(w, dt)
    elif bat:
        _, aux = bat.apply(w, dt)
    else:
        aux = {}
    sol["aux"] = aux
    return sol

"""Firefly: software-only mitigation (paper Sec. IV-A).

Telemetry-driven controller that turns a GEMM ballast workload on when
measured chip power drops below an engage threshold and backs it off when
the primary ramps up. Modeled faithfully to the description:

  * telemetry latency + sampling period (1 ms fast counters; the 100 ms
    reliable counters are shown to be too slow — see tests);
  * periodic mandatory back-off to re-read activity counters (no per-
    process counters exist), which leaves brief dips;
  * ballast resolution: the GEMM burner quantizes to discrete intensity
    steps (kernels/ballast distributes FLOPs in block multiples);
  * interference: ballast overlapping the *compute* phase costs primary
    throughput (MPS resource sharing) — reported as perf_overhead, the
    paper achieved <5%.

The TPU in-graph equivalent (compile-time co-scheduled ballast) lives in
core/ballast_inject.py; this module is the *control-loop* model used by
StratoSim and the Table-I comparison.

The engage/threshold/interference knobs are pytree leaves (vmappable);
telemetry timing and back-off cadence fix sampling indices, so they are
static metadata.

``smooth_tau`` (structure-static meta field) selects the gradient-design
relaxation: 0 is the exact hard controller below; > 0 replaces the engage
threshold's hard gate with a sigmoid and routes the ballast quantizer
through a straight-through ceil (the GEMM burner's intensity steps are
physically discrete, so the forward stays quantized and only the backward
pass is relaxed).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.smoothing.base import (energy_overhead_jax, np_apply,
                                       register_mitigation)
from repro.core.smoothing.relax import sigmoid_gate, ste_ceil
from repro.core.telemetry import TelemetrySource


@dataclasses.dataclass(frozen=True)
class Firefly:
    engage_frac: float = 0.85            # fill to this fraction of TDP
    threshold_frac: float = 0.80         # engage when below
    telemetry: TelemetrySource = dataclasses.field(
        default_factory=lambda: TelemetrySource(period_s=0.001, latency_s=0.002))
    backoff_every_s: float = 0.250       # mandatory counter re-read
    backoff_dur_s: float = 0.004
    ballast_steps: int = 8               # intensity quantization levels
    interference: float = 0.04           # primary slowdown while co-running
    hw: Hardware = DEFAULT_HW
    # 0 = exact hard semantics; > 0 = gradient-design relaxation (static
    # so hard and smooth configs never stack into one vmapped grid)
    smooth_tau: float = 0.0

    def apply_jax(self, w: jnp.ndarray, dt: float,
                  key=None) -> Tuple[jnp.ndarray, Dict]:
        tdp = self.hw.chip.tdp_w
        target = self.engage_frac * tdp
        thresh = self.threshold_frac * tdp
        w = jnp.asarray(w, jnp.float32)
        meas = self.telemetry.measure_jax(w, dt, key=key)

        n = w.shape[-1]
        every = max(int(self.backoff_every_s / dt), 1)
        bdur = max(int(self.backoff_dur_s / dt), 1)
        phase = (np.arange(n) % every) < bdur  # True = forced back-off

        raw = jnp.clip(target - meas, 0.0, None)
        step_w = target / self.ballast_steps
        if self.smooth_tau:
            # forward stays quantized (straight-through ceil); the engage
            # gate relaxes to a sigmoid at temperature smooth_tau
            ballast = ste_ceil(raw / step_w) * step_w
            ballast = ballast * sigmoid_gate(thresh - meas,
                                             self.smooth_tau, tdp)
        else:
            ballast = jnp.ceil(raw / step_w - 1e-9) * step_w
            ballast = jnp.where(meas < thresh, ballast, 0.0)
        ballast = jnp.where(jnp.asarray(phase), 0.0, ballast)
        out = jnp.minimum(w + ballast, tdp)

        # interference accounting: ballast active while primary is busy
        busy = w > thresh
        on = ballast > 0
        n_busy = busy.sum()
        mis_fire = jnp.where(busy, ballast, 0.0).sum()
        perf_overhead = jnp.where(
            n_busy > 0,
            self.interference * jnp.where(busy, on, False).sum()
            / jnp.maximum(n_busy, 1),
            0.0)
        aux = {
            "energy_overhead": energy_overhead_jax(w, out),
            "perf_overhead": perf_overhead,
            "ballast_duty": on.mean(),
            "reaches_tdp_frac": out.max() / tdp,
            "misfire_j": mis_fire * dt,
        }
        return out, aux

    def apply(self, w: np.ndarray, dt: float,
              key=None) -> Tuple[np.ndarray, Dict]:
        return np_apply(self, w, dt, key)


register_mitigation(
    Firefly,
    data_fields=("engage_frac", "threshold_frac", "interference"),
    meta_fields=("telemetry", "backoff_every_s", "backoff_dur_s",
                 "ballast_steps", "hw", "smooth_tau"))

"""GB200-style device power smoothing (paper Sec. IV-B), as a lax.scan.

Feature model (bit-faithful to the description):
  * ramp-up / ramp-down rate limits (W/s), programmable;
  * Minimum Power Floor (MPF, <= 90% TDP): while the workload is engaged,
    the chip burns at least MPF watts;
  * stop delay: on zero activity the floor holds for stop_delay seconds,
    then releases at the programmed ramp-down rate;
  * EDP cap: overshoot above TDP allowed only up to edp_factor and only
    transiently (enforced upstream by the workload model).

Energy-overhead accounting reproduces the paper's Fig. 6 experiment
(MPF=90% TDP on the production waveform -> ~10.5% extra energy).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware


@dataclasses.dataclass(frozen=True)
class GpuPowerSmoothing:
    mpf_frac: float = 0.9               # floor as fraction of TDP (<= 0.9)
    ramp_up_w_per_s: float = 1000.0     # per chip
    ramp_down_w_per_s: float = 1000.0
    stop_delay_s: float = 2.0
    activity_threshold_frac: float = 0.35  # "no real workload activity"
    # paper Sec. III-C "Control EDP": when EDP peaks are visible beyond the
    # rack PSUs the EDP must be programmed down — 1.0 clamps output at TDP
    edp_cap_frac: float = 1.0
    hw: Hardware = DEFAULT_HW

    def __post_init__(self):
        assert self.mpf_frac <= self.hw.chip.mpf_max + 1e-9, (
            f"GB200 feature caps MPF at {self.hw.chip.mpf_max:.0%} TDP")

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        tdp = self.hw.chip.tdp_w
        mpf = self.mpf_frac * tdp
        thresh = self.activity_threshold_frac * tdp
        ru, rd = self.ramp_up_w_per_s * dt, self.ramp_down_w_per_s * dt
        stop_n = self.stop_delay_s / dt

        def step(carry, p):
            o_prev, idle_n = carry
            active = p > thresh
            idle_n = jnp.where(active, 0.0, idle_n + 1.0)
            floor = jnp.where(idle_n <= stop_n, mpf, 0.0)
            target = jnp.maximum(p, floor)
            cap = tdp * min(self.edp_cap_frac, self.hw.chip.edp_factor)
            target = jnp.minimum(target, cap)
            o = jnp.clip(target, o_prev - rd, o_prev + ru)
            return (o, idle_n), o

        w_j = jnp.asarray(w, jnp.float32)
        (_, _), out = jax.lax.scan(step, (w_j[0], 0.0), w_j)
        out_np = np.asarray(out)
        aux = {
            "energy_overhead": float((out_np.sum() - w.sum()) / max(w.sum(), 1e-12)),
            "floor_w": mpf,
        }
        return out_np, aux

"""GB200-style device power smoothing (paper Sec. IV-B), as a lax.scan.

Feature model (bit-faithful to the description):
  * ramp-up / ramp-down rate limits (W/s), programmable;
  * Minimum Power Floor (MPF, <= 90% TDP): while the workload is engaged,
    the chip burns at least MPF watts;
  * stop delay: on zero activity the floor holds for stop_delay seconds,
    then releases at the programmed ramp-down rate;
  * EDP cap: overshoot above TDP allowed only up to edp_factor and only
    transiently (enforced upstream by the workload model).

Energy-overhead accounting reproduces the paper's Fig. 6 experiment
(MPF=90% TDP on the production waveform -> ~10.5% extra energy).

All continuous parameters are pytree leaves, so an (MPF x ramp) grid vmaps
through ``apply_jax`` in one compiled call (see core/engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.smoothing.base import (energy_overhead_jax, np_apply,
                                       register_mitigation)


@dataclasses.dataclass(frozen=True)
class GpuPowerSmoothing:
    mpf_frac: float = 0.9               # floor as fraction of TDP (<= 0.9)
    ramp_up_w_per_s: float = 1000.0     # per chip
    ramp_down_w_per_s: float = 1000.0
    stop_delay_s: float = 2.0
    activity_threshold_frac: float = 0.35  # "no real workload activity"
    # paper Sec. III-C "Control EDP": when EDP peaks are visible beyond the
    # rack PSUs the EDP must be programmed down — 1.0 clamps output at TDP
    edp_cap_frac: float = 1.0
    hw: Hardware = DEFAULT_HW

    def __post_init__(self):
        # only enforceable on concrete params; traced/batched leaves are
        # validated by whoever built the grid
        if isinstance(self.mpf_frac, (int, float, np.floating)):
            assert self.mpf_frac <= self.hw.chip.mpf_max + 1e-9, (
                f"GB200 feature caps MPF at {self.hw.chip.mpf_max:.0%} TDP")

    def apply_jax(self, w: jnp.ndarray, dt: float) -> Tuple[jnp.ndarray, Dict]:
        tdp = self.hw.chip.tdp_w
        mpf = self.mpf_frac * tdp
        thresh = self.activity_threshold_frac * tdp
        ru, rd = self.ramp_up_w_per_s * dt, self.ramp_down_w_per_s * dt
        stop_n = self.stop_delay_s / dt
        cap = tdp * jnp.minimum(self.edp_cap_frac, self.hw.chip.edp_factor)

        def step(carry, p):
            o_prev, idle_n = carry
            active = p > thresh
            idle_n = jnp.where(active, 0.0, idle_n + 1.0)
            floor = jnp.where(idle_n <= stop_n, mpf, 0.0)
            target = jnp.maximum(p, floor)
            target = jnp.minimum(target, cap)
            o = jnp.clip(target, o_prev - rd, o_prev + ru)
            return (o, idle_n), o

        w = jnp.asarray(w, jnp.float32)
        (_, _), out = jax.lax.scan(step, (w[0], jnp.asarray(0.0, jnp.float32)), w,
                                 unroll=8)
        aux = {
            "energy_overhead": energy_overhead_jax(w, out),
            "floor_w": jnp.asarray(mpf, jnp.float32),
        }
        return out, aux

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        return np_apply(self, w, dt)


register_mitigation(
    GpuPowerSmoothing,
    data_fields=("mpf_frac", "ramp_up_w_per_s", "ramp_down_w_per_s",
                 "stop_delay_s", "activity_threshold_frac", "edp_cap_frac"),
    meta_fields=("hw",))

"""GB200-style device power smoothing (paper Sec. IV-B), as a lax.scan.

Feature model (bit-faithful to the description):
  * ramp-up / ramp-down rate limits (W/s), programmable;
  * Minimum Power Floor (MPF, <= 90% TDP): while the workload is engaged,
    the chip burns at least MPF watts;
  * stop delay: on zero activity the floor holds for stop_delay seconds,
    then releases at the programmed ramp-down rate;
  * EDP cap: overshoot above TDP allowed only up to edp_factor and only
    transiently (enforced upstream by the workload model).

Energy-overhead accounting reproduces the paper's Fig. 6 experiment
(MPF=90% TDP on the production waveform -> ~10.5% extra energy).

All continuous parameters are pytree leaves, so an (MPF x ramp) grid vmaps
through ``apply_jax`` in one compiled call (see core/engine.py).

``smooth_tau`` (structure-static meta field) selects the gradient-design
relaxation: 0 runs the exact hard semantics below; > 0 replaces the idle
counter's step gates and the floor/cap selects with sigmoid gates and a
logaddexp max at temperature tau, so ``jax.grad`` through ``apply_jax``
sees useful sensitivities for every leaf (the hard path zeroes the
gradient of ``stop_delay_s`` and ``activity_threshold_frac`` entirely and
leaves ``mpf_frac`` with a measure-zero subgradient at the kinks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.smoothing.base import (energy_overhead_jax, np_apply,
                                       register_mitigation)
from repro.core.smoothing.relax import sigmoid_gate, smooth_max


@dataclasses.dataclass(frozen=True)
class GpuPowerSmoothing:
    mpf_frac: float = 0.9               # floor as fraction of TDP (<= 0.9)
    ramp_up_w_per_s: float = 1000.0     # per chip
    ramp_down_w_per_s: float = 1000.0
    stop_delay_s: float = 2.0
    activity_threshold_frac: float = 0.35  # "no real workload activity"
    # paper Sec. III-C "Control EDP": when EDP peaks are visible beyond the
    # rack PSUs the EDP must be programmed down — 1.0 clamps output at TDP
    edp_cap_frac: float = 1.0
    hw: Hardware = DEFAULT_HW
    # 0 = exact hard semantics (the forward-engine path); > 0 = the
    # gradient-design relaxation temperature.  Static so hard and smooth
    # configs never stack into one vmapped grid.
    smooth_tau: float = 0.0

    def __post_init__(self):
        # only enforceable on concrete params; traced/batched leaves are
        # validated by whoever built the grid
        if isinstance(self.mpf_frac, (int, float, np.floating)):
            assert self.mpf_frac <= self.hw.chip.mpf_max + 1e-9, (
                f"GB200 feature caps MPF at {self.hw.chip.mpf_max:.0%} TDP")

    def apply_jax(self, w: jnp.ndarray, dt: float) -> Tuple[jnp.ndarray, Dict]:
        if self.smooth_tau:
            return self._apply_smooth(w, dt)
        tdp = self.hw.chip.tdp_w
        mpf = self.mpf_frac * tdp
        thresh = self.activity_threshold_frac * tdp
        ru, rd = self.ramp_up_w_per_s * dt, self.ramp_down_w_per_s * dt
        stop_n = self.stop_delay_s / dt
        cap = tdp * jnp.minimum(self.edp_cap_frac, self.hw.chip.edp_factor)

        def step(carry, p):
            o_prev, idle_n = carry
            active = p > thresh
            idle_n = jnp.where(active, 0.0, idle_n + 1.0)
            floor = jnp.where(idle_n <= stop_n, mpf, 0.0)
            target = jnp.maximum(p, floor)
            target = jnp.minimum(target, cap)
            o = jnp.clip(target, o_prev - rd, o_prev + ru)
            return (o, idle_n), o

        w = jnp.asarray(w, jnp.float32)
        (_, _), out = jax.lax.scan(step, (w[0], jnp.asarray(0.0, jnp.float32)), w,
                                 unroll=8)
        aux = {
            "energy_overhead": energy_overhead_jax(w, out),
            "floor_w": jnp.asarray(mpf, jnp.float32),
        }
        return out, aux

    def _apply_smooth(self, w: jnp.ndarray, dt: float
                      ) -> Tuple[jnp.ndarray, Dict]:
        """Relaxed semantics at temperature ``smooth_tau``: the activity
        gate, idle-counter reset, stop-delay gate, and floor/cap selects
        become sigmoid blends; the ramp clip stays hard (piecewise linear
        already carries a subgradient everywhere)."""
        tau = self.smooth_tau
        tdp = self.hw.chip.tdp_w
        mpf = self.mpf_frac * tdp
        thresh = self.activity_threshold_frac * tdp
        ru, rd = self.ramp_up_w_per_s * dt, self.ramp_down_w_per_s * dt
        stop_n = self.stop_delay_s / dt
        cap = tdp * jnp.minimum(self.edp_cap_frac, self.hw.chip.edp_factor)

        def step(carry, p):
            o_prev, idle_n = carry
            active = sigmoid_gate(p - thresh, tau, tdp)
            idle_n = (1.0 - active) * (idle_n + 1.0)   # soft counter reset
            floor = mpf * sigmoid_gate(stop_n - idle_n, tau, stop_n + 1.0)
            target = smooth_max(p, floor, tau, tdp)
            target = -smooth_max(-target, -cap, tau, tdp)  # smooth min
            o = jnp.clip(target, o_prev - rd, o_prev + ru)
            return (o, idle_n), o

        w = jnp.asarray(w, jnp.float32)
        (_, _), out = jax.lax.scan(step, (w[0], jnp.asarray(0.0, jnp.float32)),
                                   w, unroll=8)
        aux = {
            "energy_overhead": energy_overhead_jax(w, out),
            "floor_w": jnp.asarray(mpf, jnp.float32),
        }
        return out, aux

    def apply(self, w: np.ndarray, dt: float) -> Tuple[np.ndarray, Dict]:
        return np_apply(self, w, dt)


register_mitigation(
    GpuPowerSmoothing,
    data_fields=("mpf_frac", "ramp_up_w_per_s", "ramp_down_w_per_s",
                 "stop_delay_s", "activity_threshold_frac", "edp_cap_frac"),
    meta_fields=("hw", "smooth_tau"))

"""Temperature-parameterized smooth relaxations of discrete mitigation
semantics, for gradient-based design (core/engine.py ``design_gradient``).

Every mitigation carries a structure-static ``smooth_tau`` meta field:

  tau == 0   the exact hard semantics — bit-identical to the pre-gradient
             code path (parity-tested), and the ONLY path the forward
             scenario engine / Study / serve layers ever run;
  tau  > 0   the design-time relaxation: hard gates become sigmoids and
             hard switches become tanh blends at temperature ``tau``, so
             ``jax.grad`` sees a useful loss landscape instead of the
             zero-measure subgradients of step functions.

``tau`` is dimensionless; each call site scales it by the natural scale of
its comparison (TDP for power gates, a counter horizon for timers), so one
temperature knob relaxes a whole mitigation coherently and annealing
tau -> 0 recovers the hard behavior continuously.

Where a relaxation would change *forward* behavior that is physically
discrete (the Firefly ballast quantizer: the GEMM burner really does run
at one of N intensities; the backstop's breaker escalation), the forward
stays hard and only the backward pass is relaxed — a straight-through
estimator via ``jax.custom_vjp`` (``ste_ceil``) or the stop-gradient
identity ``hard + (soft - stop_gradient(soft)) * surrogate`` (see
``TelemetryBackstop._apply_smooth``'s engagement gate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sigmoid_gate(x: jnp.ndarray, tau: float, scale: float) -> jnp.ndarray:
    """Smooth 0/1 gate: ``sigmoid(x / (tau * scale))`` — approaches
    ``(x > 0)`` as ``tau -> 0``.  ``scale`` is the natural magnitude of
    ``x`` (TDP for power comparisons, counts for timers), so ``tau`` stays
    a dimensionless temperature."""
    return jax.nn.sigmoid(x / (tau * scale))


def soft_sign(x: jnp.ndarray, tau: float, scale: float) -> jnp.ndarray:
    """Smooth ``jnp.sign``: ``tanh(x / (tau * scale))``."""
    return jnp.tanh(x / (tau * scale))


def smooth_max(a: jnp.ndarray, b: jnp.ndarray, tau: float,
               scale: float) -> jnp.ndarray:
    """Smooth elementwise maximum via logaddexp at temperature
    ``tau * scale``; upper-bounds the hard max by ``tau*scale*log 2``."""
    t = tau * scale
    return t * jnp.logaddexp(a / t, b / t)


@jax.custom_vjp
def ste_ceil(x: jnp.ndarray) -> jnp.ndarray:
    """``jnp.ceil(x - 1e-9)`` forward, identity backward (straight-through
    quantizer — the Firefly ballast's intensity steps are physically
    discrete, so the relaxation lives only in the VJP)."""
    return jnp.ceil(x - 1e-9)


def _ste_ceil_fwd(x):
    return ste_ceil(x), None


def _ste_ceil_bwd(_, g):
    return (g,)


ste_ceil.defvjp(_ste_ceil_fwd, _ste_ceil_bwd)

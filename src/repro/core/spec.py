"""Utility specifications (paper Sec. III) and compliance validation.

Time-domain: ramp-up / ramp-down rate limits (W/s) and a dynamic power
range (max deviation within a sliding window) — Fig. 4. Frequency-domain:
a critical band and a cap on the fraction of AC spectral energy inside it.

``UtilitySpec.validate`` is the numpy reference; ``validate_jax`` is the
pure traced mirror the batched scenario engine jits/vmaps, returning
per-violation boolean flags instead of a string list so verdicts
vectorize.

A spec splits into two halves with different compilation roles.  Its
*family* (``family()``) is everything that fixes computation shape —
band edges (which select FFT bins), the ramp/dynamic-range window sizes,
and whether a bin-amplitude check exists at all — and stays a static jit
argument.  Its *limits* (``limits()``) are the pure numeric thresholds
the metrics are compared against, and can be traced: ``validate_jax`` /
``loss_jax`` accept ``limits=`` overrides, so one compiled executable
serves every spec of the same family (lenient / moderate / tight at any
job scale).  This is what lets the serve path answer a stream of
differently-sized jobs without retracing per query.

``loss_jax`` turns the same metrics into a *smooth scalar objective* for
gradient-based mitigation design (core/engine.py ``design_gradient``):
each hard threshold comparison becomes a quadratic hinge on the
normalized excess, so the loss is zero on (margin-shrunk) compliant
waveforms, positive and differentiable outside them, and its components
line up one-to-one with the violation flags.  Both paths share
``_metrics_jax`` so the objective can never drift from the verdict.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spectrum import (band_amplitude_w, band_amplitude_w_jax,
                                 band_energy_fraction,
                                 band_energy_fraction_jax)

VIOLATION_ORDER = ("ramp_up", "ramp_down", "dynamic_range",
                   "band_energy", "band_amplitude")

# the traced-threshold keys of ``UtilitySpec.limits()`` (band_amplitude_w
# is present only when the family declares that check)
LIMIT_KEYS = ("ramp_up_w_per_s", "ramp_down_w_per_s", "dynamic_range_w",
              "max_energy_fraction", "min_ac_rms_frac",
              "max_bin_amplitude_w")


@dataclasses.dataclass(frozen=True)
class TimeDomainSpec:
    ramp_up_w_per_s: float
    ramp_down_w_per_s: float
    dynamic_range_w: float          # allowed peak-to-trough in window
    window_s: float = 1.0
    # ramp measurement granularity: utilities meter over >= this interval,
    # so single-sample dP/dt is averaged over ramp_window_s first
    ramp_window_s: float = 0.1


@dataclasses.dataclass(frozen=True)
class FrequencyDomainSpec:
    band_hz: Tuple[float, float] = (0.1, 20.0)
    max_energy_fraction: float = 0.2
    max_bin_amplitude_w: Optional[float] = None
    # the fraction cap only applies when the AC component is material:
    # a flat load with microscopic residual wobble is compliant even if
    # 100% of that wobble sits in-band
    min_ac_rms_frac: float = 0.005


@dataclasses.dataclass(frozen=True)
class UtilitySpec:
    name: str
    time: TimeDomainSpec
    freq: FrequencyDomainSpec

    # -- the family / limits split (compiled-executable reuse) --------------

    def limits(self) -> Dict[str, jnp.ndarray]:
        """The numeric thresholds as a traced-friendly dict of f32 scalars.

        Feed one family's executable a different spec's limits and it
        judges under that spec without retracing.  The bin-amplitude key
        is present iff the check exists (its existence is structural —
        part of the family)."""
        lim = {
            "ramp_up_w_per_s": jnp.asarray(self.time.ramp_up_w_per_s,
                                           jnp.float32),
            "ramp_down_w_per_s": jnp.asarray(self.time.ramp_down_w_per_s,
                                             jnp.float32),
            "dynamic_range_w": jnp.asarray(self.time.dynamic_range_w,
                                           jnp.float32),
            "max_energy_fraction": jnp.asarray(self.freq.max_energy_fraction,
                                               jnp.float32),
            "min_ac_rms_frac": jnp.asarray(self.freq.min_ac_rms_frac,
                                           jnp.float32),
        }
        if self.freq.max_bin_amplitude_w is not None:
            lim["max_bin_amplitude_w"] = jnp.asarray(
                self.freq.max_bin_amplitude_w, jnp.float32)
        return lim

    def family(self) -> "UtilitySpec":
        """The shape-determining residue of this spec: limits canonicalized
        to 1.0, name dropped.  Two specs with equal families compile to the
        SAME executable when their ``limits()`` are passed as traced
        arguments — the compiled-catalog reuse key of the serve path."""
        return UtilitySpec(
            "family",
            TimeDomainSpec(ramp_up_w_per_s=1.0, ramp_down_w_per_s=1.0,
                           dynamic_range_w=1.0, window_s=self.time.window_s,
                           ramp_window_s=self.time.ramp_window_s),
            FrequencyDomainSpec(
                band_hz=self.freq.band_hz, max_energy_fraction=1.0,
                max_bin_amplitude_w=(None if self.freq.max_bin_amplitude_w
                                     is None else 1.0),
                min_ac_rms_frac=1.0))

    def validate(self, w: np.ndarray, dt: float) -> "SpecReport":
        v: List[str] = []
        m: Dict[str, float] = {}
        # ---- ramps (averaged over the metering window)
        k = max(int(self.time.ramp_window_s / dt), 1)
        if len(w) > k:
            box = np.convolve(w, np.ones(k) / k, mode="valid")
            dp = np.diff(box) / dt
            m["max_ramp_up_w_per_s"] = float(dp.max(initial=0.0))
            m["max_ramp_down_w_per_s"] = float(-dp.min(initial=0.0))
            if m["max_ramp_up_w_per_s"] > self.time.ramp_up_w_per_s:
                v.append("ramp_up")
            if m["max_ramp_down_w_per_s"] > self.time.ramp_down_w_per_s:
                v.append("ramp_down")
        # ---- dynamic range in sliding window
        n = max(int(self.time.window_s / dt), 2)
        if len(w) >= n:
            # stride for O(len) estimate
            stride = max(n // 8, 1)
            rng = 0.0
            for i in range(0, len(w) - n, stride):
                seg = w[i:i + n]
                rng = max(rng, float(seg.max() - seg.min()))
            m["dynamic_range_w"] = rng
            if rng > self.time.dynamic_range_w:
                v.append("dynamic_range")
        # ---- frequency domain
        f_lo, f_hi = self.freq.band_hz
        frac = band_energy_fraction(w, dt, f_lo, f_hi)
        m["band_energy_fraction"] = frac
        ac_rms = float(np.std(w))
        m["ac_rms_frac"] = ac_rms / max(float(np.mean(w)), 1e-9)
        material = m["ac_rms_frac"] >= self.freq.min_ac_rms_frac
        if material and frac > self.freq.max_energy_fraction:
            v.append("band_energy")
        if self.freq.max_bin_amplitude_w is not None:
            amp = band_amplitude_w(w, dt, f_lo, f_hi)
            m["band_bin_amplitude_w"] = amp
            if amp > self.freq.max_bin_amplitude_w:
                v.append("band_amplitude")
        return SpecReport(ok=not v, violations=tuple(v), metrics=m)

    def _metrics_jax(self, w: jnp.ndarray, dt: float
                     ) -> Dict[str, jnp.ndarray]:
        """The traced metric set shared by ``validate_jax`` (hard flags)
        and ``loss_jax`` (smooth hinges).  Keys are present iff the
        waveform is long enough to measure them — lengths are static, so
        the key set is too."""
        w = jnp.asarray(w, jnp.float32)
        m: Dict[str, jnp.ndarray] = {}
        # ---- ramps (averaged over the metering window)
        k = max(int(self.time.ramp_window_s / dt), 1)
        if w.shape[-1] > k:
            box = jnp.convolve(w, jnp.ones(k, jnp.float32) / k, mode="valid")
            dp = jnp.diff(box) / dt
            m["max_ramp_up_w_per_s"] = jnp.maximum(dp.max(), 0.0)
            m["max_ramp_down_w_per_s"] = jnp.maximum(-dp.min(), 0.0)
        # ---- dynamic range in sliding window (same strided starts as the
        # numpy path, but as one [windows, n] gather instead of a loop)
        n = max(int(self.time.window_s / dt), 2)
        if w.shape[-1] >= n:
            starts = np.arange(0, w.shape[-1] - n, max(n // 8, 1))
            if len(starts):
                seg = w[starts[:, None] + np.arange(n)[None, :]]
                rng = (seg.max(axis=1) - seg.min(axis=1)).max()
            else:
                # exactly one window: the strided loop body never runs and
                # the numpy path reports 0.0 — mirror that, don't drop the key
                rng = jnp.asarray(0.0, jnp.float32)
            m["dynamic_range_w"] = rng
        # ---- frequency domain
        f_lo, f_hi = self.freq.band_hz
        m["band_energy_fraction"] = band_energy_fraction_jax(w, dt, f_lo, f_hi)
        m["ac_rms_frac"] = jnp.std(w) / jnp.maximum(jnp.mean(w), 1e-9)
        if self.freq.max_bin_amplitude_w is not None:
            m["band_bin_amplitude_w"] = band_amplitude_w_jax(w, dt, f_lo, f_hi)
        return m

    def validate_jax(self, w: jnp.ndarray, dt: float,
                     limits: Optional[Dict[str, jnp.ndarray]] = None
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray],
                                Dict[str, jnp.ndarray]]:
        """Traced mirror of ``validate``: (ok, violation flags, metrics).

        Waveform length and dt are static (they fix window/bin shapes).
        Thresholds default to this spec's own values; passing ``limits``
        (another same-family spec's ``limits()``) judges under those
        thresholds instead — the engine passes ``self.family()`` as the
        static spec and the real limits as a traced pytree, so distinct
        specs reuse one executable.  Use ``report_from_arrays`` to rebuild
        a ``SpecReport`` from one row of vmapped outputs.
        """
        lim = self.limits() if limits is None else limits
        m = self._metrics_jax(w, dt)
        flags: Dict[str, jnp.ndarray] = {}
        false = jnp.asarray(False)
        if "max_ramp_up_w_per_s" in m:
            flags["ramp_up"] = (m["max_ramp_up_w_per_s"]
                                > lim["ramp_up_w_per_s"])
            flags["ramp_down"] = (m["max_ramp_down_w_per_s"]
                                  > lim["ramp_down_w_per_s"])
        else:
            flags["ramp_up"] = flags["ramp_down"] = false
        if "dynamic_range_w" in m:
            flags["dynamic_range"] = (m["dynamic_range_w"]
                                      > lim["dynamic_range_w"])
        else:
            flags["dynamic_range"] = false
        material = m["ac_rms_frac"] >= lim["min_ac_rms_frac"]
        flags["band_energy"] = material & (m["band_energy_fraction"]
                                           > lim["max_energy_fraction"])
        if "band_bin_amplitude_w" in m:
            flags["band_amplitude"] = (m["band_bin_amplitude_w"]
                                       > lim["max_bin_amplitude_w"])
        else:
            flags["band_amplitude"] = false
        ok = ~(flags["ramp_up"] | flags["ramp_down"] | flags["dynamic_range"]
               | flags["band_energy"] | flags["band_amplitude"])
        return ok, flags, m

    def loss_jax(self, w: jnp.ndarray, dt: float, *, margin: float = 0.0,
                 limits: Optional[Dict[str, jnp.ndarray]] = None
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """Smooth scalar compliance objective: ``(total, components)``.

        Each component is the squared hinge of a ``validate_jax`` metric's
        normalized excess over its ``(1 - margin)``-shrunk limit — zero on
        (margin-)compliant waveforms, positive and differentiable outside,
        keyed like the violation flags.  ``margin`` gives a gradient
        optimizer strictly-interior targets so the final *hard* validation
        of its solution has slack.  The band-energy materiality gate
        relaxes to a sigmoid (the hard ``>=`` would zero the gradient at
        the gate); everything upstream uses hard max/min reductions, whose
        subgradients are exact on the active window.  ``limits`` overrides
        the thresholds like ``validate_jax``'s (family/limits split).
        """
        lims = self.limits() if limits is None else limits
        m = self._metrics_jax(w, dt)
        zero = jnp.asarray(0.0, jnp.float32)

        def hinge(metric, limit):
            lim = jnp.maximum(jnp.asarray(limit, jnp.float32), 1e-30)
            return jnp.square(jnp.maximum(metric / lim - (1.0 - margin), 0.0))

        comps: Dict[str, jnp.ndarray] = {
            "ramp_up": (hinge(m["max_ramp_up_w_per_s"],
                              lims["ramp_up_w_per_s"])
                        if "max_ramp_up_w_per_s" in m else zero),
            "ramp_down": (hinge(m["max_ramp_down_w_per_s"],
                                lims["ramp_down_w_per_s"])
                          if "max_ramp_down_w_per_s" in m else zero),
            "dynamic_range": (hinge(m["dynamic_range_w"],
                                    lims["dynamic_range_w"])
                              if "dynamic_range_w" in m else zero),
        }
        min_frac = jnp.maximum(jnp.asarray(lims["min_ac_rms_frac"],
                                           jnp.float32), 1e-9)
        material = jax.nn.sigmoid((m["ac_rms_frac"] / min_frac - 1.0) / 0.25)
        # far below materiality the sigmoid tail would still leak a loss
        # on numerically-flat waveforms (whose band fraction is noise);
        # hard-zero it there — the gradient only matters near the gate
        material = jnp.where(m["ac_rms_frac"] < 0.5 * min_frac, 0.0,
                             material)
        comps["band_energy"] = material * hinge(m["band_energy_fraction"],
                                                lims["max_energy_fraction"])
        comps["band_amplitude"] = (hinge(m["band_bin_amplitude_w"],
                                         lims["max_bin_amplitude_w"])
                                   if "band_bin_amplitude_w" in m else zero)
        total = sum(comps[v] for v in VIOLATION_ORDER)
        return total, comps


def report_from_arrays(ok, flags: Dict, metrics: Dict) -> "SpecReport":
    """Rebuild a SpecReport from (one row of) ``validate_jax`` outputs."""
    violations = tuple(v for v in VIOLATION_ORDER
                       if v in flags and bool(np.asarray(flags[v])))
    return SpecReport(ok=bool(np.asarray(ok)), violations=violations,
                      metrics={k: float(np.asarray(v))
                               for k, v in metrics.items()})


@dataclasses.dataclass(frozen=True)
class SpecReport:
    ok: bool
    violations: Tuple[str, ...]
    metrics: Dict[str, float]


def example_specs(job_mw: float) -> Dict[str, UtilitySpec]:
    """Representative specs at job scale (paper: '10 MW dynamic range on a
    100 MW job' is the tight case GPU smoothing alone cannot meet)."""
    P = job_mw * 1e6
    return {
        "lenient": UtilitySpec(
            "lenient",
            TimeDomainSpec(ramp_up_w_per_s=0.10 * P, ramp_down_w_per_s=0.10 * P,
                           dynamic_range_w=0.40 * P),
            FrequencyDomainSpec((0.1, 20.0), 0.5)),
        "moderate": UtilitySpec(
            "moderate",
            TimeDomainSpec(ramp_up_w_per_s=0.05 * P, ramp_down_w_per_s=0.05 * P,
                           dynamic_range_w=0.20 * P),
            FrequencyDomainSpec((0.1, 20.0), 0.2)),
        "tight": UtilitySpec(
            "tight",
            TimeDomainSpec(ramp_up_w_per_s=0.02 * P, ramp_down_w_per_s=0.02 * P,
                           dynamic_range_w=0.10 * P),
            FrequencyDomainSpec((0.1, 20.0), 0.1)),
    }

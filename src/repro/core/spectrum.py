"""Frequency-domain analysis of power waveforms (paper Fig. 3, Sec. III).

All routines are plain numpy (analysis-side); the *streaming* per-bin
monitor used by the backstop lives in kernels/goertzel (Pallas) with its
jnp oracle in kernels/goertzel/ref.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def spectrum(x: np.ndarray, dt: float) -> Tuple[np.ndarray, np.ndarray]:
    """One-sided amplitude spectrum of the AC component."""
    x = np.asarray(x, np.float64)
    xac = x - x.mean()
    n = len(xac)
    mag = np.abs(np.fft.rfft(xac * np.hanning(n))) * 2.0 / n
    freqs = np.fft.rfftfreq(n, dt)
    return freqs, mag


def band_energy_fraction(x: np.ndarray, dt: float,
                         f_lo: float, f_hi: float) -> float:
    """Fraction of total AC spectral energy inside [f_lo, f_hi]."""
    freqs, mag = spectrum(x, dt)
    e = mag ** 2
    tot = e[1:].sum()
    if tot <= 0:
        return 0.0
    sel = (freqs >= f_lo) & (freqs <= f_hi)
    sel[0] = False  # DC is not part of the AC energy budget
    return float(e[sel].sum() / tot)


def dominant_frequency(x: np.ndarray, dt: float) -> float:
    freqs, mag = spectrum(x, dt)
    if len(mag) < 2:
        return 0.0
    return float(freqs[1:][np.argmax(mag[1:])])


def band_amplitude_w(x: np.ndarray, dt: float, f_lo: float, f_hi: float) -> float:
    """Peak single-bin amplitude (watts) inside the critical band."""
    freqs, mag = spectrum(x, dt)
    sel = (freqs >= f_lo) & (freqs <= f_hi)
    return float(mag[sel].max()) if sel.any() else 0.0


def critical_band_report(x: np.ndarray, dt: float) -> Dict[str, float]:
    """The paper's bands: <1 Hz (inter-area), 1-2.5 Hz (plant coupling),
    7-100 Hz (shaft torsional)."""
    return {
        "sub_1hz": band_energy_fraction(x, dt, 0.05, 1.0),
        "plant_1_2p5hz": band_energy_fraction(x, dt, 1.0, 2.5),
        "torsional_7_100hz": band_energy_fraction(x, dt, 7.0, 100.0),
        "paper_band_0p2_3hz": band_energy_fraction(x, dt, 0.2, 3.0),
        "dominant_hz": dominant_frequency(x, dt),
    }

"""Frequency-domain analysis of power waveforms (paper Fig. 3, Sec. III).

Numpy routines are the analysis-side reference; each has a pure-jnp mirror
(``*_jax``) used inside the jit/vmap scenario engine (core/engine.py).  The
*streaming* per-bin monitor used by the backstop lives in kernels/goertzel
(Pallas) with its jnp oracle in kernels/goertzel/ref.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np


# the serve path's grid-critical probe frequencies: inter-area (<1 Hz),
# plant-coupling (1-2.5 Hz), the paper band's center, and low torsional
# bins — the spectral fingerprint the warm-start predictor reads
GRID_CRITICAL_HZ = (0.25, 0.5, 1.0, 2.0, 3.0, 5.0, 9.0)


def goertzel_bin_amplitudes(x: np.ndarray, dt: float,
                            freqs: Tuple[float, ...] = GRID_CRITICAL_HZ
                            ) -> np.ndarray:
    """Single-bin DFT amplitudes (watts) of the AC component at ``freqs``.

    This is the Goertzel evaluation the sliding monitor kernel performs,
    collapsed to one full-trace window: a modulated sum per target bin,
    O(n*K) with no FFT plan — the cheap spectral fingerprint the serve
    path's feature extractor uses (``serve/warmstart.py``).  Amplitude
    convention matches ``spectrum`` sans Hann window: a pure sine of
    amplitude A at a bin frequency reports ~A.
    """
    x = np.asarray(x, np.float64)
    n = len(x)
    if n == 0:
        return np.zeros(len(freqs))
    xac = x - x.mean()
    t = np.arange(n) * dt
    phases = np.exp(-2j * np.pi * np.asarray(freqs)[:, None] * t[None, :])
    return np.abs(phases @ xac) * 2.0 / n


def goertzel_bin_amplitudes_jax(x: jnp.ndarray, dt: float,
                                freqs: Tuple[float, ...] = GRID_CRITICAL_HZ
                                ) -> jnp.ndarray:
    """jnp mirror of ``goertzel_bin_amplitudes`` (phases are static)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[-1]
    xac = x - x.mean()
    t = np.arange(n) * dt
    ph = np.exp(-2j * np.pi * np.asarray(freqs)[:, None] * t[None, :])
    re = jnp.asarray(ph.real, jnp.float32) @ xac
    im = jnp.asarray(ph.imag, jnp.float32) @ xac
    return jnp.sqrt(re * re + im * im) * 2.0 / n


def spectrum(x: np.ndarray, dt: float) -> Tuple[np.ndarray, np.ndarray]:
    """One-sided amplitude spectrum of the AC component."""
    x = np.asarray(x, np.float64)
    xac = x - x.mean()
    n = len(xac)
    mag = np.abs(np.fft.rfft(xac * np.hanning(n))) * 2.0 / n
    freqs = np.fft.rfftfreq(n, dt)
    return freqs, mag


def band_energy_fraction(x: np.ndarray, dt: float,
                         f_lo: float, f_hi: float) -> float:
    """Fraction of total AC spectral energy inside [f_lo, f_hi]."""
    freqs, mag = spectrum(x, dt)
    e = mag ** 2
    tot = e[1:].sum()
    if tot <= 0:
        return 0.0
    sel = (freqs >= f_lo) & (freqs <= f_hi)
    sel[0] = False  # DC is not part of the AC energy budget
    return float(e[sel].sum() / tot)


def dominant_frequency(x: np.ndarray, dt: float) -> float:
    freqs, mag = spectrum(x, dt)
    if len(mag) < 2:
        return 0.0
    return float(freqs[1:][np.argmax(mag[1:])])


def band_amplitude_w(x: np.ndarray, dt: float, f_lo: float, f_hi: float) -> float:
    """Peak single-bin amplitude (watts) inside the critical band."""
    freqs, mag = spectrum(x, dt)
    sel = (freqs >= f_lo) & (freqs <= f_hi)
    return float(mag[sel].max()) if sel.any() else 0.0


def critical_band_report(x: np.ndarray, dt: float) -> Dict[str, float]:
    """The paper's bands: <1 Hz (inter-area), 1-2.5 Hz (plant coupling),
    7-100 Hz (shaft torsional)."""
    return {
        "sub_1hz": band_energy_fraction(x, dt, 0.05, 1.0),
        "plant_1_2p5hz": band_energy_fraction(x, dt, 1.0, 2.5),
        "torsional_7_100hz": band_energy_fraction(x, dt, 7.0, 100.0),
        "paper_band_0p2_3hz": band_energy_fraction(x, dt, 0.2, 3.0),
        "dominant_hz": dominant_frequency(x, dt),
    }


# ---------------------------------------------------------------------------
# jit/vmap-able mirrors.  Band edges and dt are static (they select FFT bins,
# which fixes the computation shape); the waveform is the traced input.
# ---------------------------------------------------------------------------

def spectrum_jax(x: jnp.ndarray, dt: float) -> Tuple[np.ndarray, jnp.ndarray]:
    """One-sided amplitude spectrum of the AC component (freqs are static)."""
    x = jnp.asarray(x, jnp.float32)
    xac = x - x.mean()
    n = x.shape[-1]
    mag = jnp.abs(jnp.fft.rfft(xac * jnp.asarray(np.hanning(n), jnp.float32)))
    mag = mag * 2.0 / n
    freqs = np.fft.rfftfreq(n, dt)
    return freqs, mag


def _band_mask(freqs: np.ndarray, f_lo: float, f_hi: float) -> np.ndarray:
    sel = (freqs >= f_lo) & (freqs <= f_hi)
    sel[0] = False  # DC is not part of the AC energy budget
    return sel


def band_energy_fraction_jax(x: jnp.ndarray, dt: float,
                             f_lo: float, f_hi: float) -> jnp.ndarray:
    freqs, mag = spectrum_jax(x, dt)
    e = mag ** 2
    tot = e[1:].sum()
    frac = e[_band_mask(freqs, f_lo, f_hi)].sum() / jnp.maximum(tot, 1e-30)
    return jnp.where(tot > 0, frac, 0.0)


def band_amplitude_w_jax(x: jnp.ndarray, dt: float,
                         f_lo: float, f_hi: float) -> jnp.ndarray:
    freqs, mag = spectrum_jax(x, dt)
    sel = (freqs >= f_lo) & (freqs <= f_hi)
    if not sel.any():
        return jnp.asarray(0.0, jnp.float32)
    return mag[sel].max()


def dominant_frequency_jax(x: jnp.ndarray, dt: float) -> jnp.ndarray:
    freqs, mag = spectrum_jax(x, dt)
    if len(freqs) < 2:
        return jnp.asarray(0.0, jnp.float32)
    return jnp.asarray(freqs, jnp.float32)[1:][jnp.argmax(mag[1:])]


def critical_band_report_jax(x: jnp.ndarray, dt: float) -> Dict[str, jnp.ndarray]:
    """jnp mirror of ``critical_band_report`` (one rfft, five reductions)."""
    freqs, mag = spectrum_jax(x, dt)
    e = mag ** 2
    tot = e[1:].sum()

    def frac(f_lo, f_hi):
        val = e[_band_mask(freqs, f_lo, f_hi)].sum() / jnp.maximum(tot, 1e-30)
        return jnp.where(tot > 0, val, 0.0)

    dom = (jnp.asarray(freqs, jnp.float32)[1:][jnp.argmax(mag[1:])]
           if len(freqs) >= 2 else jnp.asarray(0.0, jnp.float32))
    return {
        "sub_1hz": frac(0.05, 1.0),
        "plant_1_2p5hz": frac(1.0, 2.5),
        "torsional_7_100hz": frac(7.0, 100.0),
        "paper_band_0p2_3hz": frac(0.2, 3.0),
        "dominant_hz": dom,
    }

"""Staggered ramp scheduling (paper Sec. IV-A: 'staggering the load ramp-up
across all the participating GPUs'; applied here at rack/pod granularity).

Job start, checkpoint-restore restart, and elastic re-meshing all slam the
full fleet from idle to TDP at once — a worst-case ramp event. Given the
utility's ramp limit, schedule per-rack start offsets so the aggregate
dP/dt stays in spec; the same schedule runs in reverse for drain-down.
Integrates with ckpt/fault-tolerance: launch/train.py applies the schedule
after every restart (power-aware restart, DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware


@dataclasses.dataclass(frozen=True)
class StaggerSchedule:
    offsets_s: np.ndarray          # per-rack start offset
    rack_ramp_w_per_s: float       # within-rack ramp rate

    @property
    def total_s(self) -> float:
        return float(self.offsets_s.max())


def plan_stagger(n_racks: int, rack_power_w: float,
                 ramp_limit_w_per_s: float,
                 rack_ramp_s: float = 2.0) -> StaggerSchedule:
    """Offsets so the aggregate ramp never exceeds the utility limit.

    If a single rack's natural ramp already exceeds the limit, the per-rack
    ramp itself is stretched (that is what the GPU smoothing feature's
    programmable ramp-up rate is for, Sec. IV-B)."""
    rack_ramp = rack_power_w / rack_ramp_s
    if rack_ramp > ramp_limit_w_per_s:
        rack_ramp = ramp_limit_w_per_s
        rack_ramp_s = rack_power_w / rack_ramp
    # racks that may ramp concurrently without exceeding the limit
    conc = max(int(ramp_limit_w_per_s / rack_ramp), 1)
    offsets = (np.arange(n_racks) // conc) * rack_ramp_s
    return StaggerSchedule(offsets_s=offsets.astype(np.float64),
                           rack_ramp_w_per_s=rack_ramp)


def ramp_waveform(sched: StaggerSchedule, n_racks: int, rack_power_w: float,
                  dt: float = 0.01, *, direction: int = +1) -> np.ndarray:
    """Aggregate power during a staggered ramp (direction=-1: drain)."""
    rack_ramp_s = rack_power_w / sched.rack_ramp_w_per_s
    total = sched.total_s + rack_ramp_s + 1.0
    n = int(total / dt) + 1
    t = np.arange(n) * dt
    w = np.zeros(n)
    for r in range(n_racks):
        t0 = sched.offsets_s[r]
        ramp = np.clip((t - t0) / rack_ramp_s, 0.0, 1.0) * rack_power_w
        w += ramp
    if direction < 0:
        w = w[::-1].copy()
    return w


def max_ramp(w: np.ndarray, dt: float, window_s: float = 0.1) -> float:
    k = max(int(window_s / dt), 1)
    box = np.convolve(w, np.ones(k) / k, mode="valid")
    return float(np.abs(np.diff(box)).max() / dt)

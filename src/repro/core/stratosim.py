"""StratoSim analogue: end-to-end datacenter power simulation.

Pipeline (mirrors how the paper evaluates every mitigation 'on the real
waveform from Figure 1' before deployment):

  dry-run artifact -> phase timeline -> chip waveform -> device-level
  mitigation (GPU floor / Firefly) -> rack aggregation (+ rack battery)
  -> datacenter waveform (+ jitter, distribution loss) -> utility spec
  validation + frequency report (+ optional backstop).

``simulate`` is the per-scenario entry point used by benchmarks, tests and
the power_stabilization_demo example; it is the numpy-facing serial
reference for the batched engine (core/engine.py), which runs grids of
scenarios — and ``simulate_jit`` below, a single scenario — as one
compiled jit/vmap call.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import IterationTimeline, from_dryrun_cell, synthetic_timeline
from repro.core.smoothing.base import Mitigation, energy_overhead, np_apply
from repro.core.spec import SpecReport, UtilitySpec
from repro.core.spectrum import critical_band_report
from repro.core.waveform import WaveformConfig, aggregate, chip_waveform, swing_stats


@dataclasses.dataclass
class SimResult:
    t: np.ndarray
    dc_raw: np.ndarray              # utility-point waveform, no mitigation
    dc_mitigated: np.ndarray
    chip_raw: np.ndarray
    chip_mitigated: Optional[np.ndarray]
    energy_overhead: float
    swing: Dict[str, float]
    swing_mitigated: Dict[str, float]
    bands: Dict[str, float]
    bands_mitigated: Dict[str, float]
    spec_report: Optional[SpecReport]
    aux: Dict


def simulate(timeline: IterationTimeline, n_chips: int,
             wave_cfg: Optional[WaveformConfig] = None,
             *, device_mitigation: Optional[Mitigation] = None,
             rack_mitigation: Optional[Mitigation] = None,
             spec: Optional[UtilitySpec] = None,
             hw: Hardware = DEFAULT_HW, seed: int = 0,
             key: Optional[jax.Array] = None) -> SimResult:
    """One scenario, serially.  ``key``, when given, seeds any randomness a
    mitigation consumes (telemetry noise): the device stage draws from
    fold_in(key, 0), the rack stage from fold_in(key, 1) — the same split
    the batched engine uses, so a keyed serial run is the parity reference
    for a keyed batched row."""
    cfg = wave_cfg or WaveformConfig()
    aux: Dict = {}

    chip = chip_waveform(timeline, cfg, hw)
    dc_raw = aggregate(chip, n_chips, cfg, hw, seed=seed)

    chip_m = None
    if device_mitigation is not None:
        k = None if key is None else jax.random.fold_in(key, 0)
        chip_m, aux_d = np_apply(device_mitigation, chip, cfg.dt, k)
        aux["device"] = aux_d
        dc = aggregate(chip_m, n_chips, cfg, hw, seed=seed)
    else:
        dc = dc_raw

    if rack_mitigation is not None:
        k = None if key is None else jax.random.fold_in(key, 1)
        dc, aux_r = np_apply(rack_mitigation, dc, cfg.dt, k)
        aux["rack"] = aux_r

    report = spec.validate(dc, cfg.dt) if spec is not None else None
    t = np.arange(len(dc)) * cfg.dt
    return SimResult(
        t=t, dc_raw=dc_raw, dc_mitigated=dc,
        chip_raw=chip, chip_mitigated=chip_m,
        energy_overhead=energy_overhead(dc_raw, dc),
        swing=swing_stats(dc_raw), swing_mitigated=swing_stats(dc),
        bands=critical_band_report(dc_raw, cfg.dt),
        bands_mitigated=critical_band_report(dc, cfg.dt),
        spec_report=report, aux=aux)


def simulate_jit(timeline: IterationTimeline, n_chips: int,
                 wave_cfg: Optional[WaveformConfig] = None,
                 *, device_mitigation: Optional[Mitigation] = None,
                 rack_mitigation: Optional[Mitigation] = None,
                 spec: Optional[UtilitySpec] = None,
                 hw: Hardware = DEFAULT_HW, seed: int = 0,
                 key: Optional[jax.Array] = None) -> SimResult:
    """``simulate`` with the whole pipeline in ONE compiled call (the
    batched engine at B=1); numerically equivalent to ``simulate`` (parity
    tested in tests/test_engine.py)."""
    from repro.core.engine import simulate_batch  # lazy: engine imports us
    return simulate_batch(timeline, n_chips, wave_cfg,
                          device_mitigation=device_mitigation,
                          rack_mitigation=rack_mitigation, spec=spec,
                          hw=hw, seeds=seed,
                          keys=None if key is None else [key]).scenario(0)


def simulate_cell(cell: Dict, *, steps: int = 30, dt: float = 0.001,
                  overlap: float = 0.0, mfu: float = 0.5,
                  device_mitigation=None, rack_mitigation=None,
                  spec=None, hw: Hardware = DEFAULT_HW,
                  jitter_s: float = 0.002) -> SimResult:
    """Simulate straight from a launch/dryrun.py artifact dict."""
    tl = from_dryrun_cell(cell, hw, overlap=overlap, mfu=mfu)
    cfg = WaveformConfig(dt=dt, steps=steps, jitter_s=jitter_s)
    return simulate(tl, cell["n_chips"], cfg,
                    device_mitigation=device_mitigation,
                    rack_mitigation=rack_mitigation, spec=spec, hw=hw)

"""Declarative Study API: declare scenario axes once, run the grid as a
handful of compiled calls, query the results.

This is the public surface over the batched engine (``core/engine.py``).
A ``Study`` declares its axes — workloads (iteration timelines), fleet
sizes, mitigation configs (disabled/None entries are first-class: the
unmitigated baseline batches with everything else), utility specs, and
jitter seeds — and ``run()`` compiles the cartesian grid down to the
streaming chunked executor (``engine.stream_batches``):

  study = Study(
      workloads={"dense_2s": synthetic_timeline(2.0, 0.19),
                 "moe_3s": synthetic_timeline(3.0, 0.25, moe_notch=True)},
      fleets=[256, 512],
      configs={"none": None, "mpf90+bat": (gpu, battery)},
      specs=example_specs(job_mw=100.0),
      seeds=[0, 1],
      key=0)
  result = study.run()
  result.passing().pivot("workload", "config", "energy_overhead")

Four scale levers live in this layer:

* **Keyed randomness** — every pipeline row gets its own PRNG key
  (``fold_in(root, row)``), threaded into mitigations that consume
  randomness (telemetry noise), so noisy-telemetry sweeps see independent
  draws and the same Study with the same root key is bit-reproducible.
* **Pad-and-mask fusion** — mixed-length workloads fuse into ONE compiled
  pipeline call per mitigation-structure group (edge-padded + masked,
  exact in the valid region); the frequency/spec analysis then runs per
  true length.  ``padding="auto"`` picks this whenever lengths are mixed;
  ``"bucket"`` keeps the one-call-per-length behavior.
* **Streaming chunked execution** — ``run(stream=chunk)`` iterates the
  scenario axis in fixed-size chunks of compiled work: each chunk's
  waveforms live only on device and are reduced to metrics inside jit,
  so a 10^4–10^5-scenario grid runs in O(chunk) waveform memory and
  O(records) metric columns.  Chunked and one-shot runs are
  bit-identical; ``on_chunk`` reports progress.
* **Scenario-axis sharding** — ``shard_devices=True`` (or an explicit
  ``plan=ScenarioShardPlan``) partitions the scenario axis over a device
  mesh; it composes with chunking (each chunk is padded to a shard
  multiple), and the plan's process-local slicing makes the same code
  multi-host ready.

Results come back as a ``StudyResult``: a *columnar* record store (dict
of numpy columns, one flat record dict per scenario materialized
lazily) with filter / pivot / export helpers, plus per-row ``SimResult``
access.  The spec axis is deduplicated against the pipeline: physics
runs once per (workload, fleet, config, seed) row, each spec then judges
every row.

Beyond judging *declared* configs, ``Study.optimize()`` runs the engine's
``design`` solver (grid / gradient / hybrid) per (workload, fleet, spec)
cell and returns the solved configurations as ``designed=True`` records
in the same schema — ``result.filter(designed=True)`` separates them.
"""
from __future__ import annotations

import dataclasses
import io
import json
import time
from typing import (Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import jax
import numpy as np

from repro.core.engine import StreamChunk, design, stream_batches
from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import IterationTimeline
from repro.core.smoothing.base import Mitigation
from repro.core.spec import UtilitySpec
from repro.core.spectrum import critical_band_report
from repro.core.waveform import (WaveformConfig, aggregate, chip_waveform,
                                 phase_levels)
from repro.core.stratosim import SimResult
from repro.ckpt.resume import SweepCheckpoint
from repro.parallel.sharding import ScenarioShardPlan

PADDING_MODES = ("auto", "pad", "bucket")

# chunk size Study.run(stream=True) picks: big enough to keep the vmapped
# pipeline efficient, small enough that O(chunk * n) device waveforms stay
# tens of MB at typical trace lengths
DEFAULT_STREAM_CHUNK = 512


# ---------------------------------------------------------------------------
# axis declarations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MitigationConfig:
    """One named point on the mitigation axis.  Either stage may be None;
    the fully-disabled config is the unmitigated baseline."""
    name: str
    device: Optional[Mitigation] = None
    rack: Optional[Mitigation] = None

    @property
    def enabled(self) -> bool:
        return self.device is not None or self.rack is not None


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-resolved cell of the study grid (records align by
    ``index``; ``row`` is the pipeline row — shared across the spec axis,
    and the input to ``Study.scenario_key``)."""
    index: int
    row: int
    workload: str
    n_chips: int
    config: MitigationConfig
    spec_name: Optional[str]
    spec: Optional[UtilitySpec]
    seed: int


def _one_config(name: str, entry) -> MitigationConfig:
    if entry is None:
        return MitigationConfig(name)
    if isinstance(entry, MitigationConfig):
        return entry if entry.name == name else dataclasses.replace(entry,
                                                                    name=name)
    if isinstance(entry, (tuple, list)) and len(entry) == 2:
        return MitigationConfig(name, device=entry[0], rack=entry[1])
    raise TypeError(
        f"config {name!r}: expected None, MitigationConfig, or a "
        f"(device_mitigation, rack_mitigation) pair, got {type(entry).__name__}"
        " — a bare mitigation is ambiguous between the per-chip device stage"
        " and the aggregate rack stage")


def _as_configs(configs) -> List[MitigationConfig]:
    if configs is None:
        return [MitigationConfig("none")]
    if isinstance(configs, MitigationConfig):
        return [configs]
    if isinstance(configs, Mapping):
        return [_one_config(name, entry) for name, entry in configs.items()]
    out = []
    for i, entry in enumerate(configs):
        default = "none" if entry is None else f"config{i}"
        name = entry.name if isinstance(entry, MitigationConfig) else default
        out.append(_one_config(name, entry))
    return out


def _as_workloads(workloads) -> Dict[str, IterationTimeline]:
    if isinstance(workloads, IterationTimeline):
        return {"workload0": workloads}
    if isinstance(workloads, Mapping):
        return dict(workloads)
    return {f"workload{i}": tl for i, tl in enumerate(workloads)}


def _as_specs(specs) -> List[Tuple[Optional[str], Optional[UtilitySpec]]]:
    if specs is None:
        return [(None, None)]
    if isinstance(specs, UtilitySpec):
        return [(specs.name, specs)]
    if isinstance(specs, Mapping):
        return [(name, s) for name, s in specs.items()]
    return [(s.name, s) for s in specs]


def _as_seq(x) -> list:
    return list(x) if isinstance(x, (list, tuple)) else [x]


# ---------------------------------------------------------------------------
# row-level execution (the core behind Study.run and the serve layer's
# cross-query coalescing)
# ---------------------------------------------------------------------------

def _is_primary() -> bool:
    """Process 0 owns side effects (progress callbacks, checkpoint
    writes); single-process runs are always primary.  Host-side only —
    never trace process identity (repro-lint RPR007)."""
    return jax.process_index() == 0


def _structure_groups(rows) -> List[List[int]]:
    """Row indices grouped by (device, rack) pytree structure.  A None
    stage is a wildcard: baseline rows batch with the first concrete
    structure (the engine masks them off row-wise)."""
    def struct(m):
        return None if m is None else jax.tree.structure(m)

    dev_first = next((struct(c.device) for _, _, c, _ in rows
                      if c.device is not None), None)
    rack_first = next((struct(c.rack) for _, _, c, _ in rows
                       if c.rack is not None), None)
    groups: Dict[Tuple, List[int]] = {}
    for r, (_, _, c, _) in enumerate(rows):
        k = (struct(c.device) if c.device is not None else dev_first,
             struct(c.rack) if c.rack is not None else rack_first)
        groups.setdefault(k, []).append(r)
    return list(groups.values())


def run_rows(workloads: Mapping[str, IterationTimeline],
             rows: Sequence[Tuple[str, int, MitigationConfig, int]],
             specs: Sequence[Tuple[Optional[str], Optional[UtilitySpec]]],
             *, wave_cfg: Optional[WaveformConfig] = None,
             hw: Hardware = DEFAULT_HW,
             keys: Optional[Sequence] = None,
             padding: str = "auto",
             stream: Union[None, bool, int] = None,
             sample_chips: int = 64,
             keep_waveforms: bool = False,
             shard_devices: bool = False,
             plan: Optional[ScenarioShardPlan] = None,
             on_chunk: Optional[Callable[[int, int, float], None]] = None,
             levels: Optional[Dict[str, np.ndarray]] = None,
             resume: Optional[str] = None
             ) -> "StudyResult":
    """Run an explicit list of pipeline rows through the streaming chunked
    executor and return the columnar ``StudyResult``.

    This is ``Study.run`` with the row list made explicit: each row is a
    ``(workload_name, n_chips, MitigationConfig, seed)`` tuple and ``keys``
    optionally supplies one PRNG key per row.  ``Study.run`` builds its
    cartesian grid and delegates here; the serve layer's ``handle_many``
    calls it directly with the *union* row list of N coalesced queries
    (each query's rows carrying the keys that query would draw alone, so
    coalescing is bit-identical to running the queries one at a time).
    ``levels`` optionally supplies precomputed ``phase_levels`` arrays per
    workload name (the serve layer's memoized synthesis).

    Rows are grouped by mitigation *structure* (a GPU-floor grid and a
    Firefly grid cannot stack into one batched pytree; disabled rows join
    any group); ``padding="pad"`` fuses each structure group's mixed
    lengths into one padded call stream while ``"bucket"`` streams each
    length separately (``"auto"`` pads iff lengths mix).  ``stream``
    picks the chunk size as in ``Study.run``.

    ``resume=dir`` makes the stream restartable: after each chunk the
    primary process checkpoints that chunk's records into ``dir``
    (``ckpt/resume.SweepCheckpoint``), and a rerun with the same (or an
    append-extended) row list restores the finished chunks and only
    computes the rest — bit-identical to an uninterrupted run.  A
    mismatched grid, chunk size, or corrupt checkpoint raises
    ``ResumeError`` instead of merging wrong rows.  Requires streaming
    (``stream=``) and is exclusive with ``keep_waveforms``.

    ``on_chunk`` progress is **global** and primary-only: ``done`` /
    ``total`` count pipeline rows of the whole grid (every process runs
    every chunk of the global scenario axis, so the count is identical
    on all of them), and under a multi-process plan only process 0
    emits — worker processes stay silent.  Rows restored from a resume
    dir are reported in one leading callback per call stream.
    """
    cfg = wave_cfg or WaveformConfig()
    if padding not in PADDING_MODES:
        raise ValueError(f"padding must be one of {PADDING_MODES}")
    if stream is None or stream is False:
        chunk_size = None
    elif stream is True:
        chunk_size = DEFAULT_STREAM_CHUNK
    else:
        chunk_size = int(stream)
        if chunk_size < 1:
            raise ValueError(f"stream chunk size must be >= 1, got {stream}")
    rows = list(rows)
    specs = list(specs)
    if levels is None:
        levels = {}
    needed = {w for w, _, _, _ in rows}
    levels = dict(levels)
    for w in needed:
        if w not in levels:
            levels[w] = phase_levels(workloads[w], cfg, hw)
    row_len = [len(levels[w]) for w, _, _, _ in rows]
    mode = padding
    if mode == "auto":
        mode = "pad" if len(set(row_len)) > 1 else "bucket"
    if keys is not None:
        keys = list(keys)
        if len(keys) != len(rows):
            raise ValueError(f"keys: got {len(keys)}, expected {len(rows)}")

    primary = _is_primary()
    ckpt = None
    if resume is not None:
        if chunk_size is None:
            raise ValueError(
                "resume= requires streaming (pass stream=True or stream=N): "
                "chunk boundaries are the checkpoint points")
        if keep_waveforms:
            raise ValueError(
                "resume= does not support keep_waveforms=True — waveforms "
                "are not checkpointed, so a resumed result would miss them")
        ckpt = SweepCheckpoint(resume)
        ckpt.validate_or_init(
            workloads=workloads, rows=rows, specs=specs, keys=keys,
            cfg=cfg, hw=hw, mode=mode, sample_chips=sample_chips,
            chunk_size=chunk_size, write=primary)

    emit = on_chunk if (on_chunk is not None and primary) else None
    cols = _empty_columns(len(rows) * len(specs))
    waveforms = [None] * len(rows) if keep_waveforms else None
    total, done = len(rows), 0
    t0 = time.perf_counter()
    for gi, sg_rows in enumerate(_structure_groups(rows)):
        if mode == "pad":
            calls = [(f"g{gi}-pad", sg_rows)]
        else:
            by_len: Dict[int, List[int]] = {}
            for r in sg_rows:
                by_len.setdefault(row_len[r], []).append(r)
            calls = [(f"g{gi}-L{L}", idx)
                     for L, idx in sorted(by_len.items())]
        for call_key, idx in calls:
            lens = {row_len[r] for r in idx}
            cs_eff = max(1, min(chunk_size or len(idx), len(idx)))
            skip = 0
            if ckpt is not None:
                skip = ckpt.restore_call(call_key, idx, cs_eff, cols,
                                         len(specs))
                if skip:
                    done += skip
                    if emit is not None:
                        emit(done, total, time.perf_counter() - t0)
                if skip >= len(idx):
                    continue
            chunks = stream_batches(
                [workloads[rows[r][0]] for r in idx],
                [rows[r][1] for r in idx], cfg,
                device_mitigation=[rows[r][2].device for r in idx],
                rack_mitigation=[rows[r][2].rack for r in idx],
                specs=[sp for _, sp in specs],
                hw=hw, seeds=[rows[r][3] for r in idx],
                keys=None if keys is None else [keys[r] for r in idx],
                sample_chips=sample_chips,
                levels=[levels[rows[r][0]] for r in idx],
                pad_to=max(lens) if len(lens) > 1 else None,
                chunk_size=cs_eff,
                bands=True, keep_waveforms=keep_waveforms,
                dedup=True, shard_devices=shard_devices,
                plan=plan, skip_rows=skip)
            for ch in chunks:
                _fill_chunk(cols, waveforms, rows, row_len, idx, ch,
                            specs=specs, workloads=workloads, dt=cfg.dt)
                if ckpt is not None and primary:
                    ckpt.save_chunk(call_key, idx, ch.start, ch.stop,
                                    cols, len(specs))
                done += len(ch)
                if emit is not None:
                    emit(done, total, time.perf_counter() - t0)
    return StudyResult(columns=cols, waveforms=waveforms)


def _fill_chunk(cols: Dict[str, np.ndarray], waveforms, rows, row_len,
                idx: List[int], ch: StreamChunk, *, specs, workloads,
                dt: float) -> None:
    """Write one ``StreamChunk``'s metrics into the columnar record
    store (record position = pipeline row * n_specs + spec index)."""
    S = len(specs)
    for j in range(len(ch)):
        r = idx[ch.start + j]
        wname, n_chips, config, seed = rows[r]
        L = row_len[r]
        base = {
            "row": r, "workload": wname, "n_chips": n_chips,
            "config": config.name, "seed": seed,
            "period_s": float(workloads[wname].period_s),
            "n_samples": L,
            "mean_mw": float(ch.swing["mean_w"][j]) / 1e6,
            "swing_mw": float(ch.swing["swing_w"][j]) / 1e6,
            "swing_mitigated_mw":
                float(ch.swing_mitigated["swing_w"][j]) / 1e6,
            "energy_overhead": float(ch.energy_overhead[j]),
            "paper_band_frac":
                float(ch.bands_mitigated["paper_band_0p2_3hz"][j]),
            "designed": False,
        }
        for si, (spec_name, spec) in enumerate(specs):
            p = r * S + si
            for k, v in base.items():
                cols[k][p] = v
            cols["spec"][p] = spec_name
            if spec is not None:
                report = ch.report(si, j)
                cols["spec_ok"][p] = report.ok
                cols["violations"][p] = report.violations
                # spec metrics go into numeric side columns
                # ("metrics:<name>", NaN = not measured for this record)
                # instead of a per-record dict: at 10^6 records the dict
                # overhead alone is ~300 MB of host memory
                for mk, mv in report.metrics.items():
                    mc = cols.get("metrics:" + mk)
                    if mc is None:
                        mc = cols["metrics:" + mk] = np.full(
                            len(cols["index"]), np.nan)
                    mc[p] = mv
            else:
                cols["spec_ok"][p] = None
                cols["violations"][p] = ()
        if waveforms is not None:
            waveforms[r] = {
                "t": np.arange(L) * dt,
                "dc_raw": np.asarray(ch.dc_raw[j, :L]),
                "dc_mitigated": np.asarray(ch.dc_mitigated[j, :L]),
            }


# ---------------------------------------------------------------------------
# the study
# ---------------------------------------------------------------------------

class Study:
    """A declared scenario grid; ``run()`` compiles it to the engine.

    Axes (each a singleton or a collection):
      workloads  name -> IterationTimeline (dict, sequence, or one timeline)
      fleets     chip counts
      configs    name -> None | MitigationConfig | (device, rack) pair
      specs      None | UtilitySpec | dict name -> spec | sequence
      seeds      jitter seeds (numpy side: per-chip phase jitter draws)

    ``key`` is the PRNG root for mitigation randomness (telemetry noise):
    pipeline row ``r`` draws from ``fold_in(PRNGKey(key), r)``.  ``None``
    reverts to the legacy shared-draw behavior.  ``padding`` and
    ``shard_devices`` select the scale levers (see module docstring).
    """

    def __init__(self, workloads, *,
                 fleets: Union[int, Sequence[int]] = (512,),
                 configs=None, specs=None,
                 seeds: Union[int, Sequence[int]] = (0,),
                 wave_cfg: Optional[WaveformConfig] = None,
                 hw: Hardware = DEFAULT_HW,
                 key: Union[int, jax.Array, None] = 0,
                 padding: str = "auto",
                 shard_devices: bool = False,
                 plan: Optional[ScenarioShardPlan] = None,
                 sample_chips: int = 64,
                 keep_waveforms: bool = False):
        if padding not in PADDING_MODES:
            raise ValueError(f"padding must be one of {PADDING_MODES}")
        self.workloads = _as_workloads(workloads)
        self.fleets = [int(n) for n in _as_seq(fleets)]
        self.configs = _as_configs(configs)
        self.specs = _as_specs(specs)
        self.seeds = [int(s) for s in _as_seq(seeds)]
        self.wave_cfg = wave_cfg or WaveformConfig()
        self.hw = hw
        self.key = key
        self.padding = padding
        self.shard_devices = shard_devices
        self.plan = plan
        self.sample_chips = sample_chips
        self.keep_waveforms = keep_waveforms
        names = [c.name for c in self.configs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate config names: {names}")

    # -- declaration accessors ----------------------------------------------

    @property
    def n_rows(self) -> int:
        """Pipeline rows: the grid without the (physics-free) spec axis."""
        return (len(self.workloads) * len(self.fleets) * len(self.configs)
                * len(self.seeds))

    def __len__(self) -> int:
        return self.n_rows * len(self.specs)

    def rows(self) -> List[Tuple[str, int, MitigationConfig, int]]:
        """Pipeline rows in study order: workload-major, then fleet,
        config, seed."""
        return [(w, n, c, s)
                for w in self.workloads for n in self.fleets
                for c in self.configs for s in self.seeds]

    def scenarios(self) -> List[Scenario]:
        out = []
        for r, (w, n, c, s) in enumerate(self.rows()):
            for sn, sp in self.specs:
                out.append(Scenario(index=len(out), row=r, workload=w,
                                    n_chips=n, config=c, spec_name=sn,
                                    spec=sp, seed=s))
        return out

    def scenario_key(self, row: int) -> Optional[jax.Array]:
        """The PRNG key pipeline row ``row`` draws mitigation randomness
        from (the serial parity reference passes this to ``simulate``)."""
        if self.key is None:
            return None
        root = (self.key if isinstance(self.key, jax.Array)
                else jax.random.PRNGKey(int(self.key)))
        return jax.random.fold_in(root, row)

    def describe(self) -> str:
        lens = sorted({len(phase_levels(tl, self.wave_cfg, self.hw))
                       for tl in self.workloads.values()})
        return (f"Study: {len(self.workloads)} workloads x "
                f"{len(self.fleets)} fleets x {len(self.configs)} configs x "
                f"{len(self.seeds)} seeds = {self.n_rows} scenarios "
                f"({len(self.specs)} specs -> {len(self)} records); "
                f"waveform lengths {lens}, padding={self.padding}")

    # -- execution ----------------------------------------------------------

    def run(self, *, padding: Optional[str] = None,
            stream: Union[None, bool, int] = None,
            on_chunk: Optional[Callable[[int, int, float], None]] = None,
            resume: Optional[str] = None
            ) -> "StudyResult":
        """Run the whole grid through the streaming chunked executor.

        Rows are first grouped by mitigation *structure* (a GPU-floor
        grid and a Firefly grid cannot stack into one batched pytree;
        disabled rows join any group); pad mode fuses each structure
        group's mixed lengths into one padded call stream while bucket
        mode streams each length separately.  Each call stream runs as
        ``engine.stream_batches`` chunks: the compiled pipeline plus
        vmapped per-(length, spec) analysis reduce every chunk to metric
        arrays on device, and only those metrics reach the host, where
        they append to the columnar ``StudyResult``.

        ``stream`` picks the chunk size: ``None``/``False`` runs each
        call stream as one chunk (every scenario's waveforms in device
        memory at once — fine up to ~10^3 scenarios), ``True`` picks
        ``DEFAULT_STREAM_CHUNK``, an int is an explicit chunk size.
        Host memory is O(records) metric columns either way; device
        memory is O(chunk * padded length).  Chunked and one-shot runs
        are bit-identical — chunking only ever adds pipeline rows that
        are sliced away.

        ``on_chunk(done, total, elapsed_s)`` (optional) is called after
        every chunk with the number of pipeline scenarios finished, the
        grid total, and the wall-clock seconds since ``run`` started —
        the progress hook long sweeps (``sweep_bench``, the serve CLI)
        surface to operators.  Progress is global (done/total over the
        whole grid) and, under a multi-process plan, emitted only on
        process 0.

        ``resume=dir`` checkpoints every finished chunk into ``dir`` and
        restores them on rerun — kill-and-restart (or append-extending
        the grid) completes bit-identically to an uninterrupted run; see
        ``run_rows``.  Requires ``stream=``.

        The body is the module-level ``run_rows`` over this study's
        cartesian row list — callers with an explicit (possibly
        heterogeneous) row set, like the serve layer's coalesced
        ``handle_many``, drive ``run_rows`` directly.
        """
        rows = self.rows()
        keys = ([self.scenario_key(r) for r in range(len(rows))]
                if self.key is not None else None)
        return run_rows(
            self.workloads, rows, self.specs,
            wave_cfg=self.wave_cfg, hw=self.hw, keys=keys,
            padding=padding or self.padding, stream=stream,
            sample_chips=self.sample_chips,
            keep_waveforms=self.keep_waveforms,
            shard_devices=self.shard_devices, plan=self.plan,
            on_chunk=on_chunk, resume=resume)

    def optimize(self, *, method: str = "hybrid",
                 seed: Optional[int] = None,
                 **design_kwargs) -> "StudyResult":
        """Run a mitigation *design* per (workload, fleet, spec) cell.

        Where ``run()`` judges the study's declared configs, ``optimize()``
        asks the engine's ``design`` solver (method = "grid" | "gradient" |
        "hybrid") for a minimal-overhead (MPF, battery) configuration that
        passes each declared spec, and returns one record per cell with
        ``designed=True`` — the same record schema as ``run()`` (so
        designed rows query/pivot/export alongside declared ones via
        ``filter(designed=True)``) plus the solved ``mpf_frac`` /
        ``battery_capacity_j``.  Cells with no feasible design come back
        as ``spec_ok=False`` with ``violations=("infeasible",)``.

        ``seed`` picks the jitter draw the design waveform uses (default:
        the study's first seed).  Extra keyword arguments flow to
        ``engine.design`` (``steps``, ``smooth_tau``, ``top_k``, ...).
        """
        cfg, hw = self.wave_cfg, self.hw
        seed = self.seeds[0] if seed is None else int(seed)
        records: List[Dict] = []
        for wname, tl in self.workloads.items():
            chip = chip_waveform(tl, cfg, hw)
            for n_chips in self.fleets:
                w = aggregate(chip, n_chips, cfg, hw, seed=seed,
                              sample_chips=self.sample_chips)
                for spec_name, spec in self.specs:
                    if spec is None:
                        continue
                    sol = design(spec, w, cfg.dt, n_chips, method=method,
                                 hw=hw, **design_kwargs)
                    rec = {
                        "index": len(records),
                        "row": -1,           # no pipeline row backs a design
                        "workload": wname,
                        "n_chips": n_chips,
                        "config": f"designed[{method}]",
                        "spec": spec_name,
                        "seed": seed,
                        "period_s": float(tl.period_s),
                        "n_samples": len(w),
                        "mean_mw": float(np.mean(w)) / 1e6,
                        "swing_mw": float(w.max() - w.min()) / 1e6,
                        "designed": True,
                    }
                    if sol is None:
                        rec.update({
                            "swing_mitigated_mw": rec["swing_mw"],
                            "energy_overhead": 0.0,
                            "paper_band_frac": None,
                            "spec_ok": False,
                            "violations": ("infeasible",),
                            "metrics": {},
                            "mpf_frac": None,
                            "battery_capacity_j": None,
                        })
                    else:
                        mit = np.asarray(sol["mitigated"])
                        rec.update({
                            "swing_mitigated_mw":
                                float(mit.max() - mit.min()) / 1e6,
                            "energy_overhead": float(sol["energy_overhead"]),
                            "paper_band_frac": float(critical_band_report(
                                mit, cfg.dt)["paper_band_0p2_3hz"]),
                            "spec_ok": sol["report"].ok,
                            "violations": sol["report"].violations,
                            "metrics": sol["report"].metrics,
                            "mpf_frac": sol["mpf_frac"],
                            "battery_capacity_j": sol["battery_capacity_j"],
                        })
                    records.append(rec)
        return StudyResult(records=records)

    # row grouping by mitigation structure (module-level; kept as a
    # staticmethod alias for existing callers)
    _structure_groups = staticmethod(_structure_groups)



# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

# the columnar record schema (field order = record dict key order)
_COLUMN_DTYPES = (
    ("index", np.int64), ("row", np.int64), ("workload", object),
    ("n_chips", np.int64), ("config", object), ("spec", object),
    ("seed", np.int64), ("period_s", np.float64), ("n_samples", np.int64),
    ("mean_mw", np.float64), ("swing_mw", np.float64),
    ("swing_mitigated_mw", np.float64), ("energy_overhead", np.float64),
    ("paper_band_frac", np.float64), ("designed", np.bool_),
    ("spec_ok", object), ("violations", object),
)


def _empty_columns(n: int) -> Dict[str, np.ndarray]:
    cols = {k: np.empty(n, dtype=dt) for k, dt in _COLUMN_DTYPES}
    cols["index"] = np.arange(n, dtype=np.int64)
    return cols


def _to_py(v):
    """numpy scalar -> the python scalar the list-of-dicts records held."""
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    return v


class StudyResult:
    """Flat scenario records with query helpers, stored columnar.

    Each record is one (workload, fleet, config, seed, spec) cell:
    identity fields, swing/overhead/band metrics, and — when a spec was
    declared — ``spec_ok`` / ``violations`` / the spec's metric dict.
    ``designed`` distinguishes ``Study.optimize()`` records (solved
    configurations, carrying ``mpf_frac``/``battery_capacity_j``) from
    ``run()`` records (declared configurations); ``filter(designed=True)``
    selects them.  ``waveforms`` (when the study kept them) is indexed by
    ``record["row"]``.

    Storage is a dict of per-field numpy columns (``columns=``; how the
    streaming executor appends chunk after chunk in O(records) memory —
    numeric fields cost 8 bytes per record instead of a dict slot);
    record *dicts* are materialized lazily per row (``result[i]``,
    iteration, ``.records``) and are bit-identical to the list-of-dicts
    form this class used to hold.  Constructing from ``records=`` (a
    list of dicts, e.g. ``optimize()`` output or concatenated results)
    keeps the list verbatim — both representations answer the same
    query API.
    """

    def __init__(self, records: Optional[List[Dict]] = None,
                 waveforms: Optional[List[Dict]] = None, *,
                 columns: Optional[Dict[str, np.ndarray]] = None):
        if columns is not None and records is not None:
            raise ValueError("pass records= or columns=, not both")
        self._cols = columns
        self._rows = None if columns is not None else list(records or [])
        self._n = (len(next(iter(columns.values()))) if columns
                   else len(self._rows))
        self.waveforms = waveforms

    # -- record materialization ---------------------------------------------

    def _row(self, i: int) -> Dict:
        if self._rows is not None:
            return self._rows[i]
        rec = {k: _to_py(col[i]) for k, col in self._cols.items()
               if not k.startswith("metrics:")}
        # spec metrics are stored as numeric side columns (NaN = this
        # record's spec did not measure that key); the per-record dict
        # materializes here, not in the store
        rec["metrics"] = {k[8:]: _to_py(col[i])
                          for k, col in self._cols.items()
                          if k.startswith("metrics:")
                          and not np.isnan(col[i])}
        return rec

    @property
    def records(self) -> List[Dict]:
        """All records as plain dicts (materialized from the columns on
        first access — the O(records) dict cost is only paid by callers
        that ask for it).  The returned list becomes the authoritative
        storage, like the old list-of-dicts field: callers that mutate
        it see coherent ``len``/``filter``/iteration afterwards."""
        if self._rows is None:
            self._rows = [self._row(i) for i in range(self._n)]
            self._cols = None
        return self._rows

    def _field(self, name: str):
        """One field's values across records, without building dicts."""
        if self._rows is not None:
            return [r.get(name) for r in self._rows]
        col = self._cols.get(name)
        if col is None:
            if name == "metrics":
                m = {k[8:]: c for k, c in self._cols.items()
                     if k.startswith("metrics:")}
                if m:
                    return [{mk: _to_py(c[i]) for mk, c in m.items()
                             if not np.isnan(c[i])}
                            for i in range(len(self))]
            return [None] * len(self)
        return col

    def _subset(self, keep: Sequence[int]) -> "StudyResult":
        if self._rows is not None:
            return StudyResult([self._rows[i] for i in keep], self.waveforms)
        idx = np.asarray(keep, dtype=np.int64)
        return StudyResult(columns={k: col[idx]
                                    for k, col in self._cols.items()},
                           waveforms=self.waveforms)

    def __len__(self) -> int:
        return len(self._rows) if self._rows is not None else self._n

    def __iter__(self) -> Iterator[Dict]:
        return (self._row(i) for i in range(len(self)))

    def __getitem__(self, i: int) -> Dict:
        return self._row(i)

    # -- querying -----------------------------------------------------------

    def filter(self, **where) -> "StudyResult":
        """Records whose field equals the given value (or is contained in
        it, when a list/tuple/set is given): ``filter(workload="moe_3s",
        config=["none", "mpf90"])``."""
        fields = {k: self._field(k) for k in where}
        keep = []
        for i in range(len(self)):
            for k, v in where.items():
                got = _to_py(fields[k][i])
                if isinstance(v, (list, tuple, set, frozenset)):
                    if got not in v:
                        break
                elif got != v:
                    break
            else:
                keep.append(i)
        return self._subset(keep)

    def passing(self) -> "StudyResult":
        ok = self._field("spec_ok")
        return self._subset([i for i in range(len(self)) if ok[i]])

    def failing(self) -> "StudyResult":
        ok = self._field("spec_ok")
        return self._subset([i for i in range(len(self)) if ok[i] is False])

    def unique(self, field: str) -> List:
        seen: Dict = {}
        for v in self._field(field):
            seen.setdefault(_to_py(v), None)
        return list(seen)

    def best(self, by: str = "energy_overhead",
             among_passing: bool = True) -> Optional[Dict]:
        """The minimal-``by`` record (among spec-passing ones by default)."""
        pool = self.passing() if among_passing else self
        if not len(pool):
            return None
        vals = pool._field(by)
        return pool._row(int(np.argmin([_to_py(v) for v in vals])))

    def passing_configs(self, **where) -> List[str]:
        """Config names every matching scenario of which passes its spec,
        ordered by worst-case energy overhead (the serve-path answer)."""
        sub = self.filter(**where)
        configs, oks = sub._field("config"), sub._field("spec_ok")
        overheads = sub._field("energy_overhead")
        worst: Dict[str, float] = {}
        ok: Dict[str, bool] = {}
        for i in range(len(sub)):
            c = configs[i]
            ok[c] = ok.get(c, True) and bool(oks[i])
            worst[c] = max(worst.get(c, -np.inf), overheads[i])
        return sorted((c for c, good in ok.items() if good),
                      key=lambda c: worst[c])

    def pivot(self, index: str, columns: str,
              values: str = "spec_ok") -> Dict:
        """Nested dict table: ``pivot("workload", "config",
        "energy_overhead")[w][c]``.  Cells with several matching records
        keep the first (slice with ``filter`` for one record per cell)."""
        idx_v, col_v = self._field(index), self._field(columns)
        val_v = self._field(values)
        out: Dict = {}
        for i in range(len(self)):
            out.setdefault(_to_py(idx_v[i]), {}).setdefault(
                _to_py(col_v[i]), _to_py(val_v[i]))
        return out

    # -- export -------------------------------------------------------------

    def table(self, columns: Optional[Sequence[str]] = None) -> str:
        """Records as a markdown table (spec verdicts rendered PASS/fail)."""
        if not self.records:
            return "(no records)"
        columns = list(columns or [
            "workload", "n_chips", "config", "spec", "seed", "swing_mw",
            "swing_mitigated_mw", "energy_overhead", "spec_ok"])

        def cell(r, c):
            v = r.get(c)
            if c == "spec_ok" and v is not None:
                return "PASS" if v else ",".join(r["violations"]) or "FAIL"
            if isinstance(v, float):
                return f"{v:.4g}"
            return str(v)

        lines = ["| " + " | ".join(columns) + " |",
                 "|" + "---|" * len(columns)]
        lines += ["| " + " | ".join(cell(r, c) for c in columns) + " |"
                  for r in self.records]
        return "\n".join(lines)

    def to_records(self) -> List[Dict]:
        """JSON-safe copies (tuples -> lists) of every record."""
        return json.loads(self.to_json())

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.records, indent=2, default=list)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text + "\n")
        return text

    def to_csv(self, path: Optional[str] = None) -> str:
        """Scalar record fields as CSV (nested metric dicts are flattened
        with a ``metrics.`` prefix)."""
        import csv

        rows = []
        for r in self.records:
            flat = {k: v for k, v in r.items()
                    if not isinstance(v, (dict, tuple, list))}
            flat["violations"] = ";".join(r.get("violations", ()))
            for k, v in r.get("metrics", {}).items():
                flat[f"metrics.{k}"] = v
            rows.append(flat)
        fields = list(dict.fromkeys(k for row in rows for k in row))
        buf = io.StringIO()
        writer = csv.DictWriter(buf, fieldnames=fields)
        writer.writeheader()
        writer.writerows(rows)
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text)
        return text

    def sim_result(self, row: int) -> SimResult:
        """Rebuild the per-row ``SimResult`` waveform view (requires the
        study to have been run with ``keep_waveforms=True``)."""
        if self.waveforms is None:
            raise ValueError("run the Study with keep_waveforms=True")
        w = self.waveforms[row]
        rec = next(r for r in self.records if r["row"] == row)
        return SimResult(
            t=w["t"], dc_raw=w["dc_raw"], dc_mitigated=w["dc_mitigated"],
            chip_raw=None, chip_mitigated=None,
            energy_overhead=rec["energy_overhead"],
            swing={}, swing_mitigated={}, bands={}, bands_mitigated={},
            spec_report=None, aux={})

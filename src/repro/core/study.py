"""Declarative Study API: declare scenario axes once, run the grid as a
handful of compiled calls, query the results.

This is the public surface over the batched engine (``core/engine.py``).
A ``Study`` declares its axes — workloads (iteration timelines), fleet
sizes, mitigation configs (disabled/None entries are first-class: the
unmitigated baseline batches with everything else), utility specs, and
jitter seeds — and ``run()`` compiles the cartesian grid down to
``engine.simulate_batch`` + ``engine.analyze_batch``:

  study = Study(
      workloads={"dense_2s": synthetic_timeline(2.0, 0.19),
                 "moe_3s": synthetic_timeline(3.0, 0.25, moe_notch=True)},
      fleets=[256, 512],
      configs={"none": None, "mpf90+bat": (gpu, battery)},
      specs=example_specs(job_mw=100.0),
      seeds=[0, 1],
      key=0)
  result = study.run()
  result.passing().pivot("workload", "config", "energy_overhead")

Three scale levers live in this layer:

* **Keyed randomness** — every pipeline row gets its own PRNG key
  (``fold_in(root, row)``), threaded into mitigations that consume
  randomness (telemetry noise), so noisy-telemetry sweeps see independent
  draws and the same Study with the same root key is bit-reproducible.
* **Pad-and-mask fusion** — mixed-length workloads fuse into ONE compiled
  pipeline call per mitigation-structure group (edge-padded + masked,
  exact in the valid region); the frequency/spec analysis then runs per
  true length.  ``padding="auto"`` picks this whenever lengths are mixed;
  ``"bucket"`` keeps the one-call-per-length behavior.
* **Scenario-axis sharding** — ``shard_devices=True`` spreads the batch
  across every local device (no-op on single-device hosts).

Results come back as a ``StudyResult``: one flat record per scenario with
filter / pivot / export helpers, plus per-row ``SimResult`` access.  The
spec axis is deduplicated against the pipeline: physics runs once per
(workload, fleet, config, seed) row, each spec then judges every row.

Beyond judging *declared* configs, ``Study.optimize()`` runs the engine's
``design`` solver (grid / gradient / hybrid) per (workload, fleet, spec)
cell and returns the solved configurations as ``designed=True`` records
in the same schema — ``result.filter(designed=True)`` separates them.
"""
from __future__ import annotations

import dataclasses
import io
import json
from typing import (Dict, Iterator, List, Mapping, Optional, Sequence,
                    Tuple, Union)

import jax
import numpy as np

from repro.core.engine import (BatchResult, analyze_batch, design,
                               simulate_batch)
from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import IterationTimeline
from repro.core.smoothing.base import Mitigation
from repro.core.spec import UtilitySpec, report_from_arrays
from repro.core.spectrum import critical_band_report
from repro.core.waveform import (WaveformConfig, aggregate, chip_waveform,
                                 phase_levels)
from repro.core.stratosim import SimResult

PADDING_MODES = ("auto", "pad", "bucket")


# ---------------------------------------------------------------------------
# axis declarations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MitigationConfig:
    """One named point on the mitigation axis.  Either stage may be None;
    the fully-disabled config is the unmitigated baseline."""
    name: str
    device: Optional[Mitigation] = None
    rack: Optional[Mitigation] = None

    @property
    def enabled(self) -> bool:
        return self.device is not None or self.rack is not None


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One fully-resolved cell of the study grid (records align by
    ``index``; ``row`` is the pipeline row — shared across the spec axis,
    and the input to ``Study.scenario_key``)."""
    index: int
    row: int
    workload: str
    n_chips: int
    config: MitigationConfig
    spec_name: Optional[str]
    spec: Optional[UtilitySpec]
    seed: int


def _one_config(name: str, entry) -> MitigationConfig:
    if entry is None:
        return MitigationConfig(name)
    if isinstance(entry, MitigationConfig):
        return entry if entry.name == name else dataclasses.replace(entry,
                                                                    name=name)
    if isinstance(entry, (tuple, list)) and len(entry) == 2:
        return MitigationConfig(name, device=entry[0], rack=entry[1])
    raise TypeError(
        f"config {name!r}: expected None, MitigationConfig, or a "
        f"(device_mitigation, rack_mitigation) pair, got {type(entry).__name__}"
        " — a bare mitigation is ambiguous between the per-chip device stage"
        " and the aggregate rack stage")


def _as_configs(configs) -> List[MitigationConfig]:
    if configs is None:
        return [MitigationConfig("none")]
    if isinstance(configs, MitigationConfig):
        return [configs]
    if isinstance(configs, Mapping):
        return [_one_config(name, entry) for name, entry in configs.items()]
    out = []
    for i, entry in enumerate(configs):
        default = "none" if entry is None else f"config{i}"
        name = entry.name if isinstance(entry, MitigationConfig) else default
        out.append(_one_config(name, entry))
    return out


def _as_workloads(workloads) -> Dict[str, IterationTimeline]:
    if isinstance(workloads, IterationTimeline):
        return {"workload0": workloads}
    if isinstance(workloads, Mapping):
        return dict(workloads)
    return {f"workload{i}": tl for i, tl in enumerate(workloads)}


def _as_specs(specs) -> List[Tuple[Optional[str], Optional[UtilitySpec]]]:
    if specs is None:
        return [(None, None)]
    if isinstance(specs, UtilitySpec):
        return [(specs.name, specs)]
    if isinstance(specs, Mapping):
        return [(name, s) for name, s in specs.items()]
    return [(s.name, s) for s in specs]


def _as_seq(x) -> list:
    return list(x) if isinstance(x, (list, tuple)) else [x]


# ---------------------------------------------------------------------------
# the study
# ---------------------------------------------------------------------------

class Study:
    """A declared scenario grid; ``run()`` compiles it to the engine.

    Axes (each a singleton or a collection):
      workloads  name -> IterationTimeline (dict, sequence, or one timeline)
      fleets     chip counts
      configs    name -> None | MitigationConfig | (device, rack) pair
      specs      None | UtilitySpec | dict name -> spec | sequence
      seeds      jitter seeds (numpy side: per-chip phase jitter draws)

    ``key`` is the PRNG root for mitigation randomness (telemetry noise):
    pipeline row ``r`` draws from ``fold_in(PRNGKey(key), r)``.  ``None``
    reverts to the legacy shared-draw behavior.  ``padding`` and
    ``shard_devices`` select the scale levers (see module docstring).
    """

    def __init__(self, workloads, *,
                 fleets: Union[int, Sequence[int]] = (512,),
                 configs=None, specs=None,
                 seeds: Union[int, Sequence[int]] = (0,),
                 wave_cfg: Optional[WaveformConfig] = None,
                 hw: Hardware = DEFAULT_HW,
                 key: Union[int, jax.Array, None] = 0,
                 padding: str = "auto",
                 shard_devices: bool = False,
                 sample_chips: int = 64,
                 keep_waveforms: bool = False):
        if padding not in PADDING_MODES:
            raise ValueError(f"padding must be one of {PADDING_MODES}")
        self.workloads = _as_workloads(workloads)
        self.fleets = [int(n) for n in _as_seq(fleets)]
        self.configs = _as_configs(configs)
        self.specs = _as_specs(specs)
        self.seeds = [int(s) for s in _as_seq(seeds)]
        self.wave_cfg = wave_cfg or WaveformConfig()
        self.hw = hw
        self.key = key
        self.padding = padding
        self.shard_devices = shard_devices
        self.sample_chips = sample_chips
        self.keep_waveforms = keep_waveforms
        names = [c.name for c in self.configs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate config names: {names}")

    # -- declaration accessors ----------------------------------------------

    @property
    def n_rows(self) -> int:
        """Pipeline rows: the grid without the (physics-free) spec axis."""
        return (len(self.workloads) * len(self.fleets) * len(self.configs)
                * len(self.seeds))

    def __len__(self) -> int:
        return self.n_rows * len(self.specs)

    def rows(self) -> List[Tuple[str, int, MitigationConfig, int]]:
        """Pipeline rows in study order: workload-major, then fleet,
        config, seed."""
        return [(w, n, c, s)
                for w in self.workloads for n in self.fleets
                for c in self.configs for s in self.seeds]

    def scenarios(self) -> List[Scenario]:
        out = []
        for r, (w, n, c, s) in enumerate(self.rows()):
            for sn, sp in self.specs:
                out.append(Scenario(index=len(out), row=r, workload=w,
                                    n_chips=n, config=c, spec_name=sn,
                                    spec=sp, seed=s))
        return out

    def scenario_key(self, row: int) -> Optional[jax.Array]:
        """The PRNG key pipeline row ``row`` draws mitigation randomness
        from (the serial parity reference passes this to ``simulate``)."""
        if self.key is None:
            return None
        root = (self.key if isinstance(self.key, jax.Array)
                else jax.random.PRNGKey(int(self.key)))
        return jax.random.fold_in(root, row)

    def describe(self) -> str:
        lens = sorted({len(phase_levels(tl, self.wave_cfg, self.hw))
                       for tl in self.workloads.values()})
        return (f"Study: {len(self.workloads)} workloads x "
                f"{len(self.fleets)} fleets x {len(self.configs)} configs x "
                f"{len(self.seeds)} seeds = {self.n_rows} scenarios "
                f"({len(self.specs)} specs -> {len(self)} records); "
                f"waveform lengths {lens}, padding={self.padding}")

    # -- execution ----------------------------------------------------------

    def run(self, *, padding: Optional[str] = None) -> "StudyResult":
        """Run the whole grid: one fused pipeline call per mitigation
        *structure* group (padded) — or one per (structure, length) when
        bucketed — then one analysis call per (length, spec) group."""
        cfg, hw = self.wave_cfg, self.hw
        mode = padding or self.padding
        if mode not in PADDING_MODES:
            raise ValueError(f"padding must be one of {PADDING_MODES}")
        levels = {w: phase_levels(tl, cfg, hw)
                  for w, tl in self.workloads.items()}
        rows = self.rows()
        row_len = [len(levels[w]) for w, _, _, _ in rows]
        if mode == "auto":
            mode = "pad" if len(set(row_len)) > 1 else "bucket"
        keys = ([self.scenario_key(r) for r in range(len(rows))]
                if self.key is not None else None)

        # pipeline: rowdata[r] = (BatchResult, index within it).  Rows are
        # first grouped by mitigation *structure* (a GPU-floor grid and a
        # Firefly grid cannot stack into one batched pytree; disabled rows
        # join any group), then pad mode fuses each structure group's
        # mixed lengths into one call while bucket mode adds a call per
        # length.  Waveforms stay on device (host_arrays=False) — the
        # analysis stage slices them straight into its own jit without a
        # host round-trip; only the small per-row metric arrays are
        # materialized here.
        rowdata: List[Tuple[BatchResult, int]] = [None] * len(rows)
        for sg_rows in self._structure_groups(rows):
            if mode == "pad":
                calls = [sg_rows]
            else:
                by_len: Dict[int, List[int]] = {}
                for r in sg_rows:
                    by_len.setdefault(row_len[r], []).append(r)
                calls = [idx for _, idx in sorted(by_len.items())]
            for idx in calls:
                lens = {row_len[r] for r in idx}
                res = self._simulate(
                    [rows[r] for r in idx], levels,
                    None if keys is None else [keys[r] for r in idx],
                    pad_to=max(lens) if len(lens) > 1 else None)
                self._materialize_metrics(res)
                for b, r in enumerate(idx):
                    rowdata[r] = (res, b)

        # analysis: one vmapped call per (pipeline call, length, spec)
        # group, on the rows sliced back to their true length.  Bands are
        # spec-independent, so only the first spec of each group computes
        # them.
        analysis = [[None] * len(self.specs) for _ in rows]
        groups: Dict[Tuple[int, int], List[int]] = {}
        for r, L in enumerate(row_len):
            groups.setdefault((id(rowdata[r][0]), L), []).append(r)
        for (_, L), idx in sorted(groups.items()):
            res = rowdata[idx[0]][0]
            sel = np.asarray([rowdata[r][1] for r in idx])
            mit = res.dc_mitigated[sel][:, :L]
            for si, (_, sp) in enumerate(self.specs):
                # records only consume mitigated bands -> dc_raw=None skips
                # the raw-band FFT per row
                a = analyze_batch(None, mit, cfg.dt, sp, bands=(si == 0))
                for b, r in enumerate(idx):
                    analysis[r][si] = jax.tree.map(lambda v: v[b], a)

        return self._assemble(rows, row_len, rowdata, analysis)

    def optimize(self, *, method: str = "hybrid",
                 seed: Optional[int] = None,
                 **design_kwargs) -> "StudyResult":
        """Run a mitigation *design* per (workload, fleet, spec) cell.

        Where ``run()`` judges the study's declared configs, ``optimize()``
        asks the engine's ``design`` solver (method = "grid" | "gradient" |
        "hybrid") for a minimal-overhead (MPF, battery) configuration that
        passes each declared spec, and returns one record per cell with
        ``designed=True`` — the same record schema as ``run()`` (so
        designed rows query/pivot/export alongside declared ones via
        ``filter(designed=True)``) plus the solved ``mpf_frac`` /
        ``battery_capacity_j``.  Cells with no feasible design come back
        as ``spec_ok=False`` with ``violations=("infeasible",)``.

        ``seed`` picks the jitter draw the design waveform uses (default:
        the study's first seed).  Extra keyword arguments flow to
        ``engine.design`` (``steps``, ``smooth_tau``, ``top_k``, ...).
        """
        cfg, hw = self.wave_cfg, self.hw
        seed = self.seeds[0] if seed is None else int(seed)
        records: List[Dict] = []
        for wname, tl in self.workloads.items():
            chip = chip_waveform(tl, cfg, hw)
            for n_chips in self.fleets:
                w = aggregate(chip, n_chips, cfg, hw, seed=seed,
                              sample_chips=self.sample_chips)
                for spec_name, spec in self.specs:
                    if spec is None:
                        continue
                    sol = design(spec, w, cfg.dt, n_chips, method=method,
                                 hw=hw, **design_kwargs)
                    rec = {
                        "index": len(records),
                        "row": -1,           # no pipeline row backs a design
                        "workload": wname,
                        "n_chips": n_chips,
                        "config": f"designed[{method}]",
                        "spec": spec_name,
                        "seed": seed,
                        "period_s": float(tl.period_s),
                        "n_samples": len(w),
                        "mean_mw": float(np.mean(w)) / 1e6,
                        "swing_mw": float(w.max() - w.min()) / 1e6,
                        "designed": True,
                    }
                    if sol is None:
                        rec.update({
                            "swing_mitigated_mw": rec["swing_mw"],
                            "energy_overhead": 0.0,
                            "paper_band_frac": None,
                            "spec_ok": False,
                            "violations": ("infeasible",),
                            "metrics": {},
                            "mpf_frac": None,
                            "battery_capacity_j": None,
                        })
                    else:
                        mit = np.asarray(sol["mitigated"])
                        rec.update({
                            "swing_mitigated_mw":
                                float(mit.max() - mit.min()) / 1e6,
                            "energy_overhead": float(sol["energy_overhead"]),
                            "paper_band_frac": float(critical_band_report(
                                mit, cfg.dt)["paper_band_0p2_3hz"]),
                            "spec_ok": sol["report"].ok,
                            "violations": sol["report"].violations,
                            "metrics": sol["report"].metrics,
                            "mpf_frac": sol["mpf_frac"],
                            "battery_capacity_j": sol["battery_capacity_j"],
                        })
                    records.append(rec)
        return StudyResult(records=records)

    @staticmethod
    def _structure_groups(rows) -> List[List[int]]:
        """Row indices grouped by (device, rack) pytree structure.  A None
        stage is a wildcard: baseline rows batch with the first concrete
        structure (the engine masks them off row-wise)."""
        def struct(m):
            return None if m is None else jax.tree.structure(m)

        dev_first = next((struct(c.device) for _, _, c, _ in rows
                          if c.device is not None), None)
        rack_first = next((struct(c.rack) for _, _, c, _ in rows
                           if c.rack is not None), None)
        groups: Dict[Tuple, List[int]] = {}
        for r, (_, _, c, _) in enumerate(rows):
            k = (struct(c.device) if c.device is not None else dev_first,
                 struct(c.rack) if c.rack is not None else rack_first)
            groups.setdefault(k, []).append(r)
        return list(groups.values())

    def _simulate(self, rows, levels, keys, pad_to=None) -> BatchResult:
        return simulate_batch(
            [self.workloads[w] for w, _, _, _ in rows],
            [n for _, n, _, _ in rows],
            self.wave_cfg,
            device_mitigation=[c.device for _, _, c, _ in rows],
            rack_mitigation=[c.rack for _, _, c, _ in rows],
            spec=None, hw=self.hw,
            seeds=[s for _, _, _, s in rows],
            keys=keys, sample_chips=self.sample_chips,
            levels=[levels[w] for w, _, _, _ in rows],
            pad_to=pad_to, spectra=False,
            shard_devices=self.shard_devices, dedup=True,
            # chip-level outputs stay on (the default) even though records
            # never read them: dropping them measured consistently SLOWER
            # on CPU XLA (returning chip_m pins a layout the aggregation
            # reuses).  chip_outputs=False remains available for
            # memory-bound grids where O(B*n) waveforms dominate.
            host_arrays=False)

    @staticmethod
    def _materialize_metrics(res: BatchResult) -> None:
        """Pull the small [B]-sized metric arrays to host in one pass (the
        waveforms stay on device for the analysis stage)."""
        res.energy_overhead = np.asarray(res.energy_overhead)
        res.swing = {k: np.asarray(v) for k, v in res.swing.items()}
        res.swing_mitigated = {k: np.asarray(v)
                               for k, v in res.swing_mitigated.items()}

    def _assemble(self, rows, row_len, rowdata, analysis) -> "StudyResult":
        records: List[Dict] = []
        waveforms = [] if self.keep_waveforms else None
        for r, (wname, n_chips, config, seed) in enumerate(rows):
            res, b = rowdata[r]
            L = row_len[r]
            first = analysis[r][0]
            for si, (spec_name, spec) in enumerate(self.specs):
                a = analysis[r][si]
                rec = {
                    "index": len(records),
                    "row": r,
                    "workload": wname,
                    "n_chips": n_chips,
                    "config": config.name,
                    "spec": spec_name,
                    "seed": seed,
                    "period_s": float(self.workloads[wname].period_s),
                    "n_samples": L,
                    "mean_mw": float(res.swing["mean_w"][b]) / 1e6,
                    "swing_mw": float(res.swing["swing_w"][b]) / 1e6,
                    "swing_mitigated_mw":
                        float(res.swing_mitigated["swing_w"][b]) / 1e6,
                    "energy_overhead": float(res.energy_overhead[b]),
                    "paper_band_frac":
                        float(first["bands_mitigated"]["paper_band_0p2_3hz"]),
                    "designed": False,
                }
                if spec is not None:
                    report = report_from_arrays(
                        a["spec_ok"], a["spec_flags"], a["spec_metrics"])
                    rec["spec_ok"] = report.ok
                    rec["violations"] = report.violations
                    rec["metrics"] = report.metrics
                else:
                    rec["spec_ok"] = None
                    rec["violations"] = ()
                    rec["metrics"] = {}
                records.append(rec)
            if waveforms is not None:
                waveforms.append({
                    "t": np.asarray(res.t[:L]),
                    "dc_raw": np.asarray(res.dc_raw[b, :L]),
                    "dc_mitigated": np.asarray(res.dc_mitigated[b, :L]),
                })
        return StudyResult(records=records, waveforms=waveforms)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StudyResult:
    """Flat scenario records with query helpers.

    Each record is one (workload, fleet, config, seed, spec) cell:
    identity fields, swing/overhead/band metrics, and — when a spec was
    declared — ``spec_ok`` / ``violations`` / the spec's metric dict.
    ``designed`` distinguishes ``Study.optimize()`` records (solved
    configurations, carrying ``mpf_frac``/``battery_capacity_j``) from
    ``run()`` records (declared configurations); ``filter(designed=True)``
    selects them.  ``waveforms`` (when the study kept them) is indexed by
    ``record["row"]``.
    """
    records: List[Dict]
    waveforms: Optional[List[Dict]] = None

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self) -> Iterator[Dict]:
        return iter(self.records)

    def __getitem__(self, i: int) -> Dict:
        return self.records[i]

    # -- querying -----------------------------------------------------------

    def filter(self, **where) -> "StudyResult":
        """Records whose field equals the given value (or is contained in
        it, when a list/tuple/set is given): ``filter(workload="moe_3s",
        config=["none", "mpf90"])``."""
        def match(r):
            for k, v in where.items():
                got = r.get(k)
                if isinstance(v, (list, tuple, set, frozenset)):
                    if got not in v:
                        return False
                elif got != v:
                    return False
            return True

        return StudyResult([r for r in self.records if match(r)],
                           self.waveforms)

    def passing(self) -> "StudyResult":
        return StudyResult([r for r in self.records if r["spec_ok"]],
                           self.waveforms)

    def failing(self) -> "StudyResult":
        return StudyResult([r for r in self.records
                            if r["spec_ok"] is False], self.waveforms)

    def unique(self, field: str) -> List:
        seen: Dict = {}
        for r in self.records:
            seen.setdefault(r.get(field), None)
        return list(seen)

    def best(self, by: str = "energy_overhead",
             among_passing: bool = True) -> Optional[Dict]:
        """The minimal-``by`` record (among spec-passing ones by default)."""
        pool = self.passing().records if among_passing else self.records
        return min(pool, key=lambda r: r[by]) if pool else None

    def passing_configs(self, **where) -> List[str]:
        """Config names every matching scenario of which passes its spec,
        ordered by worst-case energy overhead (the serve-path answer)."""
        sub = self.filter(**where)
        worst: Dict[str, float] = {}
        ok: Dict[str, bool] = {}
        for r in sub.records:
            c = r["config"]
            ok[c] = ok.get(c, True) and bool(r["spec_ok"])
            worst[c] = max(worst.get(c, -np.inf), r["energy_overhead"])
        return sorted((c for c, good in ok.items() if good),
                      key=lambda c: worst[c])

    def pivot(self, index: str, columns: str,
              values: str = "spec_ok") -> Dict:
        """Nested dict table: ``pivot("workload", "config",
        "energy_overhead")[w][c]``.  Cells with several matching records
        keep the first (slice with ``filter`` for one record per cell)."""
        out: Dict = {}
        for r in self.records:
            out.setdefault(r[index], {}).setdefault(r[columns], r[values])
        return out

    # -- export -------------------------------------------------------------

    def table(self, columns: Optional[Sequence[str]] = None) -> str:
        """Records as a markdown table (spec verdicts rendered PASS/fail)."""
        if not self.records:
            return "(no records)"
        columns = list(columns or [
            "workload", "n_chips", "config", "spec", "seed", "swing_mw",
            "swing_mitigated_mw", "energy_overhead", "spec_ok"])

        def cell(r, c):
            v = r.get(c)
            if c == "spec_ok" and v is not None:
                return "PASS" if v else ",".join(r["violations"]) or "FAIL"
            if isinstance(v, float):
                return f"{v:.4g}"
            return str(v)

        lines = ["| " + " | ".join(columns) + " |",
                 "|" + "---|" * len(columns)]
        lines += ["| " + " | ".join(cell(r, c) for c in columns) + " |"
                  for r in self.records]
        return "\n".join(lines)

    def to_records(self) -> List[Dict]:
        """JSON-safe copies (tuples -> lists) of every record."""
        return json.loads(self.to_json())

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.records, indent=2, default=list)
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text + "\n")
        return text

    def to_csv(self, path: Optional[str] = None) -> str:
        """Scalar record fields as CSV (nested metric dicts are flattened
        with a ``metrics.`` prefix)."""
        import csv

        rows = []
        for r in self.records:
            flat = {k: v for k, v in r.items()
                    if not isinstance(v, (dict, tuple, list))}
            flat["violations"] = ";".join(r.get("violations", ()))
            for k, v in r.get("metrics", {}).items():
                flat[f"metrics.{k}"] = v
            rows.append(flat)
        fields = list(dict.fromkeys(k for row in rows for k in row))
        buf = io.StringIO()
        writer = csv.DictWriter(buf, fieldnames=fields)
        writer.writeheader()
        writer.writerows(rows)
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as fh:
                fh.write(text)
        return text

    def sim_result(self, row: int) -> SimResult:
        """Rebuild the per-row ``SimResult`` waveform view (requires the
        study to have been run with ``keep_waveforms=True``)."""
        if self.waveforms is None:
            raise ValueError("run the Study with keep_waveforms=True")
        w = self.waveforms[row]
        rec = next(r for r in self.records if r["row"] == row)
        return SimResult(
            t=w["t"], dc_raw=w["dc_raw"], dc_mitigated=w["dc_mitigated"],
            chip_raw=None, chip_mitigated=None,
            energy_overhead=rec["energy_overhead"],
            swing={}, swing_mitigated={}, bands={}, bands_mitigated={},
            spec_report=None, aux={})

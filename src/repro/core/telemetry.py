"""In-band power/activity telemetry emulation (paper Sec. IV-A Monitoring).

Datacenter GPUs expose instantaneous/averaged power at 1-100 ms minimum
latency depending on counter reliability; the controllers consume this
class so the latency/period trade-off is first-class in every simulation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TelemetrySource:
    period_s: float = 0.001     # sampling period (1 ms fast counters)
    latency_s: float = 0.002    # read-out latency
    noise_w: float = 0.0
    quantization_w: float = 1.0
    averaged: bool = False      # True = boxcar average over period

    def measure(self, w: np.ndarray, dt: float, seed: int = 0) -> np.ndarray:
        """Sampled+delayed view of true power w (same length, ZOH)."""
        n = len(w)
        k = max(int(round(self.period_s / dt)), 1)
        lag = int(round(self.latency_s / dt))
        if self.averaged and k > 1:
            kernel = np.ones(k) / k
            base = np.convolve(w, kernel, mode="full")[:n]
        else:
            base = w
        idx = (np.arange(n) // k) * k          # zero-order hold at samples
        m = base[np.clip(idx - lag, 0, n - 1)]
        if self.noise_w > 0:
            rng = np.random.default_rng(seed)
            m = m + rng.normal(0.0, self.noise_w, size=n)
        if self.quantization_w > 0:
            m = np.round(m / self.quantization_w) * self.quantization_w
        return m

    def measure_jax(self, w: jnp.ndarray, dt: float,
                    key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Pure traced mirror of ``measure`` for the jit/vmap engine.

        Sampling indices are static (period/latency/dt are config);
        noise, when enabled, draws from ``key`` instead of a numpy rng.
        NOTE: without an explicit ``key`` the noise vector is a fixed
        PRNGKey(0) draw — identical across calls and batch rows; thread a
        per-scenario key when sweeping noisy-telemetry configs.
        """
        n = w.shape[-1]
        k = max(int(round(self.period_s / dt)), 1)
        lag = int(round(self.latency_s / dt))
        if self.averaged and k > 1:
            kernel = jnp.ones(k, jnp.float32) / k
            base = jnp.convolve(w, kernel, mode="full")[:n]
        else:
            base = w
        idx = np.clip((np.arange(n) // k) * k - lag, 0, n - 1)
        m = base[idx]
        if self.noise_w > 0:
            key = jax.random.PRNGKey(0) if key is None else key
            m = m + self.noise_w * jax.random.normal(key, (n,), jnp.float32)
        if self.quantization_w > 0:
            m = jnp.round(m / self.quantization_w) * self.quantization_w
        return m

"""In-band power/activity telemetry emulation (paper Sec. IV-A Monitoring).

Datacenter GPUs expose instantaneous/averaged power at 1-100 ms minimum
latency depending on counter reliability; the controllers consume this
class so the latency/period trade-off is first-class in every simulation.

This module also holds the *shared monitor gating* helpers — the warm-up
denominator ramp (``warmup_scale``) and the sustain/cooldown escalation
state machine (``escalation_init`` / ``escalation_step``) — extracted
from the telemetry backstop so the offline monitor
(``TelemetryBackstop``, ``kernels/goertzel/ops.sliding_bin_power``) and
the online control-plane detector (``repro.control``) run the exact same
gating math and cannot drift.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# shared monitor gating: warm-up ramp + escalation state machine
# ---------------------------------------------------------------------------

def warmup_scale(idx, win: int) -> jnp.ndarray:
    """The sliding monitor's warm-up renormalization ``win / min(i+1, win)``.

    The kernel normalizes every output by ``2/win``; outputs before one
    full window has streamed (``i < win - 1``) are partial-window
    estimates and rescale to their true sample count.  ``idx`` is the
    global sample index (any integer/float dtype); shared by the offline
    ``sliding_bin_power`` paths and the online chunked detector so the
    two ramps are bit-identical.
    """
    denom = jnp.minimum(jnp.asarray(idx, jnp.float32) + 1.0, float(win))
    return float(win) / denom


def escalation_init() -> Tuple[jnp.ndarray, ...]:
    """Initial ``(level, above, below, detect)`` escalation carry."""
    zero = jnp.asarray(0, jnp.int32)
    return (zero, zero, zero, jnp.asarray(-1, jnp.int32))


def escalation_step(carry, amp, idx, *, threshold, win: int, n: int,
                    sustain_n: int, cool_n: int, max_level: int = 3,
                    release=None):
    """One step of the threshold-with-hysteresis escalation state machine.

    ``carry`` is ``(level, above, below, detect)`` from
    ``escalation_init``; ``amp`` the monitored amplitude at global sample
    index ``idx``.  Triggering is warm-up gated (no escalation off
    partial-window estimates, ``idx >= win - 1``) and pad-gated
    (``idx < n``).  ``amp > threshold`` sustained for ``sustain_n`` steps
    escalates one level (up to ``max_level``); staying at or below
    ``release`` (default: ``threshold`` — the backstop's exact historical
    behavior) for ``cool_n`` steps de-escalates one level.  ``detect``
    latches the first escalation index.  Pure jnp, so it runs identically
    inside the backstop's ``lax.scan`` and eagerly in the control plane's
    per-tick loop.
    """
    cls = escalation_classify(amp, idx, threshold=threshold, win=win, n=n,
                              release=release)
    return escalation_class_step(carry, cls, idx, sustain_n=sustain_n,
                                 cool_n=cool_n, max_level=max_level)


#: escalation sample classes: the amp -> decision reduction the fused
#: monitor kernel emits instead of amplitudes.  CLS_PAD is an identity
#: transition (used to pad partial blocks in ``escalation_scan``).
CLS_CLEAR, CLS_BAND, CLS_HIT, CLS_PAD = 0, 1, 2, 3


def escalation_classify(amp, idx, *, threshold, win: int, n,
                        release=None):
    """Reduce an amplitude sample to its escalation class (int8).

    ``CLS_HIT`` (2): above trigger and live; ``CLS_CLEAR`` (0): at/below
    release or not live (warm-up ``idx < win - 1`` / pad ``idx >= n``);
    ``CLS_BAND`` (1): in the hysteresis band.  This is the *only* place
    amplitudes enter the escalation machine — the state transition
    itself (``escalation_class_step`` / ``escalation_scan``) consumes
    classes, so the fused monitor kernel can classify in VMEM and never
    materialize per-sample amplitudes.  Requires ``release <= threshold``
    (hit and clear must be exclusive; the default ``release=None`` means
    ``release == threshold``).
    """
    live = (idx >= win - 1) & (idx < n)
    hit = (amp > threshold) & live
    rel = threshold if release is None else release
    clear = ~((amp > rel) & live)
    band = jnp.logical_and(~hit, ~clear)
    return (2 * hit.astype(jnp.int32)
            + band.astype(jnp.int32)).astype(jnp.int8)


def escalation_class_step(carry, cls, idx, *, sustain_n: int, cool_n: int,
                          max_level: int = 3):
    """One escalation transition from a sample *class* (see
    ``escalation_classify``).  ``CLS_PAD`` is the identity transition.
    ``escalation_step`` delegates here, so the amplitude-facing and the
    class-facing machines cannot drift."""
    level, above, below, detect = carry
    hit = cls == CLS_HIT
    clear = cls == CLS_CLEAR
    on = cls != CLS_PAD
    above = jnp.where(hit, above + 1, jnp.where(on, 0, above))
    below = jnp.where(clear, below + 1, jnp.where(on, 0, below))
    esc = hit & (above >= sustain_n) & (level < max_level)
    detect = jnp.where(esc & (detect < 0), idx, detect)
    level = jnp.where(esc, level + 1, level)
    above = jnp.where(esc, 0, above)
    deesc = clear & (below >= cool_n) & (level > 0)
    level = jnp.where(deesc, level - 1, level)
    below = jnp.where(deesc, 0, below)
    return (level, above, below, detect), level


@functools.partial(jax.jit, static_argnames=("sustain_n", "cool_n",
                                             "max_level", "block"))
def escalation_scan(cls, idx0, carry, *, sustain_n: int, cool_n: int,
                    max_level: int = 3, block: int = 512):
    """Run the escalation machine over a class stream in O(n/block)
    sequential steps — bit-identical to folding ``escalation_class_step``
    sample by sample (property-tested in tests/test_control.py).

    The machine's per-sample recurrence is the monitor's real serial
    bottleneck (a trace-length ``lax.scan`` costs ~100x the Goertzel
    kernel at 1e6 samples).  But between class *changes* the transition
    has a closed form: within a homogeneous run the escalation
    candidates sit at ``j1 = max(1, period - counter)`` and every
    ``period`` samples after, of which ``room`` (head-room to
    ``max_level``, or down to 0) are taken.  The scan therefore walks
    fixed ``block``-sample blocks: an all-one-class block applies the
    closed form as a vector expression; a mixed block (a class boundary
    — rare at telemetry rates) falls back to an unrolled inner scan.
    The trailing partial block is padded with ``CLS_PAD`` (identity);
    a homogeneous block with a trailing pad run still takes the closed
    form over its live prefix, so short online chunks (the detector's
    per-tick calls) stay on the fast path.

    ``cls``: int8 classes from ``escalation_classify``; ``idx0``: global
    sample index of ``cls[0]`` (int32) — ``detect`` latches global
    indices, so chunked calls stay bit-identical to one offline call.
    Returns ``(carry', levels [len(cls)])``.
    """
    n = cls.shape[0]
    nb = max(-(-n // block), 1)
    pad = nb * block - n
    if pad:
        cls = jnp.concatenate(
            [cls, jnp.full((pad,), CLS_PAD, cls.dtype)])
    blocks = cls.reshape(nb, block)
    starts = (jnp.asarray(idx0, jnp.int32)
              + block * jnp.arange(nb, dtype=jnp.int32))
    j = jnp.arange(1, block + 1, dtype=jnp.int32)

    def run_form(room, counter, period, m):
        # homogeneous-run closed form over the block's m live samples
        # (trailing pads are the identity): candidate k sits at sample
        # j1 + (k-1)*period (1-indexed); `room` of them are taken, the
        # counter keeps counting past the last taken candidate
        j1 = jnp.maximum(1, period - counter)
        cnt = jnp.where((j >= j1) & (j <= m), 1 + (j - j1) // period, 0)
        e = jnp.minimum(room, jnp.max(cnt))
        taken = jnp.minimum(cnt, room)
        new_counter = jnp.where(e > 0, m - (j1 + (e - 1) * period),
                                counter + m)
        return j1, e, taken, new_counter

    def fast(carry, cb, g, m):
        level, above, below, detect = carry
        c0 = cb[0]
        j1h, eh, takh, ah = run_form(max_level - level, above, sustain_n, m)
        _, ec, takc, bc = run_form(level, below, cool_n, m)
        is_hit = c0 == CLS_HIT
        is_clear = c0 == CLS_CLEAR
        levels = jnp.where(is_hit, level + takh,
                           jnp.where(is_clear, level - takc, level))
        level2 = jnp.where(is_hit, level + eh,
                           jnp.where(is_clear, level - ec, level))
        above2 = jnp.where(is_hit, ah, 0)
        below2 = jnp.where(is_clear, bc, 0)
        detect2 = jnp.where(is_hit & (eh > 0) & (detect < 0),
                            g + j1h - 1, detect)
        return (level2, above2, below2, detect2), levels

    def slow(carry, cb, g, m):
        del m
        idx = g + jnp.arange(block, dtype=jnp.int32)
        return jax.lax.scan(
            lambda c, xi: escalation_class_step(
                c, xi[0], xi[1], sustain_n=sustain_n, cool_n=cool_n,
                max_level=max_level),
            carry, (cb, idx), unroll=min(block, 16))

    def body(carry, inp):
        cb, g = inp
        j0 = jnp.arange(block, dtype=jnp.int32)
        is_pad = cb == CLS_PAD
        m = jnp.sum((~is_pad).astype(jnp.int32))   # live prefix length ...
        trailing = jnp.all(is_pad == (j0 >= m))    # ... if pads all trail
        homog = (trailing & (m > 0)
                 & jnp.all(jnp.where(j0 < m, cb == cb[0], True)))
        return jax.lax.cond(homog, fast, slow, carry, cb, g, m)

    carry, levels = jax.lax.scan(body, carry, (blocks, starts))
    return carry, levels.reshape(-1)[:n]


@dataclasses.dataclass(frozen=True)
class TelemetrySource:
    period_s: float = 0.001     # sampling period (1 ms fast counters)
    latency_s: float = 0.002    # read-out latency
    noise_w: float = 0.0
    quantization_w: float = 1.0
    averaged: bool = False      # True = boxcar average over period

    def measure(self, w: np.ndarray, dt: float, seed: int = 0) -> np.ndarray:
        """Sampled+delayed view of true power w (same length, ZOH)."""
        n = len(w)
        k = max(int(round(self.period_s / dt)), 1)
        lag = int(round(self.latency_s / dt))
        if self.averaged and k > 1:
            kernel = np.ones(k) / k
            base = np.convolve(w, kernel, mode="full")[:n]
        else:
            base = w
        idx = (np.arange(n) // k) * k          # zero-order hold at samples
        m = base[np.clip(idx - lag, 0, n - 1)]
        if self.noise_w > 0:
            rng = np.random.default_rng(seed)
            m = m + rng.normal(0.0, self.noise_w, size=n)
        if self.quantization_w > 0:
            m = np.round(m / self.quantization_w) * self.quantization_w
        return m

    def measure_jax(self, w: jnp.ndarray, dt: float,
                    key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Pure traced mirror of ``measure`` for the jit/vmap engine.

        Sampling indices are static (period/latency/dt are config);
        noise, when enabled, draws from ``key`` instead of a numpy rng.
        NOTE: without an explicit ``key`` the noise vector is a fixed
        PRNGKey(0) draw — identical across calls and batch rows; thread a
        per-scenario key when sweeping noisy-telemetry configs.
        """
        n = w.shape[-1]
        k = max(int(round(self.period_s / dt)), 1)
        lag = int(round(self.latency_s / dt))
        if self.averaged and k > 1:
            kernel = jnp.ones(k, jnp.float32) / k
            base = jnp.convolve(w, kernel, mode="full")[:n]
        else:
            base = w
        idx = np.clip((np.arange(n) // k) * k - lag, 0, n - 1)
        m = base[idx]
        if self.noise_w > 0:
            key = jax.random.PRNGKey(0) if key is None else key
            m = m + self.noise_w * jax.random.normal(key, (n,), jnp.float32)
        if self.quantization_w > 0:
            m = jnp.round(m / self.quantization_w) * self.quantization_w
        return m

"""In-band power/activity telemetry emulation (paper Sec. IV-A Monitoring).

Datacenter GPUs expose instantaneous/averaged power at 1-100 ms minimum
latency depending on counter reliability; the controllers consume this
class so the latency/period trade-off is first-class in every simulation.

This module also holds the *shared monitor gating* helpers — the warm-up
denominator ramp (``warmup_scale``) and the sustain/cooldown escalation
state machine (``escalation_init`` / ``escalation_step``) — extracted
from the telemetry backstop so the offline monitor
(``TelemetryBackstop``, ``kernels/goertzel/ops.sliding_bin_power``) and
the online control-plane detector (``repro.control``) run the exact same
gating math and cannot drift.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# shared monitor gating: warm-up ramp + escalation state machine
# ---------------------------------------------------------------------------

def warmup_scale(idx, win: int) -> jnp.ndarray:
    """The sliding monitor's warm-up renormalization ``win / min(i+1, win)``.

    The kernel normalizes every output by ``2/win``; outputs before one
    full window has streamed (``i < win - 1``) are partial-window
    estimates and rescale to their true sample count.  ``idx`` is the
    global sample index (any integer/float dtype); shared by the offline
    ``sliding_bin_power`` paths and the online chunked detector so the
    two ramps are bit-identical.
    """
    denom = jnp.minimum(jnp.asarray(idx, jnp.float32) + 1.0, float(win))
    return float(win) / denom


def escalation_init() -> Tuple[jnp.ndarray, ...]:
    """Initial ``(level, above, below, detect)`` escalation carry."""
    zero = jnp.asarray(0, jnp.int32)
    return (zero, zero, zero, jnp.asarray(-1, jnp.int32))


def escalation_step(carry, amp, idx, *, threshold, win: int, n: int,
                    sustain_n: int, cool_n: int, max_level: int = 3,
                    release=None):
    """One step of the threshold-with-hysteresis escalation state machine.

    ``carry`` is ``(level, above, below, detect)`` from
    ``escalation_init``; ``amp`` the monitored amplitude at global sample
    index ``idx``.  Triggering is warm-up gated (no escalation off
    partial-window estimates, ``idx >= win - 1``) and pad-gated
    (``idx < n``).  ``amp > threshold`` sustained for ``sustain_n`` steps
    escalates one level (up to ``max_level``); staying at or below
    ``release`` (default: ``threshold`` — the backstop's exact historical
    behavior) for ``cool_n`` steps de-escalates one level.  ``detect``
    latches the first escalation index.  Pure jnp, so it runs identically
    inside the backstop's ``lax.scan`` and eagerly in the control plane's
    per-tick loop.
    """
    level, above, below, detect = carry
    live = (idx >= win - 1) & (idx < n)
    hit = (amp > threshold) & live
    rel = threshold if release is None else release
    clear = ~((amp > rel) & live)
    above = jnp.where(hit, above + 1, 0)
    below = jnp.where(clear, below + 1, 0)
    esc = hit & (above >= sustain_n) & (level < max_level)
    detect = jnp.where(esc & (detect < 0), idx, detect)
    level = jnp.where(esc, level + 1, level)
    above = jnp.where(esc, 0, above)
    deesc = clear & (below >= cool_n) & (level > 0)
    level = jnp.where(deesc, level - 1, level)
    below = jnp.where(deesc, 0, below)
    return (level, above, below, detect), level


@dataclasses.dataclass(frozen=True)
class TelemetrySource:
    period_s: float = 0.001     # sampling period (1 ms fast counters)
    latency_s: float = 0.002    # read-out latency
    noise_w: float = 0.0
    quantization_w: float = 1.0
    averaged: bool = False      # True = boxcar average over period

    def measure(self, w: np.ndarray, dt: float, seed: int = 0) -> np.ndarray:
        """Sampled+delayed view of true power w (same length, ZOH)."""
        n = len(w)
        k = max(int(round(self.period_s / dt)), 1)
        lag = int(round(self.latency_s / dt))
        if self.averaged and k > 1:
            kernel = np.ones(k) / k
            base = np.convolve(w, kernel, mode="full")[:n]
        else:
            base = w
        idx = (np.arange(n) // k) * k          # zero-order hold at samples
        m = base[np.clip(idx - lag, 0, n - 1)]
        if self.noise_w > 0:
            rng = np.random.default_rng(seed)
            m = m + rng.normal(0.0, self.noise_w, size=n)
        if self.quantization_w > 0:
            m = np.round(m / self.quantization_w) * self.quantization_w
        return m

    def measure_jax(self, w: jnp.ndarray, dt: float,
                    key: Optional[jax.Array] = None) -> jnp.ndarray:
        """Pure traced mirror of ``measure`` for the jit/vmap engine.

        Sampling indices are static (period/latency/dt are config);
        noise, when enabled, draws from ``key`` instead of a numpy rng.
        NOTE: without an explicit ``key`` the noise vector is a fixed
        PRNGKey(0) draw — identical across calls and batch rows; thread a
        per-scenario key when sweeping noisy-telemetry configs.
        """
        n = w.shape[-1]
        k = max(int(round(self.period_s / dt)), 1)
        lag = int(round(self.latency_s / dt))
        if self.averaged and k > 1:
            kernel = jnp.ones(k, jnp.float32) / k
            base = jnp.convolve(w, kernel, mode="full")[:n]
        else:
            base = w
        idx = np.clip((np.arange(n) // k) * k - lag, 0, n - 1)
        m = base[idx]
        if self.noise_w > 0:
            key = jax.random.PRNGKey(0) if key is None else key
            m = m + self.noise_w * jax.random.normal(key, (n,), jnp.float32)
        if self.quantization_w > 0:
            m = jnp.round(m / self.quantization_w) * self.quantization_w
        return m

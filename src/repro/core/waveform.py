"""Power-waveform synthesis: phase timeline -> sampled watts.

Reproduces the paper's Fig. 1 structure: per-chip square-ish waves between
near-TDP compute and near-idle communication, EDP overshoot spikes at phase
rises, checkpoint valleys, and rack/DC aggregation with per-chip jitter
(stragglers soften edges at scale, they do not remove the swing — the job
is bulk-synchronous).

Two layers:

* the numpy-facing API (``chip_waveform`` / ``aggregate`` / ``job_waveform``)
  used by existing callers, and
* pure jnp building blocks (``chip_waveform_jax`` / ``aggregate_jax`` /
  ``swing_stats_jax``) that run inside jit/vmap for the batched scenario
  engine (core/engine.py).  The shape-determining timeline->samples
  expansion stays in numpy (``phase_levels``); everything downstream of the
  level array is traceable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import CKPT, COMM, COMPUTE, IDLE, MEMORY, IterationTimeline, Phase

MODE_POWER_ATTR = {COMPUTE: "tdp_w", MEMORY: "hbm_bound_w", COMM: "comm_w",
                   IDLE: "idle_w", CKPT: "comm_w"}


def mode_power(mode: str, hw: Hardware = DEFAULT_HW) -> float:
    return getattr(hw.chip, MODE_POWER_ATTR[mode])


@dataclasses.dataclass(frozen=True)
class WaveformConfig:
    dt: float = 0.001                 # 1 ms resolution (telemetry-grade)
    steps: int = 30                   # iterations to synthesize
    ckpt_every: int = 0               # 0 = no checkpoint phases
    ckpt_phase: Optional[Phase] = None
    edp_spikes: bool = True           # 50 ms overshoot at rising edges
    jitter_s: float = 0.0             # per-chip phase jitter (sigma)
    include_host: bool = False        # add per-chip host overhead (Fig. 2)


def phase_levels(tl: IterationTimeline, cfg: WaveformConfig,
                 hw: Hardware = DEFAULT_HW) -> np.ndarray:
    """Base per-sample power levels [n_samples] — no EDP spikes, no host.

    This is the only shape-determining step (sample count depends on the
    timeline), so it runs in numpy outside jit; the result feeds
    ``chip_waveform_jax`` inside the compiled engine.
    """
    seq = []
    for s in range(cfg.steps):
        phases = list(tl.phases)
        if cfg.ckpt_every and (s + 1) % cfg.ckpt_every == 0:
            phases.append(cfg.ckpt_phase or Phase("checkpoint", 2.0, CKPT))
        for p in phases:
            n = max(int(round(p.duration_s / cfg.dt)), 1)
            seq.append(np.full(n, mode_power(p.mode, hw)))
    return np.concatenate(seq)


def chip_waveform(tl: IterationTimeline, cfg: WaveformConfig,
                  hw: Hardware = DEFAULT_HW) -> np.ndarray:
    """One chip's power trace [n_samples] over cfg.steps iterations."""
    x = phase_levels(tl, cfg, hw)
    if cfg.edp_spikes:
        x = _add_edp_spikes(x, cfg.dt, hw)
    if cfg.include_host:
        x = x + hw.server.overhead_per_chip_w()
    return x


def _add_edp_spikes(x: np.ndarray, dt: float, hw: Hardware) -> np.ndarray:
    """EDP overshoot: brief (<=50 ms) peaks above TDP on rising edges."""
    out = x.copy()
    w = max(int(hw.chip.edp_window_s / dt), 1)
    rises = np.where(np.diff(x) > 0.25 * hw.chip.tdp_w)[0]
    for r in rises:
        hi = min(r + 1 + w, len(out))
        out[r + 1:hi] = np.maximum(out[r + 1:hi],
                                   x[r + 1] * hw.chip.edp_factor)
    return out


def chip_waveform_jax(levels: jnp.ndarray, dt: float,
                      hw: Hardware = DEFAULT_HW, *, edp_spikes: bool = True,
                      include_host: bool = False) -> jnp.ndarray:
    """jnp mirror of ``chip_waveform`` on a precomputed level array."""
    x = jnp.asarray(levels, jnp.float32)
    if edp_spikes:
        x = _add_edp_spikes_jax(x, dt, hw)
    if include_host:
        x = x + hw.server.overhead_per_chip_w()
    return x


def _add_edp_spikes_jax(x: jnp.ndarray, dt: float, hw: Hardware) -> jnp.ndarray:
    """Vectorized EDP overshoot: a rise at r plants a spike source of value
    x[r+1]*edp_factor at r+1 that persists for the EDP window; the output is
    the running max of x against all active sources (order-free, so it
    matches the serial rise-by-rise update exactly)."""
    w = max(int(hw.chip.edp_window_s / dt), 1)
    rise = jnp.diff(x) > 0.25 * hw.chip.tdp_w
    src = jnp.concatenate([jnp.zeros(1, x.dtype),
                           jnp.where(rise, x[1:], 0.0)]) * hw.chip.edp_factor
    # held[i] = max(src[i-w+1 .. i]): one sliding-window max (spikes decay
    # to 0 past the EDP window, and src >= 0, so 0-padding is neutral)
    held = jax.lax.reduce_window(src, jnp.asarray(0.0, x.dtype), jax.lax.max,
                                 (w,), (1,), [(w - 1, 0)])
    return jnp.maximum(x, held)


def jitter_shifts(cfg: WaveformConfig, seed: int = 0,
                  sample_chips: int = 64) -> np.ndarray:
    """Per-chip sample shifts (int32) used by both aggregate paths; a
    degenerate [0] when jitter is off so the aggregation math is uniform."""
    if cfg.jitter_s <= 0 or sample_chips <= 1:
        return np.zeros(1, np.int32)
    rng = np.random.default_rng(seed)
    sh = rng.normal(0.0, cfg.jitter_s / cfg.dt, size=sample_chips)
    return np.array([int(round(s)) for s in sh], np.int32)


def aggregate(chip_wave: np.ndarray, n_chips: int, cfg: WaveformConfig,
              hw: Hardware = DEFAULT_HW, *, seed: int = 0,
              sample_chips: int = 64) -> np.ndarray:
    """Datacenter-level waveform: sum of jittered chip replicas.

    Sampling `sample_chips` distinct jitter offsets and scaling captures the
    edge-softening of stragglers at O(sample) cost instead of O(n_chips).
    Shifted replicas are edge-padded (the chip holds its boundary power),
    not wrapped: rolling the tail onto the head used to create a spurious
    edge at t=0.
    """
    shifts = jitter_shifts(cfg, seed, sample_chips)
    n = len(chip_wave)
    idx = np.clip(np.arange(n)[None, :] - shifts[:, None], 0, n - 1)
    total = chip_wave[idx].mean(axis=0) * n_chips
    return total * (1.0 + hw.topo.distribution_loss)


def aggregate_jax(chip_wave: jnp.ndarray, n_chips, shifts,
                  hw: Hardware = DEFAULT_HW) -> jnp.ndarray:
    """jnp mirror of ``aggregate``: one gather against a [S, n] shift-index
    matrix replaces the per-sample-chip roll loop; edge-padded like the
    numpy path.  ``shifts`` comes from ``jitter_shifts`` ([1] zero when
    jitter is off); ``n_chips`` may be a traced scalar."""
    n = chip_wave.shape[-1]
    shifts = jnp.asarray(shifts)
    idx = jnp.clip(jnp.arange(n)[None, :] - shifts[:, None], 0, n - 1)
    total = chip_wave[idx].mean(axis=0) * n_chips
    return total * (1.0 + hw.topo.distribution_loss)


def job_waveform(tl: IterationTimeline, n_chips: int,
                 cfg: Optional[WaveformConfig] = None,
                 hw: Hardware = DEFAULT_HW, *, seed: int = 0):
    """Convenience: (t_seconds, watts) at the utility point of coupling."""
    cfg = cfg or WaveformConfig()
    cw = chip_waveform(tl, cfg, hw)
    w = aggregate(cw, n_chips, cfg, hw, seed=seed)
    t = np.arange(len(w)) * cfg.dt
    return t, w


def swing_stats(w: np.ndarray) -> Dict[str, float]:
    return {
        "peak_w": float(np.max(w)),
        "trough_w": float(np.min(w)),
        "swing_w": float(np.max(w) - np.min(w)),
        "mean_w": float(np.mean(w)),
        "swing_frac": float((np.max(w) - np.min(w)) / max(np.max(w), 1e-9)),
    }


def swing_stats_jax(w: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    peak, trough = jnp.max(w), jnp.min(w)
    return {
        "peak_w": peak,
        "trough_w": trough,
        "swing_w": peak - trough,
        "mean_w": jnp.mean(w),
        "swing_frac": (peak - trough) / jnp.maximum(peak, 1e-9),
    }

"""Power-waveform synthesis: phase timeline -> sampled watts.

Reproduces the paper's Fig. 1 structure: per-chip square-ish waves between
near-TDP compute and near-idle communication, EDP overshoot spikes at phase
rises, checkpoint valleys, and rack/DC aggregation with per-chip jitter
(stragglers soften edges at scale, they do not remove the swing — the job
is bulk-synchronous).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import CKPT, COMM, COMPUTE, IDLE, MEMORY, IterationTimeline, Phase

MODE_POWER_ATTR = {COMPUTE: "tdp_w", MEMORY: "hbm_bound_w", COMM: "comm_w",
                   IDLE: "idle_w", CKPT: "comm_w"}


def mode_power(mode: str, hw: Hardware = DEFAULT_HW) -> float:
    return getattr(hw.chip, MODE_POWER_ATTR[mode])


@dataclasses.dataclass(frozen=True)
class WaveformConfig:
    dt: float = 0.001                 # 1 ms resolution (telemetry-grade)
    steps: int = 30                   # iterations to synthesize
    ckpt_every: int = 0               # 0 = no checkpoint phases
    ckpt_phase: Optional[Phase] = None
    edp_spikes: bool = True           # 50 ms overshoot at rising edges
    jitter_s: float = 0.0             # per-chip phase jitter (sigma)
    include_host: bool = False        # add per-chip host overhead (Fig. 2)


def chip_waveform(tl: IterationTimeline, cfg: WaveformConfig,
                  hw: Hardware = DEFAULT_HW) -> np.ndarray:
    """One chip's power trace [n_samples] over cfg.steps iterations."""
    seq = []
    for s in range(cfg.steps):
        phases = list(tl.phases)
        if cfg.ckpt_every and (s + 1) % cfg.ckpt_every == 0:
            phases.append(cfg.ckpt_phase or Phase("checkpoint", 2.0, CKPT))
        for p in phases:
            n = max(int(round(p.duration_s / cfg.dt)), 1)
            seq.append(np.full(n, mode_power(p.mode, hw)))
    x = np.concatenate(seq)
    if cfg.edp_spikes:
        x = _add_edp_spikes(x, cfg.dt, hw)
    if cfg.include_host:
        x = x + hw.server.overhead_per_chip_w()
    return x


def _add_edp_spikes(x: np.ndarray, dt: float, hw: Hardware) -> np.ndarray:
    """EDP overshoot: brief (<=50 ms) peaks above TDP on rising edges."""
    out = x.copy()
    w = max(int(hw.chip.edp_window_s / dt), 1)
    rises = np.where(np.diff(x) > 0.25 * hw.chip.tdp_w)[0]
    for r in rises:
        hi = min(r + 1 + w, len(out))
        out[r + 1:hi] = np.maximum(out[r + 1:hi],
                                   x[r + 1] * hw.chip.edp_factor)
    return out


def aggregate(chip_wave: np.ndarray, n_chips: int, cfg: WaveformConfig,
              hw: Hardware = DEFAULT_HW, *, seed: int = 0,
              sample_chips: int = 64) -> np.ndarray:
    """Datacenter-level waveform: sum of jittered chip replicas.

    Sampling `sample_chips` distinct jitter offsets and scaling captures the
    edge-softening of stragglers at O(sample) cost instead of O(n_chips).
    """
    if cfg.jitter_s <= 0 or sample_chips <= 1:
        total = chip_wave * n_chips
    else:
        rng = np.random.default_rng(seed)
        shifts = rng.normal(0.0, cfg.jitter_s / cfg.dt, size=sample_chips)
        acc = np.zeros_like(chip_wave)
        for sh in shifts:
            acc += np.roll(chip_wave, int(round(sh)))
        total = acc * (n_chips / sample_chips)
    if cfg.include_host:
        pass  # host overhead already per-chip in chip_waveform
    return total * (1.0 + hw.topo.distribution_loss)


def job_waveform(tl: IterationTimeline, n_chips: int,
                 cfg: Optional[WaveformConfig] = None,
                 hw: Hardware = DEFAULT_HW, *, seed: int = 0):
    """Convenience: (t_seconds, watts) at the utility point of coupling."""
    cfg = cfg or WaveformConfig()
    cw = chip_waveform(tl, cfg, hw)
    w = aggregate(cw, n_chips, cfg, hw, seed=seed)
    t = np.arange(len(w)) * cfg.dt
    return t, w


def swing_stats(w: np.ndarray) -> Dict[str, float]:
    return {
        "peak_w": float(np.max(w)),
        "trough_w": float(np.min(w)),
        "swing_w": float(np.max(w) - np.min(w)),
        "mean_w": float(np.mean(w)),
        "swing_frac": float((np.max(w) - np.min(w)) / max(np.max(w), 1e-9)),
    }

"""Deterministic, seekable synthetic LM data pipeline.

Batches are a pure function of (seed, step) — Philox counter-based — so a
job restarted from a checkpoint at step k reproduces the exact token stream
(bitwise restart guarantee, tested in tests/test_ckpt.py). Shard-aware:
``host_slice`` restricts generation to this host's rows of the global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    host_slice: Optional[Tuple[int, int]] = None  # (start_row, rows)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(key=self.seed, counter=step))

    def __call__(self, step: int):
        rng = self._rng(step)
        b0, rows = self.host_slice or (0, self.batch)
        # generate the full global batch deterministically, slice this host
        toks = rng.integers(0, self.cfg.vocab_size,
                            size=(self.batch, self.seq + 1), dtype=np.int32)
        # structure: make it learnable (periodic patterns + noise)
        period = 1 + (np.arange(self.batch) % 7)
        base = (np.arange(self.seq + 1)[None, :] // period[:, None]) % self.cfg.vocab_size
        mask = rng.random((self.batch, self.seq + 1)) < 0.85
        toks = np.where(mask, base.astype(np.int32), toks)
        toks = toks[b0:b0 + rows]
        out = {"labels": toks[:, 1:].copy()}
        if self.cfg.input_mode == "tokens":
            out["tokens"] = toks[:, :-1].copy()
        else:
            emb_rng = self._rng(step + 1_000_000_007)
            out["inputs"] = emb_rng.standard_normal(
                (rows, self.seq, self.cfg.d_model), dtype=np.float32)
        if self.cfg.vision is not None:
            v_rng = self._rng(step + 2_000_000_011)
            out["vision_embeds"] = v_rng.standard_normal(
                (rows, self.cfg.vision.n_tokens, self.cfg.vision.dim),
                dtype=np.float32)
        return out

"""Pallas TPU kernels for the paper's two compute hot-spots:

  ballast/   — Firefly's secondary workload: a VMEM-resident GEMM burner
               with a tunable FLOP/byte intensity knob (TPU adaptation: the
               burner must load the MXU *without* stealing HBM bandwidth
               from the primary workload, so tiles are pinned in VMEM).
  goertzel/  — the telemetry backstop's streaming FFT-bin monitor
               (per-window Goertzel resonators over critical frequencies).

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper) and ref.py (pure-jnp oracle); tests sweep shapes/dtypes in
interpret mode against the oracle.
"""

from repro.kernels.ballast.ops import ballast_burn, ballast_flops

"""Ballast GEMM burner kernel (Firefly's secondary workload, TPU-native).

Each grid cell pins an (bm x bk) activation tile and a (bk x bn) weight
tile in VMEM and iterates C <- (C @ B) * decay on the MXU ``n_iter`` times.
Arithmetic intensity = n_iter * 2*bm*bk*bn FLOPs against one HBM round-trip
of the tiles — the knob that lets the burner hit a target power *without*
competing for the HBM bandwidth the primary workload's comm phase still
uses (checkpoint DMA, ICI spills). This is the deliberate TPU adaptation of
the paper's MPS GEMM ballast (DESIGN.md §5.1).

dims: multiples of 128 to keep the MXU systolic array fully fed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ballast_kernel(a_ref, b_ref, o_ref, *, n_iter: int, decay: float):
    c = a_ref[...]
    b = b_ref[...]

    def body(_, c):
        return jnp.dot(c, b, preferred_element_type=jnp.float32) * decay

    c = jax.lax.fori_loop(0, n_iter, body, c.astype(jnp.float32))
    o_ref[...] = c.astype(o_ref.dtype)


def ballast_pallas(a: jax.Array, b: jax.Array, n_iter: int,
                   *, bm: int = 256, decay: float = 0.999,
                   interpret: bool = False) -> jax.Array:
    """a: [M, K] tiles to burn through; b: [K, N] resident multiplier.

    Grid over M/bm row-blocks; each block runs the full n_iter chain in
    VMEM. Returns C [M, N] (checksum keeps XLA from eliding the work).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 == N, "iterated burner needs a square multiplier"
    assert M % bm == 0, (a.shape, bm)
    grid = (M // bm,)
    return pl.pallas_call(
        functools.partial(_ballast_kernel, n_iter=n_iter, decay=decay),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b)

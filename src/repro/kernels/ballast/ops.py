"""Jit'd wrapper: FLOP-targeted ballast burn."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.ballast.ballast import ballast_pallas


def ballast_flops(m: int, k: int, n: int, n_iter: int) -> float:
    return 2.0 * m * k * n * n_iter


def _tiles(key, m, k, n, dtype):
    a = (jax.random.normal(key, (m, k), jnp.float32) / math.sqrt(k)).astype(dtype)
    # near-orthogonal multiplier keeps iterates bounded for any n_iter
    b = (jnp.eye(k, n, dtype=jnp.float32) * 0.999).astype(dtype)
    return a, b


@functools.partial(jax.jit, static_argnames=("gflops", "m", "k", "n", "interpret"))
def ballast_burn(key, *, gflops: float, m: int = 1024, k: int = 256,
                 n: int = 256, interpret: bool = False) -> jax.Array:
    """Burn ~gflops of MXU work; returns a checksum scalar (anti-DCE)."""
    per_iter = 2.0 * m * k * n
    n_iter = max(int(gflops * 1e9 / per_iter), 1)
    a, b = _tiles(key, m, k, n, jnp.float32)
    out = ballast_pallas(a, b, n_iter, interpret=interpret)
    return jnp.sum(out) * 1e-9

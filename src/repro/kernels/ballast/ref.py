"""Pure-jnp oracle for the ballast burner kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ballast_ref(a: jax.Array, b: jax.Array, n_iter: int,
                decay: float = 0.999) -> jax.Array:
    def body(_, c):
        return jnp.dot(c, b.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * decay
    return jax.lax.fori_loop(0, n_iter, body, a.astype(jnp.float32))

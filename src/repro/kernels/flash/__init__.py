from repro.kernels.flash.ops import flash_sdpa

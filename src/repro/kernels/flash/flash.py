"""Flash-attention forward kernel (perf iteration #2, EXPERIMENTS.md §Perf).

Motivation measured on the qwen1.5-110b prefill_32k cell: with pure-JAX
chunked attention, XLA materializes every (q-block x kv-chunk) score tensor
between the QK^T and PV dots — ~94% of the cell's HBM bytes. Fusing the
whole online-softmax body into one Pallas kernel keeps scores in VMEM; HBM
traffic drops to the q/k/v/out block streams.

Layout: q [B, S, KV, G, D], k/v [B, T, KV, D] (GQA grouped; G query heads
share one kv head). Grid = (B*KV, S/q_block): each cell loads its q block
plus the full (T, D) k/v stripe for that kv head into VMEM (T=32k, D=128
bf16 -> 8 MB each) and runs the online-softmax fori over kv chunks.
dims MXU-aligned: D and blocks multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_chunk: int, q_block: int,
                  causal: bool, scale: float):
    # q_ref: [1, q_block, 1, G, D]; k_ref/v_ref: [1, T, 1, D]
    q = q_ref[0, :, 0, :, :].astype(jnp.float32)          # [qb, G, D]
    qb, G, D = q.shape
    T = k_ref.shape[1]
    q2 = q.reshape(qb * G, D) * scale
    qi = pl.program_id(1)

    def body(j, carry):
        m, l, acc = carry
        k_c = jax.lax.dynamic_slice_in_dim(k_ref[0, :, 0, :], j * kv_chunk,
                                           kv_chunk, 0).astype(jnp.float32)
        v_c = jax.lax.dynamic_slice_in_dim(v_ref[0, :, 0, :], j * kv_chunk,
                                           kv_chunk, 0).astype(jnp.float32)
        s = jnp.dot(q2, k_c.T, preferred_element_type=jnp.float32)  # [qb*G, c]
        if causal:
            pos_q = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, G), 0)
            pos_q = pos_q.reshape(qb * G)
            pos_k = j * kv_chunk + jax.lax.iota(jnp.int32, kv_chunk)
            s = jnp.where(pos_q[:, None] >= pos_k[None, :], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        e = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + e.sum(axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            e, v_c, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    n = T // kv_chunk
    m0 = jnp.full((qb * G,), NEG, jnp.float32)
    l0 = jnp.zeros((qb * G,), jnp.float32)
    a0 = jnp.zeros((qb * G, v_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, 0, :, :] = out.reshape(qb, G, -1).astype(o_ref.dtype)


def flash_pallas(q, k, v, *, q_block: int = 2048, kv_chunk: int = 1024,
                 causal: bool = True, interpret: bool = False):
    """q: [B,S,KV,G,D]; k,v: [B,T,KV,D] -> [B,S,KV,G,Dv]."""
    B, S, KV, G, D = q.shape
    T = k.shape[1]
    Dv = v.shape[-1]
    assert S % q_block == 0 and T % kv_chunk == 0, (S, q_block, T, kv_chunk)
    scale = D ** -0.5
    grid = (B * KV, S // q_block)
    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_chunk=kv_chunk, q_block=q_block,
                          causal=causal, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, 1, G, D),
                         lambda bk, i, KV=KV: (bk // KV, i, bk % KV, 0, 0)),
            pl.BlockSpec((1, T, 1, D),
                         lambda bk, i, KV=KV: (bk // KV, 0, bk % KV, 0)),
            pl.BlockSpec((1, T, 1, Dv),
                         lambda bk, i, KV=KV: (bk // KV, 0, bk % KV, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, G, Dv),
                               lambda bk, i, KV=KV: (bk // KV, i, bk % KV, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, KV, G, Dv), q.dtype),
        interpret=interpret,
    )(q, k, v)

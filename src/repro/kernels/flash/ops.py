"""Jit'd wrapper with shape-adaptive blocks."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash.flash import flash_pallas


@functools.partial(jax.jit, static_argnames=("causal", "interpret",
                                             "q_block", "kv_chunk"))
def flash_sdpa(q, k, v, *, causal: bool = True, q_block: int = 2048,
               kv_chunk: int = 1024, interpret: bool = False):
    S, T = q.shape[1], k.shape[1]
    q_block = min(q_block, S)
    kv_chunk = min(kv_chunk, T)
    return flash_pallas(q, k, v, q_block=q_block, kv_chunk=kv_chunk,
                        causal=causal, interpret=interpret)

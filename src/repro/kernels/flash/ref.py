"""Oracle for the flash kernel: the model's dense sdpa (same layouts)."""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.models.attention import _dense_sdpa


def flash_ref(q, k, v, *, causal: bool = True):
    pos_q = jnp.arange(q.shape[1])
    pos_k = jnp.arange(k.shape[1])
    return _dense_sdpa(q, k, v, pos_q, pos_k, causal, q.shape[-1] ** -0.5)

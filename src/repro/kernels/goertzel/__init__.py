from repro.kernels.goertzel.ops import bin_power

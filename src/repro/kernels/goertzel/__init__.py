from repro.kernels.goertzel.ops import bin_power, sliding_bin_power

"""Goertzel FFT-bin power kernels (telemetry backstop hot path, Sec. IV-E).

Two kernels over power telemetry:

``goertzel_pallas`` — non-overlapping windows [W, win]: each grid cell
loads a block of windows into VMEM and runs K Goertzel resonators (one
per critical frequency) across the window with a single fori_loop —
O(win*K) multiply-adds per window vs O(win log win) for a full FFT, and
only K bins of output.  The [Bw, K] resonator states live in VREGs; the
window block is the only VMEM traffic.

``sliding_goertzel_pallas`` — every-sample sliding window (the
backstop's streaming granularity): the trace is processed in
window-sized segments with *hop-and-overlap* state.  Each grid cell
computes modulated within-segment prefix sums

    P_b = sum_{p<=b} x[p] * e^{-j*omega*p}        (restarted per segment)

and assembles the window ending at segment offset ``b`` from the head of
the current segment plus the suffix of the previous one:

    |window DFT| = |P_b + e^{j*omega*win} * (P^{prev}_{win-1} - P^{prev}_b)|

The per-segment restart is the numerics fix: every partial sum is
bounded by win*max|x| (oscillation scale once the wrapper removes the
trace mean), instead of the O(n*mean) global cumulative sums whose f32
rounding buries the ~1e5 W signals the backstop guards against.  The
previous segment's prefix state is carried across grid cells in VMEM
scratch (grid dims are sequential by default), so the trace streams
through VMEM exactly once.  The phase tables (cos/sin of omega*p) and
the segment rotation e^{j*omega*win} are small [win, K]/[2, K] operands
precomputed in float64 on the host — these are the *real* phase factors
that replaced the dead cos(coef)/sin(coef) placeholder operands the
non-sliding kernel used to carry.

Outputs are bin amplitudes in the volts/watts units of the input.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _goertzel_kernel(x_ref, coef_ref, o_ref, *, win: int):
    x = x_ref[...].astype(jnp.float32)          # [Bw, win]
    coef = coef_ref[...].astype(jnp.float32)    # [K]  2*cos(w)
    Bw = x.shape[0]
    K = coef.shape[0]

    def body(t, carry):
        s1, s2 = carry                           # [Bw, K]
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, 1)  # [Bw, 1]
        s0 = xt + coef[None, :] * s1 - s2
        return (s0, s1)

    s1, s2 = jax.lax.fori_loop(
        0, win, body,
        (jnp.zeros((Bw, K), jnp.float32), jnp.zeros((Bw, K), jnp.float32)))
    # amplitude via the standard Goertzel terminal formula
    power = s1 * s1 + s2 * s2 - coef[None, :] * s1 * s2
    o_ref[...] = (2.0 / win) * jnp.sqrt(jnp.maximum(power, 0.0))


def goertzel_pallas(windows: jax.Array, coef: jax.Array,
                    *, block_w: int = 8, interpret: bool = False) -> jax.Array:
    """windows: [W, win] f32; coef: [K] = 2*cos(2*pi*f*dt). -> [W, K]."""
    W, win = windows.shape
    K = coef.shape[0]
    assert W % block_w == 0, (W, block_w)
    return pl.pallas_call(
        functools.partial(_goertzel_kernel, win=win),
        grid=(W // block_w,),
        in_specs=[
            pl.BlockSpec((block_w, win), lambda i: (i, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_w, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, K), jnp.float32),
        interpret=interpret,
    )(windows.astype(jnp.float32), coef.astype(jnp.float32))


def _sliding_kernel(x_ref, cosp_ref, sinp_ref, rot_ref, o_ref,
                    pre_re, pre_im, *, win: int):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _():
        pre_re[...] = jnp.zeros_like(pre_re)
        pre_im[...] = jnp.zeros_like(pre_im)

    x = x_ref[...].astype(jnp.float32)           # [Bs, win]
    cosp = cosp_ref[...]                          # [win, K]  cos(omega*p)
    sinp = sinp_ref[...]                          # [win, K]  sin(omega*p)
    # hop-and-overlap state: modulated prefix sums restarted every segment
    pr = jnp.cumsum(x[:, :, None] * cosp[None], axis=1)      # [Bs, win, K]
    pi = jnp.cumsum(x[:, :, None] * (-sinp[None]), axis=1)
    # previous segment's prefix state: within the block it is the row
    # above; the first row streams in from the previous grid cell's carry
    prev_r = jnp.concatenate([pre_re[...][None], pr[:-1]], axis=0)
    prev_i = jnp.concatenate([pre_im[...][None], pi[:-1]], axis=0)
    # suffix of the previous segment = its total minus its prefix
    dr = prev_r[:, -1:, :] - prev_r
    di = prev_i[:, -1:, :] - prev_i
    rr = rot_ref[0:1, :]                          # [1, K]  cos(omega*win)
    ri = rot_ref[1:2, :]                          # [1, K]  sin(omega*win)
    mr = pr + rr[None] * dr - ri[None] * di
    mi = pi + rr[None] * di + ri[None] * dr
    o_ref[...] = (2.0 / win) * jnp.sqrt(mr * mr + mi * mi)
    pre_re[...] = pr[-1]
    pre_im[...] = pi[-1]


def sliding_goertzel_pallas(xseg: jax.Array, cosp: jax.Array,
                            sinp: jax.Array, rot: jax.Array,
                            *, block_s: int = 1,
                            interpret: bool = False) -> jax.Array:
    """Streaming sliding-window Goertzel.

    xseg: [S, win] — the (mean-removed, zero-padded) trace reshaped into
    window-sized segments; cosp/sinp: [win, K] phase tables cos/sin of
    omega_k * p; rot: [2, K] = [cos, sin] of omega_k * win (the segment
    rotation).  Returns [S, win, K]: the sliding bin amplitude ending at
    every sample, normalized by 2/win (the wrapper rescales the warm-up
    ramp).  ``block_s`` segments are processed per grid cell; the
    cross-segment prefix state is carried in VMEM scratch, which relies
    on the (default) sequential grid execution order.
    """
    S, win = xseg.shape
    K = cosp.shape[1]
    assert S % block_s == 0, (S, block_s)
    return pl.pallas_call(
        functools.partial(_sliding_kernel, win=win),
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, win), lambda i: (i, 0)),
            pl.BlockSpec((win, K), lambda i: (0, 0)),
            pl.BlockSpec((win, K), lambda i: (0, 0)),
            pl.BlockSpec((2, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, win, K), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, win, K), jnp.float32),
        scratch_shapes=[pltpu.VMEM((win, K), jnp.float32),
                        pltpu.VMEM((win, K), jnp.float32)],
        interpret=interpret,
    )(xseg.astype(jnp.float32), cosp, sinp, rot)

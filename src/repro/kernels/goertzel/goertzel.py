"""Goertzel FFT-bin power kernels (telemetry backstop hot path, Sec. IV-E).

Kernels over power telemetry:

``goertzel_pallas`` — non-overlapping windows [W, win]: each grid cell
loads a block of windows into VMEM and runs K Goertzel resonators (one
per critical frequency) across the window with a single fori_loop —
O(win*K) multiply-adds per window vs O(win log win) for a full FFT, and
only K bins of output.  The [Bw, K] resonator states live in VREGs; the
window block is the only VMEM traffic.

``sliding_goertzel_pallas`` — every-sample sliding window (the
backstop's streaming granularity): the trace is processed in
window-sized segments with *hop-and-overlap* state.  Each grid cell
computes modulated within-segment prefix sums

    P_b = sum_{p<=b} x[p] * e^{-j*omega*p}        (restarted per segment)

and assembles the window ending at segment offset ``b`` from the head of
the current segment plus the suffix of the previous one:

    |window DFT| = |P_b + e^{j*omega*win} * (P^{prev}_{win-1} - P^{prev}_b)|

The per-segment restart is the numerics fix: every partial sum is
bounded by win*max|x| (oscillation scale once the wrapper removes the
trace mean), instead of the O(n*mean) global cumulative sums whose f32
rounding buries the ~1e5 W signals the backstop guards against.  The
previous segment's prefix state is carried across grid cells in VMEM
scratch (grid dims are sequential by default), so the trace streams
through VMEM exactly once.  The phase tables and the segment rotation
e^{j*omega*win} are small operands precomputed in float64 on the host.

**v1 vs v2 layout.**  The v1 kernel (``sliding_goertzel_pallas``, kept
as the benchmark baseline) works on ``[win, K]`` tables and a
``[Bs, win, K]`` amplitude block: with K=4 bins minor-most, every
vector register and VMEM tile wastes 124/128 lanes (the baselined
RPR203 finding).  The v2 kernels are *lane-major*: tables come in as
``[KP, win]`` (KP = K sublane-padded to 8; the kernel reads rows
``0..K-1``), the window axis — thousands of samples — sits on lanes,
and the K bins unroll into per-bin ``[Bs, win]`` row computations, so
every at-least-tile-sized block is lane-full and sublane-aligned.  The
warm-up renormalization (``core.telemetry.warmup_scale``) is applied
in-kernel from the global sample index.

``sliding_goertzel_v2_pallas`` materializes per-bin amplitudes (the
amps-facing API: online detector parity, counterfactual replay).
``sliding_monitor_pallas`` goes further and fuses the amps ->
escalation *decision* into the kernel: per sample it keeps only the
worst-bin amplitude and its escalation class
(``core.telemetry.escalation_classify`` semantics, threshold/release
passed as runtime scalars), plus per-window per-bin peak amplitudes —
the ``[S, win, K]`` amplitude tensor never leaves VMEM, collapsing
output traffic from 16 to 5 bytes per sample.

Outputs are bin amplitudes in the volts/watts units of the input.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _goertzel_kernel(x_ref, coef_ref, o_ref, *, win: int):
    x = x_ref[...].astype(jnp.float32)          # [Bw, win]
    coef = coef_ref[...].astype(jnp.float32)    # [K]  2*cos(w)
    Bw = x.shape[0]
    K = coef.shape[0]

    def body(t, carry):
        s1, s2 = carry                           # [Bw, K]
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, 1)  # [Bw, 1]
        s0 = xt + coef[None, :] * s1 - s2
        return (s0, s1)

    s1, s2 = jax.lax.fori_loop(
        0, win, body,
        (jnp.zeros((Bw, K), jnp.float32), jnp.zeros((Bw, K), jnp.float32)))
    # amplitude via the standard Goertzel terminal formula
    power = s1 * s1 + s2 * s2 - coef[None, :] * s1 * s2
    o_ref[...] = (2.0 / win) * jnp.sqrt(jnp.maximum(power, 0.0))


def goertzel_pallas(windows: jax.Array, coef: jax.Array,
                    *, block_w: int = 8, interpret: bool = False) -> jax.Array:
    """windows: [W, win] f32; coef: [K] = 2*cos(2*pi*f*dt). -> [W, K]."""
    W, win = windows.shape
    K = coef.shape[0]
    assert W % block_w == 0, (W, block_w)
    return pl.pallas_call(
        functools.partial(_goertzel_kernel, win=win),
        grid=(W // block_w,),
        in_specs=[
            pl.BlockSpec((block_w, win), lambda i: (i, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_w, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, K), jnp.float32),
        interpret=interpret,
    )(windows.astype(jnp.float32), coef.astype(jnp.float32))


def _sliding_kernel(x_ref, cosp_ref, sinp_ref, rot_ref, o_ref,
                    pre_re, pre_im, *, win: int):
    s = pl.program_id(0)

    @pl.when(s == 0)
    def _():
        pre_re[...] = jnp.zeros_like(pre_re)
        pre_im[...] = jnp.zeros_like(pre_im)

    x = x_ref[...].astype(jnp.float32)           # [Bs, win]
    cosp = cosp_ref[...]                          # [win, K]  cos(omega*p)
    sinp = sinp_ref[...]                          # [win, K]  sin(omega*p)
    # hop-and-overlap state: modulated prefix sums restarted every segment
    pr = jnp.cumsum(x[:, :, None] * cosp[None], axis=1)      # [Bs, win, K]
    pi = jnp.cumsum(x[:, :, None] * (-sinp[None]), axis=1)
    # previous segment's prefix state: within the block it is the row
    # above; the first row streams in from the previous grid cell's carry
    prev_r = jnp.concatenate([pre_re[...][None], pr[:-1]], axis=0)
    prev_i = jnp.concatenate([pre_im[...][None], pi[:-1]], axis=0)
    # suffix of the previous segment = its total minus its prefix
    dr = prev_r[:, -1:, :] - prev_r
    di = prev_i[:, -1:, :] - prev_i
    rr = rot_ref[0:1, :]                          # [1, K]  cos(omega*win)
    ri = rot_ref[1:2, :]                          # [1, K]  sin(omega*win)
    mr = pr + rr[None] * dr - ri[None] * di
    mi = pi + rr[None] * di + ri[None] * dr
    o_ref[...] = (2.0 / win) * jnp.sqrt(mr * mr + mi * mi)
    pre_re[...] = pr[-1]
    pre_im[...] = pi[-1]


def sliding_goertzel_pallas(xseg: jax.Array, cosp: jax.Array,
                            sinp: jax.Array, rot: jax.Array,
                            *, block_s: int = 1,
                            interpret: bool = False) -> jax.Array:
    """Streaming sliding-window Goertzel — the v1 (bin-minor) layout.

    Kept as the A/B baseline for ``benchmarks/kernels_bench.py``; the
    product paths run the lane-major v2 kernels below.

    xseg: [S, win] — the (mean-removed, zero-padded) trace reshaped into
    window-sized segments; cosp/sinp: [win, K] phase tables cos/sin of
    omega_k * p; rot: [2, K] = [cos, sin] of omega_k * win (the segment
    rotation).  Returns [S, win, K]: the sliding bin amplitude ending at
    every sample, normalized by 2/win (the caller rescales the warm-up
    ramp).  ``block_s`` segments are processed per grid cell; the
    cross-segment prefix state is carried in VMEM scratch, which relies
    on the (default) sequential grid execution order.
    """
    S, win = xseg.shape
    K = cosp.shape[1]
    assert S % block_s == 0, (S, block_s)
    return pl.pallas_call(
        functools.partial(_sliding_kernel, win=win),
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, win), lambda i: (i, 0)),
            pl.BlockSpec((win, K), lambda i: (0, 0)),
            pl.BlockSpec((win, K), lambda i: (0, 0)),
            pl.BlockSpec((2, K), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, win, K), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, win, K), jnp.float32),
        scratch_shapes=[pltpu.VMEM((win, K), jnp.float32),
                        pltpu.VMEM((win, K), jnp.float32)],
        interpret=interpret,
    )(xseg.astype(jnp.float32), cosp, sinp, rot)


# ---------------------------------------------------------------------------
# v2: lane-major layout, per-bin unrolled, optional in-kernel escalation
# ---------------------------------------------------------------------------

def _bin_amps_lane_major(x, c_ref, s_ref, r_ref, pre_re, pre_im, scale,
                         *, win: int, k: int):
    """Shared v2 kernel core: per-bin sliding amplitudes on [Bs, win]
    lane-major rows.  Yields (bin index, warm-up-scaled amp block) and
    updates the prefix-state scratch in place.  The K bins unroll as
    separate [Bs, win] computations — the long window axis stays on
    lanes, and the tables' padded sublane rows (k..KP-1) are never read.
    """
    for kk in range(k):
        pr = jnp.cumsum(x * c_ref[kk:kk + 1, :], axis=1)      # [Bs, win]
        pi = jnp.cumsum(x * (-s_ref[kk:kk + 1, :]), axis=1)
        # previous segment's prefix state: within the block the row
        # above; row 0 streams in from the previous grid cell's carry
        prev_r = jnp.concatenate([pre_re[kk:kk + 1, :], pr[:-1]], axis=0)
        prev_i = jnp.concatenate([pre_im[kk:kk + 1, :], pi[:-1]], axis=0)
        # suffix of the previous segment = its total minus its prefix
        dr = prev_r[:, -1:] - prev_r
        di = prev_i[:, -1:] - prev_i
        rr = r_ref[kk, 0]                 # cos(omega_k * win)
        ri = r_ref[kk, 1]                 # sin(omega_k * win)
        mr = pr + rr * dr - ri * di
        mi = pi + rr * di + ri * dr
        amp = (2.0 / win) * jnp.sqrt(mr * mr + mi * mi) * scale
        pre_re[kk:kk + 1, :] = pr[-1:]
        pre_im[kk:kk + 1, :] = pi[-1:]
        yield kk, amp


def _global_idx_scale(x, s0, seg0, *, win: int):
    """Global sample index of every element of the [Bs, win] block (f32 —
    exact below 2**24 samples) and its warm-up renormalization.  ``seg0``
    is the global index of the call's first segment (0 offline; the
    stream position for chunked carry calls)."""
    bs = x.shape[0]
    segb = jax.lax.broadcasted_iota(jnp.float32, (bs, win), 0)
    pos = jax.lax.broadcasted_iota(jnp.float32, (bs, win), 1)
    idx = (seg0 + s0 * bs + segb) * win + pos
    scale = float(win) / jnp.minimum(idx + 1.0, float(win))
    return idx, scale


def _sliding_kernel_v2(x_ref, cosp_ref, sinp_ref, rot_ref, par_ref,
                       re0_ref, im0_ref, *refs, win: int, k: int):
    """Amps-materializing v2 kernel: K outputs of [Bs, win] per-bin
    warm-up-scaled amplitudes, plus the final prefix-state tables (the
    last two outputs; the trailing two refs are the prefix-state
    scratch).  The state streams in through ``re0``/``im0`` (zeros for a
    fresh trace) and out through the state outputs, so a chunked caller
    can resume bit-identically — offline and online run this same
    program."""
    o_refs, (nre_ref, nim_ref), (pre_re, pre_im) = \
        refs[:-4], refs[-4:-2], refs[-2:]
    s0 = pl.program_id(0)

    @pl.when(s0 == 0)
    def _():
        pre_re[...] = re0_ref[...]
        pre_im[...] = im0_ref[...]

    x = x_ref[...].astype(jnp.float32)                        # [Bs, win]
    _, scale = _global_idx_scale(x, s0, par_ref[0, 3], win=win)
    for kk, amp in _bin_amps_lane_major(x, cosp_ref, sinp_ref, rot_ref,
                                        pre_re, pre_im, scale,
                                        win=win, k=k):
        o_refs[kk][...] = amp
    # every grid cell rewrites the same state block; the last write — the
    # final segment's prefix tables — is what the caller carries forward
    nre_ref[...] = pre_re[...]
    nim_ref[...] = pre_im[...]


def sliding_goertzel_v2_pallas(xseg: jax.Array, cosp: jax.Array,
                               sinp: jax.Array, rott: jax.Array,
                               params: jax.Array, re0: jax.Array,
                               im0: jax.Array, *, k: int, block_s: int = 1,
                               interpret: bool = False):
    """Lane-major sliding Goertzel (amps-materializing v2 variant).

    xseg: [S, win] mean-removed segments; cosp/sinp: [KP, win] lane-major
    phase tables (KP = k sublane-padded to 8; rows >= k are zero and
    unread); rott: [KP, 2] segment rotation [cos, sin] per bin; params:
    [1, 4] f32 [_, _, _, seg0] (the monitor kernel's layout; only
    ``seg0`` — the global index of ``xseg[0]``'s segment — is read
    here); re0/im0: [KP, win] incoming prefix-state
    tables (zeros for a fresh trace).  Returns
    ``(amps: K-tuple of [S, win], nre [KP, win], nim [KP, win])`` —
    warm-up-scaled per-bin amplitudes and the final prefix state
    (bit-identical to the ``ops._sliding_seg_v2`` jnp mirror at any
    ``block_s``).
    """
    S, win = xseg.shape
    kp = cosp.shape[0]
    assert S % block_s == 0, (S, block_s)
    outs = pl.pallas_call(
        functools.partial(_sliding_kernel_v2, win=win, k=k),
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, win), lambda i: (i, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
            pl.BlockSpec((kp, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
        ],
        out_specs=([pl.BlockSpec((block_s, win), lambda i: (i, 0))
                    for _ in range(k)]
                   + [pl.BlockSpec((kp, win), lambda i: (0, 0)),
                      pl.BlockSpec((kp, win), lambda i: (0, 0))]),
        out_shape=([jax.ShapeDtypeStruct((S, win), jnp.float32)
                    for _ in range(k)]
                   + [jax.ShapeDtypeStruct((kp, win), jnp.float32),
                      jax.ShapeDtypeStruct((kp, win), jnp.float32)]),
        scratch_shapes=[pltpu.VMEM((kp, win), jnp.float32),
                        pltpu.VMEM((kp, win), jnp.float32)],
        interpret=interpret,
    )(xseg.astype(jnp.float32), cosp, sinp, rott, params, re0, im0)
    return tuple(outs[:k]), outs[k], outs[k + 1]


def _monitor_kernel(x_ref, cosp_ref, sinp_ref, rot_ref, par_ref,
                    re0_ref, im0_ref, ow_ref, oc_ref, op_ref,
                    nre_ref, nim_ref, pre_re, pre_im, *, win: int, k: int):
    """Fused monitor kernel: v2 amplitudes reduced in VMEM to the
    per-sample worst-bin amplitude, its escalation class
    (``escalation_classify`` semantics — par_ref carries
    [threshold, release, n, seg0] as runtime scalars), and per-window
    per-bin peak amplitudes.  The [Bs, win] per-bin amplitude blocks
    never leave VMEM.  Prefix state streams in/out as in
    ``_sliding_kernel_v2``."""
    s0 = pl.program_id(0)

    @pl.when(s0 == 0)
    def _():
        pre_re[...] = re0_ref[...]
        pre_im[...] = im0_ref[...]

    x = x_ref[...].astype(jnp.float32)                        # [Bs, win]
    idx, scale = _global_idx_scale(x, s0, par_ref[0, 3], win=win)
    thr = par_ref[0, 0]
    rel = par_ref[0, 1]
    n = par_ref[0, 2]
    live = (idx >= win - 1) & (idx < n)
    op_ref[...] = jnp.zeros_like(op_ref)      # padded bin columns stay 0
    worst = None
    for kk, amp in _bin_amps_lane_major(x, cosp_ref, sinp_ref, rot_ref,
                                        pre_re, pre_im, scale,
                                        win=win, k=k):
        op_ref[:, kk] = jnp.where(live, amp, 0.0).max(axis=1)
        worst = amp if worst is None else jnp.maximum(worst, amp)
    # escalation_classify, inlined on the in-VMEM worst block
    hit = (worst > thr) & live
    clear = jnp.logical_not((worst > rel) & live)
    band = jnp.logical_and(~hit, ~clear)
    ow_ref[...] = worst
    oc_ref[...] = (2 * hit.astype(jnp.int32)
                   + band.astype(jnp.int32)).astype(jnp.int8)
    nre_ref[...] = pre_re[...]
    nim_ref[...] = pre_im[...]


def sliding_monitor_pallas(xseg: jax.Array, cosp: jax.Array,
                           sinp: jax.Array, rott: jax.Array,
                           params: jax.Array, re0: jax.Array,
                           im0: jax.Array, *, k: int, block_s: int = 1,
                           interpret: bool = False):
    """Fused sliding monitor: amps -> escalation decision in one kernel.

    Operands as ``sliding_goertzel_v2_pallas`` except ``params`` is a
    [1, 4] f32 row [threshold, release, n, seg0] (runtime values —
    threshold is a differentiable pytree leaf upstream; ``n`` gates
    trailing pad samples dead, exact as f32 below 2**24 samples; pass
    ``n = +inf`` for open-ended streams).  Returns
    ``(worst [S, win] f32, cls [S, win] int8, peaks [S, KP] f32,
    nre [KP, win], nim [KP, win])``: per-sample worst-bin amplitude, its
    escalation class, per-window per-bin peaks over live samples (bin
    columns >= k are zero), and the final prefix state.
    """
    S, win = xseg.shape
    kp = cosp.shape[0]
    assert S % block_s == 0, (S, block_s)
    return pl.pallas_call(
        functools.partial(_monitor_kernel, win=win, k=k),
        grid=(S // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, win), lambda i: (i, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
            pl.BlockSpec((kp, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
            pl.BlockSpec((kp, win), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((block_s, win), lambda i: (i, 0)),
                   pl.BlockSpec((block_s, win), lambda i: (i, 0)),
                   pl.BlockSpec((block_s, kp), lambda i: (i, 0)),
                   pl.BlockSpec((kp, win), lambda i: (0, 0)),
                   pl.BlockSpec((kp, win), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((S, win), jnp.float32),
                   jax.ShapeDtypeStruct((S, win), jnp.int8),
                   jax.ShapeDtypeStruct((S, kp), jnp.float32),
                   jax.ShapeDtypeStruct((kp, win), jnp.float32),
                   jax.ShapeDtypeStruct((kp, win), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((kp, win), jnp.float32),
                        pltpu.VMEM((kp, win), jnp.float32)],
        interpret=interpret,
    )(xseg.astype(jnp.float32), cosp, sinp, rott, params, re0, im0)

"""Goertzel FFT-bin power kernel (telemetry backstop hot path, Sec. IV-E).

Input: power telemetry reshaped into non-overlapping windows [W, win].
Each grid cell loads a block of windows into VMEM and runs K Goertzel
resonators (one per critical frequency) across the window with a single
fori_loop — O(win*K) multiply-adds per window vs O(win log win) for a full
FFT, and only K bins of output. On TPU the [Bw, K] state vectors live in
VREGs; the window block is the only VMEM traffic.

Outputs per-window bin amplitudes [W, K] (volts/watts units of the input).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _goertzel_kernel(x_ref, coef_ref, cw_ref, sw_ref, o_ref, *, win: int):
    x = x_ref[...].astype(jnp.float32)          # [Bw, win]
    coef = coef_ref[...].astype(jnp.float32)    # [K]  2*cos(w)
    Bw = x.shape[0]
    K = coef.shape[0]

    def body(t, carry):
        s1, s2 = carry                           # [Bw, K]
        xt = jax.lax.dynamic_slice_in_dim(x, t, 1, 1)  # [Bw, 1]
        s0 = xt + coef[None, :] * s1 - s2
        return (s0, s1)

    s1, s2 = jax.lax.fori_loop(
        0, win, body,
        (jnp.zeros((Bw, K), jnp.float32), jnp.zeros((Bw, K), jnp.float32)))
    # amplitude via the standard Goertzel terminal formula
    power = s1 * s1 + s2 * s2 - coef[None, :] * s1 * s2
    o_ref[...] = (2.0 / win) * jnp.sqrt(jnp.maximum(power, 0.0))


def goertzel_pallas(windows: jax.Array, coef: jax.Array,
                    *, block_w: int = 8, interpret: bool = False) -> jax.Array:
    """windows: [W, win] f32; coef: [K] = 2*cos(2*pi*f*dt). -> [W, K]."""
    W, win = windows.shape
    K = coef.shape[0]
    assert W % block_w == 0, (W, block_w)
    cw = jnp.cos(coef)  # placeholders to keep operand count stable
    sw = jnp.sin(coef)
    return pl.pallas_call(
        functools.partial(_goertzel_kernel, win=win),
        grid=(W // block_w,),
        in_specs=[
            pl.BlockSpec((block_w, win), lambda i: (i, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_w, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((W, K), jnp.float32),
        interpret=interpret,
    )(windows.astype(jnp.float32), coef.astype(jnp.float32), cw, sw)

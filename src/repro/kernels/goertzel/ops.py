"""Jit'd wrappers: telemetry trace -> critical-bin amplitudes.

``bin_power`` — non-overlapping windows (coarse streaming granularity).
``sliding_bin_power`` — every-sample sliding window on the streaming
Pallas kernel: the telemetry backstop's product hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.goertzel.goertzel import (goertzel_pallas,
                                             sliding_goertzel_pallas)


@functools.partial(jax.jit, static_argnames=("win", "block_w", "interpret"))
def bin_power(x: jax.Array, dt: float, freqs: jax.Array, *, win: int,
              block_w: int = 8, interpret: bool = False) -> jax.Array:
    """x: [n] power samples -> [ceil(n/win), K] bin amplitudes
    (non-overlapping windows).  The trailing partial window (``n % win``
    samples) is zero-padded after its own DC removal and normalized by
    its true sample count, so the tail of the trace is monitored too
    instead of being silently dropped."""
    n = x.shape[0]
    W = -(-n // win)
    pad_n = W * win - n
    if pad_n:
        x = jnp.concatenate([x, jnp.zeros((pad_n,), x.dtype)])
    windows = x.reshape(W, win)
    counts = np.full((W,), float(win), np.float32)
    if pad_n:
        counts[-1] = float(win - pad_n)
    counts = jnp.asarray(counts)
    valid = jnp.arange(win)[None, :] < counts[:, None]
    # remove the per-window DC component: near-DC resonator states otherwise
    # grow to win*mean and the terminal power formula cancels catastrophically
    # in f32 (the bins of interest are >= 0.1 Hz, unaffected by this).
    # Means use the true sample counts; pad samples stay exactly zero.
    means = (jnp.sum(jnp.where(valid, windows, 0.0), axis=1, keepdims=True)
             / counts[:, None])
    windows = jnp.where(valid, windows - means, 0.0)
    pad = (-W) % block_w
    if pad:
        windows = jnp.concatenate(
            [windows, jnp.zeros((pad, win), windows.dtype)], axis=0)
    coef = 2.0 * jnp.cos(2 * jnp.pi * jnp.asarray(freqs) * dt)
    out = goertzel_pallas(windows, coef, block_w=block_w, interpret=interpret)
    # the kernel normalizes by 2/win; partial windows rescale to 2/count
    return out[:W] * (float(win) / counts)[:, None]


@functools.partial(jax.jit,
                   static_argnames=("dt", "freqs", "win", "block_s",
                                    "interpret"))
def sliding_bin_power(x: jax.Array, dt: float, freqs, *, win: int,
                      block_s: int = 0,
                      interpret: bool = False) -> jax.Array:
    """x: [n] power samples -> [n, K] every-sample sliding-window bin
    amplitudes via the streaming Pallas kernel (``freqs`` must be a
    hashable static sequence of Hz; ``dt``/``win`` static).

    Semantics match the corrected float64 oracle
    (``ref.sliding_bin_power_ref``): the trace mean is removed before
    accumulation — see ``ref.py`` for the numerics rationale — and the
    first ``win - 1`` outputs are partial-window estimates normalized by
    the true sample count.  The phase tables are built in float64 on the
    host, so bin phases stay exact at any trace length.  ``block_s=0``
    picks a segment block size automatically.
    """
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    xc = x - jnp.mean(x)
    S = -(-n // win)
    if block_s <= 0:
        # a few segments per grid cell amortizes cell overhead while the
        # [block_s, win, K] intermediates stay VMEM-sized
        block_s = max(1, min(8, S))
    S_pad = S + ((-S) % block_s)
    pad_n = S_pad * win - n
    if pad_n:
        xc = jnp.concatenate([xc, jnp.zeros((pad_n,), jnp.float32)])
    xseg = xc.reshape(S_pad, win)

    omega = 2.0 * np.pi * np.asarray(freqs, np.float64) * dt
    p = np.arange(win, dtype=np.float64)[:, None]
    cosp = jnp.asarray(np.cos(omega[None, :] * p), jnp.float32)
    sinp = jnp.asarray(np.sin(omega[None, :] * p), jnp.float32)
    rot = jnp.asarray(np.stack([np.cos(omega * win), np.sin(omega * win)]),
                      jnp.float32)
    out = sliding_goertzel_pallas(xseg, cosp, sinp, rot, block_s=block_s,
                                  interpret=interpret)
    out = out.reshape(S_pad * win, -1)[:n]
    # warm-up ramp: the kernel normalizes every output by 2/win; partial
    # windows (i < win-1) renormalize to their true sample count
    denom = jnp.minimum(jnp.arange(n, dtype=jnp.float32) + 1.0, float(win))
    return out * (float(win) / denom)[:, None]

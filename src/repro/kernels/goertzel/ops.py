"""Jit'd wrapper: telemetry trace -> per-window critical-bin amplitudes."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.goertzel.goertzel import goertzel_pallas


@functools.partial(jax.jit, static_argnames=("win", "block_w", "interpret"))
def bin_power(x: jax.Array, dt: float, freqs: jax.Array, *, win: int,
              block_w: int = 8, interpret: bool = False) -> jax.Array:
    """x: [n] power samples -> [n//win, K] bin amplitudes (non-overlapping
    windows; the backstop's streaming granularity)."""
    n = x.shape[0]
    W = n // win
    windows = x[: W * win].reshape(W, win)
    # remove the per-window DC component: near-DC resonator states otherwise
    # grow to win*mean and the terminal power formula cancels catastrophically
    # in f32 (the bins of interest are >= 0.1 Hz, unaffected by this)
    windows = windows - jnp.mean(windows, axis=1, keepdims=True)
    pad = (-W) % block_w
    if pad:
        windows = jnp.concatenate(
            [windows, jnp.zeros((pad, win), windows.dtype)], axis=0)
    coef = 2.0 * jnp.cos(2 * jnp.pi * jnp.asarray(freqs) * dt)
    out = goertzel_pallas(windows, coef, block_w=block_w, interpret=interpret)
    return out[:W]

"""Jit'd wrappers: telemetry trace -> critical-bin amplitudes.

``bin_power`` — non-overlapping windows (coarse streaming granularity).
``sliding_bin_power`` — every-sample sliding window on the streaming
Pallas kernel: the telemetry backstop's product hot path.  Pass
``carry=`` (from ``sliding_carry_init``) to run the same monitor
*incrementally* over a chunked stream: the call consumes one chunk,
returns ``(amps, carry')``, and the concatenated chunked outputs are
bit-identical to one offline call on the concatenated trace — the
control plane's online detector is built on this.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.goertzel.goertzel import (goertzel_pallas,
                                             sliding_goertzel_pallas)


@functools.partial(jax.jit, static_argnames=("win", "block_w", "interpret"))
def bin_power(x: jax.Array, dt: float, freqs: jax.Array, *, win: int,
              block_w: int = 8, interpret: bool = False) -> jax.Array:
    """x: [n] power samples -> [ceil(n/win), K] bin amplitudes
    (non-overlapping windows).  The trailing partial window (``n % win``
    samples) is zero-padded after its own DC removal and normalized by
    its true sample count, so the tail of the trace is monitored too
    instead of being silently dropped."""
    n = x.shape[0]
    W = -(-n // win)
    pad_n = W * win - n
    if pad_n:
        x = jnp.concatenate([x, jnp.zeros((pad_n,), x.dtype)])
    windows = x.reshape(W, win)
    counts = np.full((W,), float(win), np.float32)
    if pad_n:
        counts[-1] = float(win - pad_n)
    counts = jnp.asarray(counts)
    valid = jnp.arange(win)[None, :] < counts[:, None]
    # remove the per-window DC component: near-DC resonator states otherwise
    # grow to win*mean and the terminal power formula cancels catastrophically
    # in f32 (the bins of interest are >= 0.1 Hz, unaffected by this).
    # Means use the true sample counts; pad samples stay exactly zero.
    means = (jnp.sum(jnp.where(valid, windows, 0.0), axis=1, keepdims=True)
             / counts[:, None])
    windows = jnp.where(valid, windows - means, 0.0)
    pad = (-W) % block_w
    if pad:
        windows = jnp.concatenate(
            [windows, jnp.zeros((pad, win), windows.dtype)], axis=0)
    coef = 2.0 * jnp.cos(2 * jnp.pi * jnp.asarray(freqs) * dt)
    out = goertzel_pallas(windows, coef, block_w=block_w, interpret=interpret)
    # the kernel normalizes by 2/win; partial windows rescale to 2/count
    return out[:W] * (float(win) / counts)[:, None]


@functools.lru_cache(maxsize=None)
def _phase_tables(freqs: Tuple[float, ...], dt: float, win: int):
    """Host-float64 sliding-Goertzel phase tables, shared by the offline
    full-trace path and the online carry path so both consume bitwise
    identical [win, K] cos/sin operands and the [2, K] segment rotation.
    Returned as host numpy (jnp.asarray at the use site) so the cache
    never captures jit-trace constants."""
    omega = 2.0 * np.pi * np.asarray(freqs, np.float64) * dt
    p = np.arange(win, dtype=np.float64)[:, None]
    cosp = np.cos(omega[None, :] * p).astype(np.float32)
    sinp = np.sin(omega[None, :] * p).astype(np.float32)
    rot = np.stack([np.cos(omega * win),
                    np.sin(omega * win)]).astype(np.float32)
    return cosp, sinp, rot


@functools.partial(jax.jit,
                   static_argnames=("dt", "freqs", "win", "block_s",
                                    "interpret"))
def _sliding_bin_power_full(x: jax.Array, dt: float, freqs, *, win: int,
                            block_s: int = 0,
                            interpret: bool = False) -> jax.Array:
    """Whole-trace sliding monitor (see ``sliding_bin_power``)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    xc = x - jnp.mean(x)
    S = -(-n // win)
    if block_s <= 0:
        # a few segments per grid cell amortizes cell overhead while the
        # [block_s, win, K] intermediates stay VMEM-sized
        block_s = max(1, min(8, S))
    S_pad = S + ((-S) % block_s)
    pad_n = S_pad * win - n
    if pad_n:
        xc = jnp.concatenate([xc, jnp.zeros((pad_n,), jnp.float32)])
    xseg = xc.reshape(S_pad, win)

    cosp, sinp, rot = (jnp.asarray(t) for t in
                       _phase_tables(tuple(freqs), dt, win))
    out = sliding_goertzel_pallas(xseg, cosp, sinp, rot, block_s=block_s,
                                  interpret=interpret)
    out = out.reshape(S_pad * win, -1)[:n]
    # warm-up ramp: the kernel normalizes every output by 2/win; partial
    # windows (i < win-1) renormalize to their true sample count
    from repro.core.telemetry import warmup_scale  # lazy: avoids import cycle
    idx = jnp.arange(n, dtype=jnp.float32)
    return out * warmup_scale(idx, win)[:, None]


class SlidingCarry(NamedTuple):
    """Explicit cross-chunk state of the sliding-Goertzel monitor.

    ``seg`` is the *window residue*: the current (mean-removed,
    zero-padded) window-sized segment buffer with ``fill`` valid samples;
    ``prev_re``/``prev_im`` are the *rotation-phase state*: the previous
    segment's modulated prefix tables ([win, K]) that the kernel carries
    in VMEM scratch across grid cells.  ``offset`` counts samples already
    emitted (global index of the next sample); ``mean`` is the DC
    operating point removed from every sample — pass the trace mean for
    offline parity, the known fleet operating point for live streams.
    Treat as opaque: build with ``sliding_carry_init``, thread through
    ``sliding_bin_power(..., carry=)``.
    """
    offset: int
    fill: int
    seg: jax.Array        # [win] f32
    prev_re: jax.Array    # [win, K] f32
    prev_im: jax.Array    # [win, K] f32
    mean: float


def sliding_carry_init(dt: float, freqs, *, win: int,
                       mean: float = 0.0) -> SlidingCarry:
    """Fresh monitor state for chunked ``sliding_bin_power`` calls.

    ``mean`` is the DC level subtracted from every incoming sample.  For
    bit-parity with the offline path on a known trace, pass
    ``float(trace_mean(x_full))``; for live streams, the fleet's known
    operating point (the monitor's AC amplitudes are insensitive to
    small DC error — it shifts only the near-DC bins).
    """
    K = len(tuple(freqs))
    zeros = jnp.zeros((win, K), jnp.float32)
    return SlidingCarry(offset=0, fill=0,
                        seg=jnp.zeros((win,), jnp.float32),
                        prev_re=zeros, prev_im=zeros,
                        mean=float(np.float32(mean)))


@jax.jit
def trace_mean(x: jax.Array) -> jax.Array:
    """f32 mean of a trace, computed exactly as the offline monitor's
    in-graph ``jnp.mean`` — use for ``sliding_carry_init(mean=...)``
    when chunked output must match the offline call bitwise."""
    return jnp.mean(jnp.asarray(x, jnp.float32))


@functools.partial(jax.jit, static_argnames=("win",))
def _sliding_seg(seg, prev_re, prev_im, cosp, sinp, rot, start, *, win: int):
    """One segment of the sliding monitor — the jitted jnp mirror of
    ``_sliding_kernel`` at ``block_s=1``.  Must stay jitted: XLA's fused
    (FMA-contracted) evaluation of this exact op graph is what the
    interpret-mode Pallas kernel lowers to; an eager evaluation differs
    by 1 ulp.  Returns (scaled [win, K] amplitudes, new prefix tables).
    """
    x = seg[None]                                            # [1, win]
    pr = jnp.cumsum(x[:, :, None] * cosp[None], axis=1)      # [1, win, K]
    pi = jnp.cumsum(x[:, :, None] * (-sinp[None]), axis=1)
    prev_r = jnp.concatenate([prev_re[None], pr[:-1]], axis=0)
    prev_i = jnp.concatenate([prev_im[None], pi[:-1]], axis=0)
    dr = prev_r[:, -1:, :] - prev_r
    di = prev_i[:, -1:, :] - prev_i
    rr = rot[0:1, :]
    ri = rot[1:2, :]
    mr = pr + rr[None] * dr - ri[None] * di
    mi = pi + rr[None] * di + ri[None] * dr
    out = (2.0 / win) * jnp.sqrt(mr * mr + mi * mi)          # [1, win, K]
    from repro.core.telemetry import warmup_scale  # lazy: avoids import cycle
    idx = start + jnp.arange(win, dtype=jnp.float32)
    return out[0] * warmup_scale(idx, win)[:, None], pr[-1], pi[-1]


def _sliding_bin_power_carry(x, dt: float, freqs, *, win: int,
                             carry: SlidingCarry):
    """Consume one concrete chunk, emitting its [m, K] amplitudes and the
    advanced carry.  A partial segment is recomputed on its zero-padded
    window buffer each call (cumsum prefixes at index b are unaffected by
    the zero tail), and only the newly-valid rows are emitted — so uneven
    tick sizes, ticks smaller than one window, and a final partial tick
    all reproduce the offline output bitwise."""
    cosp, sinp, rot = (jnp.asarray(t) for t in
                       _phase_tables(tuple(freqs), dt, win))
    K = cosp.shape[1]
    xc = np.asarray(x, np.float32) - np.float32(carry.mean)
    m = xc.shape[0]
    offset, fill = carry.offset, carry.fill
    seg = np.asarray(carry.seg)
    prev_re, prev_im = carry.prev_re, carry.prev_im
    outs = []
    pos = 0
    while pos < m:
        take = min(win - fill, m - pos)
        if take:
            seg = seg.copy()
            seg[fill:fill + take] = xc[pos:pos + take]
        new_fill = fill + take
        start = offset - fill                 # global index of seg row 0
        out, pr, pi = _sliding_seg(jnp.asarray(seg), prev_re, prev_im,
                                   cosp, sinp, rot, jnp.float32(start),
                                   win=win)
        outs.append(np.asarray(out[fill:new_fill]))
        if new_fill == win:                   # segment complete: hop
            prev_re, prev_im = pr, pi
            seg = np.zeros((win,), np.float32)
            fill = 0
        else:
            fill = new_fill
        offset += take
        pos += take
    amps = (np.concatenate(outs, axis=0) if outs
            else np.zeros((0, K), np.float32))
    new_carry = SlidingCarry(offset=offset, fill=fill,
                             seg=jnp.asarray(seg),
                             prev_re=prev_re, prev_im=prev_im,
                             mean=carry.mean)
    return amps, new_carry


def sliding_bin_power(x, dt: float, freqs, *, win: int, block_s: int = 0,
                      interpret: bool = False, carry: SlidingCarry = None):
    """x: [n] power samples -> [n, K] every-sample sliding-window bin
    amplitudes via the streaming Pallas kernel (``freqs`` must be a
    hashable static sequence of Hz; ``dt``/``win`` static).

    Semantics match the corrected float64 oracle
    (``ref.sliding_bin_power_ref``): the trace mean is removed before
    accumulation — see ``ref.py`` for the numerics rationale — and the
    first ``win - 1`` outputs are partial-window estimates normalized by
    the true sample count.  The phase tables are built in float64 on the
    host, so bin phases stay exact at any trace length.  ``block_s=0``
    picks a segment block size automatically.

    With ``carry=`` (a ``SlidingCarry`` from ``sliding_carry_init``), x
    is one *chunk* of a longer stream: the call returns
    ``(amps [len(x), K], carry')`` instead, resuming mid-window from the
    carried residue/rotation state rather than re-priming — chunked
    outputs concatenate bit-identically to one offline call on the
    concatenated trace (given ``mean=trace_mean(full)``).  The carry
    path requires concrete (non-traced) input.
    """
    if carry is None:
        return _sliding_bin_power_full(x, dt, tuple(freqs), win=win,
                                       block_s=block_s, interpret=interpret)
    return _sliding_bin_power_carry(x, dt, tuple(freqs), win=win, carry=carry)

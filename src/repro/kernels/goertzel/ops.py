"""Jit'd wrappers: telemetry trace -> critical-bin amplitudes.

``bin_power`` — non-overlapping windows (coarse streaming granularity).
``sliding_bin_power`` — every-sample sliding window on the streaming
lane-major v2 Pallas kernel: the telemetry backstop's product hot path.
Pass ``carry=`` (from ``sliding_carry_init``) to run the same monitor
*incrementally* over a chunked stream: the call consumes one chunk,
returns ``(amps, carry')``, and the concatenated chunked outputs are
bit-identical to one offline call on the concatenated trace — the
control plane's online detector is built on this.  Both directions run
the *same* Pallas program: the v2 kernels stream their prefix-state
tables in and out, so a chunked caller resumes from exactly the state
the offline kernel would hold.

``sliding_monitor_fused`` — the fused monitor: amplitudes are reduced to
the per-sample worst bin and its escalation class *inside* the kernel
(``core.telemetry.escalation_classify`` semantics), the class stream
runs through the blocked ``core.telemetry.escalation_scan``, and the
``[n, K]`` amplitude matrix never exists.  The jnp mirror
(``use_pallas=False``) is the structurally identical oracle the tests
pin bitwise.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.goertzel.goertzel import (goertzel_pallas,
                                             sliding_goertzel_pallas,
                                             sliding_goertzel_v2_pallas,
                                             sliding_monitor_pallas)

#: sublane multiple the v2 lane-major tables pad K up to (f32 tile is
#: (8, 128); rows k..KP-1 are zero and never read by the kernels)
SUBLANES = 8


@functools.lru_cache(maxsize=None)
def interpret_default() -> bool:
    """Compile the Pallas kernels only on real TPU backends; everywhere
    else (CPU CI, tests, the vmapped engine) they run in interpret mode."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("win", "block_w", "interpret"))
def bin_power(x: jax.Array, dt: float, freqs: jax.Array, *, win: int,
              block_w: int = 8, interpret: bool = False) -> jax.Array:
    """x: [n] power samples -> [ceil(n/win), K] bin amplitudes
    (non-overlapping windows).  The trailing partial window (``n % win``
    samples) is zero-padded after its own DC removal and normalized by
    its true sample count, so the tail of the trace is monitored too
    instead of being silently dropped."""
    n = x.shape[0]
    W = -(-n // win)
    pad_n = W * win - n
    if pad_n:
        x = jnp.concatenate([x, jnp.zeros((pad_n,), x.dtype)])
    windows = x.reshape(W, win)
    counts = np.full((W,), float(win), np.float32)
    if pad_n:
        counts[-1] = float(win - pad_n)
    counts = jnp.asarray(counts)
    valid = jnp.arange(win)[None, :] < counts[:, None]
    # remove the per-window DC component: near-DC resonator states otherwise
    # grow to win*mean and the terminal power formula cancels catastrophically
    # in f32 (the bins of interest are >= 0.1 Hz, unaffected by this).
    # Means use the true sample counts; pad samples stay exactly zero.
    means = (jnp.sum(jnp.where(valid, windows, 0.0), axis=1, keepdims=True)
             / counts[:, None])
    windows = jnp.where(valid, windows - means, 0.0)
    pad = (-W) % block_w
    if pad:
        windows = jnp.concatenate(
            [windows, jnp.zeros((pad, win), windows.dtype)], axis=0)
    coef = 2.0 * jnp.cos(2 * jnp.pi * jnp.asarray(freqs) * dt)
    out = goertzel_pallas(windows, coef, block_w=block_w, interpret=interpret)
    # the kernel normalizes by 2/win; partial windows rescale to 2/count
    return out[:W] * (float(win) / counts)[:, None]


@functools.lru_cache(maxsize=None)
def _phase_tables(freqs: Tuple[float, ...], dt: float, win: int):
    """Host-float64 phase tables in the v1 (bin-minor) ``[win, K]``
    layout.  Only the benchmark A/B baseline (``sliding_goertzel_pallas``
    in ``benchmarks/kernels_bench.py``) still consumes this; product
    paths use ``_phase_tables_v2``."""
    omega = 2.0 * np.pi * np.asarray(freqs, np.float64) * dt
    p = np.arange(win, dtype=np.float64)[:, None]
    cosp = np.cos(omega[None, :] * p).astype(np.float32)
    sinp = np.sin(omega[None, :] * p).astype(np.float32)
    rot = np.stack([np.cos(omega * win),
                    np.sin(omega * win)]).astype(np.float32)
    return cosp, sinp, rot


@functools.lru_cache(maxsize=None)
def _phase_tables_v2(freqs: Tuple[float, ...], dt: float, win: int):
    """Host-float64 sliding-Goertzel phase tables in the lane-major v2
    layout, shared by the offline full-trace path and the online carry
    path so both consume bitwise identical operands: ``cosp``/``sinp``
    ``[KP, win]`` (K sublane-padded to ``SUBLANES``; pad rows zero and
    unread) and the ``[KP, 2]`` segment rotation ``[cos, sin]`` of
    ``omega_k * win``.  Returned as host numpy (jnp.asarray at the use
    site) so the cache never captures jit-trace constants."""
    k = len(freqs)
    kp = -(-k // SUBLANES) * SUBLANES
    omega = 2.0 * np.pi * np.asarray(freqs, np.float64) * dt
    p = np.arange(win, dtype=np.float64)[None, :]
    cosp = np.zeros((kp, win), np.float32)
    sinp = np.zeros((kp, win), np.float32)
    rott = np.zeros((kp, 2), np.float32)
    cosp[:k] = np.cos(omega[:, None] * p)
    sinp[:k] = np.sin(omega[:, None] * p)
    rott[:k, 0] = np.cos(omega * win)
    rott[:k, 1] = np.sin(omega * win)
    return cosp, sinp, rott


@functools.lru_cache(maxsize=None)
def _phase_tables_v2_dev(freqs: Tuple[float, ...], dt: float, win: int):
    """Device-resident ``_phase_tables_v2``, for the concrete online
    carry paths: one device_put per (freqs, dt, win) instead of three
    per tick (re-uploading the [KP, win] tables dominated the per-tick
    detector cost).  Traced callers keep the host variant so jit caches
    never capture live buffers."""
    return tuple(jnp.asarray(t) for t in _phase_tables_v2(freqs, dt, win))


def _params_row(threshold, release, n, seg0) -> jax.Array:
    """The kernels' [1, 4] runtime-parameter row
    [threshold, release, n, seg0] (all f32; threshold may be traced).
    Concrete inputs build on the host — the online carry path calls this
    once per segment, and four eager jnp ops per tick are measurable."""
    vals = (threshold, release, n, seg0)
    if not any(isinstance(v, jax.core.Tracer) for v in vals):
        return np.asarray(vals, np.float32).reshape(1, 4)
    return jnp.stack([jnp.asarray(v, jnp.float32)
                      for v in vals]).reshape(1, 4)


@functools.partial(jax.jit,
                   static_argnames=("dt", "freqs", "win", "block_s",
                                    "interpret"))
def _sliding_bin_power_full(x: jax.Array, dt: float, freqs, *, win: int,
                            block_s: int = 0,
                            interpret: bool = False) -> jax.Array:
    """Whole-trace sliding monitor (see ``sliding_bin_power``)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    xc = x - jnp.mean(x)
    S = -(-n // win)
    if block_s <= 0:
        # a few segments per grid cell amortizes cell overhead while the
        # per-bin [block_s, win] intermediates stay VMEM-sized
        block_s = max(1, min(8, S))
    S_pad = S + ((-S) % block_s)
    pad_n = S_pad * win - n
    if pad_n:
        xc = jnp.concatenate([xc, jnp.zeros((pad_n,), jnp.float32)])
    xseg = xc.reshape(S_pad, win)

    cosp, sinp, rott = (jnp.asarray(t) for t in
                        _phase_tables_v2(tuple(freqs), dt, win))
    zeros = jnp.zeros_like(cosp)
    amps, _, _ = sliding_goertzel_v2_pallas(
        xseg, cosp, sinp, rott, _params_row(0.0, 0.0, n, 0.0), zeros, zeros,
        k=len(freqs), block_s=block_s, interpret=interpret)
    # the kernel applies both the 2/win normalization and the warm-up
    # ramp (core.telemetry.warmup_scale) in VMEM
    return jnp.stack(amps, axis=-1).reshape(S_pad * win, -1)[:n]


class SlidingCarry(NamedTuple):
    """Explicit cross-chunk state of the sliding-Goertzel monitor.

    ``seg`` is the *window residue*: the current (mean-removed,
    zero-padded) window-sized segment buffer with ``fill`` valid samples;
    ``prev_re``/``prev_im`` are the *rotation-phase state*: the previous
    segment's modulated prefix tables (lane-major ``[KP, win]`` — the
    exact tables the v2 kernel streams in and out).  ``offset`` counts
    samples already emitted (global index of the next sample); ``mean``
    is the DC operating point removed from every sample — pass the trace
    mean for offline parity, the known fleet operating point for live
    streams.  Treat as opaque: build with ``sliding_carry_init``, thread
    through ``sliding_bin_power(..., carry=)``.
    """
    offset: int
    fill: int
    seg: jax.Array        # [win] f32
    prev_re: jax.Array    # [KP, win] f32
    prev_im: jax.Array    # [KP, win] f32
    mean: float


def sliding_carry_init(dt: float, freqs, *, win: int,
                       mean: float = 0.0) -> SlidingCarry:
    """Fresh monitor state for chunked ``sliding_bin_power`` calls.

    ``mean`` is the DC level subtracted from every incoming sample.  For
    bit-parity with the offline path on a known trace, pass
    ``float(trace_mean(x_full))``; for live streams, the fleet's known
    operating point (the monitor's AC amplitudes are insensitive to
    small DC error — it shifts only the near-DC bins).
    """
    k = len(tuple(freqs))
    kp = -(-k // SUBLANES) * SUBLANES
    zeros = jnp.zeros((kp, win), jnp.float32)
    return SlidingCarry(offset=0, fill=0,
                        seg=jnp.zeros((win,), jnp.float32),
                        prev_re=zeros, prev_im=zeros,
                        mean=float(np.float32(mean)))


@jax.jit
def trace_mean(x: jax.Array) -> jax.Array:
    """f32 mean of a trace, computed exactly as the offline monitor's
    in-graph ``jnp.mean`` — use for ``sliding_carry_init(mean=...)``
    when chunked output must match the offline call bitwise."""
    return jnp.mean(jnp.asarray(x, jnp.float32))


@functools.partial(jax.jit, static_argnames=("win", "k", "interpret"))
def _sliding_seg_v2(seg, prev_re, prev_im, cosp, sinp, rott, seg0, *,
                    win: int, k: int, interpret: bool = True):
    """One segment of the sliding monitor *on the v2 Pallas kernel*
    (single-segment grid, carried prefix state streamed in/out) — the
    online carry path runs the same kernel program as the offline call,
    so chunked amplitudes are bit-identical by construction.  ``seg0``
    is the segment's global index (f32).  Returns
    (scaled [win, K] amplitudes, new prefix tables [KP, win] x2)."""
    amps, nre, nim = sliding_goertzel_v2_pallas(
        seg[None], cosp, sinp, rott, _params_row(0.0, 0.0, 0.0, seg0),
        prev_re, prev_im, k=k, block_s=1, interpret=interpret)
    return jnp.stack(amps, axis=-1)[0], nre, nim


def _sliding_bin_power_carry(x, dt: float, freqs, *, win: int,
                             carry: SlidingCarry, interpret: bool):
    """Consume one concrete chunk, emitting its [m, K] amplitudes and the
    advanced carry.  A partial segment is recomputed on its zero-padded
    window buffer each call (cumsum prefixes at index b are unaffected by
    the zero tail), and only the newly-valid rows are emitted — so uneven
    tick sizes, ticks smaller than one window, and a final partial tick
    all reproduce the offline output bitwise."""
    cosp, sinp, rott = _phase_tables_v2_dev(tuple(freqs), dt, win)
    K = len(tuple(freqs))
    xc = np.asarray(x, np.float32) - np.float32(carry.mean)
    m = xc.shape[0]
    offset, fill = carry.offset, carry.fill
    seg = np.asarray(carry.seg)
    prev_re, prev_im = carry.prev_re, carry.prev_im
    outs = []
    pos = 0
    while pos < m:
        take = min(win - fill, m - pos)
        if take:
            seg = seg.copy()
            seg[fill:fill + take] = xc[pos:pos + take]
        new_fill = fill + take
        seg0 = (offset - fill) // win         # global index of the segment
        out, pr, pi = _sliding_seg_v2(seg, prev_re, prev_im,
                                      cosp, sinp, rott, np.float32(seg0),
                                      win=win, k=K, interpret=interpret)
        outs.append(np.asarray(out)[fill:new_fill])
        if new_fill == win:                   # segment complete: hop
            prev_re, prev_im = pr, pi
            seg = np.zeros((win,), np.float32)
            fill = 0
        else:
            fill = new_fill
        offset += take
        pos += take
    amps = (np.concatenate(outs, axis=0) if outs
            else np.zeros((0, K), np.float32))
    new_carry = SlidingCarry(offset=offset, fill=fill, seg=seg,
                             prev_re=prev_re, prev_im=prev_im,
                             mean=carry.mean)
    return amps, new_carry


def sliding_bin_power(x, dt: float, freqs, *, win: int, block_s: int = 0,
                      interpret: Optional[bool] = None,
                      carry: SlidingCarry = None):
    """x: [n] power samples -> [n, K] every-sample sliding-window bin
    amplitudes via the streaming lane-major v2 Pallas kernel (``freqs``
    must be a hashable static sequence of Hz; ``dt``/``win`` static).

    Semantics match the corrected float64 oracle
    (``ref.sliding_bin_power_ref``): the trace mean is removed before
    accumulation — see ``ref.py`` for the numerics rationale — and the
    first ``win - 1`` outputs are partial-window estimates normalized by
    the true sample count (the warm-up ramp is applied *in-kernel*).
    The phase tables are built in float64 on the host, so bin phases
    stay exact at any trace length.  ``block_s=0`` picks a segment block
    size automatically; ``interpret=None`` compiles on TPU backends and
    interprets elsewhere.

    With ``carry=`` (a ``SlidingCarry`` from ``sliding_carry_init``), x
    is one *chunk* of a longer stream: the call returns
    ``(amps [len(x), K], carry')`` instead, resuming mid-window from the
    carried residue/rotation state rather than re-priming — chunked
    outputs concatenate bit-identically to one offline call on the
    concatenated trace (given ``mean=trace_mean(full)``), because both
    run the same kernel program with the same streamed state.  The
    carry path requires concrete (non-traced) input.
    """
    if interpret is None:
        interpret = interpret_default()
    if carry is None:
        return _sliding_bin_power_full(x, dt, tuple(freqs), win=win,
                                       block_s=block_s, interpret=interpret)
    return _sliding_bin_power_carry(x, dt, tuple(freqs), win=win,
                                    carry=carry, interpret=interpret)


# ---------------------------------------------------------------------------
# fused monitor: worst bin + escalation class in-kernel, blocked escalation
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("win", "k"))
def _monitor_scan_jnp(xseg, cosp, sinp, rott, params, re0, im0, *,
                      win: int, k: int):
    """jnp mirror of ``sliding_monitor_pallas``: one ``lax.scan`` over
    segments whose body is structurally identical to the kernel at
    ``block_s=1`` — XLA's fused (FMA-contracted) evaluation of this
    exact op graph is what the interpret-mode kernel lowers to, so the
    two are *bitwise* equal (pinned in tests/test_kernels.py).  Must
    stay jitted: an eager evaluation differs by 1 ulp."""
    S = xseg.shape[0]
    kp = cosp.shape[0]
    thr, rel, n, seg0 = (params[0, i] for i in range(4))
    pos = jax.lax.broadcasted_iota(jnp.float32, (1, win), 1)

    def seg_body(carry, inp):
        pre_re, pre_im = carry
        xs, sidx = inp
        x = xs[None]                                          # [1, win]
        idx = (seg0 + sidx) * win + pos
        scale = float(win) / jnp.minimum(idx + 1.0, float(win))
        live = (idx >= win - 1) & (idx < n)
        worst = None
        nre, nim, ppk = [], [], []
        for kk in range(k):
            pr = jnp.cumsum(x * cosp[kk:kk + 1, :], axis=1)
            pi = jnp.cumsum(x * (-sinp[kk:kk + 1, :]), axis=1)
            prev_r = jnp.concatenate([pre_re[kk:kk + 1, :], pr[:-1]], axis=0)
            prev_i = jnp.concatenate([pre_im[kk:kk + 1, :], pi[:-1]], axis=0)
            dr = prev_r[:, -1:] - prev_r
            di = prev_i[:, -1:] - prev_i
            rr = rott[kk, 0]
            ri = rott[kk, 1]
            mr = pr + rr * dr - ri * di
            mi = pi + rr * di + ri * dr
            amp = (2.0 / win) * jnp.sqrt(mr * mr + mi * mi) * scale
            ppk.append(jnp.where(live, amp, 0.0).max(axis=1))
            worst = amp if worst is None else jnp.maximum(worst, amp)
            nre.append(pr[-1:])
            nim.append(pi[-1:])
        hit = (worst > thr) & live
        clear = jnp.logical_not((worst > rel) & live)
        band = jnp.logical_and(~hit, ~clear)
        cls = (2 * hit.astype(jnp.int32)
               + band.astype(jnp.int32)).astype(jnp.int8)
        peaks = jnp.concatenate(ppk + [jnp.zeros((kp - k,), jnp.float32)])
        new_re = jnp.concatenate(nre + [pre_re[k:]], axis=0)
        new_im = jnp.concatenate(nim + [pre_im[k:]], axis=0)
        return (new_re, new_im), (worst[0], cls[0], peaks)

    (nre, nim), (worsts, clss, peaks) = jax.lax.scan(
        seg_body, (re0, im0),
        (xseg, jnp.arange(S, dtype=jnp.float32)))
    return worsts, clss, peaks, nre, nim


class MonitorCarry(NamedTuple):
    """Cross-chunk state of the *fused* monitor: the sliding-Goertzel
    carry plus the escalation machine's ``(level, above, below, detect)``
    counters.  Build with ``monitor_carry_init``, thread through
    ``sliding_monitor_fused(..., carry=)``."""
    sliding: SlidingCarry
    esc: Tuple[jax.Array, ...]


def monitor_carry_init(dt: float, freqs, *, win: int,
                       mean: float = 0.0) -> MonitorCarry:
    """Fresh fused-monitor state for chunked ``sliding_monitor_fused``
    calls (see ``sliding_carry_init`` for ``mean``)."""
    from repro.core.telemetry import escalation_init  # lazy: import cycle
    return MonitorCarry(
        sliding=sliding_carry_init(dt, freqs, win=win, mean=mean),
        esc=escalation_init())


@functools.partial(jax.jit, static_argnames=("win", "k", "interpret",
                                             "use_pallas"))
def _monitor_seg_v2(seg, prev_re, prev_im, cosp, sinp, rott, params, *,
                    win: int, k: int, interpret: bool = True,
                    use_pallas: bool = True):
    """One segment of the fused monitor (single-segment grid) — the
    online fused path.  Returns (worst [win], cls [win], peaks [KP],
    new prefix tables)."""
    if use_pallas:
        worst, cls, peaks, nre, nim = sliding_monitor_pallas(
            seg[None], cosp, sinp, rott, params, prev_re, prev_im,
            k=k, block_s=1, interpret=interpret)
    else:
        worst, cls, peaks, nre, nim = _monitor_scan_jnp(
            seg[None], cosp, sinp, rott, params, prev_re, prev_im,
            win=win, k=k)
    return worst[0], cls[0], peaks[0], nre, nim


@functools.partial(jax.jit, static_argnames=("win", "k"))
def _amps_at(nre, nim, prev_re, prev_im, rott, b, idx, *, win: int, k: int):
    """Per-bin sliding amplitudes at one sample, recombined from the
    fused kernel's streamed prefix state: ``nre``/``nim`` are the
    *current* segment's prefix tables (the kernel's state output),
    ``prev_re``/``prev_im`` the previous segment's, ``b`` the in-segment
    position and ``idx`` the global sample index.  O(K) work — this is
    how the fused online detector reports per-bin amplitudes without
    materializing any [win, K] block."""
    from repro.core.telemetry import warmup_scale  # lazy: import cycle
    pr = nre[:k, b]
    pi = nim[:k, b]
    dr = prev_re[:k, win - 1] - prev_re[:k, b]
    di = prev_im[:k, win - 1] - prev_im[:k, b]
    rr = rott[:k, 0]
    ri = rott[:k, 1]
    mr = pr + rr * dr - ri * di
    mi = pi + rr * di + ri * dr
    amp = (2.0 / win) * jnp.sqrt(mr * mr + mi * mi)
    return amp * warmup_scale(idx, win)


@functools.partial(jax.jit, static_argnames=("win", "k", "sustain_n",
                                             "cool_n", "max_level"))
def _monitor_tail(cls_cat, idx0, esc, nre, nim, prev_re, prev_im, rott,
                  b, idx, *, win: int, k: int, sustain_n: int, cool_n: int,
                  max_level: int):
    """The online chunk's post-kernel tail in one dispatch: advance the
    blocked escalation machine over the chunk's class stream and
    recombine the last sample's per-bin amplitudes from the streamed
    prefix state (the per-tick serve path is dispatch-bound on CPU, so
    the two steps share a jit)."""
    from repro.core.telemetry import escalation_scan  # lazy: import cycle
    esc2, levels = escalation_scan(cls_cat, idx0, esc, sustain_n=sustain_n,
                                   cool_n=cool_n, max_level=max_level)
    amps = _amps_at(nre, nim, prev_re, prev_im, rott, b, idx, win=win, k=k)
    return esc2, levels, amps


@functools.partial(jax.jit,
                   static_argnames=("dt", "freqs", "win", "sustain_n",
                                    "cool_n", "max_level", "block_s",
                                    "interpret", "use_pallas"))
def _sliding_monitor_full(x, threshold, release, dt: float, freqs, *,
                          win: int, sustain_n: int, cool_n: int,
                          max_level: int, block_s: int, interpret: bool,
                          use_pallas: bool):
    """Whole-trace fused monitor (see ``sliding_monitor_fused``)."""
    from repro.core.telemetry import (escalation_init,  # lazy: import cycle
                                      escalation_scan)
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    k = len(freqs)
    xc = x - jnp.mean(x)
    S = -(-n // win)
    if block_s <= 0:
        block_s = max(1, min(8, S))
    S_pad = S + ((-S) % block_s)
    pad_n = S_pad * win - n
    if pad_n:
        xc = jnp.concatenate([xc, jnp.zeros((pad_n,), jnp.float32)])
    xseg = xc.reshape(S_pad, win)
    cosp, sinp, rott = (jnp.asarray(t) for t in
                        _phase_tables_v2(tuple(freqs), dt, win))
    zeros = jnp.zeros_like(cosp)
    params = _params_row(threshold, release, n, 0.0)
    if use_pallas:
        worst2, cls2, peaks2, _, _ = sliding_monitor_pallas(
            xseg, cosp, sinp, rott, params, zeros, zeros,
            k=k, block_s=block_s, interpret=interpret)
    else:
        worst2, cls2, peaks2, _, _ = _monitor_scan_jnp(
            xseg, cosp, sinp, rott, params, zeros, zeros, win=win, k=k)
    worst = worst2.reshape(-1)[:n]
    cls = cls2.reshape(-1)[:n]
    (_, _, _, detect), levels = escalation_scan(
        cls, jnp.int32(0), escalation_init(),
        sustain_n=sustain_n, cool_n=cool_n, max_level=max_level)
    return worst, levels, detect, peaks2[:S, :k]


def _sliding_monitor_carry(x, threshold, release, dt: float, freqs, *,
                           win: int, sustain_n: int, cool_n: int,
                           max_level: int, interpret: bool,
                           use_pallas: bool, carry: MonitorCarry):
    """Consume one concrete chunk through the fused monitor (same
    recompute-partial-segment strategy as ``_sliding_bin_power_carry``).
    Returns ``(worst [m], levels [m], amps_last [K], carry')`` where
    ``amps_last`` are the per-bin amplitudes at the chunk's final sample
    (recombined from the streamed prefix state)."""
    cosp, sinp, rott = _phase_tables_v2_dev(tuple(freqs), dt, win)
    K = len(tuple(freqs))
    sl = carry.sliding
    xc = np.asarray(x, np.float32) - np.float32(sl.mean)
    m = xc.shape[0]
    offset0 = sl.offset
    offset, fill = sl.offset, sl.fill
    seg = np.asarray(sl.seg)
    prev_re, prev_im = sl.prev_re, sl.prev_im
    worsts, clss = [], []
    last = None                     # (nre, nim, prev_re, prev_im, b, seg0)
    pos = 0
    while pos < m:
        take = min(win - fill, m - pos)
        if take:
            seg = seg.copy()
            seg[fill:fill + take] = xc[pos:pos + take]
        new_fill = fill + take
        seg0 = (offset - fill) // win
        params = _params_row(threshold, release, np.inf, seg0)
        worst, cls, _, pr, pi = _monitor_seg_v2(
            seg, prev_re, prev_im, cosp, sinp, rott, params,
            win=win, k=K, interpret=interpret, use_pallas=use_pallas)
        worsts.append(np.asarray(worst)[fill:new_fill])
        clss.append(np.asarray(cls)[fill:new_fill])
        last = (pr, pi, prev_re, prev_im, new_fill - 1, seg0)
        if new_fill == win:                   # segment complete: hop
            prev_re, prev_im = pr, pi
            seg = np.zeros((win,), np.float32)
            fill = 0
        else:
            fill = new_fill
        offset += take
        pos += take
    if worsts:
        worst_cat = np.concatenate(worsts)
        cls_cat = np.concatenate(clss)
        pr, pi, pre, pim, b, seg0 = last
        esc, levels, amps_last = _monitor_tail(
            cls_cat, np.int32(offset0), carry.esc, pr, pi, pre, pim, rott,
            np.int32(b), np.float32(seg0 * win + b), win=win, k=K,
            sustain_n=sustain_n, cool_n=cool_n, max_level=max_level)
        levels = np.asarray(levels)
        amps_last = np.asarray(amps_last)
    else:
        worst_cat = np.zeros((0,), np.float32)
        levels = np.zeros((0,), np.int32)
        esc = carry.esc
        amps_last = np.zeros((K,), np.float32)
    new_carry = MonitorCarry(
        sliding=SlidingCarry(offset=offset, fill=fill, seg=seg,
                             prev_re=prev_re, prev_im=prev_im,
                             mean=sl.mean),
        esc=esc)
    return worst_cat, levels, amps_last, new_carry


def sliding_monitor_fused(x, dt: float, freqs, *, win: int, threshold,
                          sustain_n: int, cool_n: int, max_level: int = 3,
                          release=None, block_s: int = 0,
                          interpret: Optional[bool] = None,
                          use_pallas: bool = True,
                          carry: MonitorCarry = None):
    """The fused sliding monitor: worst-bin amplitude + escalation state
    straight from the trace, without ever materializing the [n, K]
    amplitude matrix.

    Offline (``carry=None``): returns ``(worst [n], levels [n], detect,
    peaks [S, K])`` — the per-sample worst-bin amplitude, escalation
    levels (``core.telemetry`` machine: ``threshold``/``release`` with
    ``sustain_n``/``cool_n`` hysteresis, warm-up and pad gated), the
    first-escalation sample index (-1 if never), and per-window per-bin
    peak amplitudes.  ``threshold`` (and ``release``, default
    ``threshold``) may be traced — they enter the kernel as runtime
    scalars.  ``use_pallas=False`` selects the structurally identical
    jnp ``lax.scan`` mirror (bitwise equal to the interpret-mode kernel;
    the differentiable path).

    Online (``carry=`` a ``MonitorCarry`` from ``monitor_carry_init``):
    consumes one concrete chunk and returns ``(worst [m], levels [m],
    amps_last [K], carry')``; chunked ``worst``/``levels`` concatenate
    bit-identically to the offline call on the concatenated trace (given
    ``mean=trace_mean(full)`` and matching ``threshold``), and
    ``amps_last`` reports per-bin amplitudes at the chunk's last sample,
    recombined in O(K) from the kernel's streamed prefix state.
    """
    if interpret is None:
        interpret = interpret_default()
    rel = threshold if release is None else release
    if carry is None:
        return _sliding_monitor_full(
            x, threshold, rel, dt, tuple(freqs), win=win,
            sustain_n=sustain_n, cool_n=cool_n, max_level=max_level,
            block_s=block_s, interpret=interpret, use_pallas=use_pallas)
    return _sliding_monitor_carry(
        x, threshold, rel, dt, tuple(freqs), win=win, sustain_n=sustain_n,
        cool_n=cool_n, max_level=max_level, interpret=interpret,
        use_pallas=use_pallas, carry=carry)

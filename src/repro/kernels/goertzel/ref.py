"""Pure oracle(s) for the Goertzel bin-power kernels.

``bin_power_ref`` — per-window DFT-bin amplitude by direct correlation
(the mathematical definition the Goertzel recurrence implements).
``sliding_bin_power_ref`` — every-sample sliding window, float64 numpy:
the gold oracle the Pallas sliding kernel is tested against.
``sliding_bin_power_jnp`` — traced jnp mirror (jit/vmap-safe).

Numerics note (the PR-3 bugfix): both sliding estimators remove the
trace mean before accumulating.  Raw MW-scale traces carry a DC offset
(~5e8 W) three to four orders of magnitude above the oscillation
amplitudes the backstop guards against (~1e5 W); feeding that DC into
f32 cumulative sums buries the signal in rounding noise (the 9 Hz bin's
quiet-trace floor reaches ~1e4 W on a 30-minute trace) and makes every
partial warm-up window read ~2*DC, so no threshold can separate a real
oscillation from a quiet trace.  Removing the mean keeps every partial
sum at oscillation scale; the bins of interest (>= 0.1 Hz) measure the
AC content, which is unchanged.  The numpy ref additionally accumulates
in float64, making it exact at any trace length.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def goertzel_ref(windows, coef) -> jnp.ndarray:
    """Exact pure-jnp mirror of the kernel recurrence.

    windows: [W, win]; coef: [K] = 2*cos(2*pi*f*dt) -> amplitudes [W, K].
    (At integer cycles-per-window this equals ``bin_power_ref``; at
    fractional bins the two estimators differ by design — tests check both.)
    """
    import jax
    windows = jnp.asarray(windows, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    W, win = windows.shape
    K = coef.shape[0]

    def step(carry, xt):  # xt: [W]
        s1, s2 = carry
        s0 = xt[:, None] + coef[None, :] * s1 - s2
        return (s0, s1), None

    (s1, s2), _ = jax.lax.scan(
        step, (jnp.zeros((W, K), jnp.float32), jnp.zeros((W, K), jnp.float32)),
        windows.T)
    power = s1 * s1 + s2 * s2 - coef[None, :] * s1 * s2
    return (2.0 / win) * jnp.sqrt(jnp.maximum(power, 0.0))


def bin_power_ref(windows, dt: float, freqs) -> jnp.ndarray:
    """windows: [W, win]; freqs: [K] Hz -> amplitudes [W, K]."""
    windows = jnp.asarray(windows, jnp.float32)
    win = windows.shape[1]
    t = jnp.arange(win)[:, None] * (2 * jnp.pi * dt) * jnp.asarray(freqs)[None, :]
    re = jnp.einsum("wt,tk->wk", windows, jnp.cos(t))
    im = jnp.einsum("wt,tk->wk", windows, jnp.sin(t))
    return (2.0 / win) * jnp.sqrt(re * re + im * im)


def sliding_bin_power_jnp(x: jnp.ndarray, dt: float, freqs,
                          win: int) -> jnp.ndarray:
    """Traced mirror of ``sliding_bin_power_ref``: every-sample sliding
    window bin amplitudes [n, K] via complex cumulative sums of the
    mean-removed trace, jit/vmap-safe (``freqs`` and ``win`` are static).

    The product path is the Pallas kernel (``ops.sliding_bin_power``);
    this oracle stays the analysis-side reference and the backstop's
    ``use_pallas=False`` fallback.
    """
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[-1]
    xc = x - jnp.mean(x)            # DC removal: see module docstring
    # phases stay in-graph: a global-phase table is [n, K] (vs the Pallas
    # kernel's [win, K] host-precomputed tables) — materializing it as a
    # constant would bake tens of MB into the executable per trace length.
    # Post mean-removal the ~1e-3 rad f32 phase error at 10-minute traces
    # only scales the AC signal, not the DC offset.
    f = jnp.asarray(freqs, jnp.float32)
    t = jnp.arange(n, dtype=jnp.float32) * dt
    ph = jnp.exp(-2j * jnp.pi * t[:, None] * f[None, :])      # [n, K]
    cs = jnp.cumsum(xc[:, None] * ph, axis=0)
    w = jnp.concatenate([cs[:win], cs[win:] - cs[:-win]]) if n > win else cs
    denom = jnp.minimum(jnp.arange(n, dtype=jnp.float32) + 1.0, float(win))
    return 2.0 * jnp.abs(w) / denom[:, None]


def sliding_bin_power_ref(x: np.ndarray, dt: float, freqs: np.ndarray,
                          win: int) -> np.ndarray:
    """Every-sample sliding-window bin amplitudes [n, K] (numpy float64 —
    the gold oracle: mean-removed AND exact accumulation)."""
    x = np.asarray(x, np.float64)
    xc = x - x.mean()
    n = len(xc)
    k = len(freqs)
    out = np.zeros((n, k))
    t = np.arange(n) * dt
    for j, f in enumerate(freqs):
        ph = np.exp(-2j * np.pi * f * t)
        cs = np.cumsum(xc * ph)
        w = cs.copy()
        w[win:] = cs[win:] - cs[:-win]
        denom = np.minimum(np.arange(n) + 1, win)
        out[:, j] = 2.0 * np.abs(w) / denom
    return out

"""Pure oracle(s) for the Goertzel bin-power kernel.

``bin_power_ref`` — per-window DFT-bin amplitude by direct correlation
(the mathematical definition the Goertzel recurrence implements).
``sliding_bin_power_ref`` — every-sample sliding window via complex
cumulative sums (used analysis-side by the backstop controller).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def goertzel_ref(windows, coef) -> jnp.ndarray:
    """Exact pure-jnp mirror of the kernel recurrence.

    windows: [W, win]; coef: [K] = 2*cos(2*pi*f*dt) -> amplitudes [W, K].
    (At integer cycles-per-window this equals ``bin_power_ref``; at
    fractional bins the two estimators differ by design — tests check both.)
    """
    import jax
    windows = jnp.asarray(windows, jnp.float32)
    coef = jnp.asarray(coef, jnp.float32)
    W, win = windows.shape
    K = coef.shape[0]

    def step(carry, xt):  # xt: [W]
        s1, s2 = carry
        s0 = xt[:, None] + coef[None, :] * s1 - s2
        return (s0, s1), None

    (s1, s2), _ = jax.lax.scan(
        step, (jnp.zeros((W, K), jnp.float32), jnp.zeros((W, K), jnp.float32)),
        windows.T)
    power = s1 * s1 + s2 * s2 - coef[None, :] * s1 * s2
    return (2.0 / win) * jnp.sqrt(jnp.maximum(power, 0.0))


def bin_power_ref(windows, dt: float, freqs) -> jnp.ndarray:
    """windows: [W, win]; freqs: [K] Hz -> amplitudes [W, K]."""
    windows = jnp.asarray(windows, jnp.float32)
    win = windows.shape[1]
    t = jnp.arange(win)[:, None] * (2 * jnp.pi * dt) * jnp.asarray(freqs)[None, :]
    re = jnp.einsum("wt,tk->wk", windows, jnp.cos(t))
    im = jnp.einsum("wt,tk->wk", windows, jnp.sin(t))
    return (2.0 / win) * jnp.sqrt(re * re + im * im)


def sliding_bin_power_jnp(x: jnp.ndarray, dt: float, freqs,
                          win: int) -> jnp.ndarray:
    """Traced mirror of ``sliding_bin_power_ref``: every-sample sliding
    window bin amplitudes [n, K] via complex cumulative sums, jit/vmap-safe
    (``freqs`` and ``win`` are static)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[-1]
    f = jnp.asarray(freqs, jnp.float32)
    t = jnp.arange(n, dtype=jnp.float32) * dt
    ph = jnp.exp(-2j * jnp.pi * t[:, None] * f[None, :])      # [n, K]
    cs = jnp.cumsum(x[:, None] * ph, axis=0)
    w = jnp.concatenate([cs[:win], cs[win:] - cs[:-win]]) if n > win else cs
    denom = jnp.minimum(jnp.arange(n, dtype=jnp.float32) + 1.0, float(win))
    return 2.0 * jnp.abs(w) / denom[:, None]


def sliding_bin_power_ref(x: np.ndarray, dt: float, freqs: np.ndarray,
                          win: int) -> np.ndarray:
    """Every-sample sliding-window bin amplitudes [n, K] (numpy)."""
    n = len(x)
    k = len(freqs)
    out = np.zeros((n, k))
    t = np.arange(n) * dt
    for j, f in enumerate(freqs):
        ph = np.exp(-2j * np.pi * f * t)
        cs = np.cumsum(x * ph)
        w = cs.copy()
        w[win:] = cs[win:] - cs[:-win]
        denom = np.minimum(np.arange(n) + 1, win)
        out[:, j] = 2.0 * np.abs(w) / denom
    return out

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. derives the arch's sharding plan (parallel/sharding.py),
  3. lowers + compiles train_step (train shapes) or serve_step/prefill
     (inference shapes) against ShapeDtypeStruct stand-ins — no allocation,
  4. records memory_analysis / cost_analysis / per-opcode collective bytes
     (parsed from the partitioned HLO) into artifacts/dryrun/<cell>.json.

EXPERIMENTS.md §Dry-run and §Roofline are generated from these artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out artifacts/dryrun
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, TrainConfig, get_config, shapes_for
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models.model import Ctx, init_cache, init_params, make_prefill
from repro.parallel.sharding import (batch_pspecs, cache_pspecs, make_plan,
                                     param_pspecs)
from repro.serve.engine import make_serve_step
from repro.train.trainer import (TrainState, in_out_shardings,
                                 init_train_state, make_train_step)

# Memory-fit knobs for the biggest archs (documented in EXPERIMENTS.md).
MOMENT_DTYPE = {
    "nemotron-4-340b": "bfloat16",
    "qwen1.5-110b": "bfloat16",
    "dbrx-132b": "bfloat16",
    "jamba-v0.1-52b": "bfloat16",
}
# grad-accumulation microbatches for train cells: global batch 256 ->
# 32/microbatch keeps per-device residuals (scan-over-layers carry stack)
# inside v5e HBM; see EXPERIMENTS.md §Dry-run.
MICROBATCH = {"train_4k": 8}

from repro.launch.hlo_analysis import hlo_collective_bytes, jaxpr_costs


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for every model input (assignment step 2)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Training/prefill batch ShapeDtypeStructs (no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"labels": sds((B, S), jnp.int32)}
    if cfg.input_mode == "tokens":
        batch["tokens"] = sds((B, S), jnp.int32)
    else:
        batch["inputs"] = sds((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.vision is not None:
        batch["vision_embeds"] = sds(
            (B, cfg.vision.n_tokens, cfg.vision.dim), jnp.bfloat16)
    return batch


def _struct(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _eval_shape_params(cfg):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def _per_device_bytes(struct_tree, shard_tree, mesh) -> int:
    total = 0
    for leaf, sh in zip(jax.tree.leaves(struct_tree), jax.tree.leaves(shard_tree)):
        n = leaf.size * jnp.dtype(leaf.dtype).itemsize
        spec = sh.spec if hasattr(sh, "spec") else sh
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                denom *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
        total += -(-n // denom)
    return total


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               tcfg: Optional[TrainConfig] = None, trace_only: bool = False,
               flash: bool = False):
    plan = make_plan(cfg, mesh, kind=shape.kind)
    ns = lambda spec: NamedSharding(mesh, spec)

    if shape.kind == "train":
        # pure-FSDP plans put every sequence on its own chip — microbatch
        # accumulation would make per-microbatch batches unshardable
        mb = 1 if plan.tp_axis is None else MICROBATCH.get(shape.name, 1)
        tcfg = tcfg or TrainConfig(
            remat="full", moment_dtype=MOMENT_DTYPE.get(cfg.name, "float32"),
            microbatches=mb)
        params_s = _eval_shape_params(cfg)
        opt_s = jax.eval_shape(
            lambda p: __import__("repro.train.optimizer", fromlist=["x"])
            .init_opt_state(p, tcfg.moment_dtype), params_s)
        state_s = TrainState(params_s, opt_s,
                             jax.ShapeDtypeStruct((), jnp.int32))
        batch_s = input_specs(cfg, shape)
        state_sh, batch_sh, _ = in_out_shardings(cfg, plan, state_s, batch_s)
        step = make_train_step(cfg, tcfg, plan)
        jf = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
        lowered = None if trace_only else jf.lower(state_s, batch_s)
        extra_structs = (state_s, state_sh)
        trace = (step, (state_s, batch_s))

    elif shape.kind == "prefill":
        params_s = _eval_shape_params(cfg)
        cache_s = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16))
        batch_s = input_specs(cfg, shape)
        batch_s.pop("labels")
        p_sh = jax.tree.map(ns, param_pspecs(cfg, plan, params_s))
        c_sh = jax.tree.map(ns, cache_pspecs(cfg, plan, cache_s))
        b_sh = jax.tree.map(ns, batch_pspecs(cfg, plan, batch_s))
        prefill = make_prefill(cfg)

        def prefill_step(params, batch, cache):
            ctx = Ctx(cfg=cfg, flash=flash, moe_sm=plan.moe_sm(cfg),
                      **plan.ctx_kwargs())
            return prefill(params, batch, cache, ctx)

        jf = jax.jit(prefill_step, in_shardings=(p_sh, b_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
        lowered = None if trace_only else jf.lower(params_s, batch_s, cache_s)
        extra_structs = ((params_s, cache_s), (p_sh, c_sh))
        trace = (prefill_step, (params_s, batch_s, cache_s))

    else:  # decode
        params_s = _eval_shape_params(cfg)
        cache_s = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len, jnp.bfloat16))
        B = shape.global_batch
        if cfg.input_mode == "tokens":
            inp_s = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        else:
            inp_s = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
        idx_s = jax.ShapeDtypeStruct((), jnp.int32)
        p_sh = jax.tree.map(ns, param_pspecs(cfg, plan, params_s))
        c_sh = jax.tree.map(ns, cache_pspecs(cfg, plan, cache_s, batch_size=B))
        from repro.parallel.sharding import dp_size
        bdp = plan.dp if B % dp_size(plan) == 0 else None
        i_sh = ns(P(bdp, *([None] * (len(inp_s.shape) - 1))))
        serve = make_serve_step(cfg, plan)
        jf = jax.jit(serve, in_shardings=(p_sh, i_sh, c_sh, ns(P())),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
        lowered = None if trace_only else jf.lower(params_s, inp_s, cache_s, idx_s)
        extra_structs = ((params_s, cache_s), (p_sh, c_sh))
        trace = (serve, (params_s, inp_s, cache_s, idx_s))

    return lowered, plan, extra_structs, trace


def run_cell(arch: str, shape: ShapeConfig, multi_pod: bool) -> Dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, plan, (structs, shards), trace = lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" in k.lower())}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                if hasattr(ma, attr):
                    mem[attr] = int(getattr(ma, attr))
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    # Always record the sharding-derived per-device state bytes (exact).
    mem["state_bytes_per_device"] = _per_device_bytes(structs, shards, mesh)

    coll = hlo_collective_bytes(compiled.as_text())
    fn, targs = trace
    exact = jaxpr_costs(fn, *targs, chips=float(mesh.devices.size))
    n_chips = mesh.devices.size
    result = {
        "arch": arch, "shape": shape.name, "kind": shape.kind,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": int(n_chips),
        "attn_mode": plan.attn_mode, "kv_repeat": plan.kv_repeat,
        "shard_vocab": plan.shard_vocab,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": cost, "memory": mem, "collectives": coll,
        "exact": exact,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--retrace", action="store_true",
                    help="recompute the jaxpr 'exact' costs in existing "
                         "artifacts without recompiling")
    ap.add_argument("--flash", action="store_true",
                    help="with --retrace: cost prefill cells with the Pallas "
                         "flash-attention kernel (forward-only)")
    args = ap.parse_args()

    if args.retrace:
        import glob
        for path in sorted(glob.glob(os.path.join(args.out, "*.json"))):
            with open(path) as f:
                res = json.load(f)
            if "error" in res:
                continue
            cfg = get_config(res["arch"])
            shape = next(s for s in shapes_for(cfg) if s.name == res["shape"])
            mesh = make_production_mesh(multi_pod=res["mesh"] == "multi")
            if args.flash and shape.kind != "prefill":
                continue  # flash kernel is forward-only (prefill/serve)
            _, _, _, (fn, targs) = lower_cell(cfg, shape, mesh, trace_only=True,
                                              flash=args.flash)
            res["exact"] = jaxpr_costs(fn, *targs, chips=float(mesh.devices.size))
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"[retrace] {os.path.basename(path)} "
                  f"flops={res['exact']['flops']:.3e} bytes={res['exact']['bytes']:.3e}",
                  flush=True)
        return

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            if args.shape != "all" and shape.name not in args.shape.split(","):
                continue
            for mp in meshes:
                cell = f"{arch}__{shape.name}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, cell + ".json")
                if os.path.exists(path):
                    print(f"[skip] {cell}", flush=True)
                    continue
                print(f"[cell] {cell} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                    n_ok += 1
                    print(f"  ok lower={res['lower_s']}s compile={res['compile_s']}s "
                          f"flops={res['cost'].get('flops', 0):.3e} "
                          f"coll={sum(res['collectives'].values()):.3e}B", flush=True)
                except Exception:
                    n_fail += 1
                    res = {"arch": arch, "shape": shape.name,
                           "mesh": "multi" if mp else "single",
                           "error": traceback.format_exc()}
                    print(f"  FAIL {cell}", flush=True)
                    traceback.print_exc()
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"dryrun done: {n_ok} ok, {n_fail} failed", flush=True)


if __name__ == "__main__":
    main()

"""Exact cost extraction for the dry-run roofline.

Two analyses, complementing ``compiled.cost_analysis()`` (which counts XLA
while-loop bodies ONCE, silently dropping the x n_layers factor — verified
in EXPERIMENTS.md §Dry-run methodology):

1. ``jaxpr_costs``: walks the step function's jaxpr, multiplying every
   ``scan``/``while`` body by its trip count. FLOPs are exact for
   dot_general-dominated programs (einsums); byte counts are an un-fused
   upper bound (every eqn's operands+outputs counted once).

2. ``hlo_collective_bytes``: parses the *partitioned* HLO, attributes every
   collective to its enclosing computation, recovers while trip counts from
   loop-condition constants, and multiplies — giving per-chip wire bytes per
   step, by opcode.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional

import jax
import numpy as np

# ---------------------------------------------------------------------------
# 1. jaxpr walker
# ---------------------------------------------------------------------------

_INNER_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr",
                       "body_jaxpr", "branches")


def _avals_bytes(avals) -> float:
    total = 0.0
    for a in avals:
        try:
            total += float(np.prod(a.shape) if a.shape else 1) * a.dtype.itemsize
        except Exception:
            pass
    return total


def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    lhs = eqn.invars[0].aval
    out = eqn.outvars[0].aval
    contract = 1.0
    for d in lc:
        contract *= lhs.shape[d]
    return 2.0 * float(np.prod(out.shape) if out.shape else 1) * contract


VMEM_BUDGET = 64e6  # per-chip bytes assumed residency-eligible (v5e: 128MB)


def _walk(jaxpr, mult: float, acc: Dict[str, float],
          chips: float = 1.0, kernel: bool = False) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        inner_mult = mult
        handled_inner = False
        if prim == "pallas_call":
            # Pallas kernel: internals live in VMEM — HBM traffic is the
            # operand/result block streams only; FLOPs = kernel-body dots
            # x grid size (each grid cell executes the body once).
            grid = getattr(eqn.params.get("grid_mapping"), "grid", ()) or (1,)
            gsz = 1.0
            for g in grid:
                gsz *= g
            _walk(eqn.params["jaxpr"], mult * gsz, acc, chips, kernel=True)
            in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
            out_avals = [v.aval for v in eqn.outvars]
            acc["bytes"] += mult * (_avals_bytes(in_avals) + _avals_bytes(out_avals))
            continue
        if prim == "scan":
            length = eqn.params.get("length", 1)
            body = eqn.params["jaxpr"].jaxpr
            _walk(body, mult * length, acc, chips, kernel)
            # VMEM-resident carries: a scan whose carry fits in VMEM does
            # not round-trip it through HBM every iteration (flash-attention
            # style blocking). Refund the per-iteration carry read+write the
            # body accounting charged. (Cost-model refinement — see
            # EXPERIMENTS.md §Perf iteration 1.)
            n_carry = eqn.params.get("num_carry", 0)
            if n_carry:
                carry_avals = [v.aval for v in body.outvars[:n_carry]]
                carry_bytes = _avals_bytes(carry_avals)
                if carry_bytes / max(chips, 1.0) < VMEM_BUDGET:
                    refund = 2.0 * carry_bytes * (length - 1) * mult
                    acc["bytes"] = max(acc["bytes"] - refund, 0.0)
            handled_inner = True
        elif prim == "while":
            # trip count unknowable in general; jax fori/scan lowers to scan.
            # Assume 1 (we never emit raw while in the model code).
            _walk(eqn.params["body_jaxpr"].jaxpr, mult, acc, chips, kernel)
            _walk(eqn.params["cond_jaxpr"].jaxpr, mult, acc, chips, kernel)
            handled_inner = True
        elif prim == "cond":
            for br in eqn.params["branches"]:
                _walk(br.jaxpr, mult, acc, chips, kernel)
            handled_inner = True
        else:
            for pname in _INNER_JAXPR_PARAMS:
                sub = eqn.params.get(pname)
                if sub is None:
                    continue
                subs = sub if isinstance(sub, (tuple, list)) else (sub,)
                for s in subs:
                    _walk(s.jaxpr if hasattr(s, "jaxpr") else s, mult, acc, chips, kernel)
                handled_inner = True
        if handled_inner:
            continue

        in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
        out_avals = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
        out_bytes = 0.0 if kernel else _avals_bytes(out_avals)
        if kernel:
            in_avals = []  # kernel internals are VMEM-resident
        if prim == "dot_general":
            # matmuls dominate real HBM traffic: operands + result
            acc["flops"] += mult * _dot_flops(eqn)
            acc["bytes"] += mult * (_avals_bytes(in_avals) + out_bytes)
        elif prim in ("gather", "scatter", "scatter-add", "scatter_add",
                      "dynamic_slice", "dynamic_update_slice", "take"):
            acc["bytes"] += mult * out_bytes * 2
        elif prim in ("broadcast_in_dim", "reshape", "transpose",
                      "convert_element_type", "squeeze", "slice",
                      "concatenate", "pad", "rev", "iota", "copy",
                      "sharding_constraint", "stop_gradient",
                      "optimization_barrier"):
            pass  # layout ops: fused / zero-cost under XLA
        elif prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_and",
                      "reduce_or", "argmax", "argmin", "reduce_precision",
                      "cumsum", "cumlogsumexp", "cummax", "sort"):
            acc["flops"] += mult * _avals_bytes(in_avals) / 4.0
            acc["bytes"] += mult * (_avals_bytes(in_avals) + out_bytes)
        else:
            # elementwise: 1 flop/elem; assume producer->consumer fusion so
            # each eqn contributes one materialized write (no re-reads)
            acc["flops"] += mult * sum(
                float(np.prod(a.shape) if a.shape else 1) for a in out_avals)
            acc["bytes"] += mult * out_bytes
    return


def jaxpr_costs(fn, *args, chips: float = 1.0, **kwargs) -> Dict[str, float]:
    """Exact (global, unpartitioned) flops & upper-bound bytes of fn(*args).

    ``chips``: partition count used only for the VMEM-residency decision on
    scan carries (global carry bytes / chips vs VMEM_BUDGET)."""
    closed = jax.make_jaxpr(fn, **kwargs)(*args)
    acc = {"flops": 0.0, "bytes": 0.0}
    _walk(closed.jaxpr, 1.0, acc, chips)
    return acc


# ---------------------------------------------------------------------------
# 2. trip-count-aware collective parsing of partitioned HLO
# ---------------------------------------------------------------------------

BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s64": 8,
         "u64": 8, "f64": 8, "s8": 1, "u8": 1, "pred": 1, "s16": 2,
         "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_COLL_RE = re.compile(
    r"=\s*((?:\([^=]*?\)|[\w\[\],\{\}]+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_WHILE_RE = re.compile(
    r"\bwhile\(.*condition=%?([\w\.\-]+).*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * BYTES[dt]
    return total


def _split_computations(text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    entry = None
    for line in text.splitlines():
        s = line.strip()
        if s.endswith("{") and "->" in s:
            m = _COMP_RE.match(s)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if s.startswith("ENTRY"):
                    entry = cur
                continue
        if s == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(s)
    if entry is not None and entry != "__entry__":
        comps["__entry__"] = comps[entry]
    return comps


def hlo_collective_bytes(text: str) -> Dict[str, float]:
    """Per-chip wire bytes by opcode, with while trip-count multipliers."""
    comps = _split_computations(text)

    # direct collective bytes + sub-calls per computation
    direct: Dict[str, Dict[str, float]] = {}
    calls: Dict[str, list] = {}
    for name, lines in comps.items():
        d: Dict[str, float] = {}
        cl = []
        for line in lines:
            mc = _COLL_RE.search(line)
            if mc:
                b = _shape_bytes(mc.group(1)) * COLL_FACTOR[mc.group(2)]
                d[mc.group(2)] = d.get(mc.group(2), 0.0) + b
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.group(1), mw.group(2)
                mt = _TRIP_RE.search(line)
                if mt:
                    trip = int(mt.group(1))
                else:  # fallback: largest constant in the loop condition
                    trip = 1
                    for cm in _CONST_RE.finditer("\n".join(comps.get(cond, []))):
                        trip = max(trip, int(cm.group(1)))
                cl.append((body, trip))
                cl.append((cond, trip))
            else:
                for cm in _CALL_RE.finditer(line):
                    cl.append((cm.group(1), 1))
        direct[name] = d
        calls[name] = cl

    total: Dict[str, float] = {}
    seen_stack = []

    def visit(name: str, mult: float):
        if name not in comps or name in seen_stack:
            return
        seen_stack.append(name)
        for op, b in direct.get(name, {}).items():
            total[op] = total.get(op, 0.0) + b * mult
        for child, trip in calls.get(name, []):
            visit(child, mult * trip)
        seen_stack.pop()

    visit("__entry__", 1.0)
    return total

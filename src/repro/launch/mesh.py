"""Production mesh factories.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run boots 512 host
placeholder devices while smoke tests and benches must see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run via launch/dryrun.py "
            "which sets XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])

"""Serving launcher: batched generation with a KV cache (CPU-runnable).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=args.prompt_len + args.gen + 1,
                      batch=args.batch)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    out = eng.generate(prompts, args.gen, temperature=args.temperature,
                       key=jax.random.PRNGKey(2))
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print(out[:, :16])


if __name__ == "__main__":
    main()

"""Training launcher: config-driven, fault-tolerant, power-aware.

Features wired in (the production path, CPU-runnable at reduced scale):
  * auto-resume from the newest checkpoint (bitwise, incl. data position);
  * async checkpointing with retention GC;
  * power-aware restart: prints/obeys the stagger schedule before ramping
    the fleet (paper Sec. IV-A / DESIGN.md §7);
  * optional in-graph ballast (Firefly, TPU-native) sized in GFLOPs.

Example (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.core as core
from repro.ckpt import CheckpointManager
from repro.configs import TrainConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.train import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ballast-gflops", type=float, default=0.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
                       total_steps=args.steps, microbatches=args.microbatches,
                       ballast=args.ballast_gflops > 0,
                       ballast_gflops=args.ballast_gflops)

    # power-aware ramp-in: at restart the whole fleet would slam from idle
    # to TDP; obey a stagger schedule sized for a moderate utility spec
    hw = core.DEFAULT_HW
    n_racks = hw.topo.racks_per_pod
    rack_w = hw.topo.chips_per_rack * hw.chip.tdp_w
    spec = core.example_specs(job_mw=n_racks * rack_w / 1e6)["moderate"]
    sched = core.plan_stagger(n_racks, rack_w, spec.time.ramp_up_w_per_s)
    print(f"[power] stagger ramp-in: {n_racks} racks over {sched.total_s:.1f}s "
          f"(rack ramp {sched.rack_ramp_w_per_s/1e3:.1f} kW/s)")

    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    mgr = None
    start = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3, async_save=True)
        restored, manifest = mgr.restore_latest(state)
        if restored is not None:
            state = restored
            start = int(manifest["step"])
            print(f"[ckpt] resumed from step {start}")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data(i).items()}
        state, m = step_fn(state, batch)
        if (i + 1) % args.log_every == 0:
            print(f"step {i+1:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i-start+1):.2f}s/step)", flush=True)
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state)
            print(f"[ckpt] saved step {i+1}", flush=True)
    if mgr:
        mgr.wait()
    print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()

from repro.models.model import (Model, init_params, forward, loss_fn,
                                make_prefill, make_decode_step, init_cache)

__all__ = ["Model", "init_params", "forward", "loss_fn", "make_prefill",
           "make_decode_step", "init_cache"]

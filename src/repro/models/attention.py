"""Attention mixers: GQA (dense + KV-chunked online-softmax), MLA, cross-attn.

Layouts (chosen for sharding friendliness — see parallel/sharding.py):
  activations      x      [B, S, d]
  queries          q      [B, S, KV, G, D]   (KV*G = n_q_heads, possibly
                                              after kv-head duplication)
  keys/values      k, v   [B, T, KV, D]
  decode KV cache  ck, cv [B, KV, S_max, D]  (seq axis sharded over "model")

KV-head duplication: when tensor-parallel degree exceeds n_kv_heads, kv
heads are repeated r times after projection (mathematically a no-op for
grouped attention; lets GSPMD shard the kv-head axis). ``ctx.kv_repeat``
carries r (1 = off).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

F32 = jnp.float32
NEG = -1e30


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------

def init_attn(key, cfg, dtype):
    a = cfg.attention
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, a.n_heads * a.head_dim, dtype).reshape(d, a.n_heads, a.head_dim),
        "wk": dense_init(ks[1], d, a.n_kv_heads * a.head_dim, dtype).reshape(d, a.n_kv_heads, a.head_dim),
        "wv": dense_init(ks[2], d, a.n_kv_heads * a.head_dim, dtype).reshape(d, a.n_kv_heads, a.head_dim),
        "wo": dense_init(ks[3], a.n_heads * a.head_dim, d, dtype).reshape(a.n_heads, a.head_dim, d),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.n_heads, a.head_dim), dtype)
        p["bk"] = jnp.zeros((a.n_kv_heads, a.head_dim), dtype)
        p["bv"] = jnp.zeros((a.n_kv_heads, a.head_dim), dtype)
    return p


def init_xattn(key, cfg, dtype):
    a, v = cfg.attention, cfg.vision
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, a.n_heads * a.head_dim, dtype).reshape(d, a.n_heads, a.head_dim),
        "wk": dense_init(ks[1], v.dim, a.n_kv_heads * a.head_dim, dtype).reshape(v.dim, a.n_kv_heads, a.head_dim),
        "wv": dense_init(ks[2], v.dim, a.n_kv_heads * a.head_dim, dtype).reshape(v.dim, a.n_kv_heads, a.head_dim),
        "wo": dense_init(ks[3], a.n_heads * a.head_dim, d, dtype).reshape(a.n_heads, a.head_dim, d),
        "gate_attn": jnp.zeros((), dtype),
    }


def init_mla(key, cfg, dtype):
    a, m = cfg.attention, cfg.mla
    d = cfg.d_model
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, a.n_heads * qk_dim, dtype).reshape(d, a.n_heads, qk_dim),
        "wdkv": dense_init(ks[1], d, m.kv_lora_rank, dtype),
        "wkr": dense_init(ks[2], d, m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wuk": dense_init(ks[3], m.kv_lora_rank, a.n_heads * m.qk_nope_head_dim, dtype).reshape(m.kv_lora_rank, a.n_heads, m.qk_nope_head_dim),
        "wuv": dense_init(ks[4], m.kv_lora_rank, a.n_heads * m.v_head_dim, dtype).reshape(m.kv_lora_rank, a.n_heads, m.v_head_dim),
        "wo": dense_init(ks[5], a.n_heads * m.v_head_dim, d, dtype).reshape(a.n_heads, m.v_head_dim, d),
    }


# ---------------------------------------------------------------------------
# Core scaled-dot-product attention over [B,S,KV,G,D] queries
# ---------------------------------------------------------------------------

def _dense_sdpa(q, k, v, pos_q, pos_k, causal, scale):
    s = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=F32) * scale
    if causal:
        mask = pos_q[:, None] >= pos_k[None, :]
        s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), v,
                     preferred_element_type=F32)
    return out.astype(q.dtype)


def _chunked_sdpa(q, k, v, pos_q, causal, scale, chunk):
    """Online-softmax (flash-style) scan over KV chunks; f32 accumulators.

    Keeps peak memory at O(S*chunk) per head instead of O(S*T).
    """
    B, S, KV, G, D = q.shape
    Dv = v.shape[-1]  # may differ from D (MLA: qk 192 vs v 128)
    T = k.shape[1]
    n = T // chunk
    assert n * chunk == T, (T, chunk)
    qf = q.astype(F32)

    def step(carry, i):
        m, l, acc = carry
        k_c = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, 1)
        v_c = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, 1)
        s = jnp.einsum("bskgd,btkd->bkgst", qf, k_c.astype(F32)) * scale
        if causal:
            pos_kc = i * chunk + jnp.arange(chunk)
            mask = pos_q[:, None] >= pos_kc[None, :]
            s = jnp.where(mask[None, None, None], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        e = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + e.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", e, v_c.astype(F32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, S), NEG, F32)
    l0 = jnp.zeros((B, KV, G, S), F32)
    a0 = jnp.zeros((B, KV, G, S, Dv), F32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)  # -> [B,S,KV,G,D]


def _q_chunked_sdpa(q, k, v, pos_q, causal, scale, chunk, q_chunk):
    """Outer scan over q blocks, inner online-softmax scan over KV chunks.

    Perf iteration #1 (EXPERIMENTS.md §Perf): with q un-chunked the f32
    softmax accumulators are [B,H,S,D] — far beyond VMEM at 32k, so every
    KV-chunk step rewrites them to HBM (the memory-roofline term exploded).
    Blocking q keeps the accumulators at [B,H,q_chunk,D] (VMEM-resident on
    TPU) and cuts accumulator HBM traffic by S/q_chunk.
    """
    B, S, KV, G, D = q.shape
    nq = S // q_chunk
    qb = q.reshape(B, nq, q_chunk, KV, G, D)
    pb = pos_q.reshape(nq, q_chunk)

    def one_block(_, inp):
        q_i, pos_i = inp
        out = _chunked_sdpa(q_i, k, v, pos_i, causal, scale, chunk)
        return None, out

    _, outs = jax.lax.scan(one_block, None,
                           (jnp.swapaxes(qb, 0, 1), pb))
    Dv = outs.shape[-1]  # v head dim (MLA: 128 vs qk 192)
    return jnp.swapaxes(outs, 0, 1).reshape(B, S, KV, G, Dv)


def sdpa(q, k, v, *, pos_q, causal=True, chunk=1024, q_chunk=2048,
         flash=False):
    """q:[B,S,KV,G,D] k,v:[B,T,KV,D] -> [B,S,KV,G,D]."""
    T = k.shape[1]
    S = q.shape[1]
    scale = 1.0 / math.sqrt(q.shape[-1])
    pos_k = jnp.arange(T)
    if (flash and S == T and S % q_chunk == 0 and T % chunk == 0
            and S > chunk):
        # Pallas fused kernel (forward-only paths); perf iteration #2
        from repro.kernels.flash.flash import flash_pallas
        return flash_pallas(q, k, v, q_block=q_chunk, kv_chunk=chunk,
                            causal=causal)
    if T <= chunk or T % chunk != 0:
        return _dense_sdpa(q, k, v, pos_q, pos_k, causal, scale)
    if S > q_chunk and S % q_chunk == 0:
        return _q_chunked_sdpa(q, k, v, pos_q, causal, scale, chunk, q_chunk)
    return _chunked_sdpa(q, k, v, pos_q, causal, scale, chunk)


def _group(q, kv_heads):
    """[B,S,H,D] -> [B,S,KV,G,D]."""
    B, S, H, D = q.shape
    return q.reshape(B, S, kv_heads, H // kv_heads, D)


def _repeat_kv(k, r, ctx):
    if r == 1:
        return k
    # Pin the pre-duplication K/V to batch-only sharding: without this,
    # GSPMD back-propagates the decode-cache's seq sharding into k and the
    # repeat becomes an "involuntary full rematerialization" (a full
    # all-gather of K/V per layer — perf iteration #3, EXPERIMENTS.md §Perf)
    k = ctx.constrain(k, "kv_pre")
    k = jnp.repeat(k, r, axis=2)
    return ctx.constrain(k, "kv_heads")


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def attn_forward(p, x, ctx, *, cache=None):
    """Self-attention over the full sequence. Returns (out, new_cache)."""
    a = ctx.cfg.attention
    r = ctx.kv_repeat
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dmk->bsmk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dmk->bsmk", x, p["wv"].astype(x.dtype))
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    pos = ctx.positions  # [S]
    q = apply_rope(q, pos[None, :, None], a.rope_theta)
    k = apply_rope(k, pos[None, :, None], a.rope_theta)
    k_pre, v_pre = k, v  # pre-duplication layout (decode-cache layout)
    k, v = _repeat_kv(k, r, ctx), _repeat_kv(v, r, ctx)
    q = ctx.constrain(_group(q, a.n_kv_heads * r), "q_heads")
    out = sdpa(q, k, v, pos_q=pos, causal=True, chunk=a.chunk_size,
               flash=ctx.flash)
    out = jnp.einsum("bskgd,kgde->bse", out,
                     _group_w(p["wo"], a.n_kv_heads * r).astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = _write_prefill_cache(cache, k_pre, v_pre, ctx)
    return out, new_cache


def _group_w(wo, kv):
    H, D, d = wo.shape
    return wo.reshape(kv, H // kv, D, d)


def _write_prefill_cache(cache, k, v, ctx):
    """k,v: [B,S,KV,D] -> cache layout [B,KV,S_max,D] (zero-padded)."""
    S_max = cache["k"].shape[2]
    k = jnp.swapaxes(k, 1, 2)
    v = jnp.swapaxes(v, 1, 2)
    pad = S_max - k.shape[2]
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return {"k": ctx.constrain(k.astype(cache["k"].dtype), "kv_cache"),
            "v": ctx.constrain(v.astype(cache["v"].dtype), "kv_cache")}


def xattn_forward(p, x, ctx, *, cache=None):
    """Gated cross-attention against precomputed vision patch embeddings."""
    a = ctx.cfg.attention
    r = ctx.kv_repeat
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cache is not None and "k" in cache and cache.get("_ready", False):
        k, v = cache["k"].astype(x.dtype), cache["v"].astype(x.dtype)
    else:
        vis = ctx.vision_embeds.astype(x.dtype)  # [B, Nv, vision_dim]
        k = jnp.einsum("bnd,dmk->bnmk", vis, p["wk"].astype(x.dtype))
        v = jnp.einsum("bnd,dmk->bnmk", vis, p["wv"].astype(x.dtype))
    k_pre, v_pre = k, v  # cache layout = pre-duplication
    k, v = _repeat_kv(k, r, ctx), _repeat_kv(v, r, ctx)
    q = _group(q, a.n_kv_heads * r)
    pos_q = jnp.zeros((x.shape[1],), jnp.int32)
    out = sdpa(q, k, v, pos_q=pos_q, causal=False, chunk=a.chunk_size)
    out = jnp.einsum("bskgd,kgde->bse", out,
                     _group_w(p["wo"], a.n_kv_heads * r).astype(x.dtype))
    out = jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype) * out
    new_cache = None
    if cache is not None:
        new_cache = {"k": k_pre.astype(cache["k"].dtype),
                     "v": v_pre.astype(cache["v"].dtype)}
    return out, new_cache


def mla_forward(p, x, ctx, *, cache=None):
    """DeepSeek-V2 Multi-head Latent Attention (full sequence)."""
    a, m = ctx.cfg.attention, ctx.cfg.mla
    from repro.models.layers import rms_norm
    pos = ctx.positions
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos[None, :, None], a.rope_theta)
    ckv = rms_norm(x @ p["wdkv"].astype(x.dtype), p["kv_norm"], ctx.cfg.norm_eps)
    krope = apply_rope((x @ p["wkr"].astype(x.dtype))[:, :, None, :],
                       pos[None, :, None], a.rope_theta)  # [B,T,1,R]
    # expand: per-head K = [k_nope | k_rope(bcast)], V from latent
    k_nope = jnp.einsum("btl,lhn->bthn", ckv, p["wuk"].astype(x.dtype))
    v = jnp.einsum("btl,lhv->bthv", ckv, p["wuv"].astype(x.dtype))
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        krope, (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1)
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    # MHA layout: KV=H, G=1; pad V to qk dim not needed (sdpa v dim free)
    out = sdpa(qh[:, :, :, None, :], k, v, pos_q=pos, causal=True,
               chunk=a.chunk_size, flash=ctx.flash)[:, :, :, 0, :]
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        S_max = cache["ckv"].shape[1]
        pad = S_max - ckv.shape[1]
        ckv_c = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))) if pad else ckv
        kr = krope[:, :, 0, :]
        kr_c = jnp.pad(kr, ((0, 0), (0, pad), (0, 0))) if pad else kr
        new_cache = {"ckv": ckv_c.astype(cache["ckv"].dtype),
                     "krope": kr_c.astype(cache["krope"].dtype)}
    return out, new_cache


# ---------------------------------------------------------------------------
# Single-token decode
# ---------------------------------------------------------------------------

def attn_decode(p, x, cache, index, ctx):
    """x: [B,1,d]; cache: {k,v: [B,KV,S,D]}; index: scalar position."""
    a = ctx.cfg.attention
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dmk->bsmk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dmk->bsmk", x, p["wv"].astype(x.dtype))
    if a.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    pos = jnp.full((1,), index)
    q = apply_rope(q, pos[None, :, None], a.rope_theta)
    k = apply_rope(k, pos[None, :, None], a.rope_theta)
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], jnp.swapaxes(k, 1, 2).astype(cache["k"].dtype), index, 2)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], jnp.swapaxes(v, 1, 2).astype(cache["v"].dtype), index, 2)
    q = _group(q, a.n_kv_heads)  # [B,1,KV,G,D]
    s = jnp.einsum("bskgd,bktd->bkgst", q, ck.astype(q.dtype),
                   preferred_element_type=F32) / math.sqrt(a.head_dim)
    mask = jnp.arange(ck.shape[2]) <= index
    s = jnp.where(mask[None, None, None, None, :], s, NEG)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,bktd->bskgd", prob.astype(q.dtype),
                     cv.astype(q.dtype), preferred_element_type=F32).astype(x.dtype)
    out = jnp.einsum("bskgd,kgde->bse", out, _group_w(p["wo"], a.n_kv_heads).astype(x.dtype))
    return out, {"k": ck, "v": cv}


def mla_decode(p, x, cache, index, ctx):
    """Weight-absorbed MLA decode: attends in the compressed latent space."""
    a, m = ctx.cfg.attention, ctx.cfg.mla
    from repro.models.layers import rms_norm
    pos = jnp.full((1,), index)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos[None, :, None], a.rope_theta)
    ckv_t = rms_norm(x @ p["wdkv"].astype(x.dtype), p["kv_norm"], ctx.cfg.norm_eps)
    kr_t = apply_rope((x @ p["wkr"].astype(x.dtype))[:, :, None, :],
                      pos[None, :, None], a.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_t.astype(cache["ckv"].dtype), index, 1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], kr_t.astype(cache["krope"].dtype), index, 1)
    # absorb W_uk into q; attend over latent cache
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, p["wuk"].astype(x.dtype))
    s = (jnp.einsum("bshl,btl->bhst", q_lat, ckv.astype(x.dtype), preferred_element_type=F32)
         + jnp.einsum("bshr,btr->bhst", q_rope, krope.astype(x.dtype), preferred_element_type=F32))
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    mask = jnp.arange(ckv.shape[1]) <= index
    s = jnp.where(mask[None, None, None, :], s, NEG)
    prob = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btl->bshl", prob, ckv.astype(x.dtype))
    out = jnp.einsum("bshl,lhv->bshv", o_lat, p["wuv"].astype(x.dtype))
    out = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(x.dtype))
    return out, {"ckv": ckv, "krope": krope}


def xattn_decode(p, x, cache, index, ctx):
    out, new_cache = xattn_forward(p, x, ctx, cache=dict(cache, _ready=True))
    return out, cache  # vision K/V static during decode


# ---------------------------------------------------------------------------
# Cache initializers
# ---------------------------------------------------------------------------

def init_attn_cache(cfg, batch, seq, dtype):
    a = cfg.attention
    shp = (batch, a.n_kv_heads, seq, a.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


def init_mla_cache(cfg, batch, seq, dtype):
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype)}


def init_xattn_cache(cfg, batch, dtype):
    a, vz = cfg.attention, cfg.vision
    shp = (batch, vz.n_tokens, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}

"""Shared primitives: norms, RoPE, initializers, dtype helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dt(name: str):
    return jnp.dtype(name)


def rms_norm(x, w, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def group_norm_heads(x, w, b, eps: float):
    """Per-head layer norm used by RWKV6 on the wkv output. x: [..., H, D]."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w + b).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, D] (D even), positions: broadcastable [..., S]."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers (fan-in scaled normal, the MaxText/Megatron default)
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def stack_init(key, n: int, init_fn):
    """vmap an init over a leading repeat axis (scan-over-layers params)."""
    return jax.vmap(init_fn)(jax.random.split(key, n))

"""Mamba (S6) selective-state-space mixer, as used by Jamba.

Training/prefill uses a ``lax.scan`` over time with f32 state; decode keeps
a (conv window, SSM state) tuple as its cache. The d_inner axis is the TP
axis (sharded over "model") — conv and scan are elementwise in d_inner so
the whole mixer is communication-free apart from the in/out projections.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

F32 = jnp.float32


def _dt_rank(d_model: int) -> int:
    return max(1, math.ceil(d_model / 16))


def init_mamba(key, cfg, dtype):
    m, d = cfg.mamba, cfg.d_model
    di = m.expand * d
    r = _dt_rank(d)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a_init = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=F32)[None, :], (di, 1))
    kx, kz = jax.random.split(ks[0])
    return {
        # separate x/z projections (a fused [d, 2*di] would force GSPMD to
        # reshard at the split point when di is TP-sharded)
        "in_proj_x": dense_init(kx, d, di, dtype),
        "in_proj_z": dense_init(kz, d, di, dtype),
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, di), F32) / math.sqrt(m.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, r + 2 * m.d_state, dtype),
        "dt_proj": dense_init(ks[3], r, di, dtype, scale=r ** -0.5 * r),  # ~ N(0, 1/sqrt(r))
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,), F32) * (0.1 - 1e-3) + 1e-3, 1e-4))).astype(dtype),
        "A_log": jnp.log(a_init).astype(F32),
        "D": jnp.ones((di,), F32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _causal_conv(x, w, b, init_window=None):
    """x: [B,S,di]; w: [K,di]. Depthwise causal conv via K shifted adds."""
    K = w.shape[0]
    if init_window is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = init_window.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K))
    return y + b.astype(x.dtype), xp[:, -(K - 1):, :]


def mamba_forward(p, x, ctx, *, cache=None):
    """x: [B,S,d] -> (out, new_cache)."""
    m = ctx.cfg.mamba
    d = ctx.cfg.d_model
    di = m.expand * d
    r = _dt_rank(d)
    xi = ctx.constrain(x @ p["in_proj_x"].astype(x.dtype), "mamba_inner")
    z = ctx.constrain(x @ p["in_proj_z"].astype(x.dtype), "mamba_inner")
    conv_init = None if cache is None else cache["conv"]
    xi, conv_win = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_init)
    xi = jax.nn.silu(xi)
    xdbl = xi @ p["x_proj"].astype(x.dtype)
    dt_r, Bc, Cc = jnp.split(xdbl, [r, r + m.d_state], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype)).astype(F32)  # [B,S,di]
    A = -jnp.exp(p["A_log"])  # [di, ds] f32

    def step(h, inp):
        xi_t, dt_t, b_t, c_t = inp  # [B,di],[B,di],[B,ds],[B,ds]
        dA = jnp.exp(dt_t[:, :, None] * A[None])          # [B,di,ds]
        dBx = dt_t[:, :, None] * b_t[:, None, :].astype(F32) * xi_t[:, :, None].astype(F32)
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, c_t.astype(F32))
        return h, y

    h0 = (jnp.zeros((x.shape[0], di, m.d_state), F32) if cache is None
          else cache["ssm"].astype(F32))
    xs = (jnp.swapaxes(xi, 0, 1), jnp.swapaxes(dt, 0, 1),
          jnp.swapaxes(Bc, 0, 1), jnp.swapaxes(Cc, 0, 1))
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.swapaxes(ys, 0, 1).astype(x.dtype) + xi * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_win.astype(cache["conv"].dtype),
                     "ssm": h_last.astype(cache["ssm"].dtype)}
    return out, new_cache


def mamba_decode(p, x, cache, index, ctx):
    """Single-token step; cache = {conv: [B,K-1,di], ssm: [B,di,ds]}."""
    out, new_cache = mamba_forward(p, x, ctx, cache=cache)
    return out, new_cache


def init_mamba_cache(cfg, batch, dtype):
    m = cfg.mamba
    di = m.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, m.d_state), F32)}

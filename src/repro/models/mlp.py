"""Dense feed-forward variants: SwiGLU, squared-ReLU (Nemotron), GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], d_model, d_ff, dtype),
         "w_out": dense_init(ks[1], d_ff, d_model, dtype)}
    if kind == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_forward(p, x, kind: str, ctx=None):
    h = x @ p["w_in"].astype(x.dtype)
    if kind == "swiglu":
        g = x @ p["w_gate"].astype(x.dtype)
        h = jax.nn.silu(g) * h
    elif kind == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(kind)
    if ctx is not None:
        h = ctx.constrain(h, "ffn_hidden")
    return h @ p["w_out"].astype(x.dtype)

"""Model assembly: layer dispatch, scan-over-repeats, loss, prefill/decode.

The repeating-unit layers are applied with a single ``lax.scan`` over the
repeat axis (params stacked [R, ...]), keeping compile time O(1) in depth —
essential for the 96-layer dry-run cells on a CPU-hosted compiler. Remat
(``jax.checkpoint``) wraps the scan body so activation memory is O(unit).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import dense_init, embed_init, rms_norm, stack_init

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Context threaded through every layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    positions: Any = None            # [S] int32 absolute positions
    vision_embeds: Any = None        # [B, Nv, vdim] (vlm stub input)
    kv_repeat: int = 1               # kv-head duplication factor (TP)
    remat: str = "none"              # none | dots | full
    constrain_fn: Optional[Callable] = None  # (x, role) -> x
    # Unroll the layer scan. Used by the dry-run so cost_analysis counts
    # every layer (XLA counts while-loop bodies once — see launch/dryrun.py).
    unroll: bool = False
    # MoE dropless mode (decode/serving): capacity = all slots, no token
    # drops — batched prefill with capacity dropping would otherwise diverge
    # from per-token decode.
    dropless: bool = False
    # Use the Pallas flash-attention kernel for full-sequence self-attention
    # (forward-only paths: prefill/serving; see kernels/flash).
    flash: bool = False
    # shard_map expert parallelism: (mesh, dp_axes, fsdp_axes, tp_axis)
    # from the sharding Plan (perf iteration #7); None = GSPMD auto.
    moe_sm: Any = None

    def constrain(self, x, role: str):
        if self.constrain_fn is None:
            return x
        return self.constrain_fn(x, role)


# ---------------------------------------------------------------------------
# Per-layer init / apply / decode dispatch
# ---------------------------------------------------------------------------

def _init_mixer(key, cfg, spec: LayerSpec, dtype):
    if spec.mixer == "attn":
        return attn_mod.init_attn(key, cfg, dtype)
    if spec.mixer == "xattn":
        return attn_mod.init_xattn(key, cfg, dtype)
    if spec.mixer == "mla":
        return attn_mod.init_mla(key, cfg, dtype)
    if spec.mixer == "mamba":
        return mamba_mod.init_mamba(key, cfg, dtype)
    if spec.mixer == "rwkv":
        return rwkv_mod.init_rwkv_tm(key, cfg, dtype)
    return {}


def _init_ffn(key, cfg, spec: LayerSpec, dtype):
    if spec.ffn == "dense":
        return mlp_mod.init_mlp(key, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    if spec.ffn == "moe":
        return moe_mod.init_moe(key, cfg, dtype)
    if spec.ffn == "rwkv_cm":
        return rwkv_mod.init_rwkv_cm(key, cfg, dtype)
    return {}


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    k1, k2 = jax.random.split(key)
    p = {"norm1": jnp.ones((cfg.d_model,), dtype),
         "mix": _init_mixer(k1, cfg, spec, dtype)}
    if spec.ffn != "none":
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        p["ffn"] = _init_ffn(k2, cfg, spec, dtype)
    return p


def _apply_mixer(spec, p, x, ctx, cache=None):
    if spec.mixer == "attn":
        return attn_mod.attn_forward(p, x, ctx, cache=cache)
    if spec.mixer == "xattn":
        return attn_mod.xattn_forward(p, x, ctx, cache=cache)
    if spec.mixer == "mla":
        return attn_mod.mla_forward(p, x, ctx, cache=cache)
    if spec.mixer == "mamba":
        return mamba_mod.mamba_forward(p, x, ctx, cache=cache)
    if spec.mixer == "rwkv":
        sub = None if cache is None else {k: cache[k] for k in ("shift_tm", "wkv")}
        return rwkv_mod.rwkv_tm_forward(p, x, ctx, cache=sub)
    return x, None


def _decode_mixer(spec, p, x, cache, index, ctx):
    if spec.mixer == "attn":
        return attn_mod.attn_decode(p, x, cache, index, ctx)
    if spec.mixer == "xattn":
        return attn_mod.xattn_decode(p, x, cache, index, ctx)
    if spec.mixer == "mla":
        return attn_mod.mla_decode(p, x, cache, index, ctx)
    if spec.mixer == "mamba":
        return mamba_mod.mamba_decode(p, x, cache, index, ctx)
    if spec.mixer == "rwkv":
        sub = {k: cache[k] for k in ("shift_tm", "wkv")}
        return rwkv_mod.rwkv_tm_forward(p, x, ctx, cache=sub)
    return x, None


def _apply_ffn(spec, p, x, ctx, cache=None):
    """Returns (out, aux_loss, new_cache)."""
    if spec.ffn == "dense":
        return mlp_mod.mlp_forward(p, x, ctx.cfg.mlp_kind, ctx), 0.0, None
    if spec.ffn == "moe":
        if ctx.moe_sm is not None:
            out, aux = moe_mod.moe_forward_shardmap(p, x, ctx.cfg, ctx, ctx.moe_sm)
        else:
            out, aux = moe_mod.moe_forward(p, x, ctx.cfg, ctx)
        return out, aux, None
    if spec.ffn == "rwkv_cm":
        sub = None if cache is None else {"shift_cm": cache["shift_cm"]}
        out, c = rwkv_mod.rwkv_cm_forward(p, x, ctx, cache=sub)
        return out, 0.0, c
    return jnp.zeros_like(x), 0.0, None


def apply_layer(spec, p, x, ctx, cache=None):
    """Pre-norm residual layer. Returns (x, aux, new_cache)."""
    eps = ctx.cfg.norm_eps
    h, mc = _apply_mixer(spec, p["mix"], rms_norm(x, p["norm1"], eps), ctx, cache=cache)
    x = x + h
    aux = 0.0
    fc = None
    if spec.ffn != "none":
        h, aux, fc = _apply_ffn(spec, p["ffn"], rms_norm(x, p["norm2"], eps), ctx, cache=cache)
        x = x + h
    return x, aux, _merge_cache(mc, fc)


def apply_layer_decode(spec, p, x, cache, index, ctx):
    eps = ctx.cfg.norm_eps
    h, mc = _decode_mixer(spec, p["mix"], rms_norm(x, p["norm1"], eps), cache, index, ctx)
    x = x + h
    fc = None
    if spec.ffn != "none":
        h, _, fc = _apply_ffn(spec, p["ffn"], rms_norm(x, p["norm2"], eps), ctx, cache=cache)
        x = x + h
    return x, _merge_cache(mc, fc)


def _merge_cache(mc, fc):
    if mc is None and fc is None:
        return None
    out = {}
    if mc:
        out.update(mc)
    if fc:
        out.update(fc)
    return out


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict:
    cfg.validate()
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4 + len(cfg.prefix))
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = {"emb": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
    params["prefix"] = [init_layer(keys[4 + i], cfg, s, dtype)
                        for i, s in enumerate(cfg.prefix)]
    unit = []
    for i, spec in enumerate(cfg.unit):
        kk = jax.random.fold_in(keys[1], i)
        unit.append(stack_init(kk, cfg.n_repeats,
                               lambda k, spec=spec: init_layer(k, cfg, spec, dtype)))
    params["unit"] = tuple(unit)
    params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    params["lm_head"] = dense_init(keys[2], cfg.d_model, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# Forward (train): backbone -> final-normed activations; loss with chunked CE
# ---------------------------------------------------------------------------

def _embed(params, cfg, batch, ctx):
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"]["emb"], batch["tokens"], axis=0)
    else:
        x = batch["inputs"]
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    return ctx.constrain(x, "activations")


def _unit_scan(params, cfg, x, ctx, aux0=0.0):
    """Scan the repeating unit; optionally remat the body."""
    def body(carry, pslice):
        xc, aux = carry
        for i, spec in enumerate(cfg.unit):
            xc, a, _ = apply_layer(spec, pslice[i], xc, ctx)
            aux = aux + a
        return (xc, aux), None

    if ctx.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif ctx.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.asarray(aux0, F32)), params["unit"],
                               unroll=cfg.n_repeats if ctx.unroll else 1)
    return x, aux


def forward(params, cfg: ModelConfig, batch, ctx: Optional[Ctx] = None):
    """Returns (final-normed activations [B,S,d], moe_aux scalar)."""
    ctx = ctx or Ctx(cfg=cfg)
    if ctx.positions is None:
        S = (batch["tokens"] if cfg.input_mode == "tokens" else batch["inputs"]).shape[1]
        ctx = dataclasses.replace(ctx, positions=jnp.arange(S))
    if cfg.vision is not None and "vision_embeds" in batch:
        ctx = dataclasses.replace(ctx, vision_embeds=batch["vision_embeds"])
    x = _embed(params, cfg, batch, ctx)
    aux = jnp.asarray(0.0, F32)
    for spec, p in zip(cfg.prefix, params["prefix"]):
        x, a, _ = apply_layer(spec, p, x, ctx)
        aux = aux + a
    x, aux = _unit_scan(params, cfg, x, ctx, aux)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _ce(logits, labels):
    logits = logits.astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def loss_fn(params, cfg: ModelConfig, batch, ctx: Optional[Ctx] = None):
    """Mean next-token CE (+ MoE aux). Chunked over seq when cfg.loss_chunk."""
    x, aux = forward(params, cfg, batch, ctx)
    labels = batch["labels"]
    w_head = params["lm_head"]
    chunk = cfg.loss_chunk
    S = x.shape[1]
    if chunk and S % chunk == 0 and S > chunk:
        n = S // chunk
        xc = x.reshape(x.shape[0], n, chunk, x.shape[2])
        lc = labels.reshape(labels.shape[0], n, chunk)

        def body(tot, inp):
            xi, li = inp  # [B,chunk,d], [B,chunk]
            logits = xi @ w_head.astype(xi.dtype)
            return tot + _ce(logits, li).sum(), None

        tot, _ = jax.lax.scan(body, jnp.asarray(0.0, F32),
                              (jnp.swapaxes(xc, 0, 1), jnp.swapaxes(lc, 0, 1)))
        ce = tot / (labels.shape[0] * S)
    else:
        logits = x @ w_head.astype(x.dtype)
        ce = _ce(logits, labels).mean()
    coef = cfg.moe.router_aux_coef if cfg.moe is not None else 0.0
    return ce + coef * aux, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# KV-cache: init / prefill / decode
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg, spec: LayerSpec, batch, seq, dtype):
    c = {}
    if spec.mixer == "attn":
        c.update(attn_mod.init_attn_cache(cfg, batch, seq, dtype))
    elif spec.mixer == "mla":
        c.update(attn_mod.init_mla_cache(cfg, batch, seq, dtype))
    elif spec.mixer == "xattn":
        c.update(attn_mod.init_xattn_cache(cfg, batch, dtype))
    elif spec.mixer == "mamba":
        c.update(mamba_mod.init_mamba_cache(cfg, batch, dtype))
    elif spec.mixer == "rwkv":
        c.update({k: v for k, v in rwkv_mod.init_rwkv_cache(cfg, batch, dtype).items()
                  if k in ("shift_tm", "wkv")})
    if spec.ffn == "rwkv_cm":
        c["shift_cm"] = jnp.zeros((batch, cfg.d_model), dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    prefix = [_init_layer_cache(cfg, s, batch, seq, dtype) for s in cfg.prefix]
    unit = []
    for spec in cfg.unit:
        one = _init_layer_cache(cfg, spec, batch, seq, dtype)
        unit.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_repeats, *a.shape)).copy(), one))
    return {"prefix": prefix, "unit": tuple(unit)}


def make_prefill(cfg: ModelConfig):
    """prefill(params, batch, cache, ctx) -> (last_logits, cache)."""
    def prefill(params, batch, cache, ctx: Optional[Ctx] = None):
        ctx = ctx or Ctx(cfg=cfg)
        S = (batch["tokens"] if cfg.input_mode == "tokens" else batch["inputs"]).shape[1]
        if ctx.positions is None:
            ctx = dataclasses.replace(ctx, positions=jnp.arange(S))
        if cfg.vision is not None and "vision_embeds" in batch:
            ctx = dataclasses.replace(ctx, vision_embeds=batch["vision_embeds"])
        x = _embed(params, cfg, batch, ctx)
        new_prefix = []
        for spec, p, c in zip(cfg.prefix, params["prefix"], cache["prefix"]):
            x, _, nc = apply_layer(spec, p, x, ctx, cache=c)
            new_prefix.append(nc)

        def body(xc, inp):
            pslice, cslice = inp
            ncs = []
            for i, spec in enumerate(cfg.unit):
                xc, _, nc = apply_layer(spec, pslice[i], xc, ctx, cache=cslice[i])
                ncs.append(nc)
            return xc, tuple(ncs)

        if ctx.remat in ("full", "dots"):
            body = jax.checkpoint(body, prevent_cse=False)
        x, new_unit = jax.lax.scan(body, x, (params["unit"], cache["unit"]),
                                   unroll=cfg.n_repeats if ctx.unroll else 1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1:, :] @ params["lm_head"].astype(x.dtype)
        return logits, {"prefix": new_prefix, "unit": new_unit}
    return prefill


def make_decode_step(cfg: ModelConfig):
    """decode(params, token_or_embed, cache, index, ctx) -> (logits, cache)."""
    def decode(params, inp, cache, index, ctx: Optional[Ctx] = None):
        ctx = ctx or Ctx(cfg=cfg)
        ctx = dataclasses.replace(ctx, positions=jnp.full((1,), index),
                                  dropless=True)
        if cfg.input_mode == "tokens":
            x = jnp.take(params["embed"]["emb"], inp, axis=0)  # [B,1,d]
        else:
            x = inp
        x = x.astype(jnp.dtype(cfg.compute_dtype))
        new_prefix = []
        for spec, p, c in zip(cfg.prefix, params["prefix"], cache["prefix"]):
            x, nc = apply_layer_decode(spec, p, x, c, index, ctx)
            new_prefix.append(nc)

        def body(xc, inp_):
            pslice, cslice = inp_
            ncs = []
            for i, spec in enumerate(cfg.unit):
                xc, nc = apply_layer_decode(spec, pslice[i], xc, cslice[i], index, ctx)
                ncs.append(nc)
            return xc, tuple(ncs)

        x, new_unit = jax.lax.scan(body, x, (params["unit"], cache["unit"]),
                                   unroll=cfg.n_repeats if ctx.unroll else 1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["lm_head"].astype(x.dtype)
        return logits, {"prefix": new_prefix, "unit": new_unit}
    return decode


# ---------------------------------------------------------------------------
# Convenience wrapper
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg

    def init(self, key):
        return init_params(key, self.cfg)

    def loss(self, params, batch, ctx=None):
        return loss_fn(params, self.cfg, batch, ctx)

    def forward(self, params, batch, ctx=None):
        return forward(params, self.cfg, batch, ctx)

    def prefill(self):
        return make_prefill(self.cfg)

    def decode_step(self):
        return make_decode_step(self.cfg)

    def init_cache(self, batch, seq, dtype=jnp.bfloat16):
        return init_cache(self.cfg, batch, seq, dtype)

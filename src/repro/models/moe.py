"""Top-k mixture-of-experts with sort-based (capacity) dispatch.

Dispatch avoids the O(N*E*C) one-hot einsum of the classic Mesh-TF
implementation: tokens are argsorted by expert id, ranked within their
expert by a cumulative count, and scattered into a [E, C, d] buffer —
O(N*k*d + E*C*d) memory. Expert weight tensors carry the expert axis first
so EP sharding (experts over "model") is a leading-axis NamedSharding; the
token->expert scatter then lowers to the expected all-to-all under GSPMD.

Includes the standard load-balancing auxiliary loss (Switch/DeepSeek form).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

F32 = jnp.float32


def init_moe(key, cfg, dtype):
    m, d = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 5)

    def e_init(k, d_in, d_out):
        std = 1.0 / math.sqrt(d_in)
        return (jax.random.normal(k, (m.n_experts, d_in, d_out), F32) * std).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, m.n_experts, jnp.float32),  # router in f32
        "w_in": e_init(ks[1], d, m.d_ff_expert),
        "w_gate": e_init(ks[2], d, m.d_ff_expert),
        "w_out": e_init(ks[3], m.d_ff_expert, d),
    }
    if m.n_shared:
        f_sh = m.n_shared * m.d_ff_shared
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {"w_in": dense_init(sk[0], d, f_sh, dtype),
                       "w_gate": dense_init(sk[1], d, f_sh, dtype),
                       "w_out": dense_init(sk[2], f_sh, d, dtype)}
    return p


def moe_forward(p, x, cfg, ctx=None) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,d] -> (out [B,S,d], aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    N = B * S
    k = m.top_k
    xt = x.reshape(N, d)

    logits = (xt.astype(F32) @ p["router"]).astype(F32)  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                  # [N, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (fraction routed * mean prob, Switch form)
    one_hot_top = jax.nn.one_hot(idx, m.n_experts, dtype=F32).sum(1)  # [N,E]
    f = one_hot_top.mean(0)            # fraction of tokens per expert (x k)
    pbar = probs.mean(0)
    aux = m.n_experts * jnp.sum(f * pbar) / k

    # ---- sort-based dispatch
    if ctx is not None and getattr(ctx, "dropless", False):
        C = N * k  # decode/serving: never drop a token
    else:
        C = int(math.ceil(N * k / m.n_experts * m.capacity_factor))
        C = max(C, 4)
    flat_e = idx.reshape(N * k)
    order = jnp.argsort(flat_e)                       # stable in jnp
    tok = order // k                                  # source token per slot
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=m.n_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(N * k) - starts[sorted_e]
    keep = rank < C
    rank_c = jnp.where(keep, rank, 0)

    gathered = xt[tok] * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((m.n_experts, C, d), xt.dtype)
    buf = buf.at[sorted_e, rank_c].add(gathered, mode="drop")
    if ctx is not None:
        buf = ctx.constrain(buf, "expert_buf")

    # ---- expert FFN (gated), expert axis leading -> EP over "model"
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(buf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
    h = jax.nn.silu(g) * h
    if ctx is not None:
        h = ctx.constrain(h, "expert_hidden")
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(buf.dtype))
    if ctx is not None:
        eo = ctx.constrain(eo, "expert_buf")

    # ---- combine back
    out_slots = eo[sorted_e, rank_c] * keep[:, None].astype(eo.dtype)
    gate_sorted = gate.reshape(N * k)[order].astype(eo.dtype)
    out = jnp.zeros((N, d), eo.dtype).at[tok].add(out_slots * gate_sorted[:, None])

    if m.n_shared:
        from repro.models.mlp import mlp_forward
        out = out + mlp_forward(p["shared"], xt, "swiglu", ctx)
    return out.reshape(B, S, d), aux.astype(F32)


def moe_forward_shardmap(p, x, cfg, ctx, sm):
    """Expert-parallel MoE with manual collectives (perf iteration #7).

    GSPMD's auto-partitioning of the sort-based dispatch moves full token
    buffers through all-reduces (dbrx train_4k: 200 s/step of wire even
    after freeing the activation placement). This shard_map version uses
    the structure Megatron TP already gives us: activations are replicated
    over "model", so each expert shard *locally* selects and computes the
    tokens routed to its experts, and the only collective is one psum of
    the [tokens, d] combine — identical wire cost to a dense TP FFN layer.

    ``sm``: (mesh, dp_axes, fsdp_axes, tp_axis) from the sharding Plan.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh, dp_axes, fsdp_axes, tp = sm
    m = cfg.moe
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape))[tp]
    E_loc = m.n_experts // tp_size
    dp = dp_axes if dp_axes else None
    F = tuple(a for a in fsdp_axes if a in mesh.axis_names)

    dropless = ctx is not None and getattr(ctx, "dropless", False)

    def body(xl, router, w_in, w_gate, w_out):
        # xl: [B_loc,S,d] (replicated over tp); w_*: [E_loc, d/F, f]
        B_loc, S, d = xl.shape
        N = B_loc * S
        k = m.top_k
        xt = xl.reshape(N, d)
        logits = (xt.astype(F32) @ router).astype(F32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        one_hot_top = jax.nn.one_hot(idx, m.n_experts, dtype=F32).sum(1)
        f_frac = one_hot_top.mean(0)
        aux = m.n_experts * jnp.sum(f_frac * probs.mean(0)) / k
        # aux is over local tokens; average across data shards
        if dp is not None:
            for ax in (dp if isinstance(dp, tuple) else (dp,)):
                aux = jax.lax.pmean(aux, ax)

        j = jax.lax.axis_index(tp)
        lo = j * E_loc
        flat_e = idx.reshape(N * k)
        mine = (flat_e >= lo) & (flat_e < lo + E_loc)
        e_loc = jnp.clip(flat_e - lo, 0, E_loc - 1)
        # local sort-based capacity dispatch (no cross-device traffic)
        if dropless:
            C = N * k
        else:
            C = max(int(math.ceil(N * k / m.n_experts * m.capacity_factor)), 4)
        order = jnp.argsort(jnp.where(mine, e_loc, E_loc))  # non-mine last
        tok = order // k
        sorted_e = e_loc[order]
        sorted_mine = mine[order]
        counts = jnp.bincount(jnp.where(mine, e_loc, E_loc), length=E_loc + 1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(N * k) - starts[jnp.where(sorted_mine, sorted_e, E_loc)]
        keep = sorted_mine & (rank < C)
        rank_c = jnp.where(keep, rank, 0)
        gathered = xt[tok] * keep[:, None].astype(xt.dtype)
        buf = jnp.zeros((E_loc, C, d), xt.dtype)
        buf = buf.at[jnp.where(keep, sorted_e, 0), rank_c].add(gathered)

        # FSDP-gather local expert weights over the weight-shard axes
        wi, wg, wo = w_in, w_gate, w_out
        for ax in F:
            wi = jax.lax.all_gather(wi, ax, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, ax, axis=2, tiled=True)
        h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(buf.dtype))
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
        eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                        wo.astype(buf.dtype))

        out_slots = eo[jnp.where(keep, sorted_e, 0), rank_c]
        out_slots = out_slots * keep[:, None].astype(eo.dtype)
        gate_sorted = gate.reshape(N * k)[order].astype(eo.dtype)
        out = jnp.zeros((N, d), eo.dtype).at[tok].add(
            out_slots * gate_sorted[:, None])
        out = jax.lax.psum(out, tp)  # the ONLY cross-model-shard traffic
        return out.reshape(B_loc, S, d), aux

    x_spec = P(dp, None, None)
    w_spec = P(tp, F if F else None, None)
    wo_spec = P(tp, None, F if F else None)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(x_spec, P(None, None), w_spec, w_spec, wo_spec),
                   out_specs=(x_spec, P()), check_rep=False)
    out, aux = fn(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
    if m.n_shared:
        from repro.models.mlp import mlp_forward
        B, S, d = x.shape
        out = out + mlp_forward(p["shared"], x.reshape(-1, d), "swiglu",
                                ctx).reshape(B, S, d)
    return out, aux.astype(F32)


def moe_forward_ref(p, x, cfg):
    """O(N*E) reference (every expert on every token) for unit tests."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt.astype(F32) @ p["router"]).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("nd,edf->enf", xt, p["w_in"].astype(xt.dtype))
    g = jnp.einsum("nd,edf->enf", xt, p["w_gate"].astype(xt.dtype))
    eo = jnp.einsum("enf,efd->end", jax.nn.silu(g) * h, p["w_out"].astype(xt.dtype))
    mask = jax.nn.one_hot(idx, m.n_experts, dtype=F32)  # [N,k,E]
    w = (mask * gate[..., None]).sum(1)                 # [N,E]
    out = jnp.einsum("end,ne->nd", eo.astype(F32), w).astype(x.dtype)
    if m.n_shared:
        from repro.models.mlp import mlp_forward
        out = out + mlp_forward(p["shared"], xt, "swiglu")
    return out.reshape(B, S, d)

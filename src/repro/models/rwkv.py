"""RWKV-6 ("Finch") mixer: token-mix with data-dependent decay + channel-mix.

State per layer: token-shift vectors and the per-head [hd_k, hd_v] wkv
matrix. The value-channel (hd_v) axis is the TP axis; decay/receptance act
on the replicated key channel so the recurrence is communication-free.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, group_norm_heads

F32 = jnp.float32


def init_rwkv_tm(key, cfg, dtype):
    d = cfg.d_model
    r = cfg.rwkv
    H, hd = d // r.head_dim, r.head_dim
    ks = jax.random.split(key, 8)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), F32)).astype(dtype),  # r,k,v,w,g
        "w0": jnp.zeros((d,), F32) - 6.0,
        "w_A": dense_init(ks[1], d, r.decay_lora, dtype),
        "w_B": dense_init(ks[2], r.decay_lora, d, dtype, scale=0.1),
        "u": (jax.random.normal(ks[3], (H, hd), F32) * 0.1).astype(F32),
        "wr": dense_init(ks[4], d, d, dtype).reshape(d, H, hd),
        "wk": dense_init(ks[5], d, d, dtype).reshape(d, H, hd),
        "wv": dense_init(ks[6], d, d, dtype).reshape(d, H, hd),
        "wg": dense_init(ks[7], d, d, dtype).reshape(d, H, hd),
        "gn_w": jnp.ones((H, hd), F32),
        "gn_b": jnp.zeros((H, hd), F32),
        "wo": dense_init(jax.random.fold_in(key, 9), d, d, dtype).reshape(H, hd, d),
    }


def init_rwkv_cm(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": (jax.random.uniform(jax.random.fold_in(key, 7), (2, d), F32)).astype(dtype),  # k, r
        "wk": dense_init(ks[0], d, f, dtype),
        "wv": dense_init(ks[1], f, d, dtype),
        "wr": dense_init(ks[2], d, d, dtype),
    }


def _shift(x, prev):
    """Token shift: x[:, t] -> x[:, t-1]; prev: [B,d] previous last token."""
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def rwkv_tm_forward(p, x, ctx, *, cache=None):
    cfg = ctx.cfg
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    H = d // hd
    B, S, _ = x.shape
    prev = jnp.zeros((B, d), x.dtype) if cache is None else cache["shift_tm"]
    xs = _shift(x, prev)
    mu = p["mu"]
    xr, xk, xv, xw, xg = (_lerp(x, xs, mu[i]) for i in range(5))
    rr = jnp.einsum("bsd,dhk->bshk", xr, p["wr"].astype(x.dtype))
    kk = jnp.einsum("bsd,dhk->bshk", xk, p["wk"].astype(x.dtype))
    vv = ctx.constrain(jnp.einsum("bsd,dhv->bshv", xv, p["wv"].astype(x.dtype)), "rwkv_v")
    gg = ctx.constrain(jnp.einsum("bsd,dhv->bshv", xg, p["wg"].astype(x.dtype)), "rwkv_v")
    # data-dependent decay (per key channel), f32 for stability
    lora = jnp.tanh(xw @ p["w_A"].astype(x.dtype)).astype(F32) @ p["w_B"].astype(F32)
    w = jnp.exp(-jnp.exp(p["w0"][None, None] + lora)).reshape(B, S, H, hd)  # in (0,1)
    u = p["u"]  # [H, hd]

    def step(Sst, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,hd] each (k-chan for r,k,w; v-chan for v)
        kv = k_t.astype(F32)[..., :, None] * v_t.astype(F32)[..., None, :]  # [B,H,k,v]
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(F32), Sst + u[None, :, :, None] * kv)
        Sst = w_t.astype(F32)[..., :, None] * Sst + kv
        return Sst, y

    S0 = (jnp.zeros((B, H, hd, hd), F32) if cache is None
          else cache["wkv"].astype(F32))
    xs_seq = tuple(jnp.swapaxes(t, 0, 1) for t in (rr, kk, vv, w))
    S_last, ys = jax.lax.scan(step, S0, xs_seq)
    y = jnp.swapaxes(ys, 0, 1)  # [B,S,H,hd_v] f32
    y = group_norm_heads(y, p["gn_w"], p["gn_b"], 64e-5).astype(x.dtype)
    y = y * jax.nn.silu(gg)
    out = jnp.einsum("bshv,hvd->bsd", y, p["wo"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"shift_tm": x[:, -1, :].astype(cache["shift_tm"].dtype),
                     "wkv": S_last.astype(cache["wkv"].dtype)}
    return out, new_cache


def rwkv_cm_forward(p, x, ctx, *, cache=None):
    prev = (jnp.zeros((x.shape[0], x.shape[-1]), x.dtype) if cache is None
            else cache["shift_cm"])
    xs = _shift(x, prev)
    xk = _lerp(x, xs, p["mu"][0])
    xr = _lerp(x, xs, p["mu"][1])
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    k = ctx.constrain(k, "ffn_hidden")
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * (k @ p["wv"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"shift_cm": x[:, -1, :].astype(cache["shift_cm"].dtype)}
    return out, new_cache


def init_rwkv_cache(cfg, batch, dtype):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    H = d // hd
    return {"shift_tm": jnp.zeros((batch, d), dtype),
            "wkv": jnp.zeros((batch, H, hd, hd), F32),
            "shift_cm": jnp.zeros((batch, d), dtype)}

from repro.parallel.sharding import Plan, make_plan, param_pspecs, batch_pspecs

"""Cross-process collectives: the scenario-mesh result merge and int8
error-feedback gradient compression.

``host_allgather`` is the merge step of the multi-host scenario driver
(``parallel/distributed.py``): after a chunk's compiled pipeline ran over
the global scenario mesh, every process holds only its shard of the
per-row metric arrays — a single jitted identity with fully-replicated
``out_shardings`` all-gathers them (one collective for the whole tree),
after which ``np.asarray`` is legal on every process and the columnar
``StudyResult`` fill is process-independent.  On a single process (or
with no plan) it degenerates to the plain ``np.asarray`` host pull the
engine always did, so the code path is shared.

``compressed_allreduce_mean`` quantizes gradients to int8 with per-block
scales before the data-parallel mean, carrying the quantization residual as
error-feedback state so the bias vanishes over steps (1-bit-Adam family).
Wire format is 8.25 bits/element vs 32 -> ~3.9x less DP all-reduce traffic;
the dry-run's collective roofline term records the saving.

Implemented with jax.lax collectives so it works under shard_map on any
mesh axis; on a single device the psum degenerates to identity (unit tests
validate the quantization algebra; the dry-run validates the lowering).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

F32 = jnp.float32
BLOCK = 256


# ---------------------------------------------------------------------------
# scenario-mesh result merge (multi-host driver)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _replicate_fn(mesh, take: Optional[int]):
    """Jitted slice-then-replicate for one mesh (cached so every chunk of
    a stream reuses one executable per shape).  ``take`` slices the
    leading axis *inside* the same program, so shard-padding rows never
    cross the wire."""
    rep = NamedSharding(mesh, P())
    if take is None:
        return jax.jit(lambda t: t, out_shardings=rep)
    return jax.jit(lambda t: jax.tree.map(lambda a: a[:take], t),
                   out_shardings=rep)


def host_allgather(tree, plan=None, *, take: Optional[int] = None):
    """Pull a (possibly scenario-sharded) result tree to host numpy on
    every process.

    ``plan`` is the ``ScenarioShardPlan`` the batch ran under (or None).
    Single-process: a plain ``np.asarray`` map — bit-identical to the
    engine's historical host pull.  Multi-process: one jitted
    replicate-all collective over the whole tree, then ``np.asarray`` on
    the now fully-addressable leaves.  ``take`` keeps only the first
    ``take`` rows (dropping shard/tail padding) in the same step.
    """
    if plan is None or plan.n_processes <= 1:
        f = (np.asarray if take is None
             else (lambda a: np.asarray(a)[:take]))
        return jax.tree.map(f, tree)
    gathered = _replicate_fn(plan.mesh, take)(tree)
    return jax.tree.map(np.asarray, gathered)


@functools.lru_cache(maxsize=None)
def _gather_rows_fn(mesh, axis: str, length: Optional[int]):
    """Jitted row gather that keeps the result on the scenario mesh:
    ``x[idx, :length]`` with sharded output, so per-(length, spec)
    analysis batches stay partitioned across processes instead of every
    process redundantly analyzing the whole chunk."""
    sh = NamedSharding(mesh, P(axis))
    if length is None:
        return jax.jit(lambda x, idx: x[idx], out_shardings=sh)
    return jax.jit(lambda x, idx: x[idx, :length], out_shardings=sh)


def gather_rows(x, idx, plan, *, length: Optional[int] = None):
    """``x[idx][:, :length]`` committed back onto ``plan``'s scenario
    mesh (multi-process), or the plain eager gather (single-process —
    unchanged numerics either way: a gather moves data, never computes).
    ``idx`` length must be a shard multiple in the multi-process case."""
    if plan is None or plan.n_processes <= 1:
        out = x[np.asarray(idx)]
        return out if length is None else out[:, :length]
    return _gather_rows_fn(plan.mesh, plan.axis, length)(
        x, jnp.asarray(np.asarray(idx), jnp.int32))


def _quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. x: flat [N] f32 (N % BLOCK == 0)."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def _dequantize_int8(q, scale):
    return (q.astype(F32) * scale).reshape(-1)


def quantize_roundtrip(x):
    """Helper for tests: dequantize(quantize(x)) with padding handling."""
    n = x.size
    pad = (-n) % BLOCK
    xf = jnp.pad(x.reshape(-1).astype(F32), (0, pad))
    q, s = _quantize_int8(xf)
    return _dequantize_int8(q, s)[:n].reshape(x.shape)


def compressed_allreduce_mean(x, err, axis_name: str):
    """Error-feedback int8 all-reduce-mean over ``axis_name``.

    x:   this shard's gradient leaf (any shape)
    err: residual carried from the previous step (same shape)
    Returns (mean_estimate, new_err).
    """
    shape = x.shape
    n = x.size
    pad = (-n) % BLOCK
    flat = (x.astype(F32) + err.astype(F32)).reshape(-1)
    flat = jnp.pad(flat, (0, pad))
    q, scale = _quantize_int8(flat)
    local_deq = _dequantize_int8(q, scale)
    new_err = (flat - local_deq)[:n].reshape(shape)
    # all-reduce the dequantized int8 payload (wire = int8 + scales)
    summed = jax.lax.psum(local_deq, axis_name)
    size = jax.lax.psum(jnp.ones((), F32), axis_name)
    return (summed / size)[:n].reshape(shape).astype(x.dtype), new_err.astype(x.dtype)


def compressed_bytes(n_elements: int) -> int:
    """Wire bytes for one shard's payload (int8 values + f32 block scales)."""
    blocks = (n_elements + BLOCK - 1) // BLOCK
    return n_elements + 4 * blocks

"""Distributed-optimization collectives: int8 error-feedback compression.

``compressed_allreduce_mean`` quantizes gradients to int8 with per-block
scales before the data-parallel mean, carrying the quantization residual as
error-feedback state so the bias vanishes over steps (1-bit-Adam family).
Wire format is 8.25 bits/element vs 32 -> ~3.9x less DP all-reduce traffic;
the dry-run's collective roofline term records the saving.

Implemented with jax.lax collectives so it works under shard_map on any
mesh axis; on a single device the psum degenerates to identity (unit tests
validate the quantization algebra; the dry-run validates the lowering).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
BLOCK = 256


def _quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. x: flat [N] f32 (N % BLOCK == 0)."""
    xb = x.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(F32)


def _dequantize_int8(q, scale):
    return (q.astype(F32) * scale).reshape(-1)


def quantize_roundtrip(x):
    """Helper for tests: dequantize(quantize(x)) with padding handling."""
    n = x.size
    pad = (-n) % BLOCK
    xf = jnp.pad(x.reshape(-1).astype(F32), (0, pad))
    q, s = _quantize_int8(xf)
    return _dequantize_int8(q, s)[:n].reshape(x.shape)


def compressed_allreduce_mean(x, err, axis_name: str):
    """Error-feedback int8 all-reduce-mean over ``axis_name``.

    x:   this shard's gradient leaf (any shape)
    err: residual carried from the previous step (same shape)
    Returns (mean_estimate, new_err).
    """
    shape = x.shape
    n = x.size
    pad = (-n) % BLOCK
    flat = (x.astype(F32) + err.astype(F32)).reshape(-1)
    flat = jnp.pad(flat, (0, pad))
    q, scale = _quantize_int8(flat)
    local_deq = _dequantize_int8(q, scale)
    new_err = (flat - local_deq)[:n].reshape(shape)
    # all-reduce the dequantized int8 payload (wire = int8 + scales)
    summed = jax.lax.psum(local_deq, axis_name)
    size = jax.lax.psum(jnp.ones((), F32), axis_name)
    return (summed / size)[:n].reshape(shape).astype(x.dtype), new_err.astype(x.dtype)


def compressed_bytes(n_elements: int) -> int:
    """Wire bytes for one shard's payload (int8 values + f32 block scales)."""
    blocks = (n_elements + BLOCK - 1) // BLOCK
    return n_elements + 4 * blocks

"""Multi-host driver for the scenario mesh: ``jax.distributed`` init,
process-local launch helpers, and the 2-process CI smoke.

The streaming engine is embarrassingly parallel along its scenario axis;
``ScenarioShardPlan`` already expresses the 1-D "scenario" mesh and the
per-process row slice (``local_rows``).  This module supplies the part
nothing drove before:

* ``initialize()`` — idempotent ``jax.distributed.initialize`` from an
  explicit coordinator or the ``REPRO_DIST_*`` env contract.  On CPU it
  switches the collectives implementation to gloo *first* — without
  that, any computation over a cross-process global array fails with
  "Multiprocess computations aren't implemented on the CPU backend".
* ``distributed_plan()`` — the ``ScenarioShardPlan`` over *all* (global)
  devices, built after init so every process sees the same mesh.
* ``launch_workers()`` / ``worker_env()`` / ``free_port()`` — the
  subprocess-simulated multi-process harness (2 CPU processes are
  sufficient proof; the same env contract drives real multi-host).
* ``python -m repro.parallel.distributed --smoke`` — CI entry: runs a
  small Study single-process, re-runs it under 2 ``jax.distributed``
  processes on the scenario mesh, and asserts the two ``StudyResult``
  record streams are bit-identical.

Process identity (``process_index``/``process_count``) is a *host-side*
constant: compute it outside jit and pass values in.  Baking it into
traced code or pytree data fields makes results differ per process —
repro-lint rule RPR007 flags exactly that.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import tempfile
from typing import Dict, List, Optional, Sequence

ENV_COORD = "REPRO_DIST_COORD"
ENV_NPROCS = "REPRO_DIST_NPROCS"
ENV_PID = "REPRO_DIST_PID"

_initialized = False


def initialize(coordinator_address: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None) -> bool:
    """Idempotent ``jax.distributed.initialize`` for the scenario mesh.

    Arguments default to the ``REPRO_DIST_COORD`` / ``REPRO_DIST_NPROCS``
    / ``REPRO_DIST_PID`` environment contract (what ``launch_workers``
    sets); with neither arguments nor env present this is a no-op so the
    same driver code runs single-process unchanged.  Returns True when
    the distributed runtime is (now) up.

    Must run before any other JAX call touches the backend: on CPU the
    collectives implementation is switched to gloo here, which only
    takes effect before backend initialization.
    """
    global _initialized
    if _initialized:
        return True
    coord = coordinator_address or os.environ.get(ENV_COORD)
    if coord is None:
        return False
    nproc = int(num_processes if num_processes is not None
                else os.environ[ENV_NPROCS])
    pid = int(process_id if process_id is not None
              else os.environ[ENV_PID])
    if nproc <= 1:
        return False
    import jax
    # CPU multiprocess collectives need gloo; harmless on other backends
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coord,
                               num_processes=nproc, process_id=pid)
    _initialized = True
    return True


def process_index() -> int:
    import jax
    return int(jax.process_index())


def process_count() -> int:
    import jax
    return int(jax.process_count())


def is_primary() -> bool:
    """True on the process that owns side effects (progress callbacks,
    checkpoint writes, result export).  Always True single-process."""
    return process_index() == 0


def distributed_plan(*, axis: str = "scenario"):
    """The ``ScenarioShardPlan`` over all global devices — every process
    builds the same mesh, so the same jit call is one SPMD program."""
    import jax
    from repro.parallel.sharding import ScenarioShardPlan
    return ScenarioShardPlan.make(jax.devices(), axis=axis)


# ---------------------------------------------------------------------------
# subprocess-simulated multi-process launch
# ---------------------------------------------------------------------------

def free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def worker_env(base_env: Optional[Dict[str, str]] = None, *,
               coordinator: str, num_processes: int,
               process_id: int) -> Dict[str, str]:
    """The env one worker subprocess needs: the ``REPRO_DIST_*`` contract
    plus a src/ ``PYTHONPATH`` entry (mirroring the test-suite pattern)."""
    env = dict(os.environ if base_env is None else base_env)
    env[ENV_COORD] = coordinator
    env[ENV_NPROCS] = str(num_processes)
    env[ENV_PID] = str(process_id)
    src = os.path.join(os.path.dirname(__file__), "..", "..")
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return env


def launch_workers(argv: Sequence[str], num_processes: int = 2, *,
                   env: Optional[Dict[str, str]] = None,
                   timeout: float = 900.0
                   ) -> List[subprocess.CompletedProcess]:
    """Run ``num_processes`` copies of ``argv`` as one ``jax.distributed``
    job (shared fresh coordinator port, per-process id) and wait for all.
    Raises if any worker exits non-zero, with that worker's stderr tail.
    """
    coord = f"localhost:{free_port()}"
    procs = [subprocess.Popen(
        list(argv), env=worker_env(env, coordinator=coord,
                                   num_processes=num_processes,
                                   process_id=pid),
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True)
        for pid in range(num_processes)]
    done = []
    for pid, p in enumerate(procs):
        try:
            out, err = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        done.append(subprocess.CompletedProcess(p.args, p.returncode,
                                                out, err))
    for pid, r in enumerate(done):
        if r.returncode != 0:
            raise RuntimeError(
                f"distributed worker {pid} exited {r.returncode}:\n"
                f"{r.stderr[-3000:]}")
    return done


# ---------------------------------------------------------------------------
# CI smoke: 2-process records bit-parity against single-process
# ---------------------------------------------------------------------------

def _smoke_study():
    import repro.core as core
    tl = core.synthetic_timeline(1.0, 0.3)
    tl2 = core.synthetic_timeline(2.0, 0.25, moe_notch=True)
    cfg = core.WaveformConfig(dt=0.002, steps=3, jitter_s=0.002)
    gpu = lambda m: core.GpuPowerSmoothing(
        mpf_frac=m, ramp_up_w_per_s=2000, ramp_down_w_per_s=2000,
        stop_delay_s=1.0)
    spec = core.example_specs(job_mw=0.05)["moderate"]
    return core.Study(
        {"w": tl, "w2": tl2}, fleets=[128, 256],
        configs={"none": None, "a": (gpu(0.8), None), "b": (gpu(0.65), None)},
        specs=spec, wave_cfg=cfg, key=0)


def _smoke_worker(out_path: str, stream: int) -> None:
    """One distributed worker: init, run the smoke Study on the global
    scenario mesh, write records JSON from the primary process."""
    assert initialize(), "worker launched without the REPRO_DIST_* contract"
    import repro.core as core  # noqa: F401  (backend now initialized)
    study = _smoke_study()
    study.plan = distributed_plan()
    res = study.run(stream=stream)
    if is_primary():
        res.to_json(out_path)
    print(f"worker {process_index()}/{process_count()} done", flush=True)


def run_smoke(num_processes: int = 2, stream: int = 5) -> None:
    ref = _smoke_study().run(stream=stream)
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "dist_records.json")
        launch_workers(
            [sys.executable, "-m", "repro.parallel.distributed",
             "--smoke-worker", "--out", out, "--stream", str(stream)],
            num_processes=num_processes)
        with open(out) as fh:
            got = json.load(fh)
    want = ref.to_records()
    assert got == want, (
        f"{num_processes}-process records differ from single-process "
        f"({sum(a != b for a, b in zip(got, want))}/{len(want)} records)")
    print(f"DISTRIBUTED_SMOKE_OK: {num_processes}-process run bit-identical "
          f"to single-process ({len(want)} records)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="2-process CPU smoke: records bit-parity vs "
                         "single-process")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--stream", type=int, default=5)
    ap.add_argument("--smoke-worker", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.smoke_worker:
        _smoke_worker(args.out, args.stream)
        return
    if args.smoke:
        run_smoke(args.processes, args.stream)
        return
    ap.print_help()


if __name__ == "__main__":
    main()

"""Sharding plans: logical roles -> PartitionSpecs over (pod, data, model).

Scheme (see DESIGN.md §6):
  * FSDP: every large weight matrix shards its d_model-ish input axis over
    ("pod","data") — GSPMD all-gathers weights per scanned layer forward and
    reduce-scatters gradients backward (ZeRO-3 semantics from annotations).
  * TP over "model": attention q-heads (with kv-head duplication so the kv
    axis equals the TP degree), FFN hidden, MoE experts (EP), Mamba d_inner,
    RWKV value channel, vocab (embed table + logits).
  * Archs whose head count does not divide the TP degree (minitron-4b,
    musicgen-medium: 24 heads vs 16) replicate attention *compute* over
    "model" and keep TP on FFN/vocab — recorded as ``attn_mode="replicated"``.
  * Decode KV caches shard the *sequence* axis over "model" (SP) so a 32k
    cache at batch 128 fits HBM; GSPMD inserts the small softmax-stat
    all-reduces.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Scenario-axis sharding (the power-study engine's data parallelism)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScenarioShardPlan:
    """1-D mesh over the scenario (batch-of-scenarios) axis.

    The power-study engine (``repro.core.engine``) is embarrassingly
    parallel along its leading scenario axis; this plan is the general
    form of its old single-host ``shard_devices`` switch: an explicit
    ``Mesh`` + ``NamedSharding`` over one named axis, so the same
    annotations GSPMD partitions on one host partition across hosts when
    the mesh is built from ``jax.devices()`` under ``jax.distributed``.

    Multi-host readiness is the point of ``local_rows``: a chunked driver
    feeds each chunk's *process-local* row slice and builds the global
    array per chunk — the chunk executor composes with the plan by
    padding every chunk to a shard multiple (``shard_batch``) before the
    compiled call.  On a single process ``local_rows`` is the whole
    chunk, so the code path is identical either way.
    """
    mesh: Mesh
    axis: str = "scenario"

    @classmethod
    def make(cls, devices=None, *, axis: str = "scenario"
             ) -> "ScenarioShardPlan":
        devs = list(jax.devices() if devices is None else devices)
        return cls(Mesh(np.asarray(devs), (axis,)), axis)

    @property
    def n_shards(self) -> int:
        return int(self.mesh.devices.size)

    @property
    def n_processes(self) -> int:
        return len({getattr(d, "process_index", 0)
                    for d in self.mesh.devices.flat})

    @property
    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis))

    def pad_rows(self, B: int) -> int:
        """Rows to append so ``B`` divides evenly across the shards."""
        return (-B) % self.n_shards

    def local_rows(self, B: int) -> slice:
        """The slice of a ``B``-row (shard-multiple) scenario batch this
        process owns — the chunk slicing a multi-host driver feeds with
        process-local data.  Single-process: the whole batch."""
        procs = self.n_processes
        if procs <= 1:
            return slice(0, B)
        per = B // procs
        rank = jax.process_index()
        return slice(rank * per, (rank + 1) * per)

    def shard_batch(self, tree, B: int):
        """Pad every batched leaf to a shard multiple (repeating the last
        row — callers slice results back to ``[:B]``) and commit it to
        the scenario mesh.  Returns ``(tree, padded_B)``.  No-op on a
        one-device mesh.

        Under ``jax.distributed`` (the mesh spans processes) each process
        holds the *same* host batch; the multi-host branch pads it
        host-side, takes this process's ``local_rows`` slice, and
        assembles the global array via
        ``jax.make_array_from_process_local_data`` — every process then
        calls the same compiled pipeline on the same global arrays (one
        SPMD program), each owning 1/n_processes of the rows."""
        if self.n_shards <= 1:
            return tree, B
        pad = self.pad_rows(B)
        sh = self.sharding
        if self.n_processes > 1:
            padded = B + pad
            rows = self.local_rows(padded)

            def put(a):
                h = np.asarray(a)
                if pad:
                    h = np.concatenate(
                        [h, np.repeat(h[-1:], pad, axis=0)], axis=0)
                return jax.make_array_from_process_local_data(
                    sh, np.ascontiguousarray(h[rows]), (padded,) + h.shape[1:])

            return jax.tree.map(put, tree), padded
        if pad:
            tree = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.repeat(a[-1:], pad, axis=0)], axis=0), tree)
        return jax.tree.map(lambda a: jax.device_put(a, sh), tree), B + pad


@functools.lru_cache(maxsize=None)
def scenario_plan() -> ScenarioShardPlan:
    """The default plan: every local device along one 'scenario' axis."""
    return ScenarioShardPlan.make()


@dataclasses.dataclass(frozen=True)
class Plan:
    mesh: Mesh
    dp_axes: Tuple[str, ...]       # batch axes, e.g. ("pod","data")
    tp_axis: Optional[str]         # "model" (None = no TP, single device)
    attn_mode: str                 # "heads" | "replicated"
    kv_repeat: int                 # kv-head duplication factor (heads mode)
    shard_vocab: bool
    # weight-shard (ZeRO/FSDP) axes. Deliberately excludes "pod": weight
    # all-gathers stay inside a pod's ICI; the pod axis carries only the
    # per-step gradient all-reduce (hierarchical DP).
    fsdp_axes: Tuple[str, ...] = ("data",)

    @property
    def dp(self):
        return self.dp_axes if self.dp_axes else None

    @property
    def fsdp(self):
        axes = tuple(a for a in self.fsdp_axes if a in self.mesh.axis_names)
        return axes if axes else None

    def constrain(self, x, role: str):
        spec = _ACT_RULES.get(role)
        if spec is None or self.tp_axis is None:
            return x
        pspec = spec(self, x.ndim)
        if pspec is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, pspec))

    def ctx_kwargs(self):
        return dict(kv_repeat=self.kv_repeat, constrain_fn=self.constrain)

    def moe_sm(self, cfg: ModelConfig):
        """shard_map expert-parallel handle when the plan supports it."""
        if self.tp_axis is None or cfg.moe is None:
            return None
        tp_size = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[self.tp_axis]
        if cfg.moe.n_experts % tp_size != 0:
            return None
        return (self.mesh, self.dp_axes, self.fsdp or (), self.tp_axis)


def make_plan(cfg: ModelConfig, mesh: Mesh, *, kind: str = "train",
              pure_fsdp: bool = False) -> Plan:
    """``pure_fsdp``: experimental opt-in (perf iteration #6, REFUTED —
    EXPERIMENTS.md §Perf): napkin math predicted pure-FSDP beats TP for
    <=20B dense archs (weight gathers ~1.3e11 B vs TP-AR 4.6e11 B on
    granite/train_4k), but GSPMD currently lowers the batch-and-weights-on-
    the-same-axes pattern through involuntary full rematerialization
    (measured 2.7e13 B all-reduce, 2.3 TB temp). Kept for re-testing under
    the Shardy partitioner."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    if tp == 1:
        return Plan(mesh, dp_axes, None, "replicated", 1, False)
    if (pure_fsdp and kind == "train" and cfg.moe is None
            and mesh.devices.size <= 256):
        return Plan(mesh, dp_axes + ("model",), None, "replicated", 1, False,
                    fsdp_axes=("data", "model"))
    attn_mode, r = "replicated", 1
    a = cfg.attention
    if a is not None:
        if a.n_heads % tp == 0 and (a.n_kv_heads % tp == 0 or tp % a.n_kv_heads == 0):
            attn_mode = "heads"
            r = max(1, tp // a.n_kv_heads)
    return Plan(mesh, dp_axes, "model", attn_mode, r,
                shard_vocab=cfg.vocab_size % tp == 0)


# ---------------------------------------------------------------------------
# Activation roles
# ---------------------------------------------------------------------------

def _heads_only(fn):
    def rule(plan: Plan, ndim: int):
        if plan.attn_mode != "heads":
            return None
        return fn(plan, ndim)
    return rule


_ACT_RULES = {
    # [B, S, d]
    "activations": lambda p, n: P(p.dp, *([None] * (n - 1))),
    # [B, S, KV', G, D]
    "q_heads": _heads_only(lambda p, n: P(p.dp, None, p.tp_axis, None, None)),
    # [B, T, KV', D]
    "kv_heads": _heads_only(lambda p, n: P(p.dp, None, p.tp_axis, None)),
    # [B, T, KV, D] pre-duplication (replicated over model)
    "kv_pre": _heads_only(lambda p, n: P(p.dp, None, None, None)),
    # [B, S, f] or [N, f]
    "ffn_hidden": lambda p, n: P(p.dp, *([None] * (n - 2)), p.tp_axis),
    # [E, C, d] / [E, C, f]: deliberately UNCONSTRAINED. Expert weights are
    # EP-sharded at the param level; forcing the activation buffers onto the
    # same axis made GSPMD reshard the token scatter/gather through full
    # all-reduces (5.5x the collective bytes on dbrx train_4k — perf
    # iteration #4, EXPERIMENTS.md §Perf). Free propagation lets the
    # partitioner pick collective-permute routes instead.
    "expert_buf": lambda p, n: None,
    "expert_hidden": lambda p, n: None,
    # [B, S, di]
    "mamba_inner": lambda p, n: P(p.dp, None, p.tp_axis),
    # [B, S, H, hd_v]
    "rwkv_v": lambda p, n: P(p.dp, None, None, p.tp_axis),
    # decode KV cache [B, KV, S, D] — SP over sequence
    "kv_cache": lambda p, n: P(p.dp, None, p.tp_axis, None),
    # [B, S, V]
    "logits": lambda p, n: (P(p.dp, None, p.tp_axis) if p.shard_vocab
                            else P(p.dp, None, None)),
}


# ---------------------------------------------------------------------------
# Parameter PartitionSpecs (path-pattern rules)
# ---------------------------------------------------------------------------

def _leaf_spec(plan: Plan, cfg: ModelConfig, path: Tuple[str, ...], ndim: int):
    """Spec for an *unstacked* layer param; caller prepends None for 'unit'."""
    F = plan.fsdp  # weight-shard (ZeRO-3) axes — intra-pod only
    T = plan.tp_axis
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    heads = plan.attn_mode == "heads"
    is_rwkv = cfg.rwkv is not None  # no assigned arch mixes rwkv with attn

    if name == "emb":  # [V, d]
        return P(T if plan.shard_vocab else None, F)
    if name == "lm_head":  # [d, V]
        return P(F, T if plan.shard_vocab else None)
    if name in ("final_norm", "norm1", "norm2", "kv_norm", "w0", "dt_bias",
                "conv_b", "D", "gate_attn", "mu", "u", "bk", "bv"):
        return P(*([None] * ndim))

    if is_rwkv:  # ---- RWKV6: TP on the value channel --------------------
        if name in ("wr", "wk") and ndim == 3:   # [d, H, hd_k] key channel
            return P(F, None, None)
        if name in ("wv", "wg") and ndim == 3:   # [d, H, hd_v] value channel
            return P(F, None, T)
        if name == "wo" and ndim == 3:           # [H, hd_v, d]
            return P(None, T, F)
        if name in ("gn_w", "gn_b"):             # [H, hd_v]
            return P(None, T)
        if name == "w_A":
            return P(F, None)
        if name == "w_B":
            return P(None, None)
        if name == "wr" and ndim == 2:           # channel-mix receptance [d,d]
            return P(F, None)
        if name == "wk" and ndim == 2:           # channel-mix [d, f]
            return P(F, T)
        if name == "wv" and ndim == 2:           # channel-mix [f, d]
            return P(T, F)

    # attention ------------------------------------------------------------
    if name == "wq":  # [d|vdim, H, hd] or mla [d, H, qk]
        return P(F, T if heads else None, None)
    if name in ("wk", "wv") and ndim == 3:
        return P(F, None, None)  # kv heads pre-duplication: replicated
    if name == "wo" and ndim == 3:  # [H, hd, d]
        return P(T if heads else None, None, F)
    if name == "bq":
        return P(T if heads else None, None)
    # MLA --------------------------------------------------------------------
    if name in ("wdkv", "wkr"):
        return P(F, None)
    if name in ("wuk", "wuv"):  # [l, H, n]
        return P(F, T if heads else None, None)
    # MoE ----------------------------------------------------------------
    if name == "router":
        return P(F, None)
    if parent != "shared" and name in ("w_in", "w_gate") and ndim == 3:  # [E,d,f]
        return P(T, F, None)
    if parent != "shared" and name == "w_out" and ndim == 3:  # [E,f,d]
        return P(T, None, F)
    # dense mlp / shared expert ---------------------------------------------
    if name in ("w_in", "w_gate"):  # [d, f]
        return P(F, T)
    if name == "w_out":  # [f, d]
        return P(T, F)
    # mamba -------------------------------------------------------------------
    if name in ("in_proj_x", "in_proj_z"):  # [d, di]
        return P(F, T)
    if name == "conv_w":  # [K, di]
        return P(None, T)
    if name == "x_proj":  # [di, r+2ds]
        return P(T, None)
    if name == "dt_proj":  # [r, di]
        return P(None, T)
    if name == "A_log":  # [di, ds]
        return P(T, None)
    return P(*([None] * ndim))


def path_contains(path, token):
    return any(t == token for t in path)


def _path_names(keypath) -> Tuple[str, ...]:
    names = []
    for k in keypath:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_pspecs(cfg: ModelConfig, plan: Plan, params_tree) -> Dict:
    """PartitionSpec pytree matching ``params_tree`` (values or shape-structs)."""
    def spec_of(keypath, leaf):
        if plan.tp_axis is None and plan.fsdp is None:
            return P()
        names = _path_names(keypath)
        ndim = len(leaf.shape)
        stacked = names and names[0] == "unit"
        base_ndim = ndim - 1 if stacked else ndim
        # RWKV cm/tm disambiguation happens via leaf rank; path carries names
        spec = _leaf_spec(plan, cfg, tuple(n for n in names if not n.isdigit()),
                          base_ndim)
        spec_t = tuple(spec) + (None,) * (base_ndim - len(spec))
        if stacked:
            spec_t = (None,) + spec_t
        assert len(spec_t) == ndim, (names, spec_t, leaf.shape)
        return P(*spec_t)

    return jax.tree_util.tree_map_with_path(spec_of, params_tree)


def batch_pspecs(cfg: ModelConfig, plan: Plan, batch_tree) -> Dict:
    def spec_of(keypath, leaf):
        if plan.dp is None:
            return P()
        return P(plan.dp, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map_with_path(spec_of, batch_tree)


def dp_size(plan: Plan) -> int:
    sizes = dict(zip(plan.mesh.axis_names, plan.mesh.devices.shape))
    n = 1
    for a in plan.dp_axes:
        n *= sizes.get(a, 1)
    return n


def cache_pspecs(cfg: ModelConfig, plan: Plan, cache_tree,
                 batch_size: int = 0) -> Dict:
    """Decode-cache specs: seq axis over "model" for attention/MLA caches.

    ``batch_size``: when given and not divisible by the dp degree (e.g. the
    long_500k cell's global_batch=1), batch dims are left unsharded.
    """
    dp = plan.dp
    if batch_size and dp is not None and batch_size % dp_size(plan) != 0:
        plan = dataclasses.replace(plan, dp_axes=())

    def spec_of(keypath, leaf):
        if plan.tp_axis is None:
            return P()
        names = _path_names(keypath)
        name = names[-1]
        ndim = len(leaf.shape)
        stacked = names and names[0] == "unit"
        base = ndim - 1 if stacked else ndim
        T = plan.tp_axis
        if name in ("k", "v") and base == 4:
            # attn cache [B,KV,S,D] -> SP on S ; xattn cache [B,Nv,KV,D]
            # (distinguish: xattn caches have n_tokens second)
            is_xattn = (cfg.vision is not None
                        and leaf.shape[stacked + 1] == cfg.vision.n_tokens)
            spec = (plan.dp, None, None, None) if is_xattn else (plan.dp, None, T, None)
        elif name == "ckv" and base == 3:  # [B,S,l]
            spec = (plan.dp, T, None)
        elif name == "krope":
            spec = (plan.dp, T, None)
        elif name == "ssm":  # [B,di,ds]
            spec = (plan.dp, T, None)
        elif name == "conv":  # [B,K-1,di]
            spec = (plan.dp, None, T)
        elif name == "wkv":  # [B,H,hdk,hdv]
            spec = (plan.dp, None, None, T)
        else:  # shift_tm/shift_cm [B,d]
            spec = (plan.dp,) + (None,) * (base - 1)
        if stacked:
            spec = (None,) + spec
        return P(*spec)
    return jax.tree_util.tree_map_with_path(spec_of, cache_tree)

from repro.serve.engine import ServeEngine

_POWER = ("PowerComplianceService", "default_catalog")
_WARMSTART = ("WarmStartPredictor", "train_warmstart", "extract_features",
              "init_warmstart", "warmstart_forward", "FEATURE_NAMES")


def __getattr__(name):
    # lazy: keeps `python -m repro.serve.power` from importing the module
    # twice (once here, once as __main__), and keeps the LLM serve engine
    # importable without pulling in the compliance/warm-start stack
    if name in _POWER:
        from repro.serve import power
        return getattr(power, name)
    if name in _WARMSTART:
        from repro.serve import warmstart
        return getattr(warmstart, name)
    raise AttributeError(name)

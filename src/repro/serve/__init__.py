from repro.serve.engine import ServeEngine


def __getattr__(name):
    # lazy: keeps `python -m repro.serve.power` from importing the module
    # twice (once here, once as __main__)
    if name in ("PowerComplianceService", "default_catalog"):
        from repro.serve import power
        return getattr(power, name)
    raise AttributeError(name)

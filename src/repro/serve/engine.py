"""Batched serving engine: prefill once, decode tokens with a KV cache.

``make_serve_step`` is the unit the dry-run lowers for decode_* shape cells:
one new token against a seq_len cache. The engine adds sampling + a python
generation loop for the runnable examples.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Ctx, Model, init_cache, make_decode_step, make_prefill


def make_serve_step(cfg: ModelConfig, plan=None, unroll: bool = False):
    """decode_step(params, inp, cache, index) -> (logits, new_cache)."""
    decode = make_decode_step(cfg)
    kwargs = {}
    if plan is not None:
        # decode caches are laid out pre-duplication; constrain only.
        # NOTE: decode keeps the GSPMD MoE path — at B tokens/step the
        # shard_map combine psum costs more than auto-partitioning
        # (measured 2.4x on dbrx decode_32k; EXPERIMENTS.md §Perf iter 7).
        kwargs = dict(kv_repeat=1, constrain_fn=plan.constrain)

    def serve_step(params, inp, cache, index):
        ctx = Ctx(cfg=cfg, unroll=unroll, **kwargs)
        return decode(params, inp, cache, index, ctx)

    return serve_step


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_seq: int, batch: int,
                 cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.cache = init_cache(cfg, batch, max_seq, cache_dtype)
        self._prefill = jax.jit(make_prefill(cfg))
        self._decode = jax.jit(make_decode_step(cfg))

    def generate(self, prompt_tokens, n_steps: int, *, temperature: float = 0.0,
                 key: Optional[jax.Array] = None, vision_embeds=None):
        """prompt_tokens: [B, L] int32. Returns [B, n_steps] generated ids."""
        B, L = prompt_tokens.shape
        assert B == self.batch and L + n_steps <= self.max_seq
        batch = {"tokens": prompt_tokens}
        if vision_embeds is not None:
            batch["vision_embeds"] = vision_embeds
        logits, cache = self._prefill(self.params, batch, self.cache)
        outs = []
        tok = self._sample(logits[:, -1, :], temperature, key, 0)
        for i in range(n_steps):
            outs.append(tok)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         jnp.asarray(L + i, jnp.int32))
            tok = self._sample(logits[:, -1, :], temperature, key, i + 1)
        self.cache = cache
        return jnp.stack(outs, axis=1)

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, i)
        return jax.random.categorical(k, logits / temperature, axis=-1).astype(jnp.int32)

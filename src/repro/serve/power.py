"""Spec-compliance query service: (workload, fleet, spec) -> passing configs.

The operator-facing question behind the paper's evaluation matrix (and the
pre-dispatch screening framing of EasyRider / resonance-safety-criterion
work): *before* dispatching a training job, which transient-mitigation
configurations keep it inside the utility spec, and at what energy cost?

``PowerComplianceService`` answers it through the Study API: a query
builds the candidate catalog (baseline + MPF floors + batteries + their
pairings, sized off the job's raw swing), runs it on the *streaming*
chunked executor, and returns the passing configs ranked by worst-case
energy overhead.  When NO catalog config passes, the service falls back
to on-demand design: the engine's grid/gradient/hybrid/warmstart solver
synthesizes a (MPF, battery) configuration for this exact query and
returns it (with ranked alternatives) under ``"designed"``.

The serve path is amortized at three levels:

* **Answer cache** — a lock-protected true-LRU (``cache_size`` entries,
  ``move_to_end`` on hit, oldest-out eviction) keyed per (workload,
  fleet, spec, padding); repeated queries are dictionary lookups.
  Identical *concurrent* misses are single-flighted: one leader thread
  runs the Study, followers wait on its event and read the cached
  answer — N identical in-flight queries execute the underlying Study
  exactly once (``stats["study_runs"]``).
* **Workload memo** — phase-level synthesis, the chip waveform, the
  aggregated fleet waveform/swing, and the warm-start spectral feature
  vector (Goertzel bins etc.) are memoized per workload / per
  (workload, fleet) / per (workload, fleet, spec), so a cache-*miss*
  for a seen workload skips synthesis + FFT/Goertzel recompute.
* **Query coalescing** — ``query_many`` / ``handle_many`` fuse N
  distinct misses into ONE padded ``run_rows`` execution over the union
  row list (each query's rows carrying the PRNG keys that query would
  draw alone, so coalescing is bit-identical to serial queries), and the
  engine's (trace length, spec *family*, mitigation structure) jit
  keying means new (workload, fleet, spec-threshold) shapes reuse the
  already-compiled executables instead of retracing.

Memory bound: the service never retains whole-study waveforms.  A query
holds O(``stream_chunk`` * trace length) waveform samples on device
while it streams, the columnar ``StudyResult`` it keeps as
``last_result`` holds metrics only (O(catalog size) small columns, no
waveforms), and the answer cache holds O(``cache_size``) JSON-sized
dicts — so resident memory is independent of how many scenarios a
query's catalog expands to.

``handle`` / ``handle_many`` are the JSON boundary (dict in, JSON-safe
dict out) a service framework would mount; the module is also a CLI
(installed as ``repro-serve``):

  PYTHONPATH=src python -m repro.serve.power \
      --period-s 2.0 --comm-frac 0.25 --n-chips 512 --spec moderate

``watch()`` / ``repro-serve watch`` is the grid-interactive entry: it
closes the ``repro.control`` loop over a replayed (or synthesized)
telemetry stream — online sliding-Goertzel detection, hysteresis + slope
early-warning policy, and intervention dispatch through the same
warm-started design path the query fallback uses:

  PYTHONPATH=src python -m repro.serve.power watch --replay ramp --timeline
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.engine import design
from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import (IterationTimeline, from_dryrun_cell,
                               load_cell, synthetic_timeline)
from repro.core.smoothing.battery import RackBattery
from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
from repro.core.spec import UtilitySpec, example_specs
from repro.core.study import MitigationConfig, StudyResult, run_rows
from repro.core.waveform import (WaveformConfig, aggregate, chip_waveform,
                                 phase_levels)


def default_catalog(swing_w: float, *,
                    mpf_grid: Sequence[float] = (0.5, 0.65, 0.8, 0.9),
                    cap_fracs: Sequence[float] = (0.5, 1.0, 2.0),
                    ramp_w_per_s: float = 2000.0,
                    stop_delay_s: float = 1.0,
                    target_tau_s: float = 10.0,
                    hw: Hardware = DEFAULT_HW) -> List[MitigationConfig]:
    """The candidate mitigation catalog for a job whose raw datacenter
    swing is ``swing_w``: the unmitigated baseline, each MPF floor alone,
    each battery sizing alone, and every pairing."""
    gpus = {f"mpf{int(m * 100)}": GpuPowerSmoothing(
        mpf_frac=m, hw=hw, ramp_up_w_per_s=ramp_w_per_s,
        ramp_down_w_per_s=ramp_w_per_s, stop_delay_s=stop_delay_s)
        for m in mpf_grid}
    bats = {f"bat{f:g}x": RackBattery(
        capacity_j=f * swing_w, max_discharge_w=swing_w,
        max_charge_w=swing_w, target_tau_s=target_tau_s)
        for f in cap_fracs}
    catalog = [MitigationConfig("none")]
    catalog += [MitigationConfig(n, device=g) for n, g in gpus.items()]
    catalog += [MitigationConfig(n, rack=b) for n, b in bats.items()]
    catalog += [MitigationConfig(f"{gn}+{bn}", device=g, rack=b)
                for gn, g in gpus.items() for bn, b in bats.items()]
    return catalog


class PowerComplianceService:
    """Serve-path wrapper: compliance queries over a mitigation catalog.

    One instance holds the waveform/telemetry configuration, the catalog
    knobs, the PRNG root, the answer LRU, and the workload memo;
    ``query`` takes the (workload, fleet, spec) triple.  The instance is
    thread-safe: all cache/memo state sits behind one lock, and
    identical concurrent misses are single-flighted.

    ``design_method="warmstart"`` routes the no-catalog-config-passes
    fallback through the learned warm-start path (``warmstart=`` takes a
    ``serve.warmstart.WarmStartPredictor`` or a checkpoint directory);
    every such answer is still hard tau=0 re-validated by the engine.
    """

    def __init__(self, *, wave_cfg: Optional[WaveformConfig] = None,
                 hw: Hardware = DEFAULT_HW,
                 mpf_grid: Sequence[float] = (0.5, 0.65, 0.8, 0.9),
                 cap_fracs: Sequence[float] = (0.5, 1.0, 2.0),
                 seeds: Sequence[int] = (0,),
                 key: Optional[int] = 0,
                 cache_size: int = 128,
                 design_fallback: bool = True,
                 design_method: str = "hybrid",
                 warmstart=None,
                 stream_chunk: int = 256,
                 memo_size: int = 32,
                 resume_dir: Optional[str] = None):
        self.wave_cfg = wave_cfg or WaveformConfig(dt=0.002, steps=10,
                                                   jitter_s=0.002)
        self.hw = hw
        self.mpf_grid = tuple(mpf_grid)
        self.cap_fracs = tuple(cap_fracs)
        self.seeds = tuple(seeds)
        self.key = key
        self.cache_size = int(cache_size)
        self.design_fallback = design_fallback
        self.design_method = design_method
        if isinstance(warmstart, str):
            from repro.serve.warmstart import WarmStartPredictor
            warmstart = WarmStartPredictor.load(warmstart)
        self.warmstart = warmstart
        if design_method == "warmstart" and warmstart is None:
            raise ValueError("design_method='warmstart' needs a warmstart= "
                             "predictor (object or checkpoint directory)")
        self.stream_chunk = int(stream_chunk)
        self.memo_size = int(memo_size)
        # service-level resume: each union execution checkpoints its
        # streaming chunks under a query-set-keyed subdirectory, so a
        # killed long-catalog query finishes from where it died when the
        # same query (set) is re-asked after restart
        self.resume_dir = resume_dir
        self.last_result: Optional[StudyResult] = None
        # all mutable state below is guarded by _lock; the heavy work
        # (synthesis, Study execution, design) runs OUTSIDE the lock
        self._lock = threading.Lock()
        self._cache: "OrderedDict[Tuple, Dict]" = OrderedDict()
        self._inflight: Dict[Tuple, threading.Event] = {}
        self._wl_memo: "OrderedDict[Tuple, Dict]" = OrderedDict()
        self._agg_memo: "OrderedDict[Tuple, Dict]" = OrderedDict()
        self._feat_memo: "OrderedDict[Tuple, object]" = OrderedDict()
        self.stats = {"hits": 0, "misses": 0, "study_runs": 0,
                      "evictions": 0, "singleflight_waits": 0,
                      "feature_hits": 0, "feature_misses": 0}

    # -- caches -------------------------------------------------------------

    def _workload_key(self, workload) -> Union[int, str]:
        try:
            return hash(workload)
        except TypeError:
            return repr(workload)

    def _cache_key(self, workload, n_chips, spec, padding) -> Tuple:
        wk = self._workload_key(workload)
        sk = spec if isinstance(spec, str) else (spec.name, repr(spec))
        return (wk, int(n_chips), sk, padding, self.wave_cfg, self.seeds)

    @staticmethod
    def _memo_get(memo: OrderedDict, key):
        hit = memo.get(key)
        if hit is not None:
            memo.move_to_end(key)
        return hit

    def _memo_put(self, memo: OrderedDict, key, value) -> None:
        memo[key] = value
        memo.move_to_end(key)
        while len(memo) > self.memo_size:
            memo.popitem(last=False)

    def _workload_state(self, workload) -> Dict:
        """Per-workload synthesis memo: phase levels + one chip's trace."""
        wk = self._workload_key(workload)
        with self._lock:
            hit = self._memo_get(self._wl_memo, wk)
        if hit is not None:
            return hit
        cfg, hw = self.wave_cfg, self.hw
        state = {"levels": phase_levels(workload, cfg, hw),
                 "chip_w": chip_waveform(workload, cfg, hw)}
        with self._lock:
            self._memo_put(self._wl_memo, wk, state)
        return state

    def _fleet_state(self, workload, n_chips: int) -> Dict:
        """Per-(workload, fleet) memo: the aggregated datacenter waveform
        (the jitter realization the catalog Study judges under, so a
        fallback-designed config is validated on the waveform the rest of
        the answer describes) plus its swing/mean summary."""
        wk = (self._workload_key(workload), int(n_chips), self.seeds[0])
        with self._lock:
            hit = self._memo_get(self._agg_memo, wk)
        if hit is not None:
            return hit
        cfg, hw = self.wave_cfg, self.hw
        w = aggregate(self._workload_state(workload)["chip_w"], n_chips,
                      cfg, hw, seed=self.seeds[0])
        state = {"w": w, "swing": float(w.max() - w.min()),
                 "mean_mw": float(w.mean()) / 1e6}
        with self._lock:
            self._memo_put(self._agg_memo, wk, state)
        return state

    def _features(self, workload, n_chips: int, spec: UtilitySpec):
        """Memoized warm-start feature vector (Goertzel fingerprint +
        spec thresholds); repeated design() misses for a seen workload
        skip the synthesis + spectral recompute."""
        fk = (self._workload_key(workload), int(n_chips),
              spec.name, repr(spec))
        with self._lock:
            hit = self._memo_get(self._feat_memo, fk)
            if hit is not None:
                self.stats["feature_hits"] += 1
                return hit
            self.stats["feature_misses"] += 1
        from repro.serve.warmstart import extract_features
        f = extract_features(spec, self._fleet_state(workload, n_chips)["w"],
                             self.wave_cfg.dt, n_chips)
        with self._lock:
            self._memo_put(self._feat_memo, fk, f)
        return f

    def cache_len(self) -> int:
        with self._lock:
            return len(self._cache)

    # -- single-flight answer cache -----------------------------------------

    def _lookup_or_lead(self, key: Tuple):
        """('hit', answer) for a cached key; ('lead', None) after claiming
        leadership of a miss.  Followers of an in-flight identical query
        block on the leader's event, then loop: on leader success the
        answer is in the cache, on leader failure one follower claims
        leadership and retries."""
        while True:
            with self._lock:
                hit = self._cache.get(key)
                if hit is not None:
                    self._cache.move_to_end(key)
                    self.stats["hits"] += 1
                    return "hit", hit
                ev = self._inflight.get(key)
                if ev is None:
                    self._inflight[key] = threading.Event()
                    self.stats["misses"] += 1
                    return "lead", None
                self.stats["singleflight_waits"] += 1
            ev.wait()

    def _finish(self, key: Tuple, answer: Optional[Dict]) -> None:
        """Leader epilogue: publish the answer (None on failure), release
        the in-flight slot, wake the followers."""
        with self._lock:
            if answer is not None:
                self._cache[key] = answer
                self._cache.move_to_end(key)
                while len(self._cache) > self.cache_size:
                    self._cache.popitem(last=False)
                    self.stats["evictions"] += 1
            ev = self._inflight.pop(key, None)
        if ev is not None:
            ev.set()

    # -- the query ----------------------------------------------------------

    def query(self, workload: IterationTimeline, n_chips: int,
              spec: Union[str, UtilitySpec] = "moderate", *,
              workload_name: str = "workload",
              padding: str = "auto",
              on_chunk=None) -> Dict:
        """(workload, fleet, spec) -> which catalog configs pass, ranked by
        worst-case (over seeds) energy overhead.

        The catalog rows run on the streaming executor via ``run_rows``
        (the same path ``query_many`` coalesces over): metrics-only
        answers, no whole-study waveform retention.  ``on_chunk(done,
        total, elapsed_s)`` optionally reports progress (cache hits and
        single-flight followers answer without invoking it)."""
        key = self._cache_key(workload, n_chips, spec, padding)
        state, hit = self._lookup_or_lead(key)
        if state == "hit":
            return hit
        answer = None
        try:
            answer = self._execute(
                [(workload, int(n_chips), spec, workload_name, padding)],
                on_chunk=on_chunk)[0]
        finally:
            self._finish(key, answer)
        return answer

    def query_many(self, queries: Sequence[Dict], *, on_chunk=None
                   ) -> List[Dict]:
        """Answer N queries, coalescing every cache miss into ONE padded
        streaming execution over the union row list.

        Each query is a dict with keys ``workload`` (IterationTimeline),
        ``n_chips``, and optional ``spec`` / ``workload_name`` /
        ``padding`` (the ``query`` signature).  Hits come from the LRU,
        duplicate misses (within the batch or against other threads)
        single-flight, and the distinct misses run as one ``run_rows``
        call — each query's rows carrying the PRNG keys it would draw
        alone, so the coalesced answers are bit-identical to N serial
        ``query`` calls."""
        norm = []
        for q in queries:
            workload = q["workload"]
            n_chips = int(q["n_chips"])
            spec = q.get("spec", "moderate")
            name = q.get("workload_name", "workload")
            padding = q.get("padding", "auto")
            norm.append((workload, n_chips, spec, name, padding))
        keys = [self._cache_key(w, n, s, p) for w, n, s, _, p in norm]

        answers: List[Optional[Dict]] = [None] * len(norm)
        lead_idx: List[int] = []
        follow_idx: List[int] = []
        claimed: Dict[Tuple, int] = {}
        for i, key in enumerate(keys):
            if key in claimed:          # duplicate within this batch
                follow_idx.append(i)
                continue
            state, hit = self._lookup_or_lead(key)
            if state == "hit":
                answers[i] = hit
            else:
                claimed[key] = i
                lead_idx.append(i)

        if lead_idx:
            got: Optional[List[Dict]] = None
            try:
                got = self._execute([norm[i] for i in lead_idx],
                                    on_chunk=on_chunk)
            finally:
                for j, i in enumerate(lead_idx):
                    ans = None if got is None else got[j]
                    answers[i] = ans
                    self._finish(keys[i], ans)

        for i in follow_idx:
            # the leader for this key is in answers already (same batch)
            # or another thread; either way the cache has it now
            state, hit = self._lookup_or_lead(keys[i])
            if state == "hit":
                answers[i] = hit
            else:               # leader failed and we inherited the lead
                try:
                    answers[i] = self._execute([norm[i]])[0]
                finally:
                    self._finish(keys[i], answers[i])
        return answers

    # -- execution (misses only; runs outside the lock) ---------------------

    def _execute(self, queries: Sequence[Tuple], *, on_chunk=None
                 ) -> List[Dict]:
        """Run N cache-missed queries as ONE union ``run_rows`` execution
        and build their answers.  Workload slots are prefixed ``q{j}:``
        and spec slots ``s{j}:`` so per-query records filter back out;
        row PRNG keys are folded from each query's LOCAL row index, so
        the union run is bit-identical to running each query alone."""
        cfg, hw = self.wave_cfg, self.hw
        workloads: Dict[str, IterationTimeline] = {}
        levels: Dict[str, object] = {}
        rows: List[Tuple[str, int, MitigationConfig, int]] = []
        keys = [] if self.key is not None else None
        specs: List[Tuple[str, UtilitySpec]] = []
        resolved = []
        if self.key is not None:
            import jax
            root = (self.key if not isinstance(self.key, int)
                    else jax.random.PRNGKey(self.key))

        for j, (workload, n_chips, spec, name, _padding) in enumerate(queries):
            fs = self._fleet_state(workload, n_chips)
            if isinstance(spec, str):
                spec = example_specs(job_mw=fs["mean_mw"])[spec]
            qname, sname = f"q{j}:{name}", f"s{j}:{spec.name}"
            workloads[qname] = workload
            levels[qname] = self._workload_state(workload)["levels"]
            catalog = default_catalog(fs["swing"], mpf_grid=self.mpf_grid,
                                      cap_fracs=self.cap_fracs, hw=hw)
            local = 0
            for c in catalog:
                for s in self.seeds:
                    rows.append((qname, n_chips, c, s))
                    if keys is not None:
                        keys.append(jax.random.fold_in(root, local))
                    local += 1
            specs.append((sname, spec))
            resolved.append((qname, sname, spec, catalog, fs))

        with self._lock:
            self.stats["study_runs"] += 1
        # bucket, not pad: padding to the union's max length changes the
        # XLA reduction tree shape (1e-8-level float drift), and the
        # coalesced answers must be bit-identical to serial queries —
        # per-length calls inside ONE run_rows still share dispatch and
        # the compiled (length, family, structure) executables
        mode = queries[0][4] if len(queries) == 1 else "bucket"
        resume = None
        if self.resume_dir is not None:
            # one subdir per coalesced query set: same queries -> same
            # dir (resume kicks in); anything else gets its own sweep
            from repro.ckpt.resume import digest
            # repr, not hash(): str hashes are per-process randomized and
            # the dir name must survive a service restart (the inner
            # rows_chain fingerprint still catches any true mismatch)
            qsig = digest([(repr(q[0]), int(q[1]),
                            q[2] if isinstance(q[2], str) else repr(q[2]),
                            q[3], q[4]) for q in queries])
            resume = os.path.join(self.resume_dir, qsig[:32])
        result = run_rows(workloads, rows, specs, wave_cfg=cfg, hw=hw,
                          keys=keys, padding=mode,
                          stream=self.stream_chunk,
                          on_chunk=on_chunk, resume=resume)
        self.last_result = result

        answers = []
        for j, (workload, n_chips, spec_in, name, _padding) in enumerate(
                queries):
            qname, sname, spec, catalog, fs = resolved[j]
            sub = result.filter(workload=qname, spec=sname)
            answers.append(self._build_answer(
                workload, n_chips, spec, name, catalog, fs, sub))
        return answers

    def _build_answer(self, workload, n_chips: int, spec: UtilitySpec,
                      name: str, catalog, fs: Dict,
                      sub: StudyResult) -> Dict:
        passing_names = sub.passing_configs()
        by_config = {c: sub.filter(config=c) for c in passing_names}
        passing = [{
            "config": c,
            "energy_overhead":
                max(r["energy_overhead"] for r in by_config[c]),
            "swing_mitigated_mw":
                max(r["swing_mitigated_mw"] for r in by_config[c]),
        } for c in passing_names]
        designed = None
        if not passing and self.design_fallback:
            # no catalog config passes: design one on demand (the engine's
            # grid/gradient/hybrid/warmstart solver on this query's
            # waveform; warmstart reads the memoized feature vector and
            # hard tau=0 re-validates whatever it returns)
            kwargs: Dict = {}
            if self.design_method == "warmstart":
                kwargs["warmstart"] = self.warmstart
                kwargs["features"] = self._features(workload, n_chips, spec)
            sol = design(spec, fs["w"], self.wave_cfg.dt, n_chips,
                         method=self.design_method, hw=self.hw, **kwargs)
            if sol is not None:
                mit = sol["mitigated"]
                designed = {
                    "config": f"designed[{sol['method']}]",
                    "mpf_frac": sol["mpf_frac"],
                    "battery_capacity_j": sol["battery_capacity_j"],
                    "energy_overhead": sol["energy_overhead"],
                    "swing_mitigated_mw":
                        round(float(mit.max() - mit.min()) / 1e6, 4),
                    "alternatives": sol["alternatives"],
                    "designed": True,
                }
                if "warmstart_path" in sol.get("aux", {}):
                    designed["warmstart_path"] = sol["aux"]["warmstart_path"]
                passing = [designed]
        return {
            "workload": name,
            "n_chips": int(n_chips),
            "spec": spec.name,
            "mean_mw": round(fs["mean_mw"], 4),
            "raw_swing_mw": round(fs["swing"] / 1e6, 4),
            "n_configs": len(catalog),
            "n_scenarios": len(catalog) * len(self.seeds),
            "compliant": bool(passing),
            "recommended": passing[0]["config"] if passing else None,
            "passing": passing,
            "designed": designed,
        }

    # -- the control plane --------------------------------------------------

    def watch(self, workload: Optional[IterationTimeline] = None,
              n_chips: int = 512,
              spec: Union[str, UtilitySpec] = "moderate", *,
              replay=None, dt: Optional[float] = None,
              freqs: Optional[Sequence[float]] = None,
              tick_s: float = 0.5, window_s: float = 4.0,
              breach_w: Optional[float] = None, trigger_frac: float = 0.85,
              release_frac: float = 0.60, lead_s: float = 2.0,
              sustain_ticks: int = 2, release_ticks: int = 4,
              dispatch_ticks: int = 1, history_s: float = 8.0,
              max_ticks: Optional[int] = None) -> Dict:
        """Close the grid-interactive control loop over one stream.

        ``replay`` is a power trace (array-like, sampled at ``dt``,
        default the service's waveform dt); without it the stream is the
        service's own synthesized fleet waveform for ``workload`` —
        i.e. "watch this job's telemetry".  The loop runs the online
        sliding-Goertzel detector (bit-identical to the offline
        monitor), the per-bin hysteresis + slope-early-warning
        controller, and the intervention ladder whose first rung is this
        service's design path (``design_method``/``warmstart``).
        Returns a JSON-safe dict: loop config + the full ``ControlLog``
        (records, per-tick series, summary with latency percentiles).
        """
        from repro.control import watch_trace
        dt = float(dt if dt is not None else self.wave_cfg.dt)
        if replay is not None:
            import numpy as np
            w = np.asarray(replay, np.float32)
        else:
            if workload is None:
                raise ValueError("watch() needs a workload or a replay=")
            w = self._fleet_state(workload, n_chips)["w"]
        if isinstance(spec, str):
            spec = example_specs(job_mw=float(w.mean()) / 1e6)[spec]
        method = (self.design_method if self.design_method != "warmstart"
                  else "warmstart")
        log = watch_trace(
            w, dt, spec=spec, n_chips=int(n_chips), freqs=freqs,
            window_s=window_s, tick_s=tick_s, breach_w=breach_w,
            trigger_frac=trigger_frac, release_frac=release_frac,
            lead_s=lead_s, sustain_ticks=sustain_ticks,
            release_ticks=release_ticks, dispatch_ticks=dispatch_ticks,
            design_method=method, warmstart=self.warmstart, hw=self.hw,
            history_s=history_s, max_ticks=max_ticks)
        out = {"spec": spec.name, "n_chips": int(n_chips), "dt": dt,
               "tick_s": tick_s, "window_s": window_s,
               "design_method": method, "timeline": log.timeline()}
        out.update(log.to_json())
        return json.loads(json.dumps(out, default=float))

    # -- JSON boundary ------------------------------------------------------

    def _parse_workload(self, wl) -> Tuple[IterationTimeline, str]:
        if isinstance(wl, dict) and "cell" in wl:
            cell = load_cell(wl["cell"])
            return from_dryrun_cell(cell, self.hw), f"{cell.get('arch', 'cell')}"
        if isinstance(wl, dict):
            tl = synthetic_timeline(
                period_s=float(wl.get("period_s", 1.0)),
                comm_frac=float(wl.get("comm_frac", 0.25)),
                moe_notch=bool(wl.get("moe_notch", False)))
            return tl, wl.get("name", "synthetic")
        raise TypeError(f"unsupported workload request: {wl!r}")

    def handle(self, request: Dict, *, on_chunk=None) -> Dict:
        """One request dict -> one JSON-safe answer dict.

        ``{"workload": {"period_s": 2.0, "comm_frac": 0.25,
                        "moe_notch": false} | {"cell": "<dryrun json>"},
           "n_chips": 512, "spec": "lenient|moderate|tight"}``

        ``on_chunk`` is a host-side progress callback (not part of the
        JSON boundary) threaded to ``query`` — the CLI's ``--progress``.
        """
        try:
            tl, name = self._parse_workload(request["workload"])
            answer = self.query(tl, int(request["n_chips"]),
                                request.get("spec", "moderate"),
                                workload_name=name, on_chunk=on_chunk)
            return json.loads(json.dumps(answer, default=float))
        except (KeyError, TypeError, ValueError, OSError) as e:
            # OSError: a bad --cell path must come back as an error dict,
            # not escape the dict-in/dict-out service boundary
            return {"error": f"{type(e).__name__}: {e}"}

    def handle_many(self, requests: Sequence[Dict], *, on_chunk=None
                    ) -> List[Dict]:
        """N request dicts -> N JSON-safe answer dicts, positionally.

        Parse failures come back as ``{"error": ...}`` in place; the
        parseable remainder is answered by ``query_many`` — cache hits
        from the LRU, all misses coalesced into one padded streaming
        execution."""
        parsed: List[Optional[Dict]] = []
        out: List[Optional[Dict]] = [None] * len(requests)
        for i, req in enumerate(requests):
            try:
                tl, name = self._parse_workload(req["workload"])
                parsed.append({"workload": tl,
                               "n_chips": int(req["n_chips"]),
                               "spec": req.get("spec", "moderate"),
                               "workload_name": name})
            except (KeyError, TypeError, ValueError, OSError) as e:
                out[i] = {"error": f"{type(e).__name__}: {e}"}
                parsed.append(None)
        live = [i for i, p in enumerate(parsed) if p is not None]
        answers = self.query_many([parsed[i] for i in live],
                                  on_chunk=on_chunk) if live else []
        for i, ans in zip(live, answers):
            out[i] = json.loads(json.dumps(ans, default=float))
        return out


def _load_replay(arg: str, dt: float):
    """--replay operand: 'ramp' (the canonical synthesized 9 Hz
    amplitude-ramp trace), a .npy array, or a JSON list of watts."""
    import numpy as np
    if arg == "ramp":
        from repro.control import synthesize_ramp
        return synthesize_ramp(dt=dt)
    if arg.endswith(".npy"):
        return np.load(arg).astype(np.float32)
    with open(arg) as f:
        return np.asarray(json.load(f), np.float32)


def _watch_main(argv: Sequence[str]) -> None:
    ap = argparse.ArgumentParser(
        prog="repro-serve watch",
        description="grid-interactive control loop over a replayed stream")
    ap.add_argument("--replay", default="ramp",
                    help="'ramp' | trace.npy | trace.json (watts)")
    ap.add_argument("--dt", type=float, default=0.002)
    ap.add_argument("--tick-s", type=float, default=0.5)
    ap.add_argument("--window-s", type=float, default=4.0)
    ap.add_argument("--n-chips", type=int, default=512)
    ap.add_argument("--spec", default="moderate",
                    choices=("lenient", "moderate", "tight"))
    ap.add_argument("--design-method", default="grid",
                    choices=("grid", "gradient", "hybrid", "warmstart"))
    ap.add_argument("--warmstart", default=None,
                    help="WarmStartPredictor checkpoint directory")
    ap.add_argument("--dispatch-ticks", type=int, default=1)
    ap.add_argument("--max-ticks", type=int, default=None)
    ap.add_argument("--timeline", action="store_true",
                    help="print the decision timeline instead of JSON")
    args = ap.parse_args(argv)

    service = PowerComplianceService(design_method=args.design_method,
                                     warmstart=args.warmstart)
    answer = service.watch(
        n_chips=args.n_chips, spec=args.spec,
        replay=_load_replay(args.replay, args.dt), dt=args.dt,
        tick_s=args.tick_s, window_s=args.window_s,
        dispatch_ticks=args.dispatch_ticks, max_ticks=args.max_ticks)
    if args.timeline:
        print(answer["timeline"])
        print(json.dumps(answer["summary"], indent=2))
    else:
        answer.pop("timeline", None)
        print(json.dumps(answer, indent=2))


def main(argv: Optional[Sequence[str]] = None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "watch":
        return _watch_main(argv[1:])
    ap = argparse.ArgumentParser(
        description="power-spec compliance query (Study API serve path); "
                    "subcommand 'watch' runs the grid-interactive control "
                    "loop over a replayed stream")
    ap.add_argument("--period-s", type=float, default=2.0)
    ap.add_argument("--comm-frac", type=float, default=0.25)
    ap.add_argument("--moe-notch", action="store_true")
    ap.add_argument("--cell", default=None,
                    help="dry-run artifact JSON (overrides the synthetic "
                         "workload flags)")
    ap.add_argument("--n-chips", type=int, default=512)
    ap.add_argument("--spec", default="moderate",
                    choices=("lenient", "moderate", "tight"))
    ap.add_argument("--design-method", default="hybrid",
                    choices=("grid", "gradient", "hybrid", "warmstart"),
                    help="fallback solver when no catalog config passes")
    ap.add_argument("--warmstart", default=None,
                    help="WarmStartPredictor checkpoint directory "
                         "(required for --design-method warmstart)")
    ap.add_argument("--progress", action="store_true",
                    help="report streaming sweep progress on stderr")
    args = ap.parse_args(argv)

    workload: Dict = ({"cell": args.cell} if args.cell else
                      {"period_s": args.period_s, "comm_frac": args.comm_frac,
                       "moe_notch": args.moe_notch})
    on_chunk = None
    if args.progress:
        def on_chunk(done: int, total: int, elapsed: float) -> None:
            print(f"# {done}/{total} scenarios in {elapsed:.1f}s",
                  file=sys.stderr)
    service = PowerComplianceService(design_method=args.design_method,
                                     warmstart=args.warmstart)
    answer = service.handle({"workload": workload, "n_chips": args.n_chips,
                             "spec": args.spec}, on_chunk=on_chunk)
    print(json.dumps(answer, indent=2))


if __name__ == "__main__":
    main()

"""Spec-compliance query service: (workload, fleet, spec) -> passing configs.

The operator-facing question behind the paper's evaluation matrix (and the
pre-dispatch screening framing of EasyRider / resonance-safety-criterion
work): *before* dispatching a training job, which transient-mitigation
configurations keep it inside the utility spec, and at what energy cost?

``PowerComplianceService`` answers it through the Study API: a query
builds the candidate catalog (baseline + MPF floors + batteries + their
pairings, sized off the job's raw swing), declares a one-workload Study,
runs it on the *streaming* chunked executor, and returns the passing
configs ranked by worst-case energy overhead.  When NO catalog config
passes, the service falls back to on-demand design: the engine's
grid/gradient/hybrid solver synthesizes a (MPF, battery) configuration
for this exact query and returns it (with ranked alternatives) under
``"designed"``.  Answers are cached per (workload, fleet, spec) so
repeated queries are dictionary lookups.

Memory bound: the service never retains whole-study waveforms.  A query
holds O(``stream_chunk`` * trace length) waveform samples on device
while it streams, the columnar ``StudyResult`` it keeps as
``last_result`` holds metrics only (O(catalog size) small columns, no
waveforms), and the answer cache holds O(``cache_size``) JSON-sized
dicts — so resident memory is independent of how many scenarios a
query's catalog expands to.

``handle`` is the JSON boundary (dict in, JSON-safe dict out) a service
framework would mount; the module is also a CLI:

  PYTHONPATH=src python -m repro.serve.power \
      --period-s 2.0 --comm-frac 0.25 --n-chips 512 --spec moderate
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.engine import design
from repro.core.hardware import DEFAULT_HW, Hardware
from repro.core.phases import (IterationTimeline, from_dryrun_cell,
                               load_cell, synthetic_timeline)
from repro.core.smoothing.battery import RackBattery
from repro.core.smoothing.gpu_floor import GpuPowerSmoothing
from repro.core.spec import UtilitySpec, example_specs
from repro.core.study import MitigationConfig, Study, StudyResult
from repro.core.waveform import WaveformConfig, aggregate, chip_waveform


def default_catalog(swing_w: float, *,
                    mpf_grid: Sequence[float] = (0.5, 0.65, 0.8, 0.9),
                    cap_fracs: Sequence[float] = (0.5, 1.0, 2.0),
                    ramp_w_per_s: float = 2000.0,
                    stop_delay_s: float = 1.0,
                    target_tau_s: float = 10.0,
                    hw: Hardware = DEFAULT_HW) -> List[MitigationConfig]:
    """The candidate mitigation catalog for a job whose raw datacenter
    swing is ``swing_w``: the unmitigated baseline, each MPF floor alone,
    each battery sizing alone, and every pairing."""
    gpus = {f"mpf{int(m * 100)}": GpuPowerSmoothing(
        mpf_frac=m, hw=hw, ramp_up_w_per_s=ramp_w_per_s,
        ramp_down_w_per_s=ramp_w_per_s, stop_delay_s=stop_delay_s)
        for m in mpf_grid}
    bats = {f"bat{f:g}x": RackBattery(
        capacity_j=f * swing_w, max_discharge_w=swing_w,
        max_charge_w=swing_w, target_tau_s=target_tau_s)
        for f in cap_fracs}
    catalog = [MitigationConfig("none")]
    catalog += [MitigationConfig(n, device=g) for n, g in gpus.items()]
    catalog += [MitigationConfig(n, rack=b) for n, b in bats.items()]
    catalog += [MitigationConfig(f"{gn}+{bn}", device=g, rack=b)
                for gn, g in gpus.items() for bn, b in bats.items()]
    return catalog


class PowerComplianceService:
    """Serve-path wrapper: compliance queries over a mitigation catalog.

    One instance holds the waveform/telemetry configuration, the catalog
    knobs, the PRNG root, and the answer cache; ``query`` takes the
    (workload, fleet, spec) triple.
    """

    def __init__(self, *, wave_cfg: Optional[WaveformConfig] = None,
                 hw: Hardware = DEFAULT_HW,
                 mpf_grid: Sequence[float] = (0.5, 0.65, 0.8, 0.9),
                 cap_fracs: Sequence[float] = (0.5, 1.0, 2.0),
                 seeds: Sequence[int] = (0,),
                 key: Optional[int] = 0,
                 cache_size: int = 128,
                 design_fallback: bool = True,
                 design_method: str = "hybrid",
                 stream_chunk: int = 256):
        self.wave_cfg = wave_cfg or WaveformConfig(dt=0.002, steps=10,
                                                   jitter_s=0.002)
        self.hw = hw
        self.mpf_grid = tuple(mpf_grid)
        self.cap_fracs = tuple(cap_fracs)
        self.seeds = tuple(seeds)
        self.key = key
        self.cache_size = cache_size
        self.design_fallback = design_fallback
        self.design_method = design_method
        self.stream_chunk = int(stream_chunk)
        self._cache: Dict[Tuple, Dict] = {}
        self.last_result: Optional[StudyResult] = None

    # -- the query ----------------------------------------------------------

    def query(self, workload: IterationTimeline, n_chips: int,
              spec: Union[str, UtilitySpec] = "moderate", *,
              workload_name: str = "workload",
              padding: str = "auto",
              on_chunk=None) -> Dict:
        """(workload, fleet, spec) -> which catalog configs pass, ranked by
        worst-case (over seeds) energy overhead.

        The catalog Study runs on the streaming executor
        (``Study.run(stream=stream_chunk)``): metrics-only answers, no
        whole-study waveform retention.  ``on_chunk(done, total,
        elapsed_s)`` optionally reports progress (cache hits answer
        without invoking it)."""
        cache_key = self._cache_key(workload, n_chips, spec, padding)
        if cache_key in self._cache:
            return self._cache[cache_key]

        cfg, hw = self.wave_cfg, self.hw
        # the same jitter realization the catalog Study judges under, so a
        # fallback-designed config is validated on the waveform the rest
        # of the answer describes
        w = aggregate(chip_waveform(workload, cfg, hw), n_chips, cfg, hw,
                      seed=self.seeds[0])
        swing = float(w.max() - w.min())
        mean_mw = float(w.mean()) / 1e6
        if isinstance(spec, str):
            spec = example_specs(job_mw=mean_mw)[spec]

        study = Study({workload_name: workload}, fleets=[n_chips],
                      configs=default_catalog(swing, mpf_grid=self.mpf_grid,
                                              cap_fracs=self.cap_fracs,
                                              hw=hw),
                      specs=spec, seeds=self.seeds, wave_cfg=cfg, hw=hw,
                      key=self.key, padding=padding)
        result = study.run(stream=self.stream_chunk, on_chunk=on_chunk)
        self.last_result = result

        passing_names = result.passing_configs()
        by_config = {c: result.filter(config=c) for c in passing_names}
        passing = [{
            "config": c,
            "energy_overhead":
                max(r["energy_overhead"] for r in by_config[c]),
            "swing_mitigated_mw":
                max(r["swing_mitigated_mw"] for r in by_config[c]),
        } for c in passing_names]
        designed = None
        if not passing and self.design_fallback:
            # no catalog config passes: design one on demand (the engine's
            # grid/gradient/hybrid solver on this query's waveform)
            sol = design(spec, w, cfg.dt, n_chips, method=self.design_method,
                         hw=self.hw)
            if sol is not None:
                mit = sol["mitigated"]
                designed = {
                    "config": f"designed[{sol['method']}]",
                    "mpf_frac": sol["mpf_frac"],
                    "battery_capacity_j": sol["battery_capacity_j"],
                    "energy_overhead": sol["energy_overhead"],
                    "swing_mitigated_mw":
                        round(float(mit.max() - mit.min()) / 1e6, 4),
                    "alternatives": sol["alternatives"],
                    "designed": True,
                }
                passing = [designed]
        answer = {
            "workload": workload_name,
            "n_chips": int(n_chips),
            "spec": spec.name,
            "mean_mw": round(mean_mw, 4),
            "raw_swing_mw": round(swing / 1e6, 4),
            "n_configs": len(study.configs),
            "n_scenarios": study.n_rows,
            "compliant": bool(passing),
            "recommended": passing[0]["config"] if passing else None,
            "passing": passing,
            "designed": designed,
        }
        if len(self._cache) >= self.cache_size:
            self._cache.pop(next(iter(self._cache)))
        self._cache[cache_key] = answer
        return answer

    def _cache_key(self, workload, n_chips, spec, padding) -> Tuple:
        try:
            wk = hash(workload)
        except TypeError:
            wk = repr(workload)
        sk = spec if isinstance(spec, str) else (spec.name, repr(spec))
        return (wk, int(n_chips), sk, padding, self.wave_cfg, self.seeds)

    # -- JSON boundary ------------------------------------------------------

    def handle(self, request: Dict, *, on_chunk=None) -> Dict:
        """One request dict -> one JSON-safe answer dict.

        ``{"workload": {"period_s": 2.0, "comm_frac": 0.25,
                        "moe_notch": false} | {"cell": "<dryrun json>"},
           "n_chips": 512, "spec": "lenient|moderate|tight"}``

        ``on_chunk`` is a host-side progress callback (not part of the
        JSON boundary) threaded to ``query`` — the CLI's ``--progress``.
        """
        try:
            wl = request["workload"]
            if isinstance(wl, dict) and "cell" in wl:
                cell = load_cell(wl["cell"])
                tl = from_dryrun_cell(cell, self.hw)
                name = f"{cell.get('arch', 'cell')}"
            elif isinstance(wl, dict):
                tl = synthetic_timeline(
                    period_s=float(wl.get("period_s", 1.0)),
                    comm_frac=float(wl.get("comm_frac", 0.25)),
                    moe_notch=bool(wl.get("moe_notch", False)))
                name = wl.get("name", "synthetic")
            else:
                raise TypeError(f"unsupported workload request: {wl!r}")
            answer = self.query(tl, int(request["n_chips"]),
                                request.get("spec", "moderate"),
                                workload_name=name, on_chunk=on_chunk)
            return json.loads(json.dumps(answer, default=float))
        except (KeyError, TypeError, ValueError, OSError) as e:
            # OSError: a bad --cell path must come back as an error dict,
            # not escape the dict-in/dict-out service boundary
            return {"error": f"{type(e).__name__}: {e}"}


def main(argv: Optional[Sequence[str]] = None) -> None:
    ap = argparse.ArgumentParser(
        description="power-spec compliance query (Study API serve path)")
    ap.add_argument("--period-s", type=float, default=2.0)
    ap.add_argument("--comm-frac", type=float, default=0.25)
    ap.add_argument("--moe-notch", action="store_true")
    ap.add_argument("--cell", default=None,
                    help="dry-run artifact JSON (overrides the synthetic "
                         "workload flags)")
    ap.add_argument("--n-chips", type=int, default=512)
    ap.add_argument("--spec", default="moderate",
                    choices=("lenient", "moderate", "tight"))
    ap.add_argument("--progress", action="store_true",
                    help="report streaming sweep progress on stderr")
    args = ap.parse_args(argv)

    workload: Dict = ({"cell": args.cell} if args.cell else
                      {"period_s": args.period_s, "comm_frac": args.comm_frac,
                       "moe_notch": args.moe_notch})
    on_chunk = None
    if args.progress:
        def on_chunk(done: int, total: int, elapsed: float) -> None:
            print(f"# {done}/{total} scenarios in {elapsed:.1f}s",
                  file=sys.stderr)
    service = PowerComplianceService()
    answer = service.handle({"workload": workload, "n_chips": args.n_chips,
                             "spec": args.spec}, on_chunk=on_chunk)
    print(json.dumps(answer, indent=2))


if __name__ == "__main__":
    main()

"""Learned warm-start for the serve path's design fallback.

The ROADMAP's "learned warm-start" item: a cache miss that falls through
to ``engine.design`` pays seconds of grid/gradient solving.  This module
amortizes that with a small MLP mapping a workload's *spectral
fingerprint* — the grid-critical Goertzel bin amplitudes, swing, trace
length, fleet size, and the spec's normalized thresholds
(``core/spectrum.py``) — to design seeds ``(mpf_frac, capacity_j,
target_tau_s)``.  ``engine.design(method="warmstart",
warmstart=predictor)`` expands the seed into a hard tau=0-validated
candidate ladder (one vmapped call, milliseconds), so answers stay
exactly verified while warm latency drops ~two orders of magnitude; see
``engine.design_warmstart`` for the escalation tiers that keep verdicts
identical to the solver this replaces.

The model is deliberately tiny (a residual GELU block from
``models/mlp.py`` between two dense projections, a few thousand
parameters) and trains in seconds with the shared Adam core
(``train.trainer.make_regression_train_step`` over ``core/optim.py``).
Targets are scale-free — mpf as a fraction of the hardware cap, capacity
in units of ``2s * swing`` (the engine's default ``cap_scale`` at its
2 s period hint), tau in units of the battery's 30 s default — so one
checkpoint serves any job power.  Checkpoints ride the so-far-unused
``ckpt/checkpoint.py`` (npy leaves + JSON manifest; the manifest's
``extra`` carries the model meta so ``WarmStartPredictor.load`` is
self-describing).  ``benchmarks/warmstart_data.py`` generates the
training sweep.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import restore_pytree, save_pytree
from repro.core.hardware import DEFAULT_HW
from repro.core.optim import adam_init
from repro.core.spec import UtilitySpec
from repro.core.spectrum import GRID_CRITICAL_HZ, goertzel_bin_amplitudes
from repro.models.layers import dense_init
from repro.models.mlp import init_mlp, mlp_forward
from repro.train.trainer import make_regression_train_step

# capacity targets are in units of (CAP_PERIOD_S * swing) — the engine's
# default cap_scale at its 2 s period hint; tau targets in units of the
# battery's default EMA horizon
CAP_PERIOD_S = 2.0
TAU_SCALE_S = 30.0

FEATURE_NAMES: Tuple[str, ...] = (
    "log10_n_chips", "log10_mean_w", "swing_frac", "trace_s",
    *(f"goertzel_{f:g}hz_frac" for f in GRID_CRITICAL_HZ),
    "dominant_critical_hz",
    "ramp_up_frac_per_s", "ramp_down_frac_per_s", "dynamic_range_frac",
    "max_energy_fraction", "log10_min_ac_rms_frac",
)
N_FEATURES = len(FEATURE_NAMES)
N_TARGETS = 3   # (mpf_frac / mpf_max, cap_j / (2s * swing), tau_s / 30s)

# indices the predictor reads back to denormalize capacity: swing_w =
# swing_frac * 10**log10_mean_w (both computed from the same waveform)
_F_LOG_MEAN = FEATURE_NAMES.index("log10_mean_w")
_F_SWING_FRAC = FEATURE_NAMES.index("swing_frac")


def extract_features(spec: UtilitySpec, w: np.ndarray, dt: float,
                     n_chips: int) -> np.ndarray:
    """The [N_FEATURES] spectral fingerprint of one (workload waveform,
    fleet, spec) query.

    Waveform terms are scale-normalized by the mean draw (the Goertzel
    amplitudes become modulation *fractions*), spec thresholds likewise —
    the same workload at 10 MW and 100 MW maps to the same point, which
    is exactly the invariance the scale-free targets need.  O(n * K)
    Goertzel sums, no FFT plan; the serve layer memoizes the result per
    (workload, fleet) so repeated misses don't recompute synthesis +
    analysis.
    """
    w = np.asarray(w, np.float64)
    mean = max(float(w.mean()), 1e-9)
    swing = float(w.max() - w.min())
    amps = goertzel_bin_amplitudes(w, dt) / mean
    dom = float(GRID_CRITICAL_HZ[int(np.argmax(amps))])
    feats = [
        np.log10(max(float(n_chips), 1.0)),
        np.log10(mean),
        swing / mean,
        len(w) * dt,
        *amps.tolist(),
        dom,
        spec.time.ramp_up_w_per_s / mean,
        spec.time.ramp_down_w_per_s / mean,
        spec.time.dynamic_range_w / mean,
        spec.freq.max_energy_fraction,
        np.log10(max(spec.freq.min_ac_rms_frac, 1e-12)),
    ]
    return np.asarray(feats, np.float32)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

def init_warmstart(key, *, n_features: int = N_FEATURES, d_model: int = 32,
                   d_ff: int = 64, n_targets: int = N_TARGETS,
                   dtype=jnp.float32) -> Dict:
    """features -> d_model embed -> residual GELU MLP block -> targets.

    The embed takes ``n_features + 1`` inputs: the model-side dense
    layers are bias-free (``models/layers.dense_init``), which pins a
    pure composition to f(0) = 0 — a constant-one input channel restores
    the bias pathway so the net can express the mean design (normalized
    features sit near 0 for typical queries)."""
    ks = jax.random.split(key, 3)
    return {"w_embed": dense_init(ks[0], n_features + 1, d_model, dtype),
            "mlp": init_mlp(ks[1], d_model, d_ff, "gelu", dtype),
            "w_head": dense_init(ks[2], d_model, n_targets, dtype)}


def warmstart_forward(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """[B, F] normalized features -> [B, T] normalized targets."""
    ones = jnp.ones((*x.shape[:-1], 1), x.dtype)
    h = jnp.concatenate([x, ones], axis=-1) @ params["w_embed"]
    h = h + mlp_forward(params["mlp"], h, "gelu")
    return h @ params["w_head"]


@jax.jit
def _predict_normalized(params: Dict, norm: Dict, x: jnp.ndarray
                        ) -> jnp.ndarray:
    x = (jnp.asarray(x, jnp.float32) - norm["mean"]) / norm["std"]
    return warmstart_forward(params, x)


class WarmStartPredictor:
    """The trained warm-start model + feature normalization + meta.

    Callable with the engine's predictor protocol —
    ``predictor(spec, w, dt, n_chips, features=None)`` returns
    ``[(mpf_frac, capacity_j, target_tau_s)]`` seeds in physical units —
    so an instance plugs straight into
    ``design(method="warmstart", warmstart=predictor)`` and into
    ``PowerComplianceService(warmstart=...)``.
    """

    def __init__(self, params: Dict, norm: Dict, meta: Dict):
        self.params = params
        self.norm = norm
        self.meta = dict(meta)

    # -- inference ----------------------------------------------------------

    def predict_normalized(self, features: np.ndarray) -> np.ndarray:
        """[B, F] raw features -> [B, T] scale-free targets."""
        x = np.atleast_2d(np.asarray(features, np.float32))
        return np.asarray(_predict_normalized(self.params, self.norm, x))

    def __call__(self, spec: UtilitySpec, w: np.ndarray, dt: float,
                 n_chips: int, features: Optional[np.ndarray] = None
                 ) -> List[Tuple[float, float, float]]:
        f = (extract_features(spec, w, dt, n_chips)
             if features is None else np.asarray(features, np.float32))
        out = self.predict_normalized(f)[0]
        swing = float(f[_F_SWING_FRAC]) * 10.0 ** float(f[_F_LOG_MEAN])
        mpf_max = float(self.meta.get("mpf_max", DEFAULT_HW.chip.mpf_max))
        mpf = float(np.clip(out[0], 0.0, 1.0)) * mpf_max
        cap = max(float(out[1]), 0.0) * CAP_PERIOD_S * swing
        # tau clamped to a sane controller range: [1/6, 4] x 30 s
        tau = float(np.clip(out[2], 1.0 / 6.0, 4.0)) * TAU_SCALE_S
        return [(mpf, cap, tau)]

    # -- persistence (ckpt/checkpoint.py) -----------------------------------

    def save(self, directory: str, step: int = 0) -> str:
        return save_pytree(directory,
                           {"params": self.params, "norm": self.norm},
                           step, extra=self.meta)

    @classmethod
    def load(cls, directory: str) -> "WarmStartPredictor":
        with open(os.path.join(directory, "manifest.json")) as fh:
            meta = json.load(fh)["extra"]
        template = {
            "params": init_warmstart(
                jax.random.PRNGKey(0),
                n_features=int(meta["n_features"]),
                d_model=int(meta["d_model"]), d_ff=int(meta["d_ff"]),
                n_targets=int(meta.get("n_targets", N_TARGETS))),
            "norm": {"mean": jnp.zeros(int(meta["n_features"])),
                     "std": jnp.ones(int(meta["n_features"]))},
        }
        tree, manifest = restore_pytree(directory, template)
        return cls(tree["params"], tree["norm"], manifest["extra"])


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def normalize_targets(targets: np.ndarray, swings: np.ndarray,
                      mpf_max: float) -> np.ndarray:
    """Physical (mpf_frac, capacity_j, tau_s) [N, 3] -> scale-free [N, 3]."""
    t = np.asarray(targets, np.float64)
    s = np.maximum(np.asarray(swings, np.float64), 1e-9)
    return np.stack([t[:, 0] / max(mpf_max, 1e-9),
                     t[:, 1] / (CAP_PERIOD_S * s),
                     t[:, 2] / TAU_SCALE_S], axis=1).astype(np.float32)


def swings_from_features(features: np.ndarray) -> np.ndarray:
    """Recover each sample's raw swing (watts) from its feature row."""
    f = np.atleast_2d(np.asarray(features, np.float64))
    return f[:, _F_SWING_FRAC] * 10.0 ** f[:, _F_LOG_MEAN]


def train_warmstart(features: np.ndarray, targets: np.ndarray, *,
                    mpf_max: float = DEFAULT_HW.chip.mpf_max,
                    d_model: int = 32, d_ff: int = 64,
                    epochs: int = 400, batch_size: int = 64,
                    lr: float = 3e-3, weight_decay: float = 1e-4,
                    seed: int = 0,
                    ) -> Tuple[WarmStartPredictor, Dict[str, List[float]]]:
    """Fit a ``WarmStartPredictor`` on solved designs.

    ``features`` [N, F] from ``extract_features``; ``targets`` [N, 3]
    *physical* ``(mpf_frac, capacity_j, target_tau_s)`` from the solver
    (``benchmarks/warmstart_data.py`` generates both).  Each sample's
    swing for capacity normalization is recovered from its own feature
    row.  Returns the predictor and a history dict (per-epoch MSE in
    normalized target space).
    """
    x = np.asarray(features, np.float32)
    if x.ndim != 2 or x.shape[1] != N_FEATURES:
        raise ValueError(f"features must be [N, {N_FEATURES}], got {x.shape}")
    y = normalize_targets(targets, swings_from_features(x), mpf_max)
    n = len(x)
    mean = x.mean(axis=0)
    std = np.maximum(x.std(axis=0), 1e-6)
    norm = {"mean": jnp.asarray(mean, jnp.float32),
            "std": jnp.asarray(std, jnp.float32)}

    params = init_warmstart(jax.random.PRNGKey(seed), d_model=d_model,
                            d_ff=d_ff)
    opt = adam_init(params)
    step = make_regression_train_step(
        functools.partial(_forward_normalized_closure, norm), lr=lr,
        weight_decay=weight_decay)

    rng = np.random.default_rng(seed)
    batch_size = max(1, min(batch_size, n))
    losses: List[float] = []
    for _ in range(epochs):
        order = rng.permutation(n)
        ep = []
        for lo in range(0, n, batch_size):
            sel = order[lo:lo + batch_size]
            params, opt, m = step(params, opt, jnp.asarray(x[sel]),
                                  jnp.asarray(y[sel]))
            ep.append(float(m["loss"]))
        losses.append(float(np.mean(ep)))
    meta = {"n_features": N_FEATURES, "n_targets": N_TARGETS,
            "d_model": d_model, "d_ff": d_ff, "mpf_max": float(mpf_max),
            "cap_period_s": CAP_PERIOD_S, "tau_scale_s": TAU_SCALE_S,
            "n_train": int(n), "final_loss": losses[-1] if losses else None,
            "feature_names": list(FEATURE_NAMES)}
    return WarmStartPredictor(params, norm, meta), {"loss": losses}


def _forward_normalized_closure(norm, params, x):
    """Module-level forward with the normalization baked in (closing over
    ``norm`` with functools.partial keeps the jitted step cacheable)."""
    xn = (x - norm["mean"]) / norm["std"]
    return warmstart_forward(params, xn)

from repro.train.optimizer import (adamw_update, clip_by_global_norm,
                                   init_opt_state, lr_schedule)
from repro.train.trainer import TrainState, make_train_step, init_train_state

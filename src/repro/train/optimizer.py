"""Pure-JAX AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule. Moments are stored in ``moment_dtype`` (bf16 for the
>=100B dry-run configs) with f32 update math.

The moment math and norm clipping live in ``repro.core.optim`` (shared
with the mitigation-design gradient loop in ``core/engine.py``); this
module keeps the training-specific pieces: the schedule, bf16 moment
storage, and the per-path weight-decay mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.optim import adam_leaf, clip_by_global_norm, global_norm

__all__ = ["init_opt_state", "lr_schedule", "global_norm",
           "clip_by_global_norm", "adamw_update"]

F32 = jnp.float32


def init_opt_state(params, moment_dtype="float32"):
    mdt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def lr_schedule(step, tcfg):
    step = step.astype(F32) + 1.0  # 1-indexed: step 0 trains at lr/warmup
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


_DECAY_EXEMPT = ("norm", "bias", "gate", "mu", "w0", "u", "dt_bias", "gn_",
                 "A_log", "D")


def _decay_mask(path_names) -> bool:
    name = path_names[-1]
    return not any(t in name for t in _DECAY_EXEMPT)


def adamw_update(params, grads, opt_state, tcfg, lr):
    count = opt_state["count"] + 1
    c = count.astype(F32)

    def upd(keypath, p, g, m, v):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in keypath)
        wd = tcfg.weight_decay if _decay_mask(names) else 0.0
        return adam_leaf(p, g, m, v, c, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
                         eps=tcfg.eps, weight_decay=wd)

    flat = jax.tree_util.tree_map_with_path(
        upd, params, grads, opt_state["m"], opt_state["v"])
    outer = jax.tree.structure(params)
    inner = jax.tree.structure((0, 0, 0))
    new_params, new_m, new_v = jax.tree.transpose(outer, inner, flat)
    return new_params, {"m": new_m, "v": new_v, "count": count}

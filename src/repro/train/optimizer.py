"""Pure-JAX AdamW with decoupled weight decay, global-norm clipping, and a
warmup+cosine schedule. Moments are stored in ``moment_dtype`` (bf16 for the
>=100B dry-run configs) with f32 update math."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def init_opt_state(params, moment_dtype="float32"):
    mdt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def lr_schedule(step, tcfg):
    step = step.astype(F32) + 1.0  # 1-indexed: step 0 trains at lr/warmup
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tcfg.warmup_steps)
                    / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(F32) * scale).astype(x.dtype), grads), g


_DECAY_EXEMPT = ("norm", "bias", "gate", "mu", "w0", "u", "dt_bias", "gn_",
                 "A_log", "D")


def _decay_mask(path_names) -> bool:
    name = path_names[-1]
    return not any(t in name for t in _DECAY_EXEMPT)


def adamw_update(params, grads, opt_state, tcfg, lr):
    count = opt_state["count"] + 1
    c = count.astype(F32)
    bc1 = 1.0 - tcfg.b1 ** c
    bc2 = 1.0 - tcfg.b2 ** c

    def upd(keypath, p, g, m, v):
        gf = g.astype(F32)
        m2 = tcfg.b1 * m.astype(F32) + (1 - tcfg.b1) * gf
        v2 = tcfg.b2 * v.astype(F32) + (1 - tcfg.b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        step = mh / (jnp.sqrt(vh) + tcfg.eps)
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        if _decay_mask(names):
            step = step + tcfg.weight_decay * p.astype(F32)
        p2 = p.astype(F32) - lr * step
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    flat = jax.tree_util.tree_map_with_path(
        upd, params, grads, opt_state["m"], opt_state["v"])
    outer = jax.tree.structure(params)
    inner = jax.tree.structure((0, 0, 0))
    new_params, new_m, new_v = jax.tree.transpose(outer, inner, flat)
    return new_params, {"m": new_m, "v": new_v, "count": count}

"""Train-step builder: value_and_grad + microbatch accumulation + AdamW,
with the paper's in-graph ballast hook (power stabilization) attached.

The returned ``train_step(state, batch)`` is pure and jit/pjit-friendly;
``in_out_shardings`` builds the NamedSharding trees for pjit from a Plan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.model import Ctx, init_params, loss_fn
from repro.train.optimizer import (adamw_update, clip_by_global_norm,
                                   init_opt_state, lr_schedule)

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jax.Array  # int32 scalar


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    params = init_params(key, cfg)
    opt = init_opt_state(params, tcfg.moment_dtype)
    return TrainState(params, opt, jnp.zeros((), jnp.int32))


def _split_microbatches(batch, n):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, plan=None,
                    unroll: bool = False):
    ctx_kwargs = plan.ctx_kwargs() if plan is not None else {}
    if plan is not None and hasattr(plan, "moe_sm"):
        ctx_kwargs["moe_sm"] = plan.moe_sm(cfg)

    def loss_for_grad(params, mb):
        ctx = Ctx(cfg=cfg, remat=tcfg.remat, unroll=unroll, **ctx_kwargs)
        loss, metrics = loss_fn(params, cfg, mb, ctx)
        if tcfg.ballast and tcfg.ballast_gflops > 0:
            from repro.core.ballast_inject import attach_ballast
            loss = attach_ballast(loss, tcfg.ballast_gflops)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def train_step(state: TrainState, batch):
        n = tcfg.microbatches
        if n > 1:
            mbs = _split_microbatches(batch, n)

            def acc(carry, mb):
                (tot, gacc) = carry
                (l, _m), g = grad_fn(state.params, mb)
                gacc = jax.tree.map(lambda a, b: a + b.astype(F32), gacc, g)
                return (tot + l, gacc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), state.params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), F32), g0), mbs)
            loss = loss / n
            grads = jax.tree.map(lambda g: (g / n), grads)
            metrics = {"ce": loss, "moe_aux": jnp.zeros((), F32)}
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_schedule(state.step, tcfg)
        new_params, new_opt = adamw_update(state.params, grads, state.opt, tcfg, lr)
        out = TrainState(new_params, new_opt, state.step + 1)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return out, metrics

    return train_step


# ---------------------------------------------------------------------------
# Small-model regression step (the serve path's warm-start predictor)
# ---------------------------------------------------------------------------

def make_regression_train_step(forward, *, lr: float = 1e-3,
                               grad_clip: float = 10.0,
                               weight_decay: float = 0.0):
    """Jitted MSE regression step over the shared pure-JAX Adam core
    (``core/optim.py`` — the same moment kernel ``design_gradient`` and
    the AdamW training step wrap).

    ``forward(params, x)`` maps a ``[B, F]`` feature batch to ``[B, T]``
    predictions; the returned ``train_step(params, opt_state, x, y)``
    gives ``(params, opt_state, metrics)`` with ``metrics["loss"]`` the
    batch MSE.  Initialize ``opt_state`` with ``core.optim.adam_init``.
    This is what trains the serve layer's ``WarmStartPredictor``
    (features -> design seeds) — a few thousand parameters, so one jit
    with the whole batch resident is the right scale.
    """
    from repro.core.optim import adam_init  # noqa: F401  (re-exported use)
    from repro.core.optim import adam_update
    from repro.core.optim import clip_by_global_norm as clip_core

    def loss_fn(params, x, y):
        pred = forward(params, x)
        return jnp.mean(jnp.square(pred - y))

    @jax.jit
    def train_step(params, opt_state, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        grads, gnorm = clip_core(grads, grad_clip)
        params, opt_state = adam_update(params, grads, opt_state, lr,
                                        weight_decay=weight_decay)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


# ---------------------------------------------------------------------------
# Compressed-gradient data-parallel step (distributed-optimization trick)
# ---------------------------------------------------------------------------

def make_dp_compressed_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                                  axis: str = "data"):
    """Data-parallel train step with int8 error-feedback gradient reduction.

    Params replicated; each shard computes grads on its batch slice; the
    mean is taken with ``compressed_allreduce_mean`` (8.25 bits/elem wire vs
    32 — the paper's Call-to-Action #1 'power-aware training algorithms'
    cuts the comm-phase duration, which directly shrinks the power trough).
    Error-feedback residuals ride in the state so the quantization bias
    vanishes across steps. State: (TrainState, err_tree).
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.collectives import compressed_allreduce_mean

    def init_err(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def step_body(state: TrainState, err, batch):
        ctx_kwargs = {}

        def loss_f(params, mb):
            ctx = Ctx(cfg=cfg, remat=tcfg.remat, **ctx_kwargs)
            return loss_fn(params, cfg, mb, ctx)

        (loss, metrics), grads = jax.value_and_grad(loss_f, has_aux=True)(
            state.params, batch)
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree.leaves(err)
        reduced, new_err = [], []
        for g, e in zip(flat_g, flat_e):
            r, ne = compressed_allreduce_mean(g, e, axis)
            reduced.append(r)
            new_err.append(ne.astype(jnp.float32))
        grads = jax.tree_util.tree_unflatten(tdef, reduced)
        err = jax.tree_util.tree_unflatten(tdef, new_err)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_schedule(state.step, tcfg)
        new_params, new_opt = adamw_update(state.params, grads, state.opt,
                                           tcfg, lr)
        loss = jax.lax.pmean(loss, axis)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), err, metrics

    rep = P()

    def train_step(state, err, batch):
        fn = shard_map(
            step_body, mesh=mesh,
            in_specs=(rep, rep, P(axis)),   # pytree-prefix specs
            out_specs=(rep, rep, rep), check_rep=False)
        return fn(state, err, batch)

    return train_step, init_err


# ---------------------------------------------------------------------------
# pjit sharding trees
# ---------------------------------------------------------------------------

def in_out_shardings(cfg: ModelConfig, plan, state_shape, batch_shape):
    """NamedSharding trees for (state, batch) -> (state, metrics)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.sharding import batch_pspecs, param_pspecs

    def ns(spec):
        return NamedSharding(plan.mesh, spec)

    pspecs = param_pspecs(cfg, plan, state_shape.params)
    param_sh = jax.tree.map(ns, pspecs)
    opt_sh = {"m": jax.tree.map(ns, pspecs), "v": jax.tree.map(ns, pspecs),
              "count": ns(P())}
    state_sh = TrainState(param_sh, opt_sh, ns(P()))
    batch_sh = jax.tree.map(ns, batch_pspecs(cfg, plan, batch_shape))
    metrics_sh = ns(P())
    return state_sh, batch_sh, metrics_sh

"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py boots the 512-device placeholder platform."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_batch(cfg, B=2, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    batch = {"labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jax.random.randint(jax.random.fold_in(k, 1),
                                             (B, S), 0, cfg.vocab_size)
    else:
        batch["inputs"] = jax.random.normal(jax.random.fold_in(k, 2),
                                            (B, S, cfg.d_model))
    if cfg.vision is not None:
        batch["vision_embeds"] = jax.random.normal(
            jax.random.fold_in(k, 3), (B, cfg.vision.n_tokens, cfg.vision.dim))
    return batch


@pytest.fixture(params=ARCH_IDS)
def arch_cfg(request):
    return reduced(get_config(request.param))

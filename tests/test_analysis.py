"""repro-lint: fixture snippets per Tier-1 rule, the Tier-2 PR-3
regression (deliberately reverted oracle re-detected), the recompile
gate, Tier-3 kernel-geometry checks, baseline semantics, and the CLI
exit contract."""
import json
import textwrap

import pytest

from repro.analysis.findings import (Baseline, Finding, apply_baseline,
                                     sort_findings)
from repro.analysis.rules import RULE_CATALOG, lint_source


def lint(src, rules=None):
    return lint_source(textwrap.dedent(src), "fixture.py", rules)


def hits(findings, rule):
    return [f for f in findings if f.rule == rule]


# ---------------------------------------------------------------------------
# Tier 1: each rule fires exactly where expected; clean twins pass
# ---------------------------------------------------------------------------

def test_rpr001_host_sync_fires_on_traced_value():
    found = lint("""
        import jax, jax.numpy as jnp

        @jax.jit
        def f(x: jnp.ndarray):
            y = jnp.sum(x)
            return float(y)
    """, rules=["RPR001"])
    assert [f.line for f in hits(found, "RPR001")] == [7]
    assert hits(found, "RPR001")[0].context == "f"


def test_rpr001_item_and_asarray_fire_static_casts_do_not():
    found = lint("""
        import jax, jax.numpy as jnp
        import numpy as np

        @jax.jit
        def f(x: jnp.ndarray, win: int):
            n = x.shape[0]            # static: .shape escape hatch
            w = int(n // win)          # static arithmetic, no finding
            a = x.sum().item()         # line 9: host sync
            b = np.asarray(x * 2)      # line 10: host materialize
            return a, b, w
    """, rules=["RPR001"])
    assert sorted(f.line for f in hits(found, "RPR001")) == [9, 10]


def test_rpr001_clean_traced_function_passes():
    found = lint("""
        import jax, jax.numpy as jnp

        @jax.jit
        def f(x: jnp.ndarray):
            return jnp.sqrt(jnp.sum(x * x))
    """, rules=["RPR001"])
    assert found == []


def test_rpr002_key_reuse_fires_split_does_not():
    found = lint("""
        import jax

        def sample(key):
            a = jax.random.normal(key, (4,))
            b = jax.random.uniform(key, (4,))   # line 6: reuse
            return a + b

        def sample_ok(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (4,))
            b = jax.random.uniform(k2, (4,))
            return a + b
    """, rules=["RPR002"])
    got = hits(found, "RPR002")
    assert [f.line for f in got] == [6]
    assert got[0].context == "sample"


def test_rpr002_loop_reuse_fires_per_iteration_fold_in_does_not():
    found = lint("""
        import jax

        def bad(key, n):
            out = []
            for i in range(n):
                out.append(jax.random.normal(key, (2,)))   # line 7
            return out

        def good(key, n):
            out = []
            for i in range(n):
                k = jax.random.fold_in(key, i)
                out.append(jax.random.normal(k, (2,)))
            return out
    """, rules=["RPR002"])
    got = hits(found, "RPR002")
    assert [f.line for f in got] == [7]
    assert "loop" in got[0].message


def test_rpr003_branch_on_data_field_fires_meta_and_guard_do_not():
    found = lint("""
        import dataclasses
        from repro.core.smoothing.base import register_mitigation

        @dataclasses.dataclass(frozen=True)
        class M:
            alpha: float = 0.5
            use_fast: bool = True

            def apply_jax(self, w, dt):
                if self.alpha > 0:                 # line 11: leaf branch
                    w = w * self.alpha
                if self.use_fast:                  # meta: fine
                    w = w + 1.0
                if isinstance(self.alpha, float):  # guard itself: fine
                    assert self.alpha < 1.0        # guarded: fine
                return w

        register_mitigation(M, data_fields=("alpha",),
                            meta_fields=("use_fast",))
    """, rules=["RPR003"])
    got = hits(found, "RPR003")
    assert [f.line for f in got] == [11]
    assert "'alpha'" in got[0].message


def test_rpr004_cumsum_fires_f64_promotion_does_not():
    found = lint("""
        import jax.numpy as jnp

        def power_profile(x):
            cs = jnp.cumsum(x)                        # line 5
            safe = jnp.cumsum(x, dtype=jnp.float64)   # promoted: fine
            return cs, safe
    """, rules=["RPR004"])
    assert [f.line for f in hits(found, "RPR004")] == [5]


def test_rpr005_branch_on_tracer_fires_shape_branch_does_not():
    found = lint("""
        import jax, jax.numpy as jnp

        @jax.jit
        def f(x: jnp.ndarray, win: int):
            if x.shape[0] % win:      # static shape arithmetic: fine
                x = x[:-1]
            m = jnp.mean(x)
            if m > 0:                  # line 9: tracer branch
                x = x - m
            return x
    """, rules=["RPR005"])
    got = hits(found, "RPR005")
    assert [f.line for f in got] == [9]


def test_rpr005_respects_static_argnames():
    found = lint("""
        import functools, jax, jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x: jnp.ndarray, mode: jnp.ndarray):
            if mode:                   # static_argnames: fine
                return x * 2
            return x
    """, rules=["RPR005"])
    assert found == []


def test_rpr005_tuple_unpack_return_annotation_untaints_host_part():
    """``freqs, mag = spectrum_jax(...)`` with a same-module
    ``-> Tuple[np.ndarray, jnp.ndarray]`` annotation: only ``mag`` is
    traced, so branching on the host ``freqs`` mask is fine while
    branching on ``mag`` still fires (the spectrum.py shape)."""
    found = lint("""
        from typing import Tuple
        import numpy as np
        import jax.numpy as jnp

        def spectrum_jax(x: jnp.ndarray, dt: float
                         ) -> Tuple[np.ndarray, jnp.ndarray]:
            freqs = np.fft.rfftfreq(x.shape[-1], dt)
            return freqs, jnp.abs(jnp.fft.rfft(x))

        def band_jax(x: jnp.ndarray, lo: float, hi: float):
            freqs, mag = spectrum_jax(x, 0.01)
            sel = (freqs >= lo) & (freqs <= hi)
            if not sel.any():              # host-side mask: fine
                return jnp.asarray(0.0)
            if mag.max() > 0:              # line 16: tracer branch
                return mag[sel].max()
            return jnp.asarray(0.0)
    """, rules=["RPR005"])
    assert [f.line for f in hits(found, "RPR005")] == [16]


def test_rpr006_mutable_default_fires_factory_does_not():
    found = lint("""
        import dataclasses
        import jax.numpy as jnp

        @dataclasses.dataclass
        class Cfg:
            freqs: list = [0.5, 1.0]                  # line 7
            table: jnp.ndarray = jnp.zeros((4,))      # line 8
            ok: tuple = (0.5, 1.0)
            also_ok: list = dataclasses.field(default_factory=list)
    """, rules=["RPR006"])
    assert sorted(f.line for f in hits(found, "RPR006")) == [7, 8]


def test_rpr007_process_identity_in_traced_code_fires():
    found = lint("""
        import jax, jax.numpy as jnp

        @jax.jit
        def f(x):
            pid = jax.process_index()          # line 6: traced constant
            return x + pid

        def shard_rows_jax(x):
            return x * jax.process_count()     # line 10: *_jax is traced
    """, rules=["RPR007"])
    assert sorted(f.line for f in hits(found, "RPR007")) == [6, 10]
    assert "same program" in hits(found, "RPR007")[0].message


def test_rpr007_host_side_process_identity_passes():
    found = lint("""
        import jax

        def local_rows(n):
            # host-side slicing off process identity is the sanctioned use
            p = jax.process_index()
            per = n // jax.process_count()
            return slice(p * per, (p + 1) * per)

        def is_primary():
            return jax.process_index() == 0
    """, rules=["RPR007"])
    assert hits(found, "RPR007") == []


def test_rpr007_pytree_data_field_fires_meta_field_does_not():
    found = lint("""
        import dataclasses
        import jax
        from repro.core.smoothing.base import register_mitigation

        @dataclasses.dataclass
        class M:
            alpha: float = 0.5
            pid: int = jax.process_index()       # line 9: data-field leaf
            n_procs: int = jax.process_count()   # meta field: host-side

            def tune(self):
                self.alpha = jax.process_index() * 0.1   # line 13

        register_mitigation(M, data_fields=("alpha", "pid"),
                            meta_fields=("n_procs",))
    """, rules=["RPR007"])
    got = hits(found, "RPR007")
    assert sorted(f.line for f in got) == [9, 13]


def test_syntax_error_reports_rpr000():
    found = lint("def broken(:\n")
    assert [f.rule for f in found] == ["RPR000"]


# ---------------------------------------------------------------------------
# baseline semantics
# ---------------------------------------------------------------------------

def test_baseline_suppresses_by_context_and_reports_stale():
    f1 = Finding("RPR004", "a.py", 10, "m", "warning", context="f")
    f2 = Finding("RPR004", "a.py", 99, "m", "warning", context="f")
    f3 = Finding("RPR004", "b.py", 10, "m", "warning", context="g")
    bl = Baseline([
        {"rule": "RPR004", "path": "a.py", "context": "f",
         "justification": "segmented"},
        {"rule": "RPR001", "path": "zz.py", "context": "gone",
         "justification": "stale"},
    ])
    active, suppressed = apply_baseline([f1, f2, f3], bl)
    # line-number independent: both a.py findings suppressed by one entry
    assert active == [f3]
    assert len(suppressed) == 2
    assert [e["context"] for e in bl.unused()] == ["gone"]


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "bl.json"
    p.write_text(json.dumps({"entries": [
        {"rule": "RPR004", "path": "a.py", "context": "f"}]}))
    with pytest.raises(ValueError, match="justification"):
        Baseline.load(str(p))


def test_sort_findings_stable_order():
    fs = [Finding("RPR005", "b.py", 2, "m"), Finding("RPR001", "a.py", 9, "m"),
          Finding("RPR001", "a.py", 3, "m")]
    assert [(f.path, f.line) for f in sort_findings(fs)] == [
        ("a.py", 3), ("a.py", 9), ("b.py", 2)]


# ---------------------------------------------------------------------------
# Tier 2: the PR-3 regression oracle + clean registered paths
# ---------------------------------------------------------------------------

def test_jaxpr_tier_redetects_pr3_reverted_oracle():
    """Revert the PR-3 fix (drop mean removal, keep the trace-length
    cumsum) and the long-axis analyzer must flag it."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_checks import check_jaxpr

    def reverted_sliding_bin_power(x, dt, freqs, win):
        # sliding_bin_power_jnp minus the xc = x - mean(x) step: the
        # exact pre-PR-3 shape — full-trace f32/c64 prefix sums on
        # MW-scale data
        t = jnp.arange(x.shape[0]) * dt
        ph = jnp.exp(-2j * jnp.pi * jnp.asarray(freqs)[None, :]
                     * t[:, None]).astype(jnp.complex64)
        cs = jnp.cumsum(x[:, None] * ph, axis=0)
        w = cs.at[win:].set(cs[win:] - cs[:-win])
        denom = jnp.minimum(jnp.arange(x.shape[0]) + 1, win)
        return 2.0 * jnp.abs(w) / denom[:, None]

    x = jnp.zeros((20_000,), jnp.float32)
    closed = jax.make_jaxpr(
        lambda x: reverted_sliding_bin_power(x, 0.001, (0.5, 1.0, 2.0, 9.0),
                                             2000))(x)
    got = check_jaxpr(closed, name="reverted_oracle")
    assert any(f.rule == "RPR101" and "cumsum" in f.message for f in got)
    # while the product path (segmented Pallas monitor) stays clean
    from repro.analysis.jaxpr_checks import trace_entry, check_jaxpr as cj
    from repro.analysis.registry import ENTRY_BY_NAME
    ep = ENTRY_BY_NAME["kernels.sliding_bin_power"]
    assert cj(trace_entry(ep), name=ep.name) == []


def test_jaxpr_tier_registered_serve_paths_clean():
    from repro.analysis.jaxpr_checks import check_entry_points
    got = check_entry_points(["serve.fingerprint", "serve.warmstart_mlp",
                              "control.detector_step"])
    assert got == []


def test_jaxpr_tier_flags_host_callback():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_checks import check_jaxpr

    def f(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    closed = jax.make_jaxpr(f)(jnp.zeros((4,), jnp.float32))
    got = check_jaxpr(closed, name="cb")
    assert [f.rule for f in got] == ["RPR102"]


def test_primitive_counts_deterministic_and_diff_names_drift():
    from repro.analysis.jaxpr_checks import primitive_counts, primitive_diff
    from repro.analysis.registry import ENTRY_BY_NAME

    ep = ENTRY_BY_NAME["serve.fingerprint"]
    c1, c2 = primitive_counts(ep), primitive_counts(ep)
    assert c1 == c2 and c1["dot_general"] == 2
    diff = primitive_diff(dict(c1), {**c1, "dot_general": 3, "exp": 1})
    assert any(line.startswith("dot_general:") for line in diff)
    assert any(line.startswith("exp:") for line in diff)


def test_recompile_gate_zero_cache_misses():
    """Second same-shape-bucket call of every registered workload must
    hit the jit cache (the recompile-storm gate)."""
    from repro.analysis.jaxpr_checks import recompile_gate
    got = recompile_gate()
    assert got == [], "\n".join(f.render() for f in got)


# ---------------------------------------------------------------------------
# Tier 3: kernel launch geometry
# ---------------------------------------------------------------------------

def test_kernel_checks_current_kernels_only_known_findings():
    from repro.analysis.kernel_checks import check_kernels
    got = check_kernels()
    # the lane-major v2 layout retired the v1 narrow-K RPR203 findings;
    # the only live findings are the intentional last-write-wins prefix
    # state outputs (sequential grid), baselined in lint_baseline.json
    assert all(f.rule == "RPR202" for f in got), \
        "\n".join(f.render() for f in got)
    assert {f.context for f in got} == {
        "goertzel.sliding_v2:out4", "goertzel.sliding_v2:out5",
        "goertzel.monitor:out3", "goertzel.monitor:out4"}


def test_kernel_checks_flag_bad_geometry():
    import jax

    from repro.analysis.kernel_checks import (KernelCase, PallasCapture,
                                              check_capture)

    class FakeSpec:
        def __init__(self, block_shape, index_map):
            self.block_shape = block_shape
            self.index_map = index_map

    case = KernelCase("fake.bad", "fake.py", lambda: None)
    cap = PallasCapture(
        grid=(3,),
        in_specs=(FakeSpec((48, 2000), lambda i: (i, 0)),),   # 100 % 48 != 0
        out_specs=(FakeSpec((16, 128), lambda i: (0, 0)),),   # all cells -> 0
        out_shapes=(jax.ShapeDtypeStruct((48, 128), "float32"),),
        scratch_shapes=(),
        operands=(jax.ShapeDtypeStruct((100, 2000), "float32"),),
    )
    got = check_capture(case, cap)
    rules = {f.rule for f in got}
    assert "RPR201" in rules          # non-dividing block
    assert "RPR202" in rules          # coverage gap + duplicate writes
    msgs = " ".join(f.message for f in got)
    assert "never written" in msgs and "multiple grid cells" in msgs


def test_kernel_checks_vmem_budget():
    import jax

    from repro.analysis.kernel_checks import (KernelCase, PallasCapture,
                                              check_capture)

    class FakeSpec:
        def __init__(self, block_shape, index_map):
            self.block_shape = block_shape
            self.index_map = index_map

    case = KernelCase("fake.huge", "fake.py", lambda: None)
    cap = PallasCapture(
        grid=(1,),
        in_specs=(FakeSpec((8192, 8192), lambda i: (0, 0)),),  # 256 MiB f32
        out_specs=(FakeSpec((8, 128), lambda i: (0, 0)),),
        out_shapes=(jax.ShapeDtypeStruct((8, 128), "float32"),),
        scratch_shapes=(),
        operands=(jax.ShapeDtypeStruct((8192, 8192), "float32"),),
    )
    got = check_capture(case, cap)
    assert any(f.rule == "RPR205" for f in got)


# ---------------------------------------------------------------------------
# dead-module report + CLI contract
# ---------------------------------------------------------------------------

def test_dead_module_report_clean_outside_launch_and_models():
    from pathlib import Path

    from repro.analysis.deadmods import check_dead_modules
    repo_root = Path(__file__).resolve().parents[1]
    got = check_dead_modules(repo_root)
    errors = [f for f in got if f.severity == "error"]
    assert errors == [], "\n".join(f.render() for f in errors)
    # launch/ entries stay visible but informational
    assert all(f.context.startswith(("repro.launch", "repro.models"))
               for f in got)


def test_cli_exit_one_on_injected_bug_zero_when_baselined(tmp_path, capsys):
    from repro.analysis.cli import main

    pkg = tmp_path / "src"
    pkg.mkdir()
    bad = pkg / "buggy.py"
    bad.write_text(textwrap.dedent("""
        import jax, jax.numpy as jnp

        @jax.jit
        def f(x: jnp.ndarray):
            return float(jnp.sum(x))
    """))
    bl = tmp_path / "bl.json"

    rc = main([str(pkg), "--root", str(tmp_path), "--tiers", "ast",
               "--baseline", str(bl), "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert [f["rule"] for f in report["findings"]] == ["RPR001"]
    assert report["findings"][0]["path"] == "src/buggy.py"

    bl.write_text(json.dumps({"version": 1, "entries": [
        {"rule": "RPR001", "path": "src/buggy.py", "context": "f",
         "justification": "fixture: intentional"}]}))
    rc = main([str(pkg), "--root", str(tmp_path), "--tiers", "ast",
               "--baseline", str(bl)])
    capsys.readouterr()
    assert rc == 0


def test_cli_repo_ast_tier_clean_under_checked_in_baseline(capsys):
    """The shipped tree + shipped baseline lint clean (the CI invariant,
    ast tier; the full-tier run is exercised in CI itself)."""
    from pathlib import Path

    from repro.analysis.cli import main
    repo_root = Path(__file__).resolve().parents[1]
    rc = main([str(repo_root / "src" / "repro"), "--root", str(repo_root),
               "--tiers", "ast"])
    out = capsys.readouterr().out
    assert rc == 0, out

"""Smoke test of the ``repro.api`` facade: every ``__all__`` export
resolves to a real object, and one tiny end-to-end declare->run->query
exercises the surface (also keeps the module reachable for the
repro-lint dead-module report)."""
import inspect

from repro import api


def test_every_export_resolves():
    missing = [n for n in api.__all__ if not hasattr(api, n)]
    assert missing == []
    # and nothing exported is a bare module (facade exports symbols)
    mods = [n for n in api.__all__ if inspect.ismodule(getattr(api, n))]
    assert mods == []


def test_minimal_study_roundtrip():
    specs = api.example_specs(job_mw=1.0)
    study = api.Study(
        workloads={"dense": api.synthetic_timeline(1.0, 0.3)},
        fleets=[64],
        configs={"none": None},
        specs={"moderate": specs["moderate"]},
        key=0,
        wave_cfg=api.WaveformConfig(dt=0.002, steps=4, jitter_s=0.002),
        sample_chips=16,
    )
    result = study.run()
    assert len(result) == 1
    rec = result[0]
    assert rec["workload"] == "dense"
    assert "energy_overhead" in rec

"""Fault tolerance: bitwise restart, retention, async, elastic resharding."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager, restore_pytree, save_pytree
from repro.configs import TrainConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.train import init_train_state, make_train_step


def _mk(cfg, tcfg, seed=0):
    state = init_train_state(jax.random.PRNGKey(seed), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(cfg, batch=4, seq=16, seed=0)
    return state, step, data


def test_save_restore_bitwise(tmp_path):
    cfg = reduced(get_config("granite-3-8b"))
    tcfg = TrainConfig(total_steps=10)
    state, step, data = _mk(cfg, tcfg)
    state, _ = step(state, {k: jnp.asarray(v) for k, v in data(0).items()})
    d = save_pytree(str(tmp_path / "ck"), state, step=1)
    restored, manifest = restore_pytree(d, state)
    assert manifest["step"] == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_restart_reproduces_training(tmp_path):
    """Kill at step 3, restore, continue — losses match the uninterrupted
    run bitwise (deterministic data pipeline + ckpt restart guarantee)."""
    cfg = reduced(get_config("granite-3-8b"))
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=2, total_steps=10)

    # uninterrupted reference
    state, step, data = _mk(cfg, tcfg)
    ref_losses = []
    for i in range(6):
        state, m = step(state, {k: jnp.asarray(v) for k, v in data(i).items()})
        ref_losses.append(float(m["loss"]))

    # interrupted run: checkpoint at step 3, "crash", restore, resume
    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=2)
    state2, step2, data2 = _mk(cfg, tcfg)
    for i in range(3):
        state2, m = step2(state2, {k: jnp.asarray(v) for k, v in data2(i).items()})
        assert float(m["loss"]) == ref_losses[i]
    mgr.save(3, state2)
    del state2  # crash

    template = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    state3, manifest = mgr.restore_latest(template)
    assert int(state3.step) == 3
    for i in range(3, 6):
        state3, m = step2(state3, {k: jnp.asarray(v) for k, v in data2(i).items()})
        assert float(m["loss"]) == ref_losses[i], (i, float(m["loss"]), ref_losses[i])


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "r"), keep=2)
    tree = {"a": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "a"), keep=3, async_save=True)
    tree = {"w": jnp.arange(100.0)}
    mgr.save(7, tree)
    mgr.wait()
    restored, man = mgr.restore_latest(tree)
    assert man["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(100.0))


def test_elastic_reshard_restore(tmp_path):
    """Restore onto a different sharding layout (elastic re-meshing)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    d = save_pytree(str(tmp_path / "e"), tree, step=0)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = restore_pytree(d, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_small_pytree_roundtrip_smoke(tmp_path):
    """Minimal dependency-free round trip (nested containers, mixed
    dtypes, scalar leaves) — keeps repro.ckpt exercised without the
    train-state machinery, so the dead-module gate sees it covered even
    if the heavyweight tests above are ever skipped."""
    tree = {
        "params": {"w": jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.zeros((4,), jnp.float16)},
        "opt": [jnp.asarray(3, jnp.int32), jnp.asarray(0.5)],
        "scale": jnp.asarray(2.0, jnp.float32),
    }
    d = save_pytree(str(tmp_path / "small"), tree, step=5)
    restored, manifest = restore_pytree(d, tree)
    assert manifest["step"] == 5
    assert jax.tree.structure(restored) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
